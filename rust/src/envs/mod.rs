//! Environments / substrates: synthetic digit corpus, MNIST contextual
//! bandit, exact tabular bandits, token reversal.

pub mod bandit;
pub mod digits;
pub mod mnist;
pub mod reversal;
