//! Token reversal environment (paper §5, App D).
//!
//! A batch is P prompts x S sampled responses (paper: 10 x 10 = 100
//! episodes). Prompts are length-H sequences over vocabulary [0, M); the
//! target is the reversed prompt; reward is per-position accuracy averaged
//! over the episode. The grouped (GRPO-style) baseline is the mean reward
//! of each prompt's response group.
//!
//! Prompts are marshaled LEFT-padded into i32[batch, H_MAX] as the
//! transformer artifacts expect (python/compile/models/transformer.py).

use crate::utils::rng::Pcg32;

#[derive(Debug, Clone, Copy)]
pub struct ReversalEnv {
    /// sequence length H (<= h_max)
    pub h: usize,
    /// vocabulary size M (<= vocab)
    pub m: usize,
    /// prompts per batch
    pub p: usize,
    /// responses per prompt
    pub s: usize,
    /// compiled maximum sequence length
    pub h_max: usize,
    /// pad token id
    pub pad: i32,
}

/// One batch of prompts, replicated S times each.
#[derive(Debug, Clone)]
pub struct PromptBatch {
    /// left-padded prompt tokens, [batch * h_max] row-major
    pub tokens: Vec<i32>,
    /// raw prompts, [p * h]
    pub raw: Vec<i32>,
    pub batch: usize,
}

impl ReversalEnv {
    pub fn new(h: usize, m: usize, p: usize, s: usize, h_max: usize, pad: i32) -> ReversalEnv {
        assert!(h >= 1 && h <= h_max, "H out of range");
        assert!(m >= 2, "vocab must be >= 2");
        ReversalEnv { h, m, p, s, h_max, pad }
    }

    pub fn batch_size(&self) -> usize {
        self.p * self.s
    }

    /// Sample P prompts and tile each S times.
    pub fn sample_prompts(&self, rng: &mut Pcg32) -> PromptBatch {
        let mut raw = Vec::with_capacity(self.p * self.h);
        for _ in 0..self.p * self.h {
            raw.push(rng.below(self.m as u32) as i32);
        }
        let batch = self.batch_size();
        let mut tokens = vec![self.pad; batch * self.h_max];
        for pi in 0..self.p {
            for si in 0..self.s {
                let ep = pi * self.s + si;
                let row = &mut tokens[ep * self.h_max..(ep + 1) * self.h_max];
                let off = self.h_max - self.h;
                for j in 0..self.h {
                    row[off + j] = raw[pi * self.h + j];
                }
            }
        }
        PromptBatch { tokens, raw, batch }
    }

    /// Target (reversed prompt) for episode `ep`.
    pub fn target(&self, batch: &PromptBatch, ep: usize) -> Vec<i32> {
        let pi = ep / self.s;
        let prompt = &batch.raw[pi * self.h..(pi + 1) * self.h];
        prompt.iter().rev().copied().collect()
    }

    /// Per-episode reward: fraction of correct positions (paper: kappa=1
    /// linear shaping of the per-position indicator mean, already in [0,1]).
    pub fn episode_reward(&self, batch: &PromptBatch, ep: usize, actions_row: &[i32]) -> f64 {
        let tgt = self.target(batch, ep);
        let correct = tgt
            .iter()
            .enumerate()
            .filter(|(j, &t)| actions_row[*j] == t)
            .count();
        correct as f64 / self.h as f64
    }

    /// Rewards for a full batch of sampled actions ([batch * h_max] row-major).
    pub fn rewards(&self, batch: &PromptBatch, actions: &[i32]) -> Vec<f64> {
        (0..batch.batch)
            .map(|ep| {
                self.episode_reward(batch, ep, &actions[ep * self.h_max..(ep + 1) * self.h_max])
            })
            .collect()
    }

    /// Per-position correctness for diagnostics ([batch, h] flattened).
    pub fn position_correct(&self, batch: &PromptBatch, actions: &[i32]) -> Vec<bool> {
        let mut out = Vec::with_capacity(batch.batch * self.h);
        for ep in 0..batch.batch {
            let tgt = self.target(batch, ep);
            let row = &actions[ep * self.h_max..(ep + 1) * self.h_max];
            for j in 0..self.h {
                out.push(row[j] == tgt[j]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> ReversalEnv {
        ReversalEnv::new(5, 4, 3, 2, 32, 64)
    }

    #[test]
    fn prompts_left_padded_and_tiled() {
        let e = env();
        let mut rng = Pcg32::seeded(0);
        let b = e.sample_prompts(&mut rng);
        assert_eq!(b.batch, 6);
        assert_eq!(b.tokens.len(), 6 * 32);
        for ep in 0..6 {
            let row = &b.tokens[ep * 32..(ep + 1) * 32];
            assert!(row[..27].iter().all(|&t| t == 64), "pad region");
            assert!(row[27..].iter().all(|&t| (0..4).contains(&t)), "prompt region");
        }
        // episodes of the same prompt share tokens
        assert_eq!(b.tokens[0..32], b.tokens[32..64]);
        // different prompts differ (w.h.p.)
        assert_ne!(b.tokens[0..32], b.tokens[2 * 32..3 * 32]);
    }

    #[test]
    fn reward_is_exact_reversal_fraction() {
        let e = env();
        let mut rng = Pcg32::seeded(1);
        let b = e.sample_prompts(&mut rng);
        let tgt = e.target(&b, 0);
        // perfect response
        let mut actions = vec![0i32; 6 * 32];
        actions[..5].copy_from_slice(&tgt);
        assert_eq!(e.episode_reward(&b, 0, &actions[..32]), 1.0);
        // break two positions
        actions[0] = (actions[0] + 1) % 4;
        actions[3] = (actions[3] + 1) % 4;
        assert!((e.episode_reward(&b, 0, &actions[..32]) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn target_is_reverse_of_prompt() {
        let e = env();
        let mut rng = Pcg32::seeded(2);
        let b = e.sample_prompts(&mut rng);
        let tgt = e.target(&b, 5); // prompt index 2
        let prompt = &b.raw[2 * 5..3 * 5];
        let rev: Vec<i32> = prompt.iter().rev().copied().collect();
        assert_eq!(tgt, rev);
    }

    #[test]
    fn rewards_batch_consistency() {
        let e = env();
        let mut rng = Pcg32::seeded(3);
        let b = e.sample_prompts(&mut rng);
        let actions = vec![1i32; 6 * 32];
        let rs = e.rewards(&b, &actions);
        assert_eq!(rs.len(), 6);
        for (ep, &r) in rs.iter().enumerate() {
            assert_eq!(r, e.episode_reward(&b, ep, &actions[ep * 32..(ep + 1) * 32]));
        }
    }

    #[test]
    fn position_correct_matches_reward() {
        let e = env();
        let mut rng = Pcg32::seeded(4);
        let b = e.sample_prompts(&mut rng);
        let actions = vec![2i32; 6 * 32];
        let pc = e.position_correct(&b, &actions);
        let rs = e.rewards(&b, &actions);
        for ep in 0..6 {
            let frac = pc[ep * 5..(ep + 1) * 5].iter().filter(|&&c| c).count() as f64 / 5.0;
            assert!((frac - rs[ep]).abs() < 1e-12);
        }
    }
}
