//! Tabular bandits with exact gradients (paper §4, App C).
//!
//! `SymmetricBandit` realizes Assumption 1: K arms, one correct arm y*,
//! deterministic indicator reward, softmax policy with uniform incorrect
//! mass. Gradients live in logit space: the score of action a is
//! phi(a) = e_a - pi, the true gradient is grad J = p * phi(y*).
//!
//! `GamblingBandit` realizes Proposition 3's two-armed slot machine.

use crate::utils::math::softmax_v;
use crate::utils::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct SymmetricBandit {
    pub k: usize,
    pub y_star: usize,
    /// policy logits z
    pub z: Vec<f32>,
}

impl SymmetricBandit {
    /// Construct with success probability exactly `p` and uniform incorrect
    /// probabilities (Assumption 1's symmetric configuration).
    pub fn with_p(k: usize, y_star: usize, p: f64) -> SymmetricBandit {
        assert!(k >= 2 && y_star < k && p > 0.0 && p < 1.0);
        let others = ((1.0 - p) / (k - 1) as f64).ln() as f32;
        let mut z = vec![others; k];
        z[y_star] = p.ln() as f32;
        SymmetricBandit { k, y_star, z }
    }

    pub fn pi(&self) -> Vec<f32> {
        softmax_v(&self.z)
    }

    pub fn p_star(&self) -> f64 {
        self.pi()[self.y_star] as f64
    }

    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        rng.categorical_from_logits(&self.z)
    }

    pub fn reward(&self, a: usize) -> f64 {
        if a == self.y_star {
            1.0
        } else {
            0.0
        }
    }

    /// Score function in logit space: phi(a) = e_a - pi.
    pub fn phi(&self, a: usize) -> Vec<f32> {
        let pi = self.pi();
        let mut v: Vec<f32> = pi.iter().map(|&p| -p).collect();
        v[a] += 1.0;
        v
    }

    /// True objective gradient: grad_z J = p * phi(y*).
    pub fn grad_j(&self) -> Vec<f32> {
        let p = self.p_star() as f32;
        self.phi(self.y_star).iter().map(|&x| p * x).collect()
    }

    /// Per-sample PG term g(a) = (r(a) - b) * phi(a).
    pub fn per_sample_grad(&self, a: usize, b: f64) -> Vec<f32> {
        let u = (self.reward(a) - b) as f32;
        self.phi(a).iter().map(|&x| u * x).collect()
    }

    /// Surprisal of action a under the current policy.
    pub fn surprisal(&self, a: usize) -> f64 {
        -(self.pi()[a] as f64).ln()
    }
}

/// Proposition 3's two-armed gambling bandit: arm 0 pays mu* exactly;
/// arm 1 pays N(mu* - delta, sigma^2). Policy plays arm 1 w.p. epsilon.
#[derive(Debug, Clone, Copy)]
pub struct GamblingBandit {
    pub mu_star: f64,
    pub delta: f64,
    pub sigma: f64,
    pub epsilon: f64,
}

impl GamblingBandit {
    pub fn new(mu_star: f64, delta: f64, sigma: f64, epsilon: f64) -> GamblingBandit {
        assert!(delta > 0.0 && sigma >= 0.0 && epsilon > 0.0 && epsilon < 1.0);
        GamblingBandit { mu_star, delta, sigma, epsilon }
    }

    /// Baseline b = V^pi = mu* - eps * delta (App C.4).
    pub fn value(&self) -> f64 {
        self.mu_star - self.epsilon * self.delta
    }

    pub fn sample_arm(&self, rng: &mut Pcg32) -> usize {
        if rng.bernoulli(self.epsilon) {
            1
        } else {
            0
        }
    }

    pub fn reward(&self, arm: usize, rng: &mut Pcg32) -> f64 {
        match arm {
            0 => self.mu_star,
            _ => self.mu_star - self.delta + self.sigma * rng.normal(),
        }
    }

    /// Exact Pr(U_2 > 0 | A = 2) = 1 - Phi((1-eps) * delta / sigma).
    pub fn p_false_positive(&self) -> f64 {
        if self.sigma == 0.0 {
            return 0.0;
        }
        1.0 - crate::utils::math::normal_cdf((1.0 - self.epsilon) * self.delta / self.sigma)
    }

    /// Surprisal of the gamble arm: log(1/eps).
    pub fn gamble_surprisal(&self) -> f64 {
        -(self.epsilon).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::math::{cosine, dot};

    #[test]
    fn with_p_hits_target_probability() {
        for &p in &[0.01, 0.1, 0.5, 0.9] {
            let b = SymmetricBandit::with_p(10, 3, p);
            assert!((b.p_star() - p).abs() < 1e-6, "p={p}");
            let pi = b.pi();
            // uniform incorrect mass
            let q = pi[0];
            for (a, &v) in pi.iter().enumerate() {
                if a != 3 {
                    assert!((v - q).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn phi_correct_is_parallel_to_grad_j() {
        // Lemma 1 part 1
        let b = SymmetricBandit::with_p(10, 0, 0.2);
        let phi = b.phi(0);
        let g = b.grad_j();
        assert!(cosine(&phi, &g) > 0.999999);
    }

    #[test]
    fn phi_incorrect_cosine_is_theta_p() {
        // Lemma 1 part 2: |cos(phi(a), grad J)| = Theta(p)
        for &p in &[0.02, 0.05, 0.1] {
            let b = SymmetricBandit::with_p(10, 0, p);
            let c = cosine(&b.phi(3), &b.grad_j()).abs();
            assert!(c < 3.0 * p && c > p / 3.0, "p={p} cos={c}");
        }
    }

    #[test]
    fn inner_product_formula() {
        // <phi(a), phi(y*)> = -p(1-p)K/(K-1)  (App C.1)
        let k = 10;
        let p = 0.3;
        let b = SymmetricBandit::with_p(k, 0, p);
        let want = -p * (1.0 - p) * k as f64 / (k - 1) as f64;
        let got = dot(&b.phi(5), &b.phi(0));
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn expected_pg_gradient_is_grad_j() {
        // E[g] with b=0: sum_a pi(a) r(a) phi(a) = p phi(y*) = grad J
        let b = SymmetricBandit::with_p(5, 2, 0.3);
        let pi = b.pi();
        let mut e = vec![0.0f32; 5];
        for a in 0..5 {
            let g = b.per_sample_grad(a, 0.0);
            for i in 0..5 {
                e[i] += pi[a] * g[i];
            }
        }
        let gj = b.grad_j();
        for i in 0..5 {
            assert!((e[i] - gj[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn sampling_matches_policy() {
        let b = SymmetricBandit::with_p(4, 1, 0.55);
        let mut rng = Pcg32::seeded(11);
        let n = 40_000;
        let hits = (0..n).filter(|_| b.sample(&mut rng) == 1).count();
        assert!((hits as f64 / n as f64 - 0.55).abs() < 0.01);
    }

    #[test]
    fn gambling_false_positive_regimes() {
        // Prop 3: sigma/delta << 1 -> tiny; >> 1 -> Theta(1)
        let reliable = GamblingBandit::new(1.0, 0.5, 0.05, 0.01);
        let pathological = GamblingBandit::new(1.0, 0.5, 5.0, 0.01);
        assert!(reliable.p_false_positive() < 1e-6);
        assert!(pathological.p_false_positive() > 0.4);
    }

    #[test]
    fn gambling_empirical_matches_exact() {
        let g = GamblingBandit::new(1.0, 0.5, 1.0, 0.05);
        let mut rng = Pcg32::seeded(12);
        let b = g.value();
        let n = 50_000;
        let fp = (0..n)
            .filter(|_| g.reward(1, &mut rng) - b > 0.0)
            .count() as f64
            / n as f64;
        assert!((fp - g.p_false_positive()).abs() < 0.01, "{fp} vs {}", g.p_false_positive());
    }

    #[test]
    fn delight_amplification_grows_as_policy_avoids_arm() {
        // Prop 3 part 3: |chi_2| factor log(1/eps) increases as eps -> 0
        let a = GamblingBandit::new(1.0, 0.5, 5.0, 0.1);
        let b = GamblingBandit::new(1.0, 0.5, 5.0, 0.001);
        assert!(b.gamble_surprisal() > a.gamble_surprisal() * 2.0);
    }
}
