//! MNIST contextual bandit (paper §3, App A): observe an image, pick a
//! digit, receive r = 1{a = y} plus optional noise. Wraps the synthetic
//! digit corpus and owns the reward-noise model of Figs 4/6.

use crate::utils::rng::Pcg32;

use super::digits::{DigitCorpus, Split, IMG_PIXELS, N_CLASSES};

/// Reward-noise configuration (paper App A.1 "Gambling experiment").
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct RewardNoise {
    /// homoskedastic sigma_R on every action
    pub sigma_r: f64,
    /// extra sigma_G on the designated gamble action
    pub sigma_g: f64,
    /// the gamble action (paper uses a = 0)
    pub gamble_action: usize,
}

impl RewardNoise {
    pub fn clean() -> RewardNoise {
        RewardNoise::default()
    }

    pub fn homoskedastic(sigma_r: f64) -> RewardNoise {
        RewardNoise { sigma_r, ..Default::default() }
    }

    pub fn gambling(sigma_g: f64) -> RewardNoise {
        RewardNoise { sigma_g, gamble_action: 0, sigma_r: 0.0 }
    }
}

#[derive(Debug, Clone)]
pub struct MnistBandit {
    pub corpus: DigitCorpus,
    pub noise: RewardNoise,
    pub batch: usize,
}

/// One sampled batch of contexts.
pub struct ContextBatch {
    /// [batch * 784] row-major images
    pub x: Vec<f32>,
    /// true labels
    pub y: Vec<usize>,
}

impl MnistBandit {
    pub fn new(seed: u64, batch: usize, noise: RewardNoise) -> MnistBandit {
        MnistBandit { corpus: DigitCorpus::new(seed), noise, batch }
    }

    pub fn n_actions(&self) -> usize {
        N_CLASSES
    }

    pub fn obs_dim(&self) -> usize {
        IMG_PIXELS
    }

    pub fn sample_contexts(&self, rng: &mut Pcg32) -> ContextBatch {
        let (x, y) = self.corpus.sample_batch(self.batch, rng);
        ContextBatch { x, y }
    }

    /// Reward for taking `action` on a context with label `y`.
    pub fn reward(&self, action: usize, y: usize, rng: &mut Pcg32) -> f64 {
        let mut r = if action == y { 1.0 } else { 0.0 };
        if self.noise.sigma_r > 0.0 {
            r += self.noise.sigma_r * rng.normal();
        }
        if self.noise.sigma_g > 0.0 && action == self.noise.gamble_action {
            r += self.noise.sigma_g * rng.normal();
        }
        r
    }

    /// Expected reward of `action` given label `y` (noise is mean-zero).
    pub fn mean_reward(&self, action: usize, y: usize) -> f64 {
        if action == y {
            1.0
        } else {
            0.0
        }
    }

    /// Materialized test set (first `n` samples) for evaluation.
    pub fn test_set(&self, n: usize) -> ContextBatch {
        let (x, y) = self.corpus.materialize(Split::Test, n);
        ContextBatch { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_reward_is_indicator() {
        let env = MnistBandit::new(0, 4, RewardNoise::clean());
        let mut rng = Pcg32::seeded(0);
        assert_eq!(env.reward(3, 3, &mut rng), 1.0);
        assert_eq!(env.reward(2, 3, &mut rng), 0.0);
    }

    #[test]
    fn homoskedastic_noise_has_right_moments() {
        let env = MnistBandit::new(0, 4, RewardNoise::homoskedastic(0.5));
        let mut rng = Pcg32::seeded(1);
        let n = 20_000;
        let rs: Vec<f64> = (0..n).map(|_| env.reward(1, 1, &mut rng)).collect();
        let mean: f64 = rs.iter().sum::<f64>() / n as f64;
        let var: f64 = rs.iter().map(|r| (r - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02);
        assert!((var.sqrt() - 0.5).abs() < 0.02);
    }

    #[test]
    fn gambling_noise_only_on_gamble_action() {
        let env = MnistBandit::new(0, 4, RewardNoise::gambling(2.0));
        let mut rng = Pcg32::seeded(2);
        // non-gamble action: exact indicator
        assert_eq!(env.reward(3, 3, &mut rng), 1.0);
        assert_eq!(env.reward(5, 3, &mut rng), 0.0);
        // gamble action: noisy even when wrong
        let r = env.reward(0, 3, &mut rng);
        assert!(r != 0.0);
        // variance check on the gamble arm
        let n = 20_000;
        let rs: Vec<f64> = (0..n).map(|_| env.reward(0, 3, &mut rng)).collect();
        let var: f64 = rs.iter().map(|r| r * r).sum::<f64>() / n as f64;
        assert!((var.sqrt() - 2.0).abs() < 0.05);
    }

    #[test]
    fn context_batches_are_seed_deterministic() {
        let env = MnistBandit::new(0, 8, RewardNoise::clean());
        let b1 = env.sample_contexts(&mut Pcg32::seeded(3));
        let b2 = env.sample_contexts(&mut Pcg32::seeded(3));
        assert_eq!(b1.x, b2.x);
        assert_eq!(b1.y, b2.y);
    }
}
