//! Synthetic procedural digit corpus — the MNIST substitution (DESIGN.md §6).
//!
//! No network access is available for the real MNIST download, so we render
//! a 10-class 28x28 digit corpus procedurally: seven-segment glyph
//! templates with per-sample geometric jitter (shift, scale, thickness),
//! intensity variation and pixel noise. Deterministic per (split, index,
//! corpus seed); the fixed split is 60k train / 10k test like MNIST.
//!
//! What the experiments need from the dataset -- a learnable 10-way visual
//! contextual bandit with a moving accuracy frontier -- is preserved; the
//! absolute error floor differs from MNIST and is reported as ours.

use crate::utils::rng::Pcg32;

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const N_CLASSES: usize = 10;
pub const TRAIN_SIZE: usize = 60_000;
pub const TEST_SIZE: usize = 10_000;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Seven-segment encoding per digit: [A, B, C, D, E, F, G]
///   A top, B top-right, C bottom-right, D bottom, E bottom-left,
///   F top-left, G middle.
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, true, true, true, false],    // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],   // 2
    [true, true, true, true, false, false, true],   // 3
    [false, true, true, false, false, true, true],  // 4
    [true, false, true, true, false, true, true],   // 5
    [true, false, true, true, true, true, true],    // 6
    [true, true, true, false, false, false, false], // 7
    [true, true, true, true, true, true, true],     // 8
    [true, true, true, true, false, true, true],    // 9
];

#[derive(Debug, Clone)]
pub struct DigitCorpus {
    seed: u64,
    /// pixel noise sigma
    pub noise: f32,
}

impl DigitCorpus {
    pub fn new(seed: u64) -> DigitCorpus {
        DigitCorpus { seed, noise: 0.12 }
    }

    /// Label of sample `idx` in `split` (uniform over classes by index).
    pub fn label(&self, _split: Split, idx: usize) -> usize {
        idx % N_CLASSES
    }

    fn sample_rng(&self, split: Split, idx: usize) -> Pcg32 {
        let s = match split {
            Split::Train => 0x7261_696e_u64,
            Split::Test => 0x7465_7374_u64,
        };
        Pcg32::new(self.seed ^ (idx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15), s)
    }

    /// Render sample `idx`: 784 pixels in [0, 1].
    pub fn image(&self, split: Split, idx: usize) -> Vec<f32> {
        let label = self.label(split, idx);
        let mut rng = self.sample_rng(split, idx);

        // geometric jitter (moderate: keeps classes separable in pixel
        // space while still moving the learning frontier over training)
        let dx = rng.below(3) as i32 - 1;
        let dy = rng.below(3) as i32 - 1;
        let scale = 0.9 + 0.2 * rng.uniform() as f32;
        let thick = 2 + rng.below(2) as i32; // 2 or 3 px
        let intensity = 0.75 + 0.25 * rng.uniform() as f32;

        let mut img = vec![0.0f32; IMG_PIXELS];
        // glyph box before jitter: x in [9, 19], y in [5, 23]
        let cx = 14.0f32;
        let cy = 14.0f32;
        let hw = 5.0 * scale; // half width
        let hh = 9.0 * scale; // half height

        let x0 = cx - hw + dx as f32;
        let x1 = cx + hw + dx as f32;
        let y0 = cy - hh + dy as f32;
        let y1 = cy + hh + dy as f32;
        let ym = cy + dy as f32;

        // each segment as a line (x_a, y_a) -> (x_b, y_b)
        let segs: [((f32, f32), (f32, f32)); 7] = [
            ((x0, y0), (x1, y0)), // A
            ((x1, y0), (x1, ym)), // B
            ((x1, ym), (x1, y1)), // C
            ((x0, y1), (x1, y1)), // D
            ((x0, ym), (x0, y1)), // E
            ((x0, y0), (x0, ym)), // F
            ((x0, ym), (x1, ym)), // G
        ];

        for (si, &on) in SEGMENTS[label].iter().enumerate() {
            if !on {
                continue;
            }
            let ((xa, ya), (xb, yb)) = segs[si];
            draw_line(&mut img, xa, ya, xb, yb, thick, intensity);
        }

        // pixel noise + clamp
        for p in img.iter_mut() {
            *p = (*p + self.noise * rng.normal() as f32).clamp(0.0, 1.0);
        }
        img
    }

    /// Materialize a full split (or its first `n` samples) into memory.
    pub fn materialize(&self, split: Split, n: usize) -> (Vec<f32>, Vec<usize>) {
        let size = match split {
            Split::Train => TRAIN_SIZE,
            Split::Test => TEST_SIZE,
        };
        let n = n.min(size);
        let mut xs = Vec::with_capacity(n * IMG_PIXELS);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            xs.extend_from_slice(&self.image(split, i));
            ys.push(self.label(split, i));
        }
        (xs, ys)
    }

    /// Sample a batch with replacement from the train split.
    pub fn sample_batch(&self, b: usize, rng: &mut Pcg32) -> (Vec<f32>, Vec<usize>) {
        let mut xs = Vec::with_capacity(b * IMG_PIXELS);
        let mut ys = Vec::with_capacity(b);
        for _ in 0..b {
            let idx = rng.below(TRAIN_SIZE as u32) as usize;
            xs.extend_from_slice(&self.image(Split::Train, idx));
            ys.push(self.label(Split::Train, idx));
        }
        (xs, ys)
    }
}

fn draw_line(img: &mut [f32], xa: f32, ya: f32, xb: f32, yb: f32, thick: i32, val: f32) {
    // supersample along the segment, stamping a thick x thick square
    let len = ((xb - xa).powi(2) + (yb - ya).powi(2)).sqrt().max(1.0);
    let steps = (len * 2.0) as usize + 1;
    let half = thick / 2;
    for s in 0..=steps {
        let t = s as f32 / steps as f32;
        let x = xa + t * (xb - xa);
        let y = ya + t * (yb - ya);
        for oy in -half..=half {
            for ox in -half..=half {
                let px = (x + ox as f32).round() as i32;
                let py = (y + oy as f32).round() as i32;
                if (0..IMG_SIDE as i32).contains(&px) && (0..IMG_SIDE as i32).contains(&py) {
                    let i = py as usize * IMG_SIDE + px as usize;
                    img[i] = img[i].max(val);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_are_deterministic() {
        let c = DigitCorpus::new(0);
        assert_eq!(c.image(Split::Train, 5), c.image(Split::Train, 5));
        assert_ne!(c.image(Split::Train, 5), c.image(Split::Train, 15)); // same label, different render
    }

    #[test]
    fn train_and_test_disjoint_renders() {
        let c = DigitCorpus::new(0);
        assert_ne!(c.image(Split::Train, 3), c.image(Split::Test, 3));
    }

    #[test]
    fn pixels_in_range_and_nonempty() {
        let c = DigitCorpus::new(1);
        for idx in 0..20 {
            let img = c.image(Split::Train, idx);
            assert_eq!(img.len(), IMG_PIXELS);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let lit = img.iter().filter(|&&p| p > 0.5).count();
            assert!(lit > 20, "digit {idx} nearly blank: {lit} bright px");
        }
    }

    #[test]
    fn labels_uniform() {
        let c = DigitCorpus::new(0);
        for i in 0..30 {
            assert_eq!(c.label(Split::Train, i), i % 10);
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean inter-class L2 distance must dominate intra-class distance,
        // otherwise the bandit is unlearnable.
        let c = DigitCorpus::new(0);
        let imgs: Vec<Vec<f32>> = (0..40).map(|i| c.image(Split::Train, i)).collect();
        let d2 = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        let mut intra = 0.0;
        let mut nintra = 0;
        let mut inter = 0.0;
        let mut ninter = 0;
        for i in 0..40 {
            for j in (i + 1)..40 {
                if i % 10 == j % 10 {
                    intra += d2(&imgs[i], &imgs[j]);
                    nintra += 1;
                } else {
                    inter += d2(&imgs[i], &imgs[j]);
                    ninter += 1;
                }
            }
        }
        let intra = intra / nintra as f32;
        let inter = inter / ninter as f32;
        assert!(
            inter > 1.25 * intra,
            "classes not separable: inter {inter} vs intra {intra}"
        );
    }

    #[test]
    fn batch_sampling_shapes() {
        let c = DigitCorpus::new(0);
        let mut rng = Pcg32::seeded(9);
        let (xs, ys) = c.sample_batch(17, &mut rng);
        assert_eq!(xs.len(), 17 * IMG_PIXELS);
        assert_eq!(ys.len(), 17);
        assert!(ys.iter().all(|&y| y < 10));
    }

    #[test]
    fn materialize_test_split() {
        let c = DigitCorpus::new(0);
        let (xs, ys) = c.materialize(Split::Test, 50);
        assert_eq!(xs.len(), 50 * IMG_PIXELS);
        assert_eq!(ys.len(), 50);
    }
}

/// Render a 28x28 image as ASCII art (for the Fig 16 kept/skipped panels).
pub fn ascii_digit(img: &[f32]) -> String {
    assert_eq!(img.len(), IMG_PIXELS);
    let glyphs = [' ', '.', ':', '+', '#', '@'];
    let mut s = String::with_capacity((IMG_SIDE + 1) * IMG_SIDE / 2);
    // halve vertical resolution (terminal cells are ~2x taller than wide)
    for row in (0..IMG_SIDE).step_by(2) {
        for col in 0..IMG_SIDE {
            let v = 0.5 * (img[row * IMG_SIDE + col]
                + img[(row + 1).min(IMG_SIDE - 1) * IMG_SIDE + col]);
            let g = ((v * (glyphs.len() - 1) as f32).round() as usize).min(glyphs.len() - 1);
            s.push(glyphs[g]);
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod ascii_tests {
    use super::*;

    #[test]
    fn ascii_digit_renders_glyph() {
        let c = DigitCorpus::new(0);
        let art = ascii_digit(&c.image(Split::Train, 8)); // an '8'
        assert_eq!(art.lines().count(), IMG_SIDE / 2);
        assert!(art.contains('@') || art.contains('#'), "no bright pixels:\n{art}");
        assert!(art.contains(' '));
    }

    #[test]
    #[should_panic]
    fn ascii_digit_rejects_bad_len() {
        ascii_digit(&[0.0; 10]);
    }
}
