//! Experiment harness: one driver per paper figure/table (DESIGN.md §3).
//!
//! `run(id, ctx)` dispatches to the driver, which writes CSVs under
//! `results/<id>/` and returns a human-readable summary whose rows mirror
//! the paper's series. `run_all` walks every experiment.

pub mod aggregate;
pub mod bandit_figs;
pub mod extensions;
pub mod mnist_figs;
pub mod reversal_figs;

use anyhow::{bail, Result};

use crate::config::ExpConfig;
use crate::runtime::Engine;

pub struct ExpCtx<'a> {
    pub eng: &'a Engine,
    pub cfg: &'a ExpConfig,
}

/// Every experiment id, in paper order.
pub const ALL: &[&str] = &[
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "prop1", "prop2", "prop3", "fig8",
    "fig9", "fig10", "fig11", "fig13", "fig15",
];

/// Extensions beyond the paper (its §7 next steps + our ablations); run
/// individually or via `repro exp extras`.
pub const EXTRAS: &[&str] =
    &["spec", "abl_pricing", "abl_eta", "abl_buckets", "abl_priority", "dist"];

/// What each id reproduces (for `repro list`).
pub fn describe(id: &str) -> &'static str {
    match id {
        "fig1" => "MNIST: PG vs DG vs DG-K(rho=0.03), fwd & bwd space (+Fig 12 test-error twin)",
        "fig2" => "MNIST: gate-rate sweep rho in {0.01..1.0}",
        "fig3" => "MNIST: compute speedup vs backward/forward cost ratio",
        "fig4" => "MNIST: delight-noise & logit-noise robustness (+Fig 17 absolute twin)",
        "fig5" => "MNIST: priority signals (bwd budget sweep + additive alpha)",
        "fig6" => "MNIST: gambling pathology (sigma_R vs sigma_G)",
        "prop1" => "bandit: Kondo gate Pareto improvement (direction/variance/cost)",
        "prop2" => "bandit: delight sign-consistency + alpha*(p,K) table (App C.3)",
        "prop3" => "bandit: gambling pathology regimes",
        "fig8" => "reversal: learning curves H=10 M=2, six methods",
        "fig9" => "reversal: vocab scaling M* (+Figs 19/21)",
        "fig10" => "reversal: length scaling H* (+Figs 18/20)",
        "fig11" => "MNIST: learning-rate sweep",
        "fig13" => "MNIST: baseline robustness (+Fig 14 bwd-space twin)",
        "fig15" => "MNIST: gate selection profile, kept vs skipped (+Fig 16 exemplars)",
        "spec" => "EXT: two-tier speculative screening pipeline, fwd-compute Pareto frontier (paper 3.2/7)",
        "abl_pricing" => "EXT: per-batch quantile vs streaming EW pricing of lambda",
        "abl_eta" => "EXT: gate temperature sweep (hard threshold <-> constant gate)",
        "abl_buckets" => "EXT: backward bucket granularity vs padding overhead",
        "abl_priority" => "EXT: Fig-5 priority sweep at trainer scale (MNIST + reversal, matched bwd budget)",
        "dist" => "EXT: actor-learner staleness sweep + fault-injection recovery (DESIGN.md \u{a7}12)",
        _ => "unknown",
    }
}

pub fn run(id: &str, ctx: &ExpCtx) -> Result<String> {
    let t0 = std::time::Instant::now();
    let body = match id {
        "fig1" => mnist_figs::fig1(ctx)?,
        "fig2" => mnist_figs::fig2(ctx)?,
        "fig3" => mnist_figs::fig3(ctx)?,
        "fig4" => mnist_figs::fig4(ctx)?,
        "fig5" => mnist_figs::fig5(ctx)?,
        "fig6" => mnist_figs::fig6(ctx)?,
        "fig11" => mnist_figs::fig11(ctx)?,
        "fig13" => mnist_figs::fig13(ctx)?,
        "fig15" => mnist_figs::fig15(ctx)?,
        "prop1" => bandit_figs::prop1(ctx)?,
        "prop2" => bandit_figs::prop2(ctx)?,
        "prop3" => bandit_figs::prop3(ctx)?,
        "fig8" => reversal_figs::fig8(ctx)?,
        "fig9" => reversal_figs::fig9(ctx)?,
        "fig10" => reversal_figs::fig10(ctx)?,
        "spec" => extensions::spec(ctx)?,
        "abl_pricing" => extensions::abl_pricing(ctx)?,
        "abl_eta" => extensions::abl_eta(ctx)?,
        "abl_buckets" => extensions::abl_buckets(ctx)?,
        "abl_priority" => extensions::abl_priority(ctx)?,
        "dist" => extensions::dist(ctx)?,
        other => bail!("unknown experiment '{other}' (see `repro list`)"),
    };
    Ok(format!(
        "=== {id}: {desc} ===\n{body}[{id} done in {:.1}s]\n",
        t0.elapsed().as_secs_f64(),
        desc = describe(id),
    ))
}
