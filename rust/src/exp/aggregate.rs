//! Seed aggregation: mean +/- SEM of learning curves across seeds, aligned
//! on eval points (all seeds share the same eval cadence).

use crate::trainers::EvalPoint;
use crate::utils::stats;

/// Mean/SEM of one metric across seeds, per eval point.
#[derive(Debug, Clone)]
pub struct AggCurve {
    pub steps: Vec<usize>,
    pub forward: Vec<f64>,
    pub backward_kept: Vec<f64>,
    pub backward_executed: Vec<f64>,
    pub mean: Vec<f64>,
    pub sem: Vec<f64>,
    /// secondary metric (test error on MNIST)
    pub mean2: Vec<f64>,
    pub sem2: Vec<f64>,
}

pub fn aggregate(curves: &[Vec<EvalPoint>]) -> AggCurve {
    assert!(!curves.is_empty());
    let n = curves.iter().map(|c| c.len()).min().unwrap();
    let mut out = AggCurve {
        steps: vec![],
        forward: vec![],
        backward_kept: vec![],
        backward_executed: vec![],
        mean: vec![],
        sem: vec![],
        mean2: vec![],
        sem2: vec![],
    };
    for i in 0..n {
        let ms: Vec<f64> = curves.iter().map(|c| c[i].metric).collect();
        let m2: Vec<f64> = curves.iter().map(|c| c[i].metric2).collect();
        out.steps.push(curves[0][i].step);
        out.forward
            .push(stats::mean(&curves.iter().map(|c| c[i].forward_samples as f64).collect::<Vec<_>>()));
        out.backward_kept.push(stats::mean(
            &curves.iter().map(|c| c[i].backward_kept as f64).collect::<Vec<_>>(),
        ));
        out.backward_executed.push(stats::mean(
            &curves.iter().map(|c| c[i].backward_executed as f64).collect::<Vec<_>>(),
        ));
        out.mean.push(stats::mean(&ms));
        out.sem.push(stats::sem(&ms));
        out.mean2.push(stats::mean(&m2));
        out.sem2.push(stats::sem(&m2));
    }
    out
}

impl AggCurve {
    pub fn final_metric(&self) -> f64 {
        *self.mean.last().unwrap_or(&f64::NAN)
    }

    pub fn final_metric2(&self) -> f64 {
        *self.mean2.last().unwrap_or(&f64::NAN)
    }

    /// Fraction of forward samples that earned a backward pass (the Fig-5
    /// comparison's x-axis: quality per backward fraction). 0 when the
    /// curve recorded no forwards.
    pub fn backward_fraction(&self) -> f64 {
        let fwd = *self.forward.last().unwrap_or(&0.0);
        let bwd = *self.backward_kept.last().unwrap_or(&0.0);
        if fwd > 0.0 { bwd / fwd } else { 0.0 }
    }

    /// First backward-kept count at which `mean` drops to <= target
    /// (linear scan; None if never reached). Used for Fig 3 time-to-error.
    pub fn backward_to_reach(&self, target: f64) -> Option<f64> {
        for i in 0..self.mean.len() {
            if self.mean[i] <= target {
                return Some(self.backward_kept[i]);
            }
        }
        None
    }

    pub fn forward_to_reach(&self, target: f64) -> Option<f64> {
        for i in 0..self.mean.len() {
            if self.mean[i] <= target {
                return Some(self.forward[i]);
            }
        }
        None
    }

    /// Mean of the metric over all eval points (paper's "average error").
    pub fn average_metric(&self) -> f64 {
        stats::mean(&self.mean)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(step: usize, m: f64) -> EvalPoint {
        EvalPoint {
            step,
            forward_samples: (step * 100) as u64,
            screen_samples: 0,
            forward_skipped: 0,
            backward_kept: (step * 3) as u64,
            backward_executed: (step * 4) as u64,
            metric: m,
            metric2: m / 2.0,
        }
    }

    #[test]
    fn aggregates_mean_and_sem() {
        let a = vec![pt(10, 0.5), pt(20, 0.3)];
        let b = vec![pt(10, 0.7), pt(20, 0.1)];
        let agg = aggregate(&[a, b]);
        assert_eq!(agg.steps, vec![10, 20]);
        assert!((agg.mean[0] - 0.6).abs() < 1e-12);
        assert!((agg.mean[1] - 0.2).abs() < 1e-12);
        assert!(agg.sem[0] > 0.0);
        assert!((agg.final_metric() - 0.2).abs() < 1e-12);
        assert!((agg.final_metric2() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn compute_to_reach() {
        let a = vec![pt(10, 0.5), pt(20, 0.3), pt(30, 0.1)];
        let agg = aggregate(&[a]);
        assert_eq!(agg.backward_to_reach(0.3), Some(60.0));
        assert_eq!(agg.forward_to_reach(0.3), Some(2000.0));
        assert_eq!(agg.backward_to_reach(0.05), None);
        assert!((agg.average_metric() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn truncates_to_shortest() {
        let a = vec![pt(10, 0.5), pt(20, 0.3)];
        let b = vec![pt(10, 0.7)];
        let agg = aggregate(&[a, b]);
        assert_eq!(agg.steps.len(), 1);
    }
}
