//! MNIST experiment drivers: Figs 1-6, 11-17 of the paper.
//! Each driver writes CSVs under `results/<id>/` and returns a printed
//! summary whose rows mirror the paper's series.

use anyhow::Result;

use crate::algo::baseline::Baseline;
use crate::algo::Method;
use crate::coordinator::{KondoGate, Priority};
use crate::envs::mnist::RewardNoise;
use crate::metrics::{ascii_curve, ascii_table, CsvWriter};
use crate::trainers::{train_mnist, MnistRunResult, MnistTrainerCfg};

use super::aggregate::{aggregate, AggCurve};
use super::ExpCtx;

fn base_cfg(ctx: &ExpCtx, method: Method, seed: u64) -> MnistTrainerCfg {
    MnistTrainerCfg {
        method,
        baseline: Baseline::Expected,
        lr: ctx.cfg.lr_mnist,
        steps: ctx.cfg.mnist_steps,
        eval_every: ctx.cfg.eval_every,
        eval_size: ctx.cfg.eval_size,
        seed,
        workers: ctx.cfg.workers,
        ..Default::default()
    }
}

/// Run one method across seeds, returning per-seed curves + aggregate.
fn run_seeds(
    ctx: &ExpCtx,
    mk: impl Fn(u64) -> MnistTrainerCfg,
) -> Result<(Vec<MnistRunResult>, AggCurve)> {
    let mut runs = Vec::new();
    for s in 0..ctx.cfg.seeds {
        runs.push(train_mnist(ctx.eng, &mk(s as u64))?);
    }
    let agg = aggregate(&runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
    Ok((runs, agg))
}

fn dgk(rho: f64) -> Method {
    Method::DgK { gate: KondoGate::rate(rho), priority: Priority::Delight }
}

fn write_curves(ctx: &ExpCtx, id: &str, series: &[(&str, &AggCurve)]) -> Result<()> {
    let mut w = CsvWriter::create(
        format!("{}/{}/curves.csv", ctx.cfg.out_dir, id),
        &[
            "series", "step", "forward", "backward_kept", "backward_executed", "train_err",
            "train_sem", "test_err", "test_sem",
        ],
    )?;
    for (name, agg) in series {
        for i in 0..agg.steps.len() {
            w.row(&[
                name.to_string(),
                agg.steps[i].to_string(),
                format!("{}", agg.forward[i]),
                format!("{}", agg.backward_kept[i]),
                format!("{}", agg.backward_executed[i]),
                format!("{}", agg.mean[i]),
                format!("{}", agg.sem[i]),
                format!("{}", agg.mean2[i]),
                format!("{}", agg.sem2[i]),
            ])?;
        }
    }
    Ok(())
}

/// Fig 1 (+ Fig 12 twin): PG vs DG vs DG-K(rho=0.03), forward & backward space.
pub fn fig1(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::new();
    let mut series = Vec::new();
    for (name, m) in [("pg", Method::Pg), ("dg", Method::Dg), ("dgk_0.03", dgk(0.03))] {
        let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, m, s))?;
        out.push_str(&ascii_curve(
            &format!("{name} train err"),
            &agg.steps.iter().map(|&s| s as f64).collect::<Vec<_>>(),
            &agg.mean,
            50,
        ));
        series.push((name.to_string(), agg));
    }
    let refs: Vec<(&str, &AggCurve)> = series.iter().map(|(n, a)| (n.as_str(), a)).collect();
    write_curves(ctx, "fig1", &refs)?;

    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(n, a)| {
            vec![
                n.clone(),
                format!("{:.4}", a.final_metric()),
                format!("{:.4}", a.final_metric2()),
                format!("{:.0}", a.backward_kept.last().unwrap_or(&0.0)),
                format!("{:.0}", a.forward.last().unwrap_or(&0.0)),
            ]
        })
        .collect();
    out.push_str(&ascii_table(
        &["method", "final train err", "final test err", "bwd samples", "fwd samples"],
        &rows,
    ));
    let bwd_pg = series[0].1.backward_kept.last().copied().unwrap_or(1.0);
    let bwd_kg = series[2].1.backward_kept.last().copied().unwrap_or(1.0).max(1.0);
    out.push_str(&format!(
        "DG-K backward reduction vs PG/DG: {:.0}x (paper: ~33x at rho=0.03; two orders of magnitude in bwd-space curves)\n",
        bwd_pg / bwd_kg
    ));
    Ok(out)
}

/// Fig 2: gate-rate sweep rho in {0.01 .. 1.0}.
pub fn fig2(ctx: &ExpCtx) -> Result<String> {
    let rhos = [0.01, 0.03, 0.05, 0.1, 0.2, 0.5, 1.0];
    let mut series = Vec::new();
    for &rho in &rhos {
        let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, dgk(rho), s))?;
        series.push((format!("rho_{rho}"), agg));
    }
    let refs: Vec<(&str, &AggCurve)> = series.iter().map(|(n, a)| (n.as_str(), a)).collect();
    write_curves(ctx, "fig2", &refs)?;
    let rows: Vec<Vec<String>> = series
        .iter()
        .map(|(n, a)| {
            vec![
                n.clone(),
                format!("{:.4}", a.final_metric2()),
                format!("{:.0}", a.backward_kept.last().unwrap_or(&0.0)),
            ]
        })
        .collect();
    let mut out = ascii_table(&["rho", "final test err", "bwd samples"], &rows);
    let b0 = series[0].1.backward_kept.last().copied().unwrap_or(1.0).max(1.0);
    let b1 = series.last().unwrap().1.backward_kept.last().copied().unwrap_or(1.0);
    out.push_str(&format!(
        "rho=0.01 uses {:.0}x fewer backward passes than rho=1.0 (paper: ~100x)\n",
        b1 / b0
    ));
    Ok(out)
}

/// Fig 3: compute speedup vs PG as a function of backward/forward cost ratio.
pub fn fig3(ctx: &ExpCtx) -> Result<String> {
    let mut curves = Vec::new();
    for (name, m) in [("pg", Method::Pg), ("dg", Method::Dg), ("dgk_0.03", dgk(0.03))] {
        let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, m, s))?;
        curves.push((name, agg));
    }
    // target error: the paper uses 5% (reachable at the paper preset's 10k
    // steps); at scaled presets use the tightest level ALL methods reach so
    // the speedup ratio is always defined.
    let worst_final =
        curves.iter().map(|(_, a)| a.final_metric2()).fold(0.0f64, f64::max);
    let target = (worst_final * 1.05 + 1e-4).max(0.05);
    let ratios = [0.0, 1.0, 2.0, 4.0, 8.0];
    let mut w = CsvWriter::create(
        format!("{}/fig3/speedup.csv", ctx.cfg.out_dir),
        &["cost_ratio", "method", "compute_to_target", "speedup_vs_pg"],
    )?;
    let mut rows = Vec::new();
    for &r in &ratios {
        let cost = |agg: &AggCurve| -> Option<f64> {
            // total = fwd + r * bwd at the first eval point reaching target
            for i in 0..agg.mean2.len() {
                if agg.mean2[i] <= target {
                    return Some(agg.forward[i] + r * agg.backward_kept[i]);
                }
            }
            None
        };
        let pg_cost = cost(&curves[0].1);
        for (name, agg) in &curves {
            let c = cost(agg);
            let speedup = match (pg_cost, c) {
                (Some(p), Some(c)) => p / c,
                _ => f64::NAN,
            };
            w.row(&[
                format!("{r}"),
                name.to_string(),
                c.map(|v| format!("{v:.0}")).unwrap_or("unreached".into()),
                format!("{speedup:.2}"),
            ])?;
            rows.push(vec![format!("{r}"), name.to_string(), format!("{speedup:.2}")]);
        }
    }
    let mut out = ascii_table(&["cost ratio", "method", "speedup vs PG"], &rows);
    out.push_str(
        "expected shape: DG ~constant speedup; DG-K speedup grows with the cost ratio (paper: 6x at ratio 4)\n",
    );
    Ok(out)
}

/// Fig 4 (+ Fig 17): delight-noise and logit-noise robustness for DG vs DG-K.
pub fn fig4(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/fig4/noise.csv", ctx.cfg.out_dir),
        &["kind", "sigma", "method", "final_test_err", "sem"],
    )?;
    let methods: [(&str, Method); 2] = [("dg", Method::Dg), ("dgk_0.03", dgk(0.03))];
    let mut rows = Vec::new();
    // (a) relative delight noise; (b) logit noise; (c) absolute delight (Fig 17)
    let sweeps: [(&str, Vec<f64>); 3] = [
        ("delight_rel", vec![0.0, 0.25, 0.5, 1.0, 2.0]),
        ("logit", vec![0.0, 0.5, 1.0, 2.0]),
        ("delight_abs", vec![0.0, 0.5, 1.0, 2.0]),
    ];
    for (kind, sigmas) in &sweeps {
        for &sigma in sigmas {
            for (name, m) in methods.iter() {
                let (_, agg) = run_seeds(ctx, |s| {
                    let mut c = base_cfg(ctx, *m, s);
                    match *kind {
                        "delight_rel" => c.delight_noise_rel = sigma,
                        "logit" => c.logit_noise = sigma,
                        _ => c.delight_noise_abs = sigma,
                    }
                    c
                })?;
                let e = agg.final_metric2();
                let sem = *agg.sem2.last().unwrap_or(&0.0);
                w.row(&[
                    kind.to_string(),
                    format!("{sigma}"),
                    name.to_string(),
                    format!("{e:.4}"),
                    format!("{sem:.4}"),
                ])?;
                rows.push(vec![
                    kind.to_string(),
                    format!("{sigma}"),
                    name.to_string(),
                    format!("{e:.4}"),
                ]);
            }
        }
    }
    let mut out = ascii_table(&["noise kind", "sigma", "method", "final test err"], &rows);
    out.push_str("expected shape: DG tolerates ~50% relative delight noise / logit sigma ~1; DG-K degrades earlier\n");
    Ok(out)
}

/// Fig 5: priority-signal comparison (backward budget sweep + additive alpha).
pub fn fig5(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/fig5/priority.csv", ctx.cfg.out_dir),
        &["panel", "param", "priority", "final_test_err", "bwd_kept", "bwd_frac"],
    )?;
    let mut rows = Vec::new();
    // (a) error vs backward batch size, by priority -- every priority runs
    // at the SAME rate-priced budget, so the comparison axis is quality vs
    // backward fraction (kept backwards / forward samples)
    let priorities = [
        Priority::Delight,
        Priority::Advantage,
        Priority::Surprisal,
        Priority::AbsAdvantage,
        Priority::Uniform,
    ];
    for &kept in &[3usize, 10, 30] {
        let rho = kept as f64 / 100.0;
        for pr in priorities {
            let m = Method::DgK { gate: KondoGate::rate(rho), priority: pr };
            let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, m, s))?;
            let e = agg.final_metric2();
            let frac = agg.backward_fraction();
            w.row(&[
                "bwd_batch".into(),
                kept.to_string(),
                pr.name(),
                format!("{e:.4}"),
                format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
                format!("{frac:.4}"),
            ])?;
            rows.push(vec![
                "bwd".into(),
                kept.to_string(),
                pr.name(),
                format!("{e:.4}"),
                format!("{frac:.3}"),
            ]);
        }
    }
    // (b) additive alpha sweep at rho = 0.03 (delight as the flat reference)
    for &alpha in &[0.0, 0.25, 0.5, 0.75, 1.0] {
        let m = Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Additive { alpha },
        };
        let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, m, s))?;
        let e = agg.final_metric2();
        let frac = agg.backward_fraction();
        w.row(&[
            "alpha".into(),
            format!("{alpha}"),
            format!("additive_{alpha}"),
            format!("{e:.4}"),
            format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
            format!("{frac:.4}"),
        ])?;
        rows.push(vec![
            "alpha".into(),
            format!("{alpha}"),
            "additive".into(),
            format!("{e:.4}"),
            format!("{frac:.3}"),
        ]);
    }
    let mut out =
        ascii_table(&["panel", "param", "priority", "final test err", "bwd frac"], &rows);
    out.push_str("expected shape: delight robust across budgets; surprisal-only fails; additive collapses at low alpha (Prop 2); bwd frac matches rho for every priority (same budget, different ranking)\n");
    Ok(out)
}

/// Fig 6: gambling pathology on MNIST (homoskedastic vs gambling noise).
pub fn fig6(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/fig6/gambling.csv", ctx.cfg.out_dir),
        &["noise_kind", "sigma", "method", "final_test_err"],
    )?;
    let mut rows = Vec::new();
    let methods: [(&str, Method); 2] = [("pg", Method::Pg), ("dg", Method::Dg)];
    for &sigma in &[0.0, 0.5, 1.0, 2.0, 5.0] {
        for (name, m) in methods.iter() {
            let (_, agg) = run_seeds(ctx, |s| {
                let mut c = base_cfg(ctx, *m, s);
                c.noise = RewardNoise::homoskedastic(sigma);
                c
            })?;
            let e = agg.final_metric2();
            w.row(&["homoskedastic".into(), format!("{sigma}"), name.to_string(), format!("{e:.4}")])?;
            rows.push(vec!["homo".into(), format!("{sigma}"), name.to_string(), format!("{e:.4}")]);
        }
    }
    for &sigma in &[0.0, 0.5, 1.0, 1.5, 2.0] {
        for (name, m) in methods.iter() {
            let (_, agg) = run_seeds(ctx, |s| {
                let mut c = base_cfg(ctx, *m, s);
                c.noise = RewardNoise::gambling(sigma);
                c
            })?;
            let e = agg.final_metric2();
            w.row(&["gambling".into(), format!("{sigma}"), name.to_string(), format!("{e:.4}")])?;
            rows.push(vec!["gamble".into(), format!("{sigma}"), name.to_string(), format!("{e:.4}")]);
        }
    }
    let mut out = ascii_table(&["kind", "sigma", "method", "final test err"], &rows);
    out.push_str("expected shape: homoskedastic degrades PG and DG together; gambling collapses DG near sigma_G ~ 1 while PG degrades gracefully (Prop 3)\n");
    Ok(out)
}

/// Fig 11: learning-rate sweep for PG / DG / DG-K.
pub fn fig11(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/fig11/lr.csv", ctx.cfg.out_dir),
        &["lr", "method", "final_train_err", "final_test_err"],
    )?;
    let mut rows = Vec::new();
    for &lr in &[1e-4, 3e-4, 1e-3, 3e-3] {
        for (name, m) in [("pg", Method::Pg), ("dg", Method::Dg), ("dgk_0.03", dgk(0.03))] {
            let (_, agg) = run_seeds(ctx, |s| {
                let mut c = base_cfg(ctx, m, s);
                c.lr = lr;
                c
            })?;
            w.row(&[
                format!("{lr}"),
                name.to_string(),
                format!("{:.4}", agg.final_metric()),
                format!("{:.4}", agg.final_metric2()),
            ])?;
            rows.push(vec![
                format!("{lr}"),
                name.to_string(),
                format!("{:.4}", agg.final_metric()),
                format!("{:.4}", agg.final_metric2()),
            ]);
        }
    }
    let mut out = ascii_table(&["lr", "method", "train err", "test err"], &rows);
    out.push_str("expected shape: shared optimum near lr=1e-3; train and test track closely\n");
    Ok(out)
}

/// Figs 13-14: baseline robustness (zero / constant / expected / oracle).
pub fn fig13(ctx: &ExpCtx) -> Result<String> {
    let baselines = [
        Baseline::Zero,
        Baseline::Constant(0.5),
        Baseline::Expected,
        Baseline::Oracle,
    ];
    let mut w = CsvWriter::create(
        format!("{}/fig13/baselines.csv", ctx.cfg.out_dir),
        &["baseline", "method", "final_test_err", "bwd_samples"],
    )?;
    let mut rows = Vec::new();
    for bl in baselines {
        for (name, m) in [("pg", Method::Pg), ("dg", Method::Dg), ("dgk_0.03", dgk(0.03))] {
            let (_, agg) = run_seeds(ctx, |s| {
                let mut c = base_cfg(ctx, m, s);
                c.baseline = bl;
                c
            })?;
            w.row(&[
                bl.name(),
                name.to_string(),
                format!("{:.4}", agg.final_metric2()),
                format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
            ])?;
            rows.push(vec![
                bl.name(),
                name.to_string(),
                format!("{:.4}", agg.final_metric2()),
                format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
            ]);
        }
    }
    let mut out = ascii_table(&["baseline", "method", "test err", "bwd samples"], &rows);
    out.push_str("expected shape: DG-K matches DG in fwd space and dominates in bwd space under all baselines\n");
    Ok(out)
}

/// Figs 15-16: gate selection profile -- ECDF of pi(y*) for kept vs skipped
/// samples at three training stages, plus (y, a, p) exemplars.
pub fn fig15(ctx: &ExpCtx) -> Result<String> {
    let steps = ctx.cfg.mnist_steps;
    let stages = vec![steps / 10, steps / 2, steps];
    let mut cfg = base_cfg(ctx, dgk(0.03), 0);
    cfg.gate_profile_steps = stages.clone();
    let res = train_mnist(ctx.eng, &cfg)?;

    let mut w = CsvWriter::create(
        format!("{}/fig15/gate_profile.csv", ctx.cfg.out_dir),
        &["stage_step", "group", "p_star"],
    )?;
    let mut out = String::new();
    for gp in &res.gate_profiles {
        for &p in &gp.kept_p {
            w.row(&[gp.step.to_string(), "kept".into(), format!("{p:.5}")])?;
        }
        for &p in &gp.skipped_p {
            w.row(&[gp.step.to_string(), "skipped".into(), format!("{p:.5}")])?;
        }
        let mk = crate::utils::stats::mean(&gp.kept_p);
        let ms = crate::utils::stats::mean(&gp.skipped_p);
        out.push_str(&format!(
            "step {:>5}: mean pi(y*) kept {:.3} vs skipped {:.3} ({} kept / {} skipped)\n",
            gp.step,
            mk,
            ms,
            gp.kept_p.len(),
            gp.skipped_p.len()
        ));
        // Fig 16 exemplars: (y, a, p) of first few kept / skipped
        for (label, samples) in
            [("kept", &gp.kept_samples), ("skipped", &gp.skipped_samples)]
        {
            let ex: Vec<String> = samples
                .iter()
                .take(5)
                .map(|(y, a, p)| format!("y={y} a={a} p={p:.2}"))
                .collect();
            out.push_str(&format!("  {label:>8}: {}\n", ex.join(" | ")));
        }
    }
    out.push_str("expected shape: kept samples have systematically lower pi(y*) (the learning frontier) once the policy is past the uniform stage\n");
    Ok(out)
}
