//! Extension drivers beyond the paper's figures: the §7 "natural next
//! steps" (speculative delight screening, adaptive pricing) and ablations
//! of this implementation's own design choices (DESIGN.md §7).

use anyhow::Result;

use crate::algo::baseline::Baseline;
use crate::algo::Method;
use crate::coordinator::speculative::precision_under_noise;
use crate::coordinator::{BucketSet, KondoGate, Priority, ScreenCfg};
use crate::distrib::{train_distrib, DistribMode};
use crate::metrics::{ascii_table, CsvWriter};
use crate::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};
use crate::utils::rng::Pcg32;
use crate::utils::stats;

use super::ExpCtx;

fn dgk(rho: f64) -> Method {
    Method::DgK { gate: KondoGate::rate(rho), priority: Priority::Delight }
}

fn cfg_of(ctx: &ExpCtx, method: Method, seed: u64) -> MnistTrainerCfg {
    MnistTrainerCfg {
        method,
        baseline: Baseline::Expected,
        lr: ctx.cfg.lr_mnist,
        steps: ctx.cfg.mnist_steps,
        eval_every: ctx.cfg.eval_every,
        eval_size: ctx.cfg.eval_size,
        seed,
        workers: ctx.cfg.workers,
        screen: ctx.cfg.screen_cfg(),
        ..Default::default()
    }
}

/// Cost of one draft dot product in forward-sample equivalents: a [784]
/// dot against the testbed MLP forward's ~25k multiplies (784*32 + 32*10).
const SCREEN_COST: f64 = 0.03;
/// The paper's "typical" backward/forward cost ratio (Fig 3).
const COST_RATIO: f64 = 4.0;

/// `spec`: the two-tier speculative screening pipeline (paper §3.2/§7,
/// DESIGN.md §8). A warm online linear draft pre-gates the batch at
/// `rho_screen` so only survivors pay the full forward; the Kondo gate
/// then prices the backward over the survivors' exact delight. The tier-2
/// rate is rescaled by 1/rho_screen so every variant targets the SAME
/// backward budget -- the sweep isolates the forward-compute axis and
/// reports its Pareto frontier under the three-term cost model
/// `screen + forward + r * backward`.
pub fn spec(ctx: &ExpCtx) -> Result<String> {
    // the whole sweep honours the CLI/config priority knob, so the
    // forward-compute frontier can be drawn for any Fig-5 gate signal
    // (the CI smoke runs this twice: delight and additive)
    let priority = ctx.cfg.gate_priority()?;
    let mut w = CsvWriter::create(
        format!("{}/spec/speculative.csv", ctx.cfg.out_dir),
        &[
            "variant", "priority", "seed", "final_test_err", "fwd_samples", "fwd_executed",
            "fwd_skipped", "screen_samples", "bwd_kept", "total_compute",
            "draft_precision",
        ],
    )?;
    let rho_bwd = 0.03;
    let variants: [(&str, f64); 4] =
        [("unscreened", 1.0), ("screen_50", 0.5), ("screen_25", 0.25), ("screen_10", 0.1)];
    // (name, mean err, mean executed total compute) per variant, for the
    // frontier marking below
    let mut summary: Vec<(String, f64, f64, Vec<String>)> = Vec::new();
    for (name, rho_screen) in variants {
        let gate_rho = (rho_bwd / rho_screen).min(1.0);
        let mut errs = Vec::new();
        let mut precs = Vec::new();
        let mut totals = Vec::new();
        // counters are per-seed (gate/screen decisions are seeded), so the
        // summary reports their means like every other column
        let mut fwd = Vec::new();
        let mut fwd_exec = Vec::new();
        let mut skipped = Vec::new();
        let mut bwd = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let mut c = cfg_of(ctx, dgk(gate_rho).with_priority(priority), s as u64);
            c.screen = ScreenCfg {
                rho_screen,
                draft_lr: ctx.cfg.draft_lr,
                // warm fast enough that short smoke configs still screen
                warmup_batches: (ctx.cfg.screen_warmup as u64).min(ctx.cfg.mnist_steps as u64 / 4),
            };
            let res = train_mnist(ctx.eng, &c)?;
            let total = res.ledger.total_compute_screened_executed(SCREEN_COST, COST_RATIO);
            w.row(&[
                name.into(),
                priority.name(),
                s.to_string(),
                format!("{:.4}", res.final_test_err),
                res.ledger.forward_samples.to_string(),
                res.ledger.forward_executed.to_string(),
                res.ledger.forward_skipped.to_string(),
                res.ledger.screen_samples.to_string(),
                res.ledger.backward_kept.to_string(),
                format!("{total:.0}"),
                format!("{:.3}", res.draft_precision),
            ])?;
            errs.push(res.final_test_err);
            precs.push(res.draft_precision);
            totals.push(total);
            fwd.push(res.ledger.forward_samples as f64);
            fwd_exec.push(res.ledger.forward_executed as f64);
            skipped.push(res.ledger.forward_skipped as f64);
            bwd.push(res.ledger.backward_kept as f64);
        }
        let mean_err = stats::mean(&errs);
        let mean_total = stats::mean(&totals);
        summary.push((
            name.to_string(),
            mean_err,
            mean_total,
            vec![
                name.to_string(),
                format!("{mean_err:.4}"),
                format!("{:.0}", stats::mean(&fwd)),
                format!("{:.0}", stats::mean(&fwd_exec)),
                format!("{:.0}", stats::mean(&skipped)),
                format!("{:.0}", stats::mean(&bwd)),
                format!("{mean_total:.0}"),
                format!("{:.3}", stats::mean(&precs)),
            ],
        ));
    }
    // Pareto frontier over (total compute, test error): a variant is on
    // the frontier iff no other variant is at least as good on both axes
    // and strictly better on one
    let mut rows = Vec::new();
    for (i, (_, err, total, cells)) in summary.iter().enumerate() {
        let dominated = summary.iter().enumerate().any(|(j, (_, e2, t2, _))| {
            j != i && *e2 <= *err && *t2 <= *total && (*e2 < *err || *t2 < *total)
        });
        let mut cells = cells.clone();
        cells.push(if dominated { "".into() } else { "*".into() });
        rows.push(cells);
    }
    let mut out = ascii_table(
        &[
            "variant", "final test err", "fwd samples", "fwd executed", "fwd skipped",
            "bwd kept", "total compute", "screen precision", "pareto",
        ],
        &rows,
    );
    // synthetic precision-vs-noise curve (how approximate may the draft be?)
    let mut rng = Pcg32::seeded(31);
    let mut noise_rows = Vec::new();
    for &nl in &[0.0, 0.25, 0.5, 1.0, 2.0] {
        let ps: Vec<f64> =
            (0..50).map(|_| precision_under_noise(100, 0.03, nl, &mut rng)).collect();
        noise_rows.push(vec![format!("{nl}"), format!("{:.3}", stats::mean(&ps))]);
    }
    out.push_str(&ascii_table(&["rel noise on chi", "top-3% precision"], &noise_rows));
    out.push_str(&format!(
        "three-term cost: {SCREEN_COST} * screen + fwd_executed + {COST_RATIO} * bwd_executed; all variants target the same backward budget (rho_bwd = {rho_bwd}); gate priority: {}\n\
         paper 3.2/7: the gate tolerates approximate delight, so a one-dot draft screen can spare most full forwards — '*' marks the compute/error Pareto frontier\n",
        priority.name()
    ));
    Ok(out)
}

/// `abl_priority`: the Fig-5 priority comparison AT SCALE -- every
/// priority variant runs through both real trainers (MNIST bandit and
/// token reversal) at the same rate-priced backward budget, emitting final
/// eval quality vs backward fraction per priority. This is the
/// scenario-diversity half of the ROADMAP item: the mis-ranking results
/// (delight robust, surprisal-only fails, small-alpha additive collapses)
/// reproduce outside the bandit testbed.
pub fn abl_priority(ctx: &ExpCtx) -> Result<String> {
    // an `additive:<alpha>` CLI knob parameterizes the additive entry of
    // the sweep; any other configured priority leaves the default alpha
    let alpha = match ctx.cfg.gate_priority()? {
        Priority::Additive { alpha } => alpha,
        _ => 0.2,
    };
    let set = [
        Priority::Delight,
        Priority::Advantage,
        Priority::Surprisal,
        Priority::AbsAdvantage,
        Priority::Uniform,
        Priority::Additive { alpha },
    ];
    let rho = 0.1; // matched backward budget across every priority
    let mut w = CsvWriter::create(
        format!("{}/abl_priority/priority.csv", ctx.cfg.out_dir),
        &["scale", "priority", "final_metric", "bwd_kept", "fwd_samples", "bwd_frac"],
    )?;
    let mut rows = Vec::new();
    for pr in set {
        let m = Method::DgK { gate: KondoGate::rate(rho), priority: pr };
        // MNIST scale: final test error (lower is better)
        let mut errs = Vec::new();
        let mut fracs = Vec::new();
        let mut kept = 0u64;
        let mut fwd = 0u64;
        for s in 0..ctx.cfg.seeds {
            let res = train_mnist(ctx.eng, &cfg_of(ctx, m, s as u64))?;
            errs.push(res.final_test_err);
            kept = res.ledger.backward_kept;
            fwd = res.ledger.forward_samples;
            fracs.push(kept as f64 / fwd.max(1) as f64);
        }
        let frac = stats::mean(&fracs);
        let err = stats::mean(&errs);
        w.row(&[
            "mnist".into(),
            pr.name(),
            format!("{err:.4}"),
            kept.to_string(),
            fwd.to_string(),
            format!("{frac:.4}"),
        ])?;
        rows.push(vec!["mnist".into(), pr.name(), format!("{err:.4}"), format!("{frac:.3}")]);
        // token-reversal scale: final reward (higher is better)
        let mut rewards = Vec::new();
        let mut rfracs = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let c = ReversalTrainerCfg {
                method: m,
                lr: ctx.cfg.lr_rev,
                steps: ctx.cfg.rev_steps,
                h: 6,
                m: 2,
                seed: s as u64,
                eval_every: (ctx.cfg.rev_steps / 10).max(1),
                inner_epochs: 1,
                screen: ctx.cfg.screen_cfg(),
                workers: ctx.cfg.workers,
                ..Default::default()
            };
            let res = train_reversal(ctx.eng, &c)?;
            rewards.push(res.final_reward);
            kept = res.ledger.backward_kept;
            fwd = res.ledger.forward_samples;
            rfracs.push(kept as f64 / fwd.max(1) as f64);
        }
        let frac = stats::mean(&rfracs);
        let reward = stats::mean(&rewards);
        w.row(&[
            "reversal".into(),
            pr.name(),
            format!("{reward:.4}"),
            kept.to_string(),
            fwd.to_string(),
            format!("{frac:.4}"),
        ])?;
        rows.push(vec![
            "reversal".into(),
            pr.name(),
            format!("{reward:.4}"),
            format!("{frac:.3}"),
        ]);
    }
    let mut out = ascii_table(
        &["scale", "priority", "final metric (err | reward)", "bwd frac"],
        &rows,
    );
    out.push_str(&format!(
        "all priorities priced at the same budget (rho = {rho}); Fig 5 / Prop 2 at trainer scale: delight holds quality, additive(alpha={alpha}) spends its budget on mis-ranked rare failures\n"
    ));
    Ok(out)
}

/// `abl_pricing`: per-batch quantile (Algorithm 1 line 5) vs streaming EW
/// quantile pricing — same target rate, different lambda estimators.
pub fn abl_pricing(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/abl_pricing/pricing.csv", ctx.cfg.out_dir),
        &["pricing", "seed", "final_test_err", "gate_rate", "bwd_kept"],
    )?;
    let mut rows = Vec::new();
    for (name, streaming) in [("batch_quantile", false), ("streaming_ew", true)] {
        let mut errs = Vec::new();
        let mut rates = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let mut c = cfg_of(ctx, dgk(0.03), s as u64);
            c.streaming_lambda = streaming;
            let res = train_mnist(ctx.eng, &c)?;
            w.row(&[
                name.into(),
                s.to_string(),
                format!("{:.4}", res.final_test_err),
                format!("{:.4}", res.ledger.gate_rate()),
                res.ledger.backward_kept.to_string(),
            ])?;
            errs.push(res.final_test_err);
            rates.push(res.ledger.gate_rate());
        }
        rows.push(vec![
            name.into(),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.4}", stats::mean(&rates)),
        ]);
    }
    let mut out = ascii_table(&["pricing", "final test err", "empirical gate rate"], &rows);
    out.push_str("streaming pricing costs O(1) per sample instead of a per-batch sort and should track the same rate\n");
    Ok(out)
}

/// `abl_eta`: gate temperature sweep — eta -> 0 is the hard threshold,
/// large eta forgets delight (the two limits of §2.1).
pub fn abl_eta(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/abl_eta/eta.csv", ctx.cfg.out_dir),
        &["eta", "final_test_err", "gate_rate"],
    )?;
    let mut rows = Vec::new();
    for &eta in &[0.0, 0.01, 0.1, 1.0, 10.0] {
        let m = Method::DgK {
            gate: KondoGate::rate(0.03).with_eta(eta),
            priority: Priority::Delight,
        };
        let mut errs = Vec::new();
        let mut rates = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let res = train_mnist(ctx.eng, &cfg_of(ctx, m, s as u64))?;
            errs.push(res.final_test_err);
            rates.push(res.ledger.gate_rate());
        }
        w.rowf(&[eta, stats::mean(&errs), stats::mean(&rates)])?;
        rows.push(vec![
            format!("{eta}"),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.4}", stats::mean(&rates)),
        ]);
    }
    let mut out = ascii_table(&["eta", "final test err", "empirical gate rate"], &rows);
    out.push_str("small eta ~ hard top-rho gate; large eta approaches a constant coin-flip gate (rate -> 0.5, PG-like sampling)\n");
    Ok(out)
}

/// `abl_buckets`: bucket-set granularity — executed backward slots per
/// kept-count under different compiled capacity sets (analytic, plus the
/// padding overhead actually observed at rho = 3%).
pub fn abl_buckets(ctx: &ExpCtx) -> Result<String> {
    let sets: [(&str, Vec<usize>); 4] = [
        ("full_only", vec![100]),
        ("pow2", vec![4, 8, 16, 32, 64, 100]),
        ("dense", vec![2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 100]),
        ("coarse", vec![25, 100]),
    ];
    let mut w = CsvWriter::create(
        format!("{}/abl_buckets/buckets.csv", ctx.cfg.out_dir),
        &["set", "kept", "executed", "overhead"],
    )?;
    let mut rows = Vec::new();
    for (name, caps) in &sets {
        let b = BucketSet::new(caps.clone()).unwrap();
        for &kept in &[1usize, 3, 10, 30, 100] {
            let ex = b.executed_slots(kept);
            let ovh = ex as f64 / kept as f64;
            w.row(&[
                name.to_string(),
                kept.to_string(),
                ex.to_string(),
                format!("{ovh:.2}"),
            ])?;
            if kept == 3 {
                rows.push(vec![
                    name.to_string(),
                    ex.to_string(),
                    format!("{ovh:.2}x"),
                ]);
            }
        }
    }
    let mut out = ascii_table(
        &["bucket set", "slots executed for 3 kept", "overhead"],
        &rows,
    );
    out.push_str("the compiled set {4,8,...,100} keeps rho=3% padding overhead at 1.33x vs 33x for a single full-batch executable — why the gate's savings survive static shapes\n");
    Ok(out)
}

/// `dist`: the actor–learner runtime (DESIGN.md §12) under staleness and
/// faults. Sweeps snapshot lag with the staleness-priced gate and runs
/// whatever `fault_spec` the config carries at every point, so a single
/// invocation doubles as the CI fault-injection smoke: one greppable
/// `[dist]` line per run carries the full recovery ledger, and with
/// `seeds=1` the counters are exact (deterministic FaultPlan).
pub fn dist(ctx: &ExpCtx) -> Result<String> {
    let priority = ctx.cfg.gate_priority()?;
    let method = dgk(0.25).with_priority(priority);
    let mut w = CsvWriter::create(
        format!("{}/dist/dist.csv", ctx.cfg.out_dir),
        &[
            "lag", "seed", "final_test_err", "fwd_samples", "bwd_kept", "stale_samples",
            "stale_kept", "quarantined", "quarantined_batches", "crashes", "restarts",
            "timeouts", "shed", "wire_corrupt_frames", "wire_reconnects", "handshake_rejects",
        ],
    )?;
    // sweep around the configured lag; `fault_spec`'s own `lag=` override,
    // if present, pins every point instead (the spec wins by design)
    let lags: Vec<usize> =
        if ctx.cfg.snapshot_lag > 1 { vec![0, 1, ctx.cfg.snapshot_lag] } else { vec![0, 1, 3] };
    let mut rows = Vec::new();
    for &lag in &lags {
        let mut errs = Vec::new();
        let mut stale_frac = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let mut d = ctx.cfg.distrib_cfg(method, s as u64)?;
            d.lag = lag;
            let res = train_distrib(ctx.eng, &d, &DistribMode::Threaded)?;
            let l = &res.ledger;
            w.row(&[
                lag.to_string(),
                s.to_string(),
                format!("{:.4}", res.final_test_err),
                l.forward_samples.to_string(),
                l.backward_kept.to_string(),
                l.stale_samples.to_string(),
                l.stale_kept.to_string(),
                l.quarantined_samples.to_string(),
                l.quarantined_batches.to_string(),
                l.actor_crashes.to_string(),
                l.actor_restarts.to_string(),
                l.actor_timeouts.to_string(),
                l.shed_samples.to_string(),
                l.wire_corrupt_frames.to_string(),
                l.wire_reconnects.to_string(),
                l.handshake_rejects.to_string(),
            ])?;
            println!(
                "[dist] lag={lag} seed={s} actor_crashes={} actor_restarts={} timeouts={} shed={} quarantined={} quarantined_batches={} stale={} stale_kept={} wire_corrupt_frames={} wire_reconnects={} handshake_rejects={} err={:.4}",
                l.actor_crashes,
                l.actor_restarts,
                l.actor_timeouts,
                l.shed_samples,
                l.quarantined_samples,
                l.quarantined_batches,
                l.stale_samples,
                l.stale_kept,
                l.wire_corrupt_frames,
                l.wire_reconnects,
                l.handshake_rejects,
                res.final_test_err,
            );
            errs.push(res.final_test_err);
            stale_frac.push(if l.forward_samples > 0 {
                l.stale_samples as f64 / l.forward_samples as f64
            } else {
                0.0
            });
        }
        rows.push(vec![
            lag.to_string(),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.3}", stats::mean(&stale_frac)),
        ]);
    }
    let mut out = ascii_table(&["snapshot lag", "final test err", "stale admitted frac"], &rows);
    out.push_str("staleness is priced, not refused: the gate rate tightens by stale_penalty^lag per batch (arXiv 2603.20521), so lagged fleets trade throughput for selectivity instead of diverging\n");
    Ok(out)
}
