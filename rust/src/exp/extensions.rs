//! Extension drivers beyond the paper's figures: the §7 "natural next
//! steps" (speculative delight screening, adaptive pricing) and ablations
//! of this implementation's own design choices (DESIGN.md §7).

use anyhow::Result;

use crate::algo::baseline::Baseline;
use crate::algo::Method;
use crate::coordinator::speculative::precision_under_noise;
use crate::coordinator::{BucketSet, KondoGate, Priority};
use crate::metrics::{ascii_table, CsvWriter};
use crate::trainers::{train_mnist, MnistTrainerCfg};
use crate::utils::rng::Pcg32;
use crate::utils::stats;

use super::ExpCtx;

fn dgk(rho: f64) -> Method {
    Method::DgK { gate: KondoGate::rate(rho), priority: Priority::Delight }
}

fn cfg_of(ctx: &ExpCtx, method: Method, seed: u64) -> MnistTrainerCfg {
    MnistTrainerCfg {
        method,
        baseline: Baseline::Expected,
        lr: ctx.cfg.lr_mnist,
        steps: ctx.cfg.mnist_steps,
        eval_every: ctx.cfg.eval_every,
        eval_size: ctx.cfg.eval_size,
        seed,
        workers: ctx.cfg.workers,
        ..Default::default()
    }
}

/// `spec`: speculative-decoding-for-training (paper §3.2/§7). An online
/// linear draft predicts delight; the gate screens on the prediction.
/// Reports learning quality, backward budget, and screening precision of
/// the draft against exact delight.
pub fn spec(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/spec/speculative.csv", ctx.cfg.out_dir),
        &["variant", "seed", "final_test_err", "bwd_kept", "draft_precision"],
    )?;
    let mut rows = Vec::new();
    for (name, draft) in [("exact_delight", false), ("draft_screen", true)] {
        let mut errs = Vec::new();
        let mut precs = Vec::new();
        let mut bwd = 0u64;
        for s in 0..ctx.cfg.seeds {
            let mut c = cfg_of(ctx, dgk(0.03), s as u64);
            c.draft_screen = draft;
            let res = train_mnist(ctx.eng, &c)?;
            w.row(&[
                name.into(),
                s.to_string(),
                format!("{:.4}", res.final_test_err),
                res.ledger.backward_kept.to_string(),
                format!("{:.3}", res.draft_precision),
            ])?;
            errs.push(res.final_test_err);
            precs.push(res.draft_precision);
            bwd = res.ledger.backward_kept;
        }
        rows.push(vec![
            name.into(),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.3}", stats::mean(&precs)),
            bwd.to_string(),
        ]);
    }
    // synthetic precision-vs-noise curve (how approximate may the draft be?)
    let mut rng = Pcg32::seeded(31);
    let mut noise_rows = Vec::new();
    for &nl in &[0.0, 0.25, 0.5, 1.0, 2.0] {
        let ps: Vec<f64> =
            (0..50).map(|_| precision_under_noise(100, 0.03, nl, &mut rng)).collect();
        noise_rows.push(vec![format!("{nl}"), format!("{:.3}", stats::mean(&ps))]);
    }
    let mut out = ascii_table(
        &["screen", "final test err", "screen precision", "bwd kept"],
        &rows,
    );
    out.push_str(&ascii_table(&["rel noise on chi", "top-3% precision"], &noise_rows));
    out.push_str("paper 3.2: approximate delight preserves most of the gate's value — the draft screen should trade a little error for zero-cost screening\n");
    Ok(out)
}

/// `abl_pricing`: per-batch quantile (Algorithm 1 line 5) vs streaming EW
/// quantile pricing — same target rate, different lambda estimators.
pub fn abl_pricing(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/abl_pricing/pricing.csv", ctx.cfg.out_dir),
        &["pricing", "seed", "final_test_err", "gate_rate", "bwd_kept"],
    )?;
    let mut rows = Vec::new();
    for (name, streaming) in [("batch_quantile", false), ("streaming_ew", true)] {
        let mut errs = Vec::new();
        let mut rates = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let mut c = cfg_of(ctx, dgk(0.03), s as u64);
            c.streaming_lambda = streaming;
            let res = train_mnist(ctx.eng, &c)?;
            w.row(&[
                name.into(),
                s.to_string(),
                format!("{:.4}", res.final_test_err),
                format!("{:.4}", res.ledger.gate_rate()),
                res.ledger.backward_kept.to_string(),
            ])?;
            errs.push(res.final_test_err);
            rates.push(res.ledger.gate_rate());
        }
        rows.push(vec![
            name.into(),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.4}", stats::mean(&rates)),
        ]);
    }
    let mut out = ascii_table(&["pricing", "final test err", "empirical gate rate"], &rows);
    out.push_str("streaming pricing costs O(1) per sample instead of a per-batch sort and should track the same rate\n");
    Ok(out)
}

/// `abl_eta`: gate temperature sweep — eta -> 0 is the hard threshold,
/// large eta forgets delight (the two limits of §2.1).
pub fn abl_eta(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/abl_eta/eta.csv", ctx.cfg.out_dir),
        &["eta", "final_test_err", "gate_rate"],
    )?;
    let mut rows = Vec::new();
    for &eta in &[0.0, 0.01, 0.1, 1.0, 10.0] {
        let m = Method::DgK {
            gate: KondoGate::rate(0.03).with_eta(eta),
            priority: Priority::Delight,
        };
        let mut errs = Vec::new();
        let mut rates = Vec::new();
        for s in 0..ctx.cfg.seeds {
            let res = train_mnist(ctx.eng, &cfg_of(ctx, m, s as u64))?;
            errs.push(res.final_test_err);
            rates.push(res.ledger.gate_rate());
        }
        w.rowf(&[eta, stats::mean(&errs), stats::mean(&rates)])?;
        rows.push(vec![
            format!("{eta}"),
            format!("{:.4}", stats::mean(&errs)),
            format!("{:.4}", stats::mean(&rates)),
        ]);
    }
    let mut out = ascii_table(&["eta", "final test err", "empirical gate rate"], &rows);
    out.push_str("small eta ~ hard top-rho gate; large eta approaches a constant coin-flip gate (rate -> 0.5, PG-like sampling)\n");
    Ok(out)
}

/// `abl_buckets`: bucket-set granularity — executed backward slots per
/// kept-count under different compiled capacity sets (analytic, plus the
/// padding overhead actually observed at rho = 3%).
pub fn abl_buckets(ctx: &ExpCtx) -> Result<String> {
    let sets: [(&str, Vec<usize>); 4] = [
        ("full_only", vec![100]),
        ("pow2", vec![4, 8, 16, 32, 64, 100]),
        ("dense", vec![2, 4, 6, 8, 12, 16, 24, 32, 48, 64, 100]),
        ("coarse", vec![25, 100]),
    ];
    let mut w = CsvWriter::create(
        format!("{}/abl_buckets/buckets.csv", ctx.cfg.out_dir),
        &["set", "kept", "executed", "overhead"],
    )?;
    let mut rows = Vec::new();
    for (name, caps) in &sets {
        let b = BucketSet::new(caps.clone()).unwrap();
        for &kept in &[1usize, 3, 10, 30, 100] {
            let ex = b.executed_slots(kept);
            let ovh = ex as f64 / kept as f64;
            w.row(&[
                name.to_string(),
                kept.to_string(),
                ex.to_string(),
                format!("{ovh:.2}"),
            ])?;
            if kept == 3 {
                rows.push(vec![
                    name.to_string(),
                    ex.to_string(),
                    format!("{ovh:.2}x"),
                ]);
            }
        }
    }
    let mut out = ascii_table(
        &["bucket set", "slots executed for 3 kept", "overhead"],
        &rows,
    );
    out.push_str("the compiled set {4,8,...,100} keeps rho=3% padding overhead at 1.33x vs 33x for a single full-batch executable — why the gate's savings survive static shapes\n");
    Ok(out)
}
