//! Tabular-bandit drivers: Propositions 1-3 and the App C.3 alpha* table.

use anyhow::Result;

use crate::bandit_math::{
    additive_separates, alpha_star, delight_separates, gambling_stats, gradient_geometry,
};
use crate::envs::bandit::GamblingBandit;
use crate::metrics::{ascii_table, CsvWriter};
use crate::utils::rng::Pcg32;

use super::ExpCtx;

/// Proposition 1 / Lemma 1 / Remark 1: gradient geometry of PG vs the
/// zero-price Kondo gate across (p, B).
pub fn prop1(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/prop1/geometry.csv", ctx.cfg.out_dir),
        &["p", "batch", "cos_pg", "cos_kg", "varperp_pg", "varperp_kg", "bwd_pg", "bwd_kg"],
    )?;
    let mut rng = Pcg32::seeded(7);
    let mut rows = Vec::new();
    for &p in &[0.02, 0.05, 0.1, 0.3] {
        for &b in &[25usize, 100, 400] {
            let g = gradient_geometry(10, p, b, 300, &mut rng);
            w.rowf(&[
                p,
                b as f64,
                g.cos_pg,
                g.cos_kg,
                g.varperp_pg,
                g.varperp_kg,
                g.bwd_pg,
                g.bwd_kg,
            ])?;
            rows.push(vec![
                format!("{p}"),
                format!("{b}"),
                format!("{:.3}", g.cos_pg),
                format!("{:.3}", g.cos_kg),
                format!("{:.2e}", g.varperp_pg),
                format!("{:.1e}", g.varperp_kg),
                format!("{:.0}", g.bwd_pg),
                format!("{:.1}", g.bwd_kg),
            ]);
        }
    }
    let mut out = ascii_table(
        &["p", "B", "cos PG", "cos KG", "var_perp PG", "var_perp KG", "bwd PG", "bwd KG"],
        &rows,
    );
    out.push_str("Prop 1: KG cosine ~ 1 with zero perpendicular variance at ~pB backward passes; PG cosine ~ p*sqrt(B) (Remark 1)\n");
    Ok(out)
}

/// Proposition 2: the alpha*(p, K) table (App C.3) + separation checks.
pub fn prop2(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/prop2/alpha_star.csv", ctx.cfg.out_dir),
        &["K", "p", "L", "alpha_star", "delight_separates", "additive_at_half"],
    )?;
    // the paper's table rows + a below-uniform row
    let cases = [(10usize, 0.5), (100, 0.5), (100, 0.9), (50_000, 0.5), (20, 0.03)];
    let mut rows = Vec::new();
    for &(k, p) in &cases {
        let l = (p * (k - 1) as f64 / (1.0 - p)).ln();
        let astar = alpha_star(p, k);
        let dsep = delight_separates(p, k);
        let asep = additive_separates(p, k, 0.5);
        w.row(&[
            k.to_string(),
            format!("{p}"),
            format!("{l:.2}"),
            format!("{astar:.3}"),
            dsep.to_string(),
            asep.to_string(),
        ])?;
        rows.push(vec![
            format!("({k}, {p})"),
            format!("{l:.1}"),
            format!("{astar:.2}"),
            dsep.to_string(),
            asep.to_string(),
        ]);
    }
    let mut out =
        ascii_table(&["(K, p)", "L", "alpha*", "delight ok", "additive@0.5 ok"], &rows);
    out.push_str("paper App C.3: alpha* = 0.69 / 0.82 / 0.87 / 0.92 for the four table rows; delight separates everywhere\n");
    Ok(out)
}

/// Proposition 3: gambling false positives vs sigma/delta + amplification.
pub fn prop3(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/prop3/gambling.csv", ctx.cfg.out_dir),
        &["sigma_over_delta", "p_false_pos_exact", "p_false_pos_mc", "epsilon", "amplification"],
    )?;
    let mut rng = Pcg32::seeded(13);
    let mut rows = Vec::new();
    for &ratio in &[0.1, 0.3, 1.0, 3.0, 10.0] {
        let g = GamblingBandit::new(1.0, 0.5, 0.5 * ratio, 0.01);
        let st = gambling_stats(&g);
        // Monte-Carlo check of the closed form
        let n = 20_000;
        let b = g.value();
        let mc = (0..n).filter(|_| g.reward(1, &mut rng) - b > 0.0).count() as f64 / n as f64;
        w.rowf(&[ratio, st.p_false_positive, mc, g.epsilon, st.amplification])?;
        rows.push(vec![
            format!("{ratio}"),
            format!("{:.4}", st.p_false_positive),
            format!("{mc:.4}"),
            format!("{:.2}", st.amplification),
        ]);
    }
    // amplification growth as the policy avoids the arm (part 3)
    let mut amp_rows = Vec::new();
    for &eps in &[0.1, 0.01, 0.001] {
        let g = GamblingBandit::new(1.0, 0.5, 5.0, eps);
        amp_rows.push(vec![format!("{eps}"), format!("{:.2}", g.gamble_surprisal())]);
    }
    let mut out = ascii_table(
        &["sigma/delta", "Pr(U2>0) exact", "Pr(U2>0) MC", "log(1/eps)"],
        &rows,
    );
    out.push_str(&ascii_table(&["epsilon", "delight amplification"], &amp_rows));
    out.push_str("Prop 3: false positives vanish for sigma/delta << 1, are Theta(1) for >> 1; amplification log(1/eps) grows as the policy avoids the arm\n");
    Ok(out)
}
