//! Token-reversal drivers: Fig 8 (learning curves), Fig 9 (vocab scaling,
//! + Figs 19/21), Fig 10 (length scaling, + Figs 18/20).

use anyhow::Result;

use crate::algo::Method;
use crate::coordinator::{KondoGate, Priority};
use crate::metrics::{ascii_curve, ascii_table, CsvWriter};
use crate::trainers::{train_reversal, ReversalRunResult, ReversalTrainerCfg};
use crate::utils::stats;

use super::aggregate::{aggregate, AggCurve};
use super::ExpCtx;

const SOLVED: f64 = 0.75; // paper App D.1: solved if avg reward > 0.75

fn methods() -> Vec<(&'static str, Method)> {
    vec![
        ("pg", Method::Pg),
        ("ppo", Method::Ppo { eps: 0.2 }),
        ("pmpo", Method::Pmpo { alpha: 1.0 }),
        ("dg", Method::Dg),
        ("dgk_rho3", Method::DgK { gate: KondoGate::rate(0.03), priority: Priority::Delight }),
        ("dgk_lam0", Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight }),
    ]
}

fn run_seeds(
    ctx: &ExpCtx,
    mk: impl Fn(u64) -> ReversalTrainerCfg,
) -> Result<(Vec<ReversalRunResult>, AggCurve)> {
    let mut runs = Vec::new();
    for s in 0..ctx.cfg.seeds {
        runs.push(train_reversal(ctx.eng, &mk(s as u64))?);
    }
    let agg = aggregate(&runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
    Ok((runs, agg))
}

fn base_cfg(ctx: &ExpCtx, method: Method, h: usize, m: usize, seed: u64) -> ReversalTrainerCfg {
    ReversalTrainerCfg {
        method,
        lr: ctx.cfg.lr_rev,
        steps: ctx.cfg.rev_steps,
        h,
        m,
        seed,
        eval_every: (ctx.cfg.rev_steps / 20).max(1),
        inner_epochs: 1,
        screen: ctx.cfg.screen_cfg(),
        workers: ctx.cfg.workers,
        // figure runs are short sweeps: no checkpointing
        ..Default::default()
    }
}

/// Fig 8: learning curves at H=10, M=2 for all six methods.
pub fn fig8(ctx: &ExpCtx) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/fig8/curves.csv", ctx.cfg.out_dir),
        &["method", "step", "forward", "backward_kept", "backward_executed", "reward", "sem"],
    )?;
    let mut out = String::new();
    let mut rows = Vec::new();
    for (name, m) in methods() {
        let (_, agg) = run_seeds(ctx, |s| base_cfg(ctx, m, 10, 2, s))?;
        for i in 0..agg.steps.len() {
            w.row(&[
                name.to_string(),
                agg.steps[i].to_string(),
                format!("{}", agg.forward[i]),
                format!("{}", agg.backward_kept[i]),
                format!("{}", agg.backward_executed[i]),
                format!("{}", agg.mean[i]),
                format!("{}", agg.sem[i]),
            ])?;
        }
        out.push_str(&ascii_curve(
            &format!("{name} reward"),
            &agg.steps.iter().map(|&s| s as f64).collect::<Vec<_>>(),
            &agg.mean,
            50,
        ));
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", agg.final_metric()),
            format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
            format!("{:.0}", agg.forward.last().unwrap_or(&0.0)),
        ]);
    }
    out.push_str(&ascii_table(
        &["method", "final reward", "bwd tokens", "fwd tokens"],
        &rows,
    ));
    out.push_str("expected shape: DG and both DG-K variants >> PG/PPO/PMPO in fwd space; DG-K collapses the bwd axis (paper Fig 8)\n");
    Ok(out)
}

/// Methods for the scaling sweeps: the paper's central four (PPO/PMPO are
/// kept in Fig 8; dropping them here fits the single-core budget).
fn scaling_methods() -> Vec<(&'static str, Method)> {
    methods()
        .into_iter()
        .filter(|(n, _)| !matches!(*n, "ppo" | "pmpo"))
        .collect()
}

/// Shared scaling driver: sweep one axis, report solved*/avg-err/final-err
/// per method (Figs 9/19/21 for vocab, Figs 10/18/20 for length).
fn scaling(
    ctx: &ExpCtx,
    id: &str,
    axis_name: &str,
    points: &[(usize, usize)], // (h, m) pairs
    axis_of: impl Fn(usize, usize) -> usize,
) -> Result<String> {
    let mut w = CsvWriter::create(
        format!("{}/{}/scaling.csv", ctx.cfg.out_dir, id),
        &[
            axis_name, "method", "mean_reward", "final_reward", "avg_err", "final_err",
            "solved", "bwd_tokens", "fwd_tokens",
        ],
    )?;
    let mut per_method: std::collections::BTreeMap<String, Vec<(usize, bool, f64, f64)>> =
        Default::default();
    // scaled preset: one seed and 3/4 of the configured steps per point
    // (the solved-threshold statistic is robust to this; SEM comes from
    // the paper preset).
    let steps = (ctx.cfg.rev_steps * 3 / 4).max(40);
    for &(h, m) in points {
        for (name, meth) in scaling_methods() {
            let (runs, agg) = {
                let mut runs = Vec::new();
                for s in 0..ctx.cfg.seeds.min(1).max(1) {
                    let mut c = base_cfg(ctx, meth, h, m, s as u64);
                    c.steps = steps;
                    c.eval_every = (steps / 10).max(1);
                    runs.push(train_reversal(ctx.eng, &c)?);
                }
                let agg = aggregate(&runs.iter().map(|r| r.curve.clone()).collect::<Vec<_>>());
                (runs, agg)
            };
            let mean_reward =
                stats::mean(&runs.iter().map(|r| r.mean_reward).collect::<Vec<_>>());
            let final_reward = agg.final_metric();
            // paper: average-reward criterion over a full-length run; at the
            // scaled preset we use the final smoothed reward (the training
            // average is dominated by the pre-convergence phase there)
            let solved = final_reward > SOLVED;
            let axis = axis_of(h, m);
            w.row(&[
                axis.to_string(),
                name.to_string(),
                format!("{mean_reward:.4}"),
                format!("{final_reward:.4}"),
                format!("{:.4}", 1.0 - mean_reward),
                format!("{:.4}", 1.0 - final_reward),
                (solved as u8).to_string(),
                format!("{:.0}", agg.backward_kept.last().unwrap_or(&0.0)),
                format!("{:.0}", agg.forward.last().unwrap_or(&0.0)),
            ])?;
            per_method.entry(name.to_string()).or_default().push((
                axis,
                solved,
                1.0 - mean_reward,
                *agg.backward_kept.last().unwrap_or(&0.0),
            ));
        }
    }
    // headline: largest axis value solved per method + its backward cost
    let mut rows = Vec::new();
    for (name, pts) in &per_method {
        let star = pts.iter().filter(|p| p.1).map(|p| p.0).max();
        let avg_err = stats::mean(&pts.iter().map(|p| p.2).collect::<Vec<_>>());
        let bwd = pts.last().map(|p| p.3).unwrap_or(0.0);
        rows.push(vec![
            name.clone(),
            star.map(|v| v.to_string()).unwrap_or("-".into()),
            format!("{avg_err:.3}"),
            format!("{bwd:.0}"),
        ]);
    }
    let mut out = ascii_table(
        &["method", &format!("{axis_name}* solved"), "avg err", "bwd tokens @max"],
        &rows,
    );
    out.push_str("expected shape: DG family solves larger problems; DG-K does it at a sliver of backward compute; fixed rho degrades at the extreme while lam=0 tracks DG\n");
    Ok(out)
}

/// Fig 9 (+ 19/21): vocabulary scaling at H=10.
pub fn fig9(ctx: &ExpCtx) -> Result<String> {
    let ms: Vec<(usize, usize)> =
        [2usize, 4, 8, 16].iter().map(|&m| (10usize, m)).collect();
    scaling(ctx, "fig9", "M", &ms, |_, m| m)
}

/// Fig 10 (+ 18/20): sequence-length scaling at M=2.
pub fn fig10(ctx: &ExpCtx) -> Result<String> {
    let hs: Vec<(usize, usize)> =
        [4usize, 8, 12, 16, 24].iter().map(|&h| (h, 2usize)).collect();
    scaling(ctx, "fig10", "H", &hs, |h, _| h)
}
