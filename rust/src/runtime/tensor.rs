//! Host-side tensors, Literal marshaling for the PJRT boundary, and the
//! tensor arena that keeps the gated hot path allocation-free.
//!
//! **Arena ownership (DESIGN.md §9).** Buffer lifecycle across the
//! Screen→Forward→Gate→Backward pipeline: a producer *takes* a buffer
//! (`take_f32_zeroed` & friends — thread-local freelist first, then the
//! shared pool, then a counted fresh allocation), wraps it in a
//! `HostTensor`, and whoever ends the buffer's life *recycles* it back
//! (`recycle_f32` / `recycle_tensor`). Call-local scratch (gathered
//! chunk inputs) is taken and recycled on the same worker thread; outputs
//! that cross threads (gradient tensors, forward rows) are recycled by
//! their consumer — the gradient accumulator, the shard merge, or the
//! trainer at end of step — and overflow into the shared pool, where the
//! next step's workers pick them up. Pool workers flush their local
//! freelists to the shared pool on exit so one training run's arena
//! warms the next. Steady state: zero fresh allocations per step,
//! observable via [`arena_stats`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::kernels::WeightPack;
use super::manifest::{DType, TensorSig};

/// A host tensor: shape + data, f32 or i32 (the only dtypes artifacts
/// use). An f32 tensor may carry a [`WeightPack`] — the GEMM-ready
/// panel layout built once per step beside parameter marshalling and
/// shared by reference (`Arc`) across every forward shard and backward
/// chunk. The pack is derived data: equality ignores it.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32>, pack: Option<Arc<WeightPack>> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl PartialEq for HostTensor {
    /// Shape + data only; the pack is a derived cache of `data` and must
    /// never influence equality.
    fn eq(&self, other: &HostTensor) -> bool {
        match (self, other) {
            (
                HostTensor::F32 { shape: sa, data: da, .. },
                HostTensor::F32 { shape: sb, data: db, .. },
            ) => sa == sb && da == db,
            (
                HostTensor::I32 { shape: sa, data: da },
                HostTensor::I32 { shape: sb, data: db },
            ) => sa == sb && da == db,
            _ => false,
        }
    }
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data, pack: None }
    }

    /// An f32 tensor carrying its GEMM pack (parameter marshalling path).
    pub fn f32_packed(shape: &[usize], data: Vec<f32>, pack: Arc<WeightPack>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data, pack: Some(pack) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
            pack: None,
        }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::i32(&[1], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    /// The attached GEMM pack, if the marshalling layer built one.
    pub fn pack(&self) -> Option<&WeightPack> {
        match self {
            HostTensor::F32 { pack, .. } => pack.as_deref(),
            HostTensor::I32 { .. } => None,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Validate against a manifest signature.
    pub fn check_sig(&self, sig: &TensorSig) -> Result<()> {
        if self.shape() != sig.shape.as_slice() {
            bail!(
                "input '{}': shape {:?} does not match manifest {:?}",
                sig.name,
                self.shape(),
                sig.shape
            );
        }
        if self.dtype() != sig.dtype {
            bail!("input '{}': dtype {:?} != manifest {:?}", sig.name, self.dtype(), sig.dtype);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => Literal::vec1(data),
            HostTensor::I32 { data, .. } => Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &Literal, sig: &TensorSig) -> Result<HostTensor> {
        let (got, t) = match sig.dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()?;
                (data.len(), HostTensor::F32 { shape: sig.shape.clone(), data, pack: None })
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()?;
                (data.len(), HostTensor::I32 { shape: sig.shape.clone(), data })
            }
        };
        if got != sig.numel() {
            return Err(anyhow!(
                "output '{}': got {got} elements, manifest says {}",
                sig.name,
                sig.numel()
            ));
        }
        Ok(t)
    }
}

// ---- tensor arena ----

/// Soft cap on buffers parked in one thread-local freelist; overflow goes
/// to the shared pool so cross-thread producer/consumer cycles (worker
/// allocates, caller recycles) still converge to zero fresh allocations.
const LOCAL_CAP: usize = 16;

/// A freelist of reusable tensor buffers. Public so tests can drive one
/// directly; production code uses the thread-local + shared pair through
/// the free functions below.
#[derive(Debug, Default)]
pub struct TensorArena {
    f32s: Vec<Vec<f32>>,
    i32s: Vec<Vec<i32>>,
}

impl TensorArena {
    pub fn new() -> TensorArena {
        TensorArena::default()
    }

    pub fn len(&self) -> usize {
        self.f32s.len() + self.i32s.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Best-fit take: the parked buffer with the smallest capacity still
    /// `>= cap` (so a small request cannot burn the one big buffer a
    /// later large request needs). Freelists stay small (LOCAL_CAP-ish),
    /// so the scan is cheap.
    fn take_f32(&mut self, cap: usize) -> Option<Vec<f32>> {
        let mut best: Option<usize> = None;
        for (i, b) in self.f32s.iter().enumerate() {
            if b.capacity() >= cap
                && best.map_or(true, |j| b.capacity() < self.f32s[j].capacity())
            {
                best = Some(i);
            }
        }
        best.map(|i| self.f32s.swap_remove(i))
    }

    fn take_i32(&mut self, cap: usize) -> Option<Vec<i32>> {
        let mut best: Option<usize> = None;
        for (i, b) in self.i32s.iter().enumerate() {
            if b.capacity() >= cap
                && best.map_or(true, |j| b.capacity() < self.i32s[j].capacity())
            {
                best = Some(i);
            }
        }
        best.map(|i| self.i32s.swap_remove(i))
    }

    fn give_f32(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.f32s.push(v);
        }
    }

    fn give_i32(&mut self, v: Vec<i32>) {
        if v.capacity() > 0 {
            self.i32s.push(v);
        }
    }
}

thread_local! {
    static LOCAL_ARENA: std::cell::RefCell<TensorArena> =
        std::cell::RefCell::new(TensorArena::new());
}

fn shared_arena() -> &'static Mutex<TensorArena> {
    static SHARED: OnceLock<Mutex<TensorArena>> = OnceLock::new();
    SHARED.get_or_init(|| Mutex::new(TensorArena::new()))
}

static FRESH_F32: AtomicU64 = AtomicU64::new(0);
static FRESH_I32: AtomicU64 = AtomicU64::new(0);

/// Fresh-allocation counters (buffers the arena could not serve from a
/// freelist). The arena-recycling tests assert these stop growing once
/// the hot path is warm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaStats {
    pub fresh_f32: u64,
    pub fresh_i32: u64,
}

impl ArenaStats {
    pub fn total(&self) -> u64 {
        self.fresh_f32 + self.fresh_i32
    }
}

pub fn arena_stats() -> ArenaStats {
    ArenaStats {
        fresh_f32: FRESH_F32.load(Ordering::Relaxed),
        fresh_i32: FRESH_I32.load(Ordering::Relaxed),
    }
}

fn pop_f32(cap: usize) -> Option<Vec<f32>> {
    if let Some(v) = LOCAL_ARENA.with(|a| a.borrow_mut().take_f32(cap)) {
        return Some(v);
    }
    shared_arena().lock().unwrap().take_f32(cap)
}

fn pop_i32(cap: usize) -> Option<Vec<i32>> {
    if let Some(v) = LOCAL_ARENA.with(|a| a.borrow_mut().take_i32(cap)) {
        return Some(v);
    }
    shared_arena().lock().unwrap().take_i32(cap)
}

/// A zero-filled f32 buffer of exactly `len` elements (freelist-served
/// when a parked buffer fits; the fill is what the old `vec![0.0; n]`
/// paid anyway, minus the allocation).
pub fn take_f32_zeroed(len: usize) -> Vec<f32> {
    match pop_f32(len) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => {
            FRESH_F32.fetch_add(1, Ordering::Relaxed);
            vec![0.0; len]
        }
    }
}

/// An empty f32 buffer with capacity `>= cap` (extend-style producers:
/// shard merges). Length 0 — the caller appends.
pub fn take_f32_empty(cap: usize) -> Vec<f32> {
    match pop_f32(cap) {
        Some(mut v) => {
            v.clear();
            v
        }
        None => {
            FRESH_F32.fetch_add(1, Ordering::Relaxed);
            Vec::with_capacity(cap)
        }
    }
}

/// A `len`-element i32 buffer filled with `fill`.
pub fn take_i32_filled(len: usize, fill: i32) -> Vec<i32> {
    match pop_i32(len) {
        Some(mut v) => {
            v.clear();
            v.resize(len, fill);
            v
        }
        None => {
            FRESH_I32.fetch_add(1, Ordering::Relaxed);
            vec![fill; len]
        }
    }
}

pub fn take_i32_zeroed(len: usize) -> Vec<i32> {
    take_i32_filled(len, 0)
}

/// Park a buffer for reuse: thread-local up to `LOCAL_CAP`, shared pool
/// beyond (which is how worker-allocated buffers recycled on the caller
/// thread find their way back to the workers).
pub fn recycle_f32(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    let overflow = LOCAL_ARENA.with(|a| {
        let mut a = a.borrow_mut();
        if a.f32s.len() < LOCAL_CAP {
            a.give_f32(v);
            None
        } else {
            Some(v)
        }
    });
    if let Some(v) = overflow {
        shared_arena().lock().unwrap().give_f32(v);
    }
}

pub fn recycle_i32(v: Vec<i32>) {
    if v.capacity() == 0 {
        return;
    }
    let overflow = LOCAL_ARENA.with(|a| {
        let mut a = a.borrow_mut();
        if a.i32s.len() < LOCAL_CAP {
            a.give_i32(v);
            None
        } else {
            Some(v)
        }
    });
    if let Some(v) = overflow {
        shared_arena().lock().unwrap().give_i32(v);
    }
}

/// Recycle a whole tensor's backing buffer (consumer-side hand-back; the
/// pack, if any, is just an `Arc` drop).
pub fn recycle_tensor(t: HostTensor) {
    match t {
        HostTensor::F32 { data, .. } => recycle_f32(data),
        HostTensor::I32 { data, .. } => recycle_i32(data),
    }
}

/// Move every buffer parked on this thread into the shared pool. Pool
/// workers call this on exit so a finished run's warm arena serves the
/// next run's (fresh) worker threads.
pub fn flush_local_arena_to_shared() {
    let drained = LOCAL_ARENA.with(|a| {
        let mut a = a.borrow_mut();
        (std::mem::take(&mut a.f32s), std::mem::take(&mut a.i32s))
    });
    let mut shared = shared_arena().lock().unwrap();
    for v in drained.0 {
        shared.give_f32(v);
    }
    for v in drained.1 {
        shared.give_i32(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(shape: &[usize], dtype: DType) -> TensorSig {
        TensorSig { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn shape_checks() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert!(t.check_sig(&sig(&[2, 3], DType::F32)).is_ok());
        assert!(t.check_sig(&sig(&[3, 2], DType::F32)).is_err());
        assert!(t.check_sig(&sig(&[2, 3], DType::I32)).is_err());
    }

    #[test]
    #[should_panic]
    fn bad_numel_panics() {
        HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn constructors_and_accessors() {
        let z = HostTensor::zeros_f32(&[3, 2]);
        assert_eq!(z.shape(), &[3, 2]);
        assert_eq!(z.numel(), 6);
        assert_eq!(z.dtype(), DType::F32);
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(z.pack().is_none());

        let s = HostTensor::scalar_i32(-7);
        assert_eq!(s.shape(), &[1]);
        assert_eq!(s.as_i32().unwrap(), &[-7]);
        assert!(s.pack().is_none());
    }

    #[test]
    fn packed_tensor_carries_pack_but_equality_ignores_it() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let pack = Arc::new(WeightPack::new(&data, 2, 3, 5));
        let packed = HostTensor::f32_packed(&[2, 3], data.clone(), Arc::clone(&pack));
        let plain = HostTensor::f32(&[2, 3], data);
        assert_eq!(packed.pack().unwrap().version(), 5);
        assert_eq!(packed, plain, "pack must not affect equality");
        // and the pack reconstructs the matrix it was built from
        assert_eq!(packed.pack().unwrap().unpack(), packed.as_f32().unwrap());
    }

    #[test]
    fn dtype_accessors_reject_wrong_type() {
        let f = HostTensor::f32(&[2], vec![1.0, 2.0]);
        let i = HostTensor::i32(&[2], vec![1, 2]);
        assert!(f.as_i32().is_err());
        assert!(i.as_f32().is_err());
        assert!(f.clone().into_i32().is_err());
        assert!(i.clone().into_f32().is_err());
        assert_eq!(f.into_f32().unwrap(), vec![1.0, 2.0]);
        assert_eq!(i.into_i32().unwrap(), vec![1, 2]);
    }

    #[test]
    fn from_literal_rejects_wrong_element_count() {
        let t = HostTensor::f32(&[4], vec![1.0; 4]);
        let lit = t.to_literal().unwrap();
        // manifest says 6 elements but the literal carries 4
        assert!(HostTensor::from_literal(&lit, &sig(&[2, 3], DType::F32)).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &sig(&[2, 2], DType::F32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[3], vec![7, -1, 42]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &sig(&[3], DType::I32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn arena_take_recycle_reuses_the_buffer() {
        // a recycled buffer is served back (same allocation) and zeroed
        let mut v = take_f32_zeroed(100);
        v[17] = 3.5;
        let ptr = v.as_ptr();
        recycle_f32(v);
        let v2 = take_f32_zeroed(100);
        assert_eq!(v2.as_ptr(), ptr, "freelist must reuse the allocation");
        assert!(v2.iter().all(|&x| x == 0.0), "served buffer must be zeroed");
        recycle_f32(v2);
    }

    #[test]
    fn arena_best_fit_prefers_smallest_adequate_buffer() {
        let mut arena = TensorArena::new();
        arena.give_f32(Vec::with_capacity(1000));
        arena.give_f32(Vec::with_capacity(10));
        arena.give_f32(Vec::with_capacity(100));
        let v = arena.take_f32(50).unwrap();
        assert_eq!(v.capacity(), 100, "best fit: smallest capacity >= request");
        assert!(arena.take_f32(5000).is_none(), "no parked buffer fits");
        assert_eq!(arena.len(), 2);
    }

    #[test]
    fn arena_counts_only_fresh_allocations() {
        // global counters are shared with concurrently-running tests, so
        // only >=-style claims are safe here; the exact zero-fresh
        // steady-state accounting is locked in isolation by
        // rust/tests/kernel_contracts.rs
        let before = arena_stats();
        // fresh: nothing parked can be this large (unique size)
        let v = take_f32_zeroed(777_001);
        assert!(arena_stats().fresh_f32 - before.fresh_f32 >= 1);
        let ptr = v.as_ptr();
        recycle_f32(v);
        // served from this thread's freelist: same allocation back
        let v2 = take_f32_zeroed(777_001);
        assert_eq!(v2.as_ptr(), ptr, "freelist must serve the recycled buffer");
        recycle_f32(v2);
    }

    #[test]
    fn arena_i32_and_tensor_recycling() {
        let v = take_i32_filled(64, 8);
        assert!(v.iter().all(|&x| x == 8));
        let t = HostTensor::i32(&[64], v);
        recycle_tensor(t);
        let v2 = take_i32_zeroed(64);
        assert!(v2.iter().all(|&x| x == 0), "fill value must not leak through");
        recycle_i32(v2);
    }

    #[test]
    fn flush_moves_local_buffers_to_shared() {
        let v = take_f32_zeroed(54_321);
        let ptr = v.as_ptr();
        recycle_f32(v);
        flush_local_arena_to_shared();
        // now only reachable via the shared pool
        let got = shared_arena().lock().unwrap().take_f32(54_321);
        match got {
            Some(b) => {
                assert_eq!(b.as_ptr(), ptr);
                recycle_f32(b);
            }
            // another test may have raced it away; reachable-at-all is
            // the property, absence means someone took (and will recycle)
            None => {}
        }
    }
}
