//! Host-side tensors and Literal marshaling for the PJRT boundary.

use anyhow::{anyhow, bail, Result};
use xla::Literal;

use super::manifest::{DType, TensorSig};

/// A host tensor: shape + data, f32 or i32 (the only dtypes artifacts use).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::i32(&[1], vec![v])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32 { .. } => DType::F32,
            HostTensor::I32 { .. } => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Validate against a manifest signature.
    pub fn check_sig(&self, sig: &TensorSig) -> Result<()> {
        if self.shape() != sig.shape.as_slice() {
            bail!(
                "input '{}': shape {:?} does not match manifest {:?}",
                sig.name,
                self.shape(),
                sig.shape
            );
        }
        if self.dtype() != sig.dtype {
            bail!("input '{}': dtype {:?} != manifest {:?}", sig.name, self.dtype(), sig.dtype);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => Literal::vec1(data),
            HostTensor::I32 { data, .. } => Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &Literal, sig: &TensorSig) -> Result<HostTensor> {
        let (got, t) = match sig.dtype {
            DType::F32 => {
                let data = lit.to_vec::<f32>()?;
                (data.len(), HostTensor::F32 { shape: sig.shape.clone(), data })
            }
            DType::I32 => {
                let data = lit.to_vec::<i32>()?;
                (data.len(), HostTensor::I32 { shape: sig.shape.clone(), data })
            }
        };
        if got != sig.numel() {
            return Err(anyhow!(
                "output '{}': got {got} elements, manifest says {}",
                sig.name,
                sig.numel()
            ));
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(shape: &[usize], dtype: DType) -> TensorSig {
        TensorSig { name: "t".into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn shape_checks() {
        let t = HostTensor::f32(&[2, 3], vec![0.0; 6]);
        assert!(t.check_sig(&sig(&[2, 3], DType::F32)).is_ok());
        assert!(t.check_sig(&sig(&[3, 2], DType::F32)).is_err());
        assert!(t.check_sig(&sig(&[2, 3], DType::I32)).is_err());
    }

    #[test]
    #[should_panic]
    fn bad_numel_panics() {
        HostTensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn constructors_and_accessors() {
        let z = HostTensor::zeros_f32(&[3, 2]);
        assert_eq!(z.shape(), &[3, 2]);
        assert_eq!(z.numel(), 6);
        assert_eq!(z.dtype(), DType::F32);
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));

        let s = HostTensor::scalar_i32(-7);
        assert_eq!(s.shape(), &[1]);
        assert_eq!(s.as_i32().unwrap(), &[-7]);
    }

    #[test]
    fn dtype_accessors_reject_wrong_type() {
        let f = HostTensor::f32(&[2], vec![1.0, 2.0]);
        let i = HostTensor::i32(&[2], vec![1, 2]);
        assert!(f.as_i32().is_err());
        assert!(i.as_f32().is_err());
        assert!(f.clone().into_i32().is_err());
        assert!(i.clone().into_f32().is_err());
        assert_eq!(f.into_f32().unwrap(), vec![1.0, 2.0]);
        assert_eq!(i.into_i32().unwrap(), vec![1, 2]);
    }

    #[test]
    fn from_literal_rejects_wrong_element_count() {
        let t = HostTensor::f32(&[4], vec![1.0; 4]);
        let lit = t.to_literal().unwrap();
        // manifest says 6 elements but the literal carries 4
        assert!(HostTensor::from_literal(&lit, &sig(&[2, 3], DType::F32)).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &sig(&[2, 2], DType::F32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(&[3], vec![7, -1, 42]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &sig(&[3], DType::I32)).unwrap();
        assert_eq!(t, back);
    }
}
