//! Execution engine behind the L3 hot path: one artifact namespace, two
//! backends.
//!
//! - **PJRT**: loads HLO-text artifacts produced by `make artifacts`
//!   (pattern follows /opt/xla-example/load_hlo: `HloModuleProto::
//!   from_text_file` -> `XlaComputation::from_proto` -> `client.compile`
//!   -> `execute`). Executables are compiled lazily and cached for the
//!   process lifetime. In this offline build the `xla` crate is a vendored
//!   stub, so this backend errors at client creation with a pointer to the
//!   native testbed; the code path is kept compiling so the real bindings
//!   can be swapped back in without touching this file.
//! - **Native testbed** (`Engine::native_testbed()`): the pure-Rust
//!   reference backend of `runtime/native.rs`, with the same artifact
//!   names/signatures over small models. It is deterministic and
//!   row-independent, which is what the sharded-coordinator tests lock.
//!   Its compute runs on the shared kernel layer (`runtime/kernels.rs`:
//!   blocked GEMM over packed weight panels attached to the marshalled
//!   parameter tensors, fused epilogues, fixed lane-tree reductions) and
//!   its outputs are tensor-arena buffers (`runtime/tensor.rs`) that
//!   consumers recycle — callers treat them as ordinary `HostTensor`s;
//!   recycling is an optimization, never a requirement.
//!
//! The engine is `Sync` and `execute` takes `&self`: worker threads of the
//! coordinator pool call it concurrently. Executable lookup holds the
//! cache lock only long enough to clone the handle; execution itself runs
//! unlocked. Per-artifact call counts and wall-clock feed the compute
//! ledger and the perf pass.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::native::NativeTestbed;
use super::tensor::HostTensor;

#[derive(Debug, Default, Clone, Copy)]
pub struct ArtifactStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

enum Backend {
    Pjrt {
        client: PjRtClient,
        dir: PathBuf,
        execs: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    },
    Native(NativeTestbed),
}

pub struct Engine {
    backend: Backend,
    manifest: Manifest,
    stats: Mutex<HashMap<String, ArtifactStats>>,
}

impl Engine {
    /// Open an artifact directory produced by `make artifacts` (PJRT
    /// backend). Fails in offline builds where `xla` is the vendored stub.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine {
            backend: Backend::Pjrt { client, dir, execs: Mutex::new(HashMap::new()) },
            manifest,
            stats: Mutex::new(HashMap::new()),
        })
    }

    /// The built-in pure-Rust backend: same artifact contract, small
    /// models, no compiled artifacts or PJRT needed. This is what tests,
    /// benches, and `artifacts_dir = "native"` runs use.
    pub fn native_testbed() -> Engine {
        Engine {
            backend: Backend::Native(NativeTestbed::default()),
            manifest: NativeTestbed::manifest(),
            stats: Mutex::new(HashMap::new()),
        }
    }

    /// Enable (or disable) the **non-golden** f32-fast forward tier
    /// (DESIGN.md §13) on the native backend. A config knob, not state:
    /// it participates in checkpoint fingerprints exactly like a learning
    /// rate, so a resume under a different setting is rejected. No-op on
    /// the PJRT backend (artifact precision is fixed at AOT time).
    pub fn with_f32_fast(mut self, on: bool) -> Engine {
        if let Backend::Native(nb) = &mut self.backend {
            nb.f32_fast = on;
        }
        self
    }

    /// Whether the non-golden f32-fast forward tier is active.
    pub fn f32_fast(&self) -> bool {
        match &self.backend {
            Backend::Native(nb) => nb.f32_fast,
            Backend::Pjrt { .. } => false,
        }
    }

    /// Open `dir`, falling back to the native testbed when `dir` is the
    /// literal `"native"` or has no manifest. The fallback is announced on
    /// stderr so a typo'd artifacts dir cannot silently swap the backend
    /// under an experiment run.
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref();
        if dir == Path::new("native") {
            return Ok(Engine::native_testbed());
        }
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "note: no compiled artifacts at {} -- running on the native testbed \
                 backend (small reference models)",
                dir.display()
            );
            return Ok(Engine::native_testbed());
        }
        Engine::new(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native(_))
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Pjrt { client, .. } => client.platform_name(),
            Backend::Native(_) => "native-testbed".to_string(),
        }
    }

    /// Compile (or fetch cached) executable for a PJRT artifact.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        let Backend::Pjrt { client, dir, execs } = &self.backend else {
            return Ok(());
        };
        {
            let execs = execs.lock().unwrap();
            if execs.contains_key(name) {
                return Ok(());
            }
        }
        let sig = self.manifest.artifact(name)?;
        let path = dir.join(&sig.file);
        let t0 = Instant::now();
        let proto =
            HloModuleProto::from_text_file(path.to_str().context("artifact path not utf-8")?)
                .with_context(|| format!("loading {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        execs.lock().unwrap().insert(name.to_string(), exe);
        self.stats.lock().unwrap().entry(name.to_string()).or_default().compile_secs += dt;
        Ok(())
    }

    /// Pre-compile a set of artifacts (e.g. at trainer startup). No-op on
    /// the native backend.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with owned host tensors. Thin wrapper over
    /// [`Engine::execute_refs`] for call sites that already hold a
    /// `Vec<HostTensor>`; the hot path (trainer forward/backward chunks)
    /// uses `execute_refs` directly so the marshalled parameter tensors
    /// can be shared across calls instead of cloned per call.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Execute an artifact with borrowed host tensors; validates the input
    /// signature against the manifest and unpacks the output tuple.
    /// Thread-safe: called concurrently from coordinator pool workers.
    ///
    /// Taking `&[&HostTensor]` keeps the hot path zero-copy: one marshal
    /// of the parameter tensors serves every backward chunk and forward
    /// shard of a step, with only a pointer list built per call.
    pub fn execute_refs(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        // borrow the signature (no per-call clone of shapes/names)
        let sig = self.manifest.artifact(name)?;
        if inputs.len() != sig.inputs.len() {
            bail!(
                "artifact '{name}': got {} inputs, manifest says {}",
                inputs.len(),
                sig.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&sig.inputs) {
            t.check_sig(s).with_context(|| format!("artifact '{name}'"))?;
        }

        // compile and marshal OUTSIDE the timed region: total_secs must
        // not double-count what compile_secs already records
        self.ensure_compiled(name)?;
        let outputs = match &self.backend {
            Backend::Native(nb) => {
                let t0 = Instant::now();
                let out = nb.execute(name, inputs)?;
                self.record_call(name, t0.elapsed().as_secs_f64());
                out
            }
            Backend::Pjrt { execs, .. } => {
                let lits: Vec<Literal> =
                    inputs.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
                // clone the handle out of the cache so concurrent workers
                // execute without serializing on the lock
                let exe = execs.lock().unwrap().get(name).unwrap().clone();
                let t0 = Instant::now();
                let result = exe.execute::<Literal>(&lits)?;
                let out_lit = result[0][0].to_literal_sync()?;
                self.record_call(name, t0.elapsed().as_secs_f64());
                // aot.py lowers with return_tuple=True: always a tuple.
                // Arity must be checked HERE -- the zip below would
                // silently drop surplus tuple elements.
                let parts = out_lit.to_tuple()?;
                if parts.len() != sig.outputs.len() {
                    bail!(
                        "artifact '{name}': got {} outputs, manifest says {}",
                        parts.len(),
                        sig.outputs.len()
                    );
                }
                parts
                    .iter()
                    .zip(&sig.outputs)
                    .map(|(lit, s)| HostTensor::from_literal(lit, s))
                    .collect::<Result<Vec<_>>>()?
            }
        };

        // shape/dtype validation of whatever the backend handed back (the
        // PJRT arm already guaranteed matching arity; the native backend
        // constructs outputs directly from its own manifest)
        for (t, s) in outputs.iter().zip(&sig.outputs) {
            t.check_sig(s).with_context(|| format!("artifact '{name}' output"))?;
        }
        Ok(outputs)
    }

    fn record_call(&self, name: &str, secs: f64) {
        let mut st = self.stats.lock().unwrap();
        let e = st.entry(name.to_string()).or_default();
        e.calls += 1;
        e.total_secs += secs;
    }

    /// Per-artifact timing snapshot (for EXPERIMENTS.md perf tables).
    pub fn stats(&self) -> Vec<(String, ArtifactStats)> {
        let st = self.stats.lock().unwrap();
        let mut v: Vec<_> = st.iter().map(|(k, s)| (k.clone(), *s)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Measured mean wall-clock seconds per call of an artifact, if called.
    pub fn mean_secs(&self, name: &str) -> Option<f64> {
        let st = self.stats.lock().unwrap();
        st.get(name).filter(|s| s.calls > 0).map(|s| s.total_secs / s.calls as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_testbed_executes_mnist_forward() {
        let eng = Engine::native_testbed();
        assert!(eng.is_native());
        assert_eq!(eng.platform(), "native-testbed");
        let man = eng.manifest();
        let rules = man.model("mnist").unwrap().to_vec();
        let params = crate::model::ParamStore::init(&rules, 1);
        let b = man.constants.mnist_batch;
        let mut inputs = params.as_inputs();
        inputs.push(HostTensor::zeros_f32(&[b, man.constants.mnist_in]));
        inputs.push(HostTensor::zeros_f32(&[b, man.constants.mnist_actions]));
        let out = eng.execute("mnist_fwd", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[b, man.constants.mnist_actions]);
        // stats recorded
        assert_eq!(eng.stats().len(), 1);
        assert!(eng.mean_secs("mnist_fwd").is_some());
    }

    #[test]
    fn execute_refs_shares_marshalled_params_across_calls() {
        // the hot-path contract: one marshalled parameter list serves many
        // calls by reference, each with its own extra inputs appended
        let eng = Engine::native_testbed();
        let man = eng.manifest();
        let rules = man.model("mnist").unwrap().to_vec();
        let params = crate::model::ParamStore::init(&rules, 1);
        let param_inputs = params.as_inputs();
        let b = man.constants.mnist_batch;
        let x = HostTensor::zeros_f32(&[b, man.constants.mnist_in]);
        let noise = HostTensor::zeros_f32(&[b, man.constants.mnist_actions]);
        let mut refs: Vec<&HostTensor> = param_inputs.iter().collect();
        refs.push(&x);
        refs.push(&noise);
        let first = eng.execute_refs("mnist_fwd", &refs).unwrap();
        let second = eng.execute_refs("mnist_fwd", &refs).unwrap();
        assert_eq!(first[0].as_f32().unwrap(), second[0].as_f32().unwrap());
        assert_eq!(eng.stats()[0].1.calls, 2);
    }

    #[test]
    fn with_f32_fast_flips_the_forward_tier() {
        let eng = Engine::native_testbed();
        assert!(!eng.f32_fast(), "exact by default");
        let eng = eng.with_f32_fast(true);
        assert!(eng.f32_fast());
        let man = eng.manifest();
        let rules = man.model("mnist").unwrap().to_vec();
        let params = crate::model::ParamStore::init(&rules, 1);
        let b = man.constants.mnist_batch;
        let mut inputs = params.as_inputs();
        inputs.push(HostTensor::zeros_f32(&[b, man.constants.mnist_in]));
        inputs.push(HostTensor::zeros_f32(&[b, man.constants.mnist_actions]));
        // still a valid normalized forward under the fast tier
        let out = eng.execute("mnist_fwd", &inputs).unwrap();
        for row in out[0].as_f32().unwrap().chunks(man.constants.mnist_actions) {
            let s: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn execute_validates_signatures() {
        let eng = Engine::native_testbed();
        // wrong arity
        assert!(eng.execute("mnist_fwd", &[]).is_err());
        // unknown artifact
        assert!(eng.execute("nope", &[]).is_err());
    }

    #[test]
    fn engine_is_shared_across_threads() {
        let eng = Engine::native_testbed();
        let man = eng.manifest();
        let rules = man.model("mnist").unwrap().to_vec();
        let params = crate::model::ParamStore::init(&rules, 1);
        let b = man.constants.mnist_batch;
        std::thread::scope(|s| {
            for _ in 0..4 {
                let eng = &eng;
                let params = &params;
                s.spawn(move || {
                    let mut inputs = params.as_inputs();
                    inputs.push(HostTensor::zeros_f32(&[b, eng.manifest().constants.mnist_in]));
                    inputs
                        .push(HostTensor::zeros_f32(&[b, eng.manifest().constants.mnist_actions]));
                    eng.execute("mnist_fwd", &inputs).unwrap();
                });
            }
        });
        let st = eng.stats();
        assert_eq!(st[0].1.calls, 4);
    }
}
