//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, and executes them from the L3 hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`.
//! Executables are compiled lazily (first use) and cached for the process
//! lifetime; per-artifact call counts and wall-clock are recorded for the
//! compute ledger and the perf pass.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::Manifest;
use super::tensor::HostTensor;

#[derive(Debug, Default, Clone, Copy)]
pub struct ArtifactStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    execs: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    stats: Mutex<HashMap<String, ArtifactStats>>,
}

impl Engine {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir,
            manifest,
            execs: Mutex::new(HashMap::new()),
            stats: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for an artifact.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        {
            let execs = self.execs.lock().unwrap();
            if execs.contains_key(name) {
                return Ok(());
            }
        }
        let sig = self.manifest.artifact(name)?;
        let path = self.dir.join(&sig.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.execs.lock().unwrap().insert(name.to_string(), exe);
        self.stats.lock().unwrap().entry(name.to_string()).or_default().compile_secs += dt;
        Ok(())
    }

    /// Pre-compile a set of artifacts (e.g. at trainer startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with host tensors; validates the input signature
    /// against the manifest and unpacks the output tuple.
    pub fn execute(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "artifact '{name}': got {} inputs, manifest says {}",
                inputs.len(),
                sig.inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&sig.inputs) {
            t.check_sig(s).with_context(|| format!("artifact '{name}'"))?;
        }
        self.ensure_compiled(name)?;

        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let result = {
            let execs = self.execs.lock().unwrap();
            let exe = execs.get(name).unwrap();
            exe.execute::<Literal>(&lits)?
        };
        let out_lit = result[0][0].to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = self.stats.lock().unwrap();
            let e = st.entry(name.to_string()).or_default();
            e.calls += 1;
            e.total_secs += dt;
        }

        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = out_lit.to_tuple()?;
        if parts.len() != sig.outputs.len() {
            bail!(
                "artifact '{name}': got {} outputs, manifest says {}",
                parts.len(),
                sig.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&sig.outputs)
            .map(|(lit, s)| HostTensor::from_literal(lit, s))
            .collect()
    }

    /// Per-artifact timing snapshot (for EXPERIMENTS.md perf tables).
    pub fn stats(&self) -> Vec<(String, ArtifactStats)> {
        let st = self.stats.lock().unwrap();
        let mut v: Vec<_> = st.iter().map(|(k, s)| (k.clone(), *s)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Measured mean wall-clock seconds per call of an artifact, if called.
    pub fn mean_secs(&self, name: &str) -> Option<f64> {
        let st = self.stats.lock().unwrap();
        st.get(name).filter(|s| s.calls > 0).map(|s| s.total_secs / s.calls as f64)
    }
}
