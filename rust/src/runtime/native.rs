//! Native testbed backend: a pure-Rust reference implementation of the
//! artifact contract, used when no compiled HLO artifact set is available
//! (this offline environment has no PJRT runtime at all).
//!
//! The testbed registers the same artifact *names and signatures* the AOT
//! pipeline would emit -- `mnist_fwd`, `mnist_bwd_c{cap}`, `rev8_rollout`,
//! ... -- over deliberately small models: a 784-32-10 tanh MLP for the
//! MNIST bandit and a pointer-attention model (learned position-attention
//! x token-emission table) for token reversal. The trainers, gate,
//! batcher, and worker pool run unmodified against it.
//!
//! All inner math routes through the shared kernel layer
//! (`runtime/kernels.rs`, DESIGN.md §9): the MLP runs as a blocked GEMM
//! over packed weight panels with fused bias+tanh and
//! logits+log-softmax epilogues, the reversal logits through the
//! gather-mix kernel, the attention backward through the batched
//! softmax-Jacobian kernel. This module keeps only the orchestration
//! loops (rows, episodes, positions) and the artifact plumbing. Outputs
//! are written into tensor-arena buffers (`runtime/tensor.rs`) instead of
//! fresh allocations; consumers recycle them.
//!
//! Determinism contract (DESIGN.md §"L3 parallelism" + §9): every
//! artifact here is **row-independent** -- output row i is a pure
//! function of input row i and the parameters -- and every reduction
//! inside a row uses the kernels' fixed index-ordered lane tree, a
//! function of operand shapes only. Executing a batch whole, in shards,
//! or padded to a larger capacity therefore yields bit-identical rows,
//! which is what makes `workers=N` training trajectories bit-equal to
//! `workers=1`.

use anyhow::{bail, Result};

use crate::utils::math::LANES;
use crate::utils::rng::Pcg32;

use super::kernels::{
    self, gather_mix_masked, gemm_bias_logsoftmax, gemm_bias_tanh, logsumexp_1pass, softmax_rows,
    WeightPack,
};
use super::manifest::{ArtifactSig, Constants, DType, InitKind, InitRule, Manifest, TensorSig};
use super::tensor::{self, HostTensor};

// ---- testbed shape constants (small: tests train in seconds) ----
pub const MNIST_BATCH: usize = 32;
pub const MNIST_EVAL_BATCH: usize = 64;
pub const MNIST_HIDDEN: usize = 32;
pub const MNIST_ACTIONS: usize = 10;
pub const MNIST_IN: usize = 784;
/// Bucket ladder tops out BELOW the batch (32) on purpose: ungated
/// methods must split into several chunks, so the chunk-order gradient
/// merge of the worker pool is exercised (and determinism-tested) even
/// on small runs.
pub const MNIST_CAPS: [usize; 3] = [4, 8, 16];
pub const REV_BATCH: usize = 100;
pub const REV_HMAX: usize = 8;
pub const REV_VOCAB: usize = 8;
/// pad token id (== vocab, one past the last real token)
pub const REV_PAD: usize = 8;
/// max cap 64 < batch 100: full-batch backwards split into two chunks
pub const REV_CAPS: [usize; 5] = [4, 8, 16, 32, 64];
const NEG: f32 = -1.0e30;

/// Executor for the native artifact set. The only configuration is the
/// **non-golden** `f32_fast` knob (DESIGN.md §13): when set, the
/// forward-tier MNIST GEMMs (`mnist_fwd*`, `mnist_fwd_eval`) run with f32
/// accumulators. The backward always recomputes through the exact f64
/// lane-tree kernels, and the reversal artifacts are excluded (their
/// kernels are tiny and memory-bound; an approximate tier buys nothing).
#[derive(Debug, Default)]
pub struct NativeTestbed {
    pub f32_fast: bool,
}

fn sig(name: &str, shape: &[usize], dtype: DType) -> TensorSig {
    TensorSig { name: name.to_string(), shape: shape.to_vec(), dtype }
}

fn param_sigs(rules: &[InitRule]) -> Vec<TensorSig> {
    rules.iter().map(|r| sig(&r.name, &r.shape, DType::F32)).collect()
}

fn mnist_rules() -> Vec<InitRule> {
    vec![
        InitRule {
            name: "w1".into(),
            shape: vec![MNIST_IN, MNIST_HIDDEN],
            kind: InitKind::Normal { scale: 0.05 },
        },
        InitRule { name: "b1".into(), shape: vec![MNIST_HIDDEN], kind: InitKind::Zeros },
        InitRule {
            name: "w2".into(),
            shape: vec![MNIST_HIDDEN, MNIST_ACTIONS],
            kind: InitKind::Normal { scale: 0.05 },
        },
        InitRule { name: "b2".into(), shape: vec![MNIST_ACTIONS], kind: InitKind::Zeros },
    ]
}

fn rev_rules() -> Vec<InitRule> {
    vec![
        InitRule { name: "attn".into(), shape: vec![REV_HMAX, REV_HMAX], kind: InitKind::Zeros },
        InitRule {
            name: "emit".into(),
            shape: vec![REV_VOCAB + 1, REV_VOCAB],
            kind: InitKind::Normal { scale: 0.05 },
        },
    ]
}

fn art(name: &str, inputs: Vec<TensorSig>, outputs: Vec<TensorSig>) -> (String, ArtifactSig) {
    (
        name.to_string(),
        ArtifactSig { name: name.to_string(), file: "<native>".to_string(), inputs, outputs },
    )
}

impl NativeTestbed {
    /// The manifest the AOT pipeline would have produced for this set.
    pub fn manifest() -> Manifest {
        let constants = Constants {
            mnist_batch: MNIST_BATCH,
            mnist_eval_batch: MNIST_EVAL_BATCH,
            mnist_actions: MNIST_ACTIONS,
            mnist_in: MNIST_IN,
            mnist_bwd_caps: MNIST_CAPS.to_vec(),
            mnist_fwd_caps: MNIST_CAPS.to_vec(),
            rev_batch: REV_BATCH,
            rev_sets: vec![REV_HMAX],
            h_max: REV_HMAX,
            vocab: REV_VOCAB,
            pad: REV_PAD,
            rev_bwd_caps: REV_CAPS.to_vec(),
            neg_inf: NEG as f64,
        };

        let mnist = mnist_rules();
        let rev = rev_rules();
        let mut artifacts = std::collections::BTreeMap::new();

        // MNIST forward (training batch, with exploration-noise input) at
        // the full batch plus every shard capacity, eval forward, and the
        // bucketed backward set.
        let fwd = |cap: usize, name: &str| {
            let mut inputs = param_sigs(&mnist);
            inputs.push(sig("x", &[cap, MNIST_IN], DType::F32));
            inputs.push(sig("noise", &[cap, MNIST_ACTIONS], DType::F32));
            art(name, inputs, vec![sig("logp", &[cap, MNIST_ACTIONS], DType::F32)])
        };
        let (k, v) = fwd(MNIST_BATCH, "mnist_fwd");
        artifacts.insert(k, v);
        for cap in MNIST_CAPS {
            let (k, v) = fwd(cap, &format!("mnist_fwd_c{cap}"));
            artifacts.insert(k, v);
        }
        {
            let mut inputs = param_sigs(&mnist);
            inputs.push(sig("x", &[MNIST_EVAL_BATCH, MNIST_IN], DType::F32));
            let (k, v) = art(
                "mnist_fwd_eval",
                inputs,
                vec![sig("logp", &[MNIST_EVAL_BATCH, MNIST_ACTIONS], DType::F32)],
            );
            artifacts.insert(k, v);
        }
        for cap in MNIST_CAPS {
            let mut inputs = param_sigs(&mnist);
            inputs.push(sig("x", &[cap, MNIST_IN], DType::F32));
            inputs.push(sig("actions", &[cap], DType::I32));
            inputs.push(sig("w", &[cap], DType::F32));
            let mut outputs = vec![sig("loss", &[1], DType::F32)];
            outputs.extend(param_sigs(&mnist).into_iter().map(|mut t| {
                t.name = format!("g_{}", t.name);
                t
            }));
            let (k, v) = art(&format!("mnist_bwd_c{cap}"), inputs, outputs);
            artifacts.insert(k, v);
        }

        // Token reversal: rollout + re-scoring forward at the full batch,
        // bucketed backward per episode capacity.
        {
            let mut inputs = param_sigs(&rev);
            inputs.push(sig("prompt", &[REV_BATCH, REV_HMAX], DType::I32));
            inputs.push(sig("h", &[1], DType::I32));
            inputs.push(sig("m", &[1], DType::I32));
            inputs.push(sig("seed", &[1], DType::I32));
            let (k, v) = art(
                &format!("rev{REV_HMAX}_rollout"),
                inputs,
                vec![
                    sig("actions", &[REV_BATCH, REV_HMAX], DType::I32),
                    sig("logp", &[REV_BATCH, REV_HMAX], DType::F32),
                ],
            );
            artifacts.insert(k, v);
        }
        {
            let mut inputs = param_sigs(&rev);
            inputs.push(sig("prompt", &[REV_BATCH, REV_HMAX], DType::I32));
            inputs.push(sig("actions", &[REV_BATCH, REV_HMAX], DType::I32));
            inputs.push(sig("h", &[1], DType::I32));
            inputs.push(sig("m", &[1], DType::I32));
            let (k, v) = art(
                &format!("rev{REV_HMAX}_fwd"),
                inputs,
                vec![sig("logp", &[REV_BATCH, REV_HMAX], DType::F32)],
            );
            artifacts.insert(k, v);
        }
        for cap in REV_CAPS {
            let mut inputs = param_sigs(&rev);
            inputs.push(sig("prompt", &[cap, REV_HMAX], DType::I32));
            inputs.push(sig("actions", &[cap, REV_HMAX], DType::I32));
            inputs.push(sig("w", &[cap, REV_HMAX], DType::F32));
            inputs.push(sig("h", &[1], DType::I32));
            inputs.push(sig("m", &[1], DType::I32));
            let outputs = vec![
                sig("loss", &[1], DType::F32),
                sig("g_attn", &[REV_HMAX, REV_HMAX], DType::F32),
                sig("g_emit", &[REV_VOCAB + 1, REV_VOCAB], DType::F32),
            ];
            let (k, v) = art(&format!("rev{REV_HMAX}_bwd_c{cap}"), inputs, outputs);
            artifacts.insert(k, v);
        }

        let mut models = std::collections::BTreeMap::new();
        models.insert("mnist".to_string(), mnist);
        models.insert(format!("reversal{REV_HMAX}"), rev);

        Manifest { constants, models, artifacts }
    }

    /// Execute one artifact. Inputs are already validated against the
    /// manifest signature by the engine, so shapes can be trusted here.
    /// Borrowed inputs keep the engine hot path zero-copy: parameter
    /// tensors marshalled once per step are shared across every call.
    pub fn execute(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if name == "mnist_fwd" {
            return mnist_forward(inputs, MNIST_BATCH, true, self.f32_fast);
        }
        if name == "mnist_fwd_eval" {
            return mnist_forward(inputs, MNIST_EVAL_BATCH, false, self.f32_fast);
        }
        if let Some(cap) = suffix_cap(name, "mnist_fwd_c") {
            return mnist_forward(inputs, cap, true, self.f32_fast);
        }
        if let Some(cap) = suffix_cap(name, "mnist_bwd_c") {
            return mnist_backward(inputs, cap);
        }
        if name == format!("rev{REV_HMAX}_rollout") {
            return rev_rollout(inputs);
        }
        if name == format!("rev{REV_HMAX}_fwd") {
            return rev_forward(inputs);
        }
        if let Some(cap) = suffix_cap(name, &format!("rev{REV_HMAX}_bwd_c")) {
            return rev_backward(inputs, cap);
        }
        bail!("native testbed: unknown artifact '{name}'")
    }
}

fn suffix_cap(name: &str, prefix: &str) -> Option<usize> {
    name.strip_prefix(prefix).and_then(|s| s.parse().ok())
}

/// A GEMM-ready view of a weight input: the pack the marshalling layer
/// attached (the once-per-step shared pack), or -- for callers that hand
/// in bare tensors, e.g. direct backend tests -- a pack built on the
/// spot. Both layouts are identical functions of the weights, so the
/// two paths are bit-equal.
enum PackRef<'a> {
    Shared(&'a WeightPack),
    Owned(WeightPack),
}

impl std::ops::Deref for PackRef<'_> {
    type Target = WeightPack;
    fn deref(&self) -> &WeightPack {
        match self {
            PackRef::Shared(p) => p,
            PackRef::Owned(p) => p,
        }
    }
}

fn pack_of<'a>(t: &'a HostTensor) -> Result<PackRef<'a>> {
    if let Some(p) = t.pack() {
        return Ok(PackRef::Shared(p));
    }
    let s = t.shape();
    if s.len() != 2 {
        bail!("expected a 2-D weight tensor, got shape {s:?}");
    }
    Ok(PackRef::Owned(WeightPack::new(t.as_f32()?, s[0], s[1], 0)))
}

// ---- MNIST MLP: x[784] -> tanh(32) -> log-softmax(10) ----
//
// One fused kernel call per layer: `gemm_bias_tanh` produces the hidden
// activations, `gemm_bias_logsoftmax` the normalized log-probabilities
// (bias, optional exploration noise, and the single-pass logsumexp all
// inside the epilogue). Per output element the reduction is the kernels'
// fixed lane tree over the input dimension -- a function of shapes only,
// identical whether the row runs in a full batch, a shard, or alone.

fn mnist_forward(
    inputs: &[&HostTensor],
    cap: usize,
    with_noise: bool,
    f32_fast: bool,
) -> Result<Vec<HostTensor>> {
    let w1p = pack_of(inputs[0])?;
    let b1 = inputs[1].as_f32()?;
    let w2p = pack_of(inputs[2])?;
    let b2 = inputs[3].as_f32()?;
    let x = inputs[4].as_f32()?;
    let noise = if with_noise { Some(inputs[5].as_f32()?) } else { None };

    let mut hidden = tensor::take_f32_zeroed(cap * MNIST_HIDDEN);
    let mut logp = tensor::take_f32_zeroed(cap * MNIST_ACTIONS);
    if f32_fast {
        // non-golden forward tier: f32 accumulators (DESIGN.md §13)
        kernels::gemm_bias_tanh_f32fast(x, cap, &w1p, b1, &mut hidden);
        kernels::gemm_bias_logsoftmax_f32fast(&hidden, cap, &w2p, b2, noise, &mut logp);
    } else {
        gemm_bias_tanh(x, cap, &w1p, b1, &mut hidden);
        gemm_bias_logsoftmax(&hidden, cap, &w2p, b2, noise, &mut logp);
    }
    tensor::recycle_f32(hidden);
    Ok(vec![HostTensor::f32(&[cap, MNIST_ACTIONS], logp)])
}

/// Weighted score-function backward: L = -sum_i w_i log pi(a_i); outputs
/// [loss, g_w1, g_b1, g_w2, g_b2]. Zero-weight (padding) rows are skipped,
/// which is exact because every contribution scales with w_i.
///
/// The recomputation runs through the same GEMM kernels as the forward
/// (one-row calls -- bit-identical to the batched form by row
/// independence); the gradient scatter is `outer_acc`/`axpy` (one
/// contribution per element per sample, in sample order) and the hidden
/// backprop one `matvec_rows` of lane-reduced dots. Gradients accumulate
/// into arena buffers the accumulator recycles.
fn mnist_backward(inputs: &[&HostTensor], cap: usize) -> Result<Vec<HostTensor>> {
    let w1p = pack_of(inputs[0])?;
    let b1 = inputs[1].as_f32()?;
    let w2 = inputs[2].as_f32()?;
    let w2p = pack_of(inputs[2])?;
    let b2 = inputs[3].as_f32()?;
    let x = inputs[4].as_f32()?;
    let actions = inputs[5].as_i32()?;
    let w = inputs[6].as_f32()?;

    let mut loss = 0.0f64;
    let mut gw1 = tensor::take_f32_zeroed(MNIST_IN * MNIST_HIDDEN);
    let mut gb1 = tensor::take_f32_zeroed(MNIST_HIDDEN);
    let mut gw2 = tensor::take_f32_zeroed(MNIST_HIDDEN * MNIST_ACTIONS);
    let mut gb2 = tensor::take_f32_zeroed(MNIST_ACTIONS);
    let mut h = [0.0f32; MNIST_HIDDEN];
    let mut logp = [0.0f32; MNIST_ACTIONS];
    let mut dl = [0.0f32; MNIST_ACTIONS];
    let mut dh = [0.0f64; MNIST_HIDDEN];
    let mut dpre = [0.0f32; MNIST_HIDDEN];

    for i in 0..cap {
        let wi = w[i];
        if wi == 0.0 {
            continue;
        }
        let a = actions[i] as usize;
        if a >= MNIST_ACTIONS {
            bail!("mnist_bwd: action {a} out of range");
        }
        let xi = &x[i * MNIST_IN..(i + 1) * MNIST_IN];
        // always the exact f64 lane-tree kernels, never the f32-fast tier:
        // the gated backward is golden (DESIGN.md §13)
        gemm_bias_tanh(xi, 1, &w1p, b1, &mut h);
        gemm_bias_logsoftmax(&h, 1, &w2p, b2, None, &mut logp);
        loss += wi as f64 * (-(logp[a] as f64));

        // dL/dlogits = w * (softmax - onehot(a))
        for (k, dlk) in dl.iter_mut().enumerate() {
            let p = logp[k].exp();
            *dlk = wi * (p - if k == a { 1.0 } else { 0.0 });
        }
        kernels::axpy(1.0, &dl, &mut gb2);
        kernels::outer_acc(&h, &dl, &mut gw2);
        kernels::matvec_rows(w2, MNIST_HIDDEN, MNIST_ACTIONS, &dl, &mut dh);
        for j in 0..MNIST_HIDDEN {
            let dp = ((1.0 - h[j] as f64 * h[j] as f64) * dh[j]) as f32;
            gb1[j] += dp;
            dpre[j] = dp;
        }
        kernels::outer_acc(xi, &dpre, &mut gw1);
    }

    let mut loss_t = tensor::take_f32_zeroed(1);
    loss_t[0] = loss as f32;
    Ok(vec![
        HostTensor::f32(&[1], loss_t),
        HostTensor::f32(&[MNIST_IN, MNIST_HIDDEN], gw1),
        HostTensor::f32(&[MNIST_HIDDEN], gb1),
        HostTensor::f32(&[MNIST_HIDDEN, MNIST_ACTIONS], gw2),
        HostTensor::f32(&[MNIST_ACTIONS], gb2),
    ])
}

// ---- token reversal: pointer-attention model ----
//
// alpha[j, k] = softmax_k(attn[j, :]) is a learned soft pointer from
// output position j to prompt position k; logits[ep, j, v] =
// sum_k alpha[j, k] * emit[prompt[ep, k], v], masked to the active
// vocabulary m. Solving reversal means learning alpha[j, .] ->
// onehot(h_max - 1 - j + offset) and emit -> identity. The softmax rows,
// the masked attention mix, and the attention backward all run through
// the kernel layer (`softmax_rows`, `gather_mix_masked`,
// `softmax_jacobian_rows`).

fn rev_scalars(inputs: &[&HostTensor], h_idx: usize) -> Result<(usize, usize)> {
    let h = inputs[h_idx].as_i32()?[0] as usize;
    let m = inputs[h_idx + 1].as_i32()?[0] as usize;
    if h == 0 || h > REV_HMAX || m < 2 || m > REV_VOCAB {
        bail!("rev artifact: bad h={h} or m={m}");
    }
    Ok((h, m))
}

fn check_token(t: i32) -> Result<usize> {
    let t = t as usize;
    if t > REV_PAD {
        bail!("rev artifact: token id {t} out of range");
    }
    Ok(t)
}

/// Check and widen one episode's prompt tokens into `trow` (reused across
/// episodes; gathering the token ids once hoists the per-(position, vocab)
/// bounds checks out of the attention inner loops).
fn gather_tokens(prow: &[i32], trow: &mut [usize]) -> Result<()> {
    for (t, &p) in trow.iter_mut().zip(prow) {
        *t = check_token(p)?;
    }
    Ok(())
}

fn rev_rollout(inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let attn = inputs[0].as_f32()?;
    let emit = inputs[1].as_f32()?;
    let prompt = inputs[2].as_i32()?;
    let (h, m) = rev_scalars(inputs, 3)?;
    let seed = inputs[5].as_i32()?[0] as u64;

    let mut alpha = [0.0f32; REV_HMAX * REV_HMAX];
    softmax_rows(attn, REV_HMAX, REV_HMAX, &mut alpha);
    let mut actions = tensor::take_i32_filled(REV_BATCH * REV_HMAX, REV_PAD as i32);
    let mut logp = tensor::take_f32_zeroed(REV_BATCH * REV_HMAX);
    let mut trow = [0usize; REV_HMAX];
    let mut acc = [0.0f64; REV_VOCAB * LANES];
    let mut logits = [NEG; REV_VOCAB];
    for ep in 0..REV_BATCH {
        let prow = &prompt[ep * REV_HMAX..(ep + 1) * REV_HMAX];
        gather_tokens(prow, &mut trow)?;
        // per-episode stream: sampling is independent of how the batch
        // would be sharded (rollout runs whole-batch today, but the
        // contract keeps this future-proof)
        let mut rng = Pcg32::new(seed, ep as u64);
        for j in 0..h {
            let alpha_row = &alpha[j * REV_HMAX..(j + 1) * REV_HMAX];
            gather_mix_masked(alpha_row, emit, REV_VOCAB, &trow, m, NEG, &mut acc, &mut logits);
            let a = rng.categorical_from_logits(&logits);
            let lse = logsumexp_1pass(&logits);
            actions[ep * REV_HMAX + j] = a as i32;
            logp[ep * REV_HMAX + j] = logits[a] - lse;
        }
    }
    Ok(vec![
        HostTensor::i32(&[REV_BATCH, REV_HMAX], actions),
        HostTensor::f32(&[REV_BATCH, REV_HMAX], logp),
    ])
}

fn rev_forward(inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let attn = inputs[0].as_f32()?;
    let emit = inputs[1].as_f32()?;
    let prompt = inputs[2].as_i32()?;
    let actions = inputs[3].as_i32()?;
    let (h, m) = rev_scalars(inputs, 4)?;

    let mut alpha = [0.0f32; REV_HMAX * REV_HMAX];
    softmax_rows(attn, REV_HMAX, REV_HMAX, &mut alpha);
    let mut logp = tensor::take_f32_zeroed(REV_BATCH * REV_HMAX);
    let mut trow = [0usize; REV_HMAX];
    let mut acc = [0.0f64; REV_VOCAB * LANES];
    let mut logits = [NEG; REV_VOCAB];
    for ep in 0..REV_BATCH {
        let prow = &prompt[ep * REV_HMAX..(ep + 1) * REV_HMAX];
        gather_tokens(prow, &mut trow)?;
        for j in 0..h {
            let a = actions[ep * REV_HMAX + j] as usize;
            if a >= m {
                bail!("rev_fwd: action {a} outside active vocab {m}");
            }
            let alpha_row = &alpha[j * REV_HMAX..(j + 1) * REV_HMAX];
            gather_mix_masked(alpha_row, emit, REV_VOCAB, &trow, m, NEG, &mut acc, &mut logits);
            let lse = logsumexp_1pass(&logits);
            logp[ep * REV_HMAX + j] = logits[a] - lse;
        }
    }
    Ok(vec![HostTensor::f32(&[REV_BATCH, REV_HMAX], logp)])
}

/// Episode-bucketed backward: L = -sum_{ep,j} w[ep,j] log pi(a[ep,j]);
/// outputs [loss, g_attn, g_emit]. Zero-weight tokens (skipped by the
/// gate, or whole padding episodes) contribute nothing.
///
/// The emit-gradient scatter is one `axpy` per prompt position
/// (contiguous emit / g_emit row access, one contribution per element per
/// token, in (episode, position) order); the alpha gradient is one
/// lane-reduced dot per position, and the final attention backward is
/// the batched `softmax_jacobian_rows` kernel over all attention rows.
fn rev_backward(inputs: &[&HostTensor], cap: usize) -> Result<Vec<HostTensor>> {
    let attn = inputs[0].as_f32()?;
    let emit = inputs[1].as_f32()?;
    let prompt = inputs[2].as_i32()?;
    let actions = inputs[3].as_i32()?;
    let w = inputs[4].as_f32()?;
    let (h, m) = rev_scalars(inputs, 5)?;

    let mut alpha = [0.0f32; REV_HMAX * REV_HMAX];
    softmax_rows(attn, REV_HMAX, REV_HMAX, &mut alpha);
    let mut loss = 0.0f64;
    let mut dalpha = [0.0f32; REV_HMAX * REV_HMAX];
    let mut gemit = tensor::take_f32_zeroed((REV_VOCAB + 1) * REV_VOCAB);
    let mut trow = [0usize; REV_HMAX];
    let mut acc = [0.0f64; REV_VOCAB * LANES];
    let mut logits = [NEG; REV_VOCAB];
    let mut dl = [0.0f32; REV_VOCAB];

    for ep in 0..cap {
        let prow = &prompt[ep * REV_HMAX..(ep + 1) * REV_HMAX];
        gather_tokens(prow, &mut trow)?;
        for j in 0..h {
            let wij = w[ep * REV_HMAX + j];
            if wij == 0.0 {
                continue;
            }
            let a = actions[ep * REV_HMAX + j] as usize;
            if a >= m {
                bail!("rev_bwd: action {a} outside active vocab {m}");
            }
            let alpha_row = &alpha[j * REV_HMAX..(j + 1) * REV_HMAX];
            gather_mix_masked(alpha_row, emit, REV_VOCAB, &trow, m, NEG, &mut acc, &mut logits);
            let lse = logsumexp_1pass(&logits);
            loss += wij as f64 * ((lse - logits[a]) as f64);
            // dL/dlogits = w * (softmax - onehot(a))
            for (v, dv) in dl.iter_mut().enumerate().take(m) {
                let p = (logits[v] - lse).exp();
                *dv = wij * (p - if v == a { 1.0 } else { 0.0 });
            }
            let darow = &mut dalpha[j * REV_HMAX..(j + 1) * REV_HMAX];
            for (k, &t) in trow.iter().enumerate() {
                let erow = &emit[t * REV_VOCAB..t * REV_VOCAB + m];
                let grow = &mut gemit[t * REV_VOCAB..t * REV_VOCAB + m];
                kernels::axpy(alpha_row[k], &dl[..m], grow);
                darow[k] += crate::utils::math::dot(&dl[..m], erow) as f32;
            }
        }
    }

    // batched softmax Jacobian over all attention rows:
    // d attn = alpha * (d alpha - <alpha, d alpha>)
    let mut gattn = tensor::take_f32_zeroed(REV_HMAX * REV_HMAX);
    kernels::softmax_jacobian_rows(&alpha, &dalpha, REV_HMAX, REV_HMAX, &mut gattn);

    let mut loss_t = tensor::take_f32_zeroed(1);
    loss_t[0] = loss as f32;
    Ok(vec![
        HostTensor::f32(&[1], loss_t),
        HostTensor::f32(&[REV_HMAX, REV_HMAX], gattn),
        HostTensor::f32(&[REV_VOCAB + 1, REV_VOCAB], gemit),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamStore;

    /// Borrow a tensor list the way the engine hands it to the backend.
    fn refs(v: &[HostTensor]) -> Vec<&HostTensor> {
        v.iter().collect()
    }

    fn mnist_inputs(cap: usize, with_noise: bool) -> Vec<HostTensor> {
        let params = ParamStore::init(&mnist_rules(), 7);
        let mut rng = Pcg32::seeded(3);
        let x: Vec<f32> = (0..cap * MNIST_IN).map(|_| rng.normal() as f32).collect();
        let mut inputs = params.as_inputs();
        inputs.push(HostTensor::f32(&[cap, MNIST_IN], x));
        if with_noise {
            inputs.push(HostTensor::zeros_f32(&[cap, MNIST_ACTIONS]));
        }
        inputs
    }

    #[test]
    fn manifest_is_self_consistent() {
        let m = NativeTestbed::manifest();
        assert_eq!(m.constants.mnist_batch, MNIST_BATCH);
        assert!(m.artifact("mnist_fwd").is_ok());
        assert!(m.artifact("mnist_fwd_eval").is_ok());
        for cap in MNIST_CAPS {
            assert!(m.artifact(&format!("mnist_bwd_c{cap}")).is_ok());
            assert!(m.artifact(&format!("mnist_fwd_c{cap}")).is_ok());
        }
        assert!(m.artifact("rev8_rollout").is_ok());
        assert_eq!(m.model("mnist").unwrap().len(), 4);
        assert_eq!(m.model("reversal8").unwrap().len(), 2);
    }

    #[test]
    fn mnist_forward_rows_are_normalized_logprobs() {
        let out =
            mnist_forward(&refs(&mnist_inputs(MNIST_BATCH, true)), MNIST_BATCH, true, false)
                .unwrap();
        let logp = out[0].as_f32().unwrap();
        for row in logp.chunks(MNIST_ACTIONS) {
            let s: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        }
    }

    #[test]
    fn mnist_forward_is_row_independent() {
        // the determinism contract: row i is the same whether computed in
        // a full batch or alone in a padded shard
        let full_in = mnist_inputs(MNIST_BATCH, true);
        let full = mnist_forward(&refs(&full_in), MNIST_BATCH, true, false).unwrap();
        let logp_full = full[0].as_f32().unwrap();

        let x = full_in[4].as_f32().unwrap();
        let i = 17;
        let mut shard_in = full_in[..4].to_vec();
        let mut xs = vec![0.0f32; 4 * MNIST_IN];
        xs[..MNIST_IN].copy_from_slice(&x[i * MNIST_IN..(i + 1) * MNIST_IN]);
        shard_in.push(HostTensor::f32(&[4, MNIST_IN], xs));
        shard_in.push(HostTensor::zeros_f32(&[4, MNIST_ACTIONS]));
        let shard = mnist_forward(&refs(&shard_in), 4, true, false).unwrap();
        let logp_shard = shard[0].as_f32().unwrap();
        assert_eq!(
            &logp_full[i * MNIST_ACTIONS..(i + 1) * MNIST_ACTIONS],
            &logp_shard[..MNIST_ACTIONS]
        );
    }

    #[test]
    fn packed_and_unpacked_inputs_are_bit_identical() {
        // the pack-cache fallback contract: a bare weight tensor (no
        // attached pack) produces exactly what the marshalled, packed
        // tensor produces
        let packed_in = mnist_inputs(8, true);
        assert!(packed_in[0].pack().is_some(), "as_inputs must attach packs");
        let mut bare_in = packed_in.clone();
        for t in bare_in.iter_mut().take(4) {
            *t = HostTensor::f32(t.shape(), t.as_f32().unwrap().to_vec());
        }
        assert!(bare_in[0].pack().is_none());
        let a = mnist_forward(&refs(&packed_in), 8, true, false).unwrap();
        let b = mnist_forward(&refs(&bare_in), 8, true, false).unwrap();
        assert_eq!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    }

    #[test]
    fn f32fast_forward_is_normalized_close_and_deterministic() {
        // the non-golden tier: still valid log-probabilities, close to the
        // golden forward, bit-stable across repeats — but no golden
        // comparison anywhere, by design
        let inputs = mnist_inputs(8, true);
        let golden = mnist_forward(&refs(&inputs), 8, true, false).unwrap();
        let fast = mnist_forward(&refs(&inputs), 8, true, true).unwrap();
        let fast2 = mnist_forward(&refs(&inputs), 8, true, true).unwrap();
        assert_eq!(fast[0].as_f32().unwrap(), fast2[0].as_f32().unwrap());
        let g = golden[0].as_f32().unwrap();
        let f = fast[0].as_f32().unwrap();
        for row in f.chunks(MNIST_ACTIONS) {
            let s: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "fast row sums to {s}");
        }
        for i in 0..g.len() {
            assert!((g[i] - f[i]).abs() < 1e-3, "logp[{i}]: {} vs {}", g[i], f[i]);
        }
    }

    #[test]
    fn backend_f32_fast_flag_routes_the_forward_only() {
        let exact = NativeTestbed::default();
        let fast = NativeTestbed { f32_fast: true };
        let inputs = mnist_inputs(MNIST_BATCH, true);
        let a = exact.execute("mnist_fwd", &refs(&inputs)).unwrap();
        let b = fast.execute("mnist_fwd", &refs(&inputs)).unwrap();
        // forward tier differs (approximate) ...
        assert_ne!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
        // ... but the backward is identical bits under both flags
        let params = ParamStore::init(&mnist_rules(), 7);
        let mut rng = Pcg32::seeded(3);
        let x: Vec<f32> = (0..4 * MNIST_IN).map(|_| rng.normal() as f32).collect();
        let mut inp = params.as_inputs();
        inp.push(HostTensor::f32(&[4, MNIST_IN], x));
        inp.push(HostTensor::i32(&[4], vec![1, 2, 3, 4]));
        inp.push(HostTensor::f32(&[4], vec![1.0, 0.5, -0.5, 1.0]));
        let ga = exact.execute("mnist_bwd_c4", &refs(&inp)).unwrap();
        let gb = fast.execute("mnist_bwd_c4", &refs(&inp)).unwrap();
        for (ta, tb) in ga.iter().zip(&gb) {
            assert_eq!(ta.as_f32().unwrap(), tb.as_f32().unwrap());
        }
    }

    #[test]
    fn mnist_backward_matches_finite_difference() {
        let cap = 4;
        let params = ParamStore::init(&mnist_rules(), 11);
        let mut rng = Pcg32::seeded(5);
        let x: Vec<f32> = (0..cap * MNIST_IN).map(|_| rng.normal() as f32).collect();
        let actions: Vec<i32> = (0..cap).map(|_| rng.below(10) as i32).collect();
        let w = vec![0.7f32, -0.3, 0.0, 1.1];

        let loss_of = |p: &ParamStore| -> f64 {
            let mut inp = p.as_inputs();
            inp.push(HostTensor::f32(&[cap, MNIST_IN], x.clone()));
            inp.push(HostTensor::i32(&[cap], actions.clone()));
            inp.push(HostTensor::f32(&[cap], w.clone()));
            mnist_backward(&refs(&inp), cap).unwrap()[0].as_f32().unwrap()[0] as f64
        };

        let mut inp = params.as_inputs();
        inp.push(HostTensor::f32(&[cap, MNIST_IN], x.clone()));
        inp.push(HostTensor::i32(&[cap], actions.clone()));
        inp.push(HostTensor::f32(&[cap], w.clone()));
        let out = mnist_backward(&refs(&inp), cap).unwrap();

        // probe a few coordinates of each gradient tensor
        for (ti, n_probe) in [(1usize, 3usize), (2, 2), (3, 3), (4, 2)] {
            let g = out[ti].as_f32().unwrap();
            for probe in 0..n_probe {
                let idx = (probe * 131) % g.len();
                let eps = 1e-3f32;
                let mut pp = params.clone();
                pp.tensor_mut(ti - 1)[idx] += eps;
                let mut pm = params.clone();
                pm.tensor_mut(ti - 1)[idx] -= eps;
                let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * eps as f64);
                assert!(
                    (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "tensor {ti} idx {idx}: fd {fd} vs analytic {}",
                    g[idx]
                );
            }
        }
    }

    #[test]
    fn zero_weight_rows_do_not_contribute() {
        let cap = 8;
        let params = ParamStore::init(&mnist_rules(), 2);
        let mut rng = Pcg32::seeded(9);
        let x: Vec<f32> = (0..cap * MNIST_IN).map(|_| rng.normal() as f32).collect();
        let actions: Vec<i32> = (0..cap).map(|_| rng.below(10) as i32).collect();
        let mut w = vec![0.0f32; cap];
        w[2] = 1.0;

        let run = |x: &[f32], actions: &[i32], w: &[f32], cap: usize| {
            let mut inp = params.as_inputs();
            inp.push(HostTensor::f32(&[cap, MNIST_IN], x.to_vec()));
            inp.push(HostTensor::i32(&[cap], actions.to_vec()));
            inp.push(HostTensor::f32(&[cap], w.to_vec()));
            mnist_backward(&refs(&inp), cap).unwrap()
        };
        let full = run(&x, &actions, &w, cap);
        // same single sample packed alone into the cap-4 bucket
        let mut xs = vec![0.0f32; 4 * MNIST_IN];
        xs[..MNIST_IN].copy_from_slice(&x[2 * MNIST_IN..3 * MNIST_IN]);
        let small = run(&xs, &[actions[2], 0, 0, 0], &[1.0, 0.0, 0.0, 0.0], 4);
        for (a, b) in full.iter().zip(&small) {
            assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        }
    }

    #[test]
    fn rev_rollout_is_deterministic_and_masked() {
        let params = ParamStore::init(&rev_rules(), 4);
        let mut prompt = vec![REV_PAD as i32; REV_BATCH * REV_HMAX];
        for (i, t) in prompt.iter_mut().enumerate() {
            if i % REV_HMAX >= REV_HMAX - 4 {
                *t = (i % 2) as i32;
            }
        }
        let mk = || {
            let mut inp = params.as_inputs();
            inp.push(HostTensor::i32(&[REV_BATCH, REV_HMAX], prompt.clone()));
            inp.push(HostTensor::scalar_i32(4));
            inp.push(HostTensor::scalar_i32(2));
            inp.push(HostTensor::scalar_i32(1234));
            rev_rollout(&refs(&inp)).unwrap()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a[0].as_i32().unwrap(), b[0].as_i32().unwrap());
        assert_eq!(a[1].as_f32().unwrap(), b[1].as_f32().unwrap());
        // sampled tokens live in the active vocab m=2
        for ep in 0..REV_BATCH {
            for j in 0..4 {
                let t = a[0].as_i32().unwrap()[ep * REV_HMAX + j];
                assert!((0..2).contains(&t), "token {t}");
            }
        }
    }

    #[test]
    fn rev_backward_matches_finite_difference() {
        let params = ParamStore::init(&rev_rules(), 8);
        let cap = 4;
        let h = 3;
        let mut rng = Pcg32::seeded(12);
        let mut prompt = vec![REV_PAD as i32; cap * REV_HMAX];
        let mut actions = vec![0i32; cap * REV_HMAX];
        let mut w = vec![0.0f32; cap * REV_HMAX];
        for ep in 0..cap {
            for j in 0..h {
                prompt[ep * REV_HMAX + (REV_HMAX - h) + j] = rng.below(2) as i32;
                actions[ep * REV_HMAX + j] = rng.below(2) as i32;
                w[ep * REV_HMAX + j] = rng.normal() as f32;
            }
        }
        let loss_of = |p: &ParamStore| -> f64 {
            let mut inp = p.as_inputs();
            inp.push(HostTensor::i32(&[cap, REV_HMAX], prompt.clone()));
            inp.push(HostTensor::i32(&[cap, REV_HMAX], actions.clone()));
            inp.push(HostTensor::f32(&[cap, REV_HMAX], w.clone()));
            inp.push(HostTensor::scalar_i32(h as i32));
            inp.push(HostTensor::scalar_i32(2));
            rev_backward(&refs(&inp), cap).unwrap()[0].as_f32().unwrap()[0] as f64
        };
        let mut inp = params.as_inputs();
        inp.push(HostTensor::i32(&[cap, REV_HMAX], prompt.clone()));
        inp.push(HostTensor::i32(&[cap, REV_HMAX], actions.clone()));
        inp.push(HostTensor::f32(&[cap, REV_HMAX], w.clone()));
        inp.push(HostTensor::scalar_i32(h as i32));
        inp.push(HostTensor::scalar_i32(2));
        let out = rev_backward(&refs(&inp), cap).unwrap();

        for (ti, n_probe) in [(1usize, 4usize), (2, 4)] {
            let g = out[ti].as_f32().unwrap();
            for probe in 0..n_probe {
                let idx = (probe * 17) % g.len();
                let eps = 1e-3f32;
                let mut pp = params.clone();
                pp.tensor_mut(ti - 1)[idx] += eps;
                let mut pm = params.clone();
                pm.tensor_mut(ti - 1)[idx] -= eps;
                let fd = (loss_of(&pp) - loss_of(&pm)) / (2.0 * eps as f64);
                assert!(
                    (fd - g[idx] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "tensor {ti} idx {idx}: fd {fd} vs analytic {}",
                    g[idx]
                );
            }
        }
    }
}
