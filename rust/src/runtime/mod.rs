//! Runtime layer: execution engine, artifact manifest, host tensors, the
//! native kernel layer (`kernels` — blocked GEMM over packed weight
//! panels, fused epilogues, lane-reduced reductions), and the tensor
//! arena that keeps the gated hot path allocation-free.
//!
//! Two interchangeable backends sit behind one artifact namespace: the
//! PJRT engine over HLO-text artifacts built by `make artifacts` (python
//! is never on the request path), and the pure-Rust native testbed
//! (`Engine::native_testbed()`) that implements the same contract with
//! row-independent, bit-deterministic math -- the substrate the sharded
//! coordinator's determinism tests run on.

pub mod engine;
pub mod kernels;
pub mod manifest;
pub mod native;
pub mod tensor;

pub use engine::Engine;
pub use kernels::WeightPack;
pub use manifest::{ArtifactSig, Constants, DType, InitKind, InitRule, Manifest, TensorSig};
pub use native::NativeTestbed;
pub use tensor::{arena_stats, ArenaStats, HostTensor, TensorArena};
