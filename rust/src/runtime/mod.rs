//! Runtime layer: PJRT client wrapper, artifact manifest, host tensors.
//!
//! Loads the HLO-text artifacts built once by `make artifacts` (python is
//! never on the request path) and executes them on the CPU PJRT client.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{ArtifactSig, Constants, DType, InitKind, InitRule, Manifest, TensorSig};
pub use tensor::HostTensor;
