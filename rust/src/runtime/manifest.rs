//! `artifacts/manifest.json` — the contract between `aot.py` (L2) and the
//! Rust runtime. Parsed with the in-repo JSON substrate; every accessor
//! fails loudly on schema drift so a stale artifact set cannot be run.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::utils::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum InitKind {
    Normal { scale: f64 },
    Zeros,
    Ones,
}

#[derive(Debug, Clone)]
pub struct InitRule {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: InitKind,
}

impl InitRule {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static shape constants pinned at AOT time (see python/compile/config.py).
#[derive(Debug, Clone)]
pub struct Constants {
    pub mnist_batch: usize,
    pub mnist_eval_batch: usize,
    pub mnist_actions: usize,
    pub mnist_in: usize,
    pub mnist_bwd_caps: Vec<usize>,
    /// capacities with compiled shard-sized forward artifacts
    /// (`mnist_fwd_c{cap}`); empty = forward sharding unavailable.
    /// Optional in manifest.json for compatibility with older artifact
    /// sets.
    pub mnist_fwd_caps: Vec<usize>,
    pub rev_batch: usize,
    /// compiled reversal shape sets (h_max values, ascending)
    pub rev_sets: Vec<usize>,
    pub h_max: usize,
    pub vocab: usize,
    pub pad: usize,
    pub rev_bwd_caps: Vec<usize>,
    pub neg_inf: f64,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub constants: Constants,
    /// model name -> parameter init rules in artifact-argument order
    pub models: BTreeMap<String, Vec<InitRule>>,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

fn sig_from(j: &Json) -> Result<TensorSig> {
    let name = j.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("sig: name"))?;
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("sig: shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("sig: bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::parse(
        j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("sig: dtype"))?,
    )?;
    Ok(TensorSig { name: name.to_string(), shape, dtype })
}

fn usize_arr(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("constants: {key}"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("constants: {key} entry")))
        .collect()
}

fn usize_of(j: &Json, key: &str) -> Result<usize> {
    j.get(key).and_then(Json::as_usize).ok_or_else(|| anyhow!("constants: {key}"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let c = j.get("constants").ok_or_else(|| anyhow!("missing constants"))?;
        let constants = Constants {
            mnist_batch: usize_of(c, "mnist_batch")?,
            mnist_eval_batch: usize_of(c, "mnist_eval_batch")?,
            mnist_actions: usize_of(c, "mnist_actions")?,
            mnist_in: usize_of(c, "mnist_in")?,
            mnist_bwd_caps: usize_arr(c, "mnist_bwd_caps")?,
            mnist_fwd_caps: usize_arr(c, "mnist_fwd_caps").unwrap_or_default(),
            rev_batch: usize_of(c, "rev_batch")?,
            rev_sets: usize_arr(c, "rev_sets")?,
            h_max: usize_of(c, "h_max")?,
            vocab: usize_of(c, "vocab")?,
            pad: usize_of(c, "pad")?,
            rev_bwd_caps: usize_arr(c, "rev_bwd_caps")?,
            neg_inf: c.get("neg_inf").and_then(Json::as_f64).ok_or_else(|| anyhow!("neg_inf"))?,
        };

        let mut models = BTreeMap::new();
        let jm = j.get("models").and_then(Json::as_obj).ok_or_else(|| anyhow!("models"))?;
        for (mname, mv) in jm {
            let params = mv
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("model {mname}: params"))?;
            let mut rules = Vec::new();
            for p in params {
                let name =
                    p.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("param name"))?;
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("param shape"))?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow!("param dim")))
                    .collect::<Result<Vec<_>>>()?;
                let kind = match p.get("kind").and_then(Json::as_str) {
                    Some("normal") => InitKind::Normal {
                        scale: p
                            .get("scale")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("normal needs scale"))?,
                    },
                    Some("zeros") => InitKind::Zeros,
                    Some("ones") => InitKind::Ones,
                    other => bail!("param {name}: bad init kind {other:?}"),
                };
                rules.push(InitRule { name: name.to_string(), shape, kind });
            }
            models.insert(mname.clone(), rules);
        }

        let mut artifacts = BTreeMap::new();
        let ja = j.get("artifacts").and_then(Json::as_obj).ok_or_else(|| anyhow!("artifacts"))?;
        for (aname, av) in ja {
            let file =
                av.get("file").and_then(Json::as_str).ok_or_else(|| anyhow!("artifact file"))?;
            let inputs = av
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact inputs"))?
                .iter()
                .map(sig_from)
                .collect::<Result<Vec<_>>>()?;
            let outputs = av
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact outputs"))?
                .iter()
                .map(sig_from)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                aname.clone(),
                ArtifactSig { name: aname.clone(), file: file.to_string(), inputs, outputs },
            );
        }
        Ok(Manifest { constants, models, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (run `make artifacts`)"))
    }

    pub fn model(&self, name: &str) -> Result<&[InitRule]> {
        self.models
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }

    /// Number of parameter tensors of a model (= leading artifact inputs).
    pub fn n_params(&self, model: &str) -> usize {
        self.models.get(model).map(|v| v.len()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "constants": {"mnist_batch": 100, "mnist_eval_batch": 500,
        "mnist_actions": 10, "mnist_in": 784, "mnist_bwd_caps": [4, 100],
        "rev_batch": 100, "rev_sets": [16, 32], "h_max": 32, "vocab": 64, "pad": 64,
        "rev_bwd_caps": [13], "neg_inf": -1e+30},
      "models": {"mnist": {"params": [
        {"name": "w1", "shape": [784, 100], "kind": "normal", "scale": 0.05},
        {"name": "b1", "shape": [100], "kind": "zeros"}]}},
      "artifacts": {"mnist_fwd": {"file": "mnist_fwd.hlo.txt",
        "inputs": [{"name": "w1", "shape": [784, 100], "dtype": "f32"}],
        "outputs": [{"name": "logp", "shape": [100, 10], "dtype": "f32"}]}}
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.constants.mnist_batch, 100);
        assert_eq!(m.constants.neg_inf, -1e30);
        assert_eq!(m.constants.mnist_bwd_caps, vec![4, 100]);
        // optional key absent -> forward sharding disabled
        assert!(m.constants.mnist_fwd_caps.is_empty());
        let rules = m.model("mnist").unwrap();
        assert_eq!(rules.len(), 2);
        assert_eq!(rules[0].kind, InitKind::Normal { scale: 0.05 });
        assert_eq!(rules[0].numel(), 78400);
        let a = m.artifact("mnist_fwd").unwrap();
        assert_eq!(a.inputs[0].dtype, DType::F32);
        assert_eq!(a.outputs[0].shape, vec![100, 10]);
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(MINI).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let bad = MINI.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
