//! Native kernel layer: the shared compute primitives behind both native
//! artifact families (DESIGN.md §9).
//!
//! Everything the native backend used to do with per-sample scalar loops
//! routes through here: a cache-blocked GEMM microkernel over packed
//! weight panels with fused epilogues (bias+tanh, bias+noise+log-softmax),
//! a single-pass logsumexp, the gather-mix kernel behind the reversal
//! pointer-attention logits, a batched softmax-Jacobian, and the
//! elementwise update kernels (`axpy`, `outer_acc`) the backwards scatter
//! through.
//!
//! **Determinism rule (the redefined contract).** Every reduction in this
//! module accumulates element `i` into lane `i % LANES` in ascending
//! index order and combines the lanes with the fixed tree
//! `(l0 + l1) + (l2 + l3)` (`utils::math::lane_reduce` — the same scheme
//! `utils::math::dot` uses). The reduction order is therefore a pure
//! function of the operand *shapes*, never of worker count, thread,
//! blocking, or batching: computing a row alone, in a shard, or in a
//! padded capacity call yields bit-identical values, which is what keeps
//! the gated_e2e worker-invariance guarantee intact on these kernels.
//! Epilogue terms enter in a fixed order too: lane tree, then bias, then
//! optional noise, all in f64, cast to f32 once at the end.
//!
//! **Pack cache.** GEMM weights are consumed as [`WeightPack`]s —
//! row-panel-contiguous layouts built **once per optimizer step** beside
//! parameter marshalling (`ParamStore::marshal_into`) and shared by
//! reference (an `Arc` inside the marshalled `HostTensor`) across every
//! forward shard and backward chunk of the step. The pack is keyed by the
//! `ParamStore` version so a stale pack is detectable in debug builds;
//! [`packs_built`] counts builds so tests can assert exactly one pack per
//! weight matrix per step regardless of worker count.
//!
//! **SIMD lowering (DESIGN.md §13).** With `--features simd` on x86_64,
//! the microkernel and the elementwise epilogue loops dispatch at runtime
//! ([`simd_enabled`]) onto the AVX2 twins in `utils::simd`, which perform
//! the identical per-lane operations and the identical `(l0+l1)+(l2+l3)`
//! tree — the feature changes speed, never bits. Every dispatched kernel
//! keeps a public `*_scalar` twin, and `rust/tests/simd_equivalence.rs`
//! locks bitwise equality between the two across ragged shapes. Cache
//! blocking is a [`KernelTune`] (shape-keyed via [`tune_for`], sweepable
//! via `cargo bench --bench kernels -- --autotune`) that may vary **only
//! the tile traversal order**, never any accumulation order. The
//! `*_f32fast` variants are a separate, explicitly **non-golden** method
//! axis (f32 accumulators for the screen/forward tier only).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::utils::math::{lane_reduce, lane_reduce_f32, LANES};

/// Columns per packed weight panel (the register-tile width of the GEMM
/// microkernel). With `LANES` f64 accumulators per column the inner loop
/// keeps `PANEL * LANES = 16` accumulators live — sized for the vector
/// register file, and fixed so the packed layout is a pure function of
/// the weight shape.
pub const PANEL: usize = 4;

/// Global count of weight-pack builds (fresh packs and in-place refills).
/// Tests assert the once-per-step pack contract against deltas of this
/// counter; it is not used for control flow.
static PACKS_BUILT: AtomicU64 = AtomicU64::new(0);

pub fn packs_built() -> u64 {
    PACKS_BUILT.load(Ordering::Relaxed)
}

/// A `[k, n]` weight matrix repacked row-panel-contiguous for the GEMM
/// microkernel: panel `p` holds columns `[p*PANEL, (p+1)*PANEL)` for all
/// `k` rows contiguously (`data[(p*k + kk)*PANEL + j] = w[kk*n + p*PANEL
/// + j]`, zero-padded past column `n`). Streaming a panel touches one
/// contiguous `k * PANEL` block per output tile instead of `PANEL`
/// strided columns.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightPack {
    k: usize,
    n: usize,
    version: u64,
    data: Vec<f32>,
}

impl WeightPack {
    pub fn new(w: &[f32], k: usize, n: usize, version: u64) -> WeightPack {
        // loud at the boundary: a short slice must not reach the panel
        // loop (same contract `refill` enforces)
        assert_eq!(w.len(), k * n, "weight pack shape mismatch");
        let panels = n.div_ceil(PANEL);
        let mut pack = WeightPack { k, n, version, data: vec![0.0; panels * k * PANEL] };
        pack.refill(w, version);
        pack
    }

    /// Refresh the pack in place from updated weights (same shape). This
    /// is the steady-state per-step path: no allocation, one pass over
    /// the matrix, counted in [`packs_built`].
    pub fn refill(&mut self, w: &[f32], version: u64) {
        assert_eq!(w.len(), self.k * self.n, "weight pack shape mismatch");
        PACKS_BUILT.fetch_add(1, Ordering::Relaxed);
        self.version = version;
        let (k, n) = (self.k, self.n);
        for p in 0..n.div_ceil(PANEL) {
            let base = p * k * PANEL;
            for kk in 0..k {
                for j in 0..PANEL {
                    let col = p * PANEL + j;
                    self.data[base + kk * PANEL + j] =
                        if col < n { w[kk * n + col] } else { 0.0 };
                }
            }
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// The `ParamStore` version this pack was built from (stale-pack
    /// debug checks).
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn n_panels(&self) -> usize {
        self.n.div_ceil(PANEL)
    }

    fn panel(&self, p: usize) -> &[f32] {
        &self.data[p * self.k * PANEL..(p + 1) * self.k * PANEL]
    }

    /// Reconstruct the row-major matrix (tests / debugging).
    pub fn unpack(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.k * self.n];
        for p in 0..self.n_panels() {
            let panel = self.panel(p);
            for kk in 0..self.k {
                for j in 0..PANEL {
                    let col = p * PANEL + j;
                    if col < self.n {
                        w[kk * self.n + col] = panel[kk * PANEL + j];
                    }
                }
            }
        }
        w
    }
}

/// Whether kernel calls lower onto the AVX2 backend: compiled in by the
/// `simd` cargo feature on x86_64 and confirmed by one-time runtime CPU
/// detection. Purely a speed switch — the lowering is bit-identical by
/// construction (DESIGN.md §13) and locked by
/// `rust/tests/simd_equivalence.rs`.
#[inline]
pub fn simd_enabled() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        crate::utils::simd::avx2()
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// Cache-blocking plan for the GEMM traversal. A tune may vary **only**
/// which (row, panel) tile executes when; `PANEL` (the packed layout) and
/// `LANES` (the reduction tree) are frozen, and every tile is computed
/// identically under every tune — so all tunes are bitwise
/// interchangeable (locked by the tune-invariance tests) and tuning sits
/// entirely outside the golden contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelTune {
    /// Rows per traversal block (>= 1).
    pub row_block: usize,
    /// Weight panels per traversal block (>= 1).
    pub panel_block: usize,
}

impl KernelTune {
    /// Compile-time default: a block streams `panel_block * k * PANEL`
    /// packed weights against `row_block` input rows — sized to keep the
    /// working set in L2 for the repo's shapes on typical x86_64 parts.
    pub const DEFAULT: KernelTune = KernelTune { row_block: 8, panel_block: 16 };
}

/// Shape-keyed tune lookup: the `(k, n)` entry from the optional tune
/// file named by the `KONDO_KERNEL_TUNE` env var (emitted by `cargo bench
/// --bench kernels -- --autotune`, read once per process), else
/// [`KernelTune::DEFAULT`].
pub fn tune_for(k: usize, n: usize) -> KernelTune {
    static TABLE: OnceLock<BTreeMap<(usize, usize), KernelTune>> = OnceLock::new();
    let table = TABLE.get_or_init(|| {
        std::env::var("KONDO_KERNEL_TUNE")
            .ok()
            .and_then(|path| std::fs::read_to_string(path).ok())
            .map(|text| parse_tune_file(&text))
            .unwrap_or_default()
    });
    table.get(&(k, n)).copied().unwrap_or(KernelTune::DEFAULT)
}

/// Parse a tune file: one `k n row_block panel_block` line per shape,
/// `#` starts a comment. Lines with zero blocks (a traversal block must
/// make progress) or the wrong field count are ignored. Pure, so tests
/// cover it without touching process-global env state.
pub fn parse_tune_file(text: &str) -> BTreeMap<(usize, usize), KernelTune> {
    let mut table = BTreeMap::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<usize> =
            line.split_whitespace().filter_map(|t| t.parse().ok()).collect();
        if fields.len() == 4
            && line.split_whitespace().count() == 4
            && fields[2] >= 1
            && fields[3] >= 1
        {
            table.insert(
                (fields[0], fields[1]),
                KernelTune { row_block: fields[2], panel_block: fields[3] },
            );
        }
    }
    table
}

/// One register tile of the microkernel: `acc[j][l]` accumulates
/// `x[kk] * panel[kk][j]` for `kk ≡ l (mod LANES)`, ascending — the fixed
/// lane assignment of the determinism rule.
#[inline]
fn panel_dot(xr: &[f32], panel: &[f32], k: usize, acc: &mut [[f64; LANES]; PANEL]) {
    *acc = [[0.0; LANES]; PANEL];
    let chunks = k / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let xv = xr[base + l] as f64;
            let prow = &panel[(base + l) * PANEL..(base + l + 1) * PANEL];
            for (j, &pv) in prow.iter().enumerate() {
                acc[j][l] += xv * pv as f64;
            }
        }
    }
    let base = chunks * LANES;
    for l in 0..(k - base) {
        let xv = xr[base + l] as f64;
        let prow = &panel[(base + l) * PANEL..(base + l + 1) * PANEL];
        for (j, &pv) in prow.iter().enumerate() {
            acc[j][l] += xv * pv as f64;
        }
    }
}

/// Column sums for one (row, panel) tile: `sums[j]` = the lane-tree sum
/// of `x[kk] * panel[kk][j]`. The single dispatch point between the
/// scalar microkernel and its AVX2 twin — both produce the post-tree
/// values, so every epilogue downstream is shared code.
#[inline]
fn panel_sums(xr: &[f32], panel: &[f32], k: usize, simd: bool, sums: &mut [f64; PANEL]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        // safety: `simd` is only true after runtime detection (see
        // simd_enabled / the *_scalar twins, which pass false)
        unsafe { crate::utils::simd::panel_dot_avx2(xr, panel, k, sums) };
        return;
    }
    let _ = simd;
    let mut acc = [[0.0f64; LANES]; PANEL];
    panel_dot(xr, panel, k, &mut acc);
    for (s, accj) in sums.iter_mut().zip(acc.iter()) {
        *s = lane_reduce(accj);
    }
}

/// f32-accumulating tile for the **non-golden** fast path: same lane
/// assignment and tree as [`panel_dot`] + `lane_reduce`, with f32
/// accumulators throughout.
#[inline]
fn panel_sums_f32(xr: &[f32], panel: &[f32], k: usize, sums: &mut [f32; PANEL]) {
    let mut acc = [[0.0f32; LANES]; PANEL];
    let chunks = k / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let xv = xr[base + l];
            let prow = &panel[(base + l) * PANEL..(base + l + 1) * PANEL];
            for (j, &pv) in prow.iter().enumerate() {
                acc[j][l] += xv * pv;
            }
        }
    }
    let base = chunks * LANES;
    for l in 0..(k - base) {
        let xv = xr[base + l];
        let prow = &panel[(base + l) * PANEL..(base + l + 1) * PANEL];
        for (j, &pv) in prow.iter().enumerate() {
            acc[j][l] += xv * pv;
        }
    }
    for (s, accj) in sums.iter_mut().zip(acc.iter()) {
        *s = lane_reduce_f32(accj);
    }
}

/// `xs[i] -= s` with the subtract (an exact elementwise f32 op)
/// optionally vectorized; bitwise identical either way.
#[inline]
fn sub_scalar_inplace(xs: &mut [f32], s: f32, simd: bool) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        unsafe { crate::utils::simd::sub_scalar_inplace_avx2(xs, s) };
        return;
    }
    let _ = simd;
    for x in xs.iter_mut() {
        *x -= s;
    }
}

/// `out[i] = src[i] - s`, same dispatch.
#[inline]
fn sub_scalar_into(src: &[f32], s: f32, simd: bool, out: &mut [f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        unsafe { crate::utils::simd::sub_scalar_avx2(src, s, out) };
        return;
    }
    let _ = simd;
    for (o, &v) in out.iter_mut().zip(src) {
        *o = v - s;
    }
}

/// Blocked GEMM with fused bias + tanh epilogue:
/// `out[r, c] = tanh(bias[c] + sum_k x[r, k] * W[k, c])`, `x` row-major
/// `[rows, k]`, `out` `[rows, n]`. Row `r` of the output is a pure
/// function of row `r` of `x` and the pack — batching rows changes
/// nothing (row independence), and the per-element reduction is the
/// fixed lane tree.
pub fn gemm_bias_tanh(x: &[f32], rows: usize, w: &WeightPack, bias: &[f32], out: &mut [f32]) {
    gemm_bias_tanh_impl(x, rows, w, bias, out, simd_enabled(), tune_for(w.k, w.n));
}

/// Scalar twin of [`gemm_bias_tanh`] (bitwise identical; equivalence
/// locked by `rust/tests/simd_equivalence.rs`).
pub fn gemm_bias_tanh_scalar(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    out: &mut [f32],
) {
    gemm_bias_tanh_impl(x, rows, w, bias, out, false, tune_for(w.k, w.n));
}

/// [`gemm_bias_tanh`] under an explicit tune — the autotune sweep entry
/// point. Bitwise identical to every other tune.
pub fn gemm_bias_tanh_with(
    tune: KernelTune,
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    out: &mut [f32],
) {
    gemm_bias_tanh_impl(x, rows, w, bias, out, simd_enabled(), tune);
}

fn gemm_bias_tanh_impl(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    out: &mut [f32],
    simd: bool,
    t: KernelTune,
) {
    let (k, n) = (w.k, w.n);
    debug_assert!(x.len() >= rows * k && out.len() >= rows * n && bias.len() == n);
    let (rb, pb) = (t.row_block.max(1), t.panel_block.max(1));
    let np = w.n_panels();
    let mut sums = [0.0f64; PANEL];
    for r0 in (0..rows).step_by(rb) {
        let r1 = (r0 + rb).min(rows);
        for p0 in (0..np).step_by(pb) {
            let p1 = (p0 + pb).min(np);
            for r in r0..r1 {
                let xr = &x[r * k..(r + 1) * k];
                let orow = &mut out[r * n..(r + 1) * n];
                for p in p0..p1 {
                    panel_sums(xr, w.panel(p), k, simd, &mut sums);
                    let j0 = p * PANEL;
                    for j in 0..PANEL.min(n - j0) {
                        orow[j0 + j] = (bias[j0 + j] as f64 + sums[j]).tanh() as f32;
                    }
                }
            }
        }
    }
}

/// **Non-golden** f32-fast twin of [`gemm_bias_tanh`]: f32 accumulators,
/// f32 epilogue. For the screen/forward tier only — never the gated
/// backward, never anything a checkpoint or golden compares (DESIGN.md
/// §13). Deterministic (shape-keyed order), just not bit-comparable to
/// the golden kernel.
pub fn gemm_bias_tanh_f32fast(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    debug_assert!(x.len() >= rows * k && out.len() >= rows * n && bias.len() == n);
    let mut sums = [0.0f32; PANEL];
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for p in 0..w.n_panels() {
            panel_sums_f32(xr, w.panel(p), k, &mut sums);
            let j0 = p * PANEL;
            for j in 0..PANEL.min(n - j0) {
                orow[j0 + j] = (bias[j0 + j] + sums[j]).tanh();
            }
        }
    }
}

/// Blocked GEMM with fused bias (+ optional per-row additive noise) +
/// log-softmax epilogue: `logits[r, c] = bias[c] + sum_k x[r, k]*W[k, c]
/// (+ noise[r, c])`, `out[r, c] = logits[r, c] - logsumexp(logits[r, :])`.
/// Logits are staged directly in `out` (no scratch, no allocation), then
/// normalized row-wise in a second pass — which is what lets the GEMM
/// traversal be arbitrarily blocked without touching the value.
pub fn gemm_bias_logsoftmax(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    noise: Option<&[f32]>,
    out: &mut [f32],
) {
    gemm_bias_logsoftmax_impl(x, rows, w, bias, noise, out, simd_enabled(), tune_for(w.k, w.n));
}

/// Scalar twin of [`gemm_bias_logsoftmax`] (bitwise identical).
pub fn gemm_bias_logsoftmax_scalar(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    noise: Option<&[f32]>,
    out: &mut [f32],
) {
    gemm_bias_logsoftmax_impl(x, rows, w, bias, noise, out, false, tune_for(w.k, w.n));
}

/// [`gemm_bias_logsoftmax`] under an explicit tune (autotune sweeps).
pub fn gemm_bias_logsoftmax_with(
    tune: KernelTune,
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    noise: Option<&[f32]>,
    out: &mut [f32],
) {
    gemm_bias_logsoftmax_impl(x, rows, w, bias, noise, out, simd_enabled(), tune);
}

#[allow(clippy::too_many_arguments)]
fn gemm_bias_logsoftmax_impl(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    noise: Option<&[f32]>,
    out: &mut [f32],
    simd: bool,
    t: KernelTune,
) {
    let (k, n) = (w.k, w.n);
    debug_assert!(x.len() >= rows * k && out.len() >= rows * n && bias.len() == n);
    let (rb, pb) = (t.row_block.max(1), t.panel_block.max(1));
    let np = w.n_panels();
    let mut sums = [0.0f64; PANEL];
    // pass 1: stage the logits tile by tile — tiles are disjoint and each
    // is computed identically, so any traversal order yields the same bits
    for r0 in (0..rows).step_by(rb) {
        let r1 = (r0 + rb).min(rows);
        for p0 in (0..np).step_by(pb) {
            let p1 = (p0 + pb).min(np);
            for r in r0..r1 {
                let xr = &x[r * k..(r + 1) * k];
                let orow = &mut out[r * n..(r + 1) * n];
                for p in p0..p1 {
                    panel_sums(xr, w.panel(p), k, simd, &mut sums);
                    let j0 = p * PANEL;
                    for j in 0..PANEL.min(n - j0) {
                        let c = j0 + j;
                        // fixed epilogue order: lane tree, bias, then noise
                        let mut v = bias[c] as f64 + sums[j];
                        if let Some(nz) = noise {
                            v += nz[r * n + c] as f64;
                        }
                        orow[c] = v as f32;
                    }
                }
            }
        }
    }
    // pass 2: row-wise normalization. logsumexp stays the sequential
    // scalar kernel (its running max/rescale is order-critical); only the
    // exact elementwise subtract is vectorized.
    for r in 0..rows {
        let orow = &mut out[r * n..(r + 1) * n];
        let lse = logsumexp_1pass(orow);
        sub_scalar_inplace(orow, lse, simd);
    }
}

/// **Non-golden** f32-fast twin of [`gemm_bias_logsoftmax`]: f32
/// accumulators and epilogue (the logsumexp itself keeps its f64
/// internals — it is cheap and shared). Screen/forward tier only.
pub fn gemm_bias_logsoftmax_f32fast(
    x: &[f32],
    rows: usize,
    w: &WeightPack,
    bias: &[f32],
    noise: Option<&[f32]>,
    out: &mut [f32],
) {
    let (k, n) = (w.k, w.n);
    debug_assert!(x.len() >= rows * k && out.len() >= rows * n && bias.len() == n);
    let mut sums = [0.0f32; PANEL];
    for r in 0..rows {
        let xr = &x[r * k..(r + 1) * k];
        let orow = &mut out[r * n..(r + 1) * n];
        for p in 0..w.n_panels() {
            panel_sums_f32(xr, w.panel(p), k, &mut sums);
            let j0 = p * PANEL;
            for j in 0..PANEL.min(n - j0) {
                let c = j0 + j;
                let mut v = bias[c] + sums[j];
                if let Some(nz) = noise {
                    v += nz[r * n + c];
                }
                orow[c] = v;
            }
        }
        let lse = logsumexp_1pass(orow);
        for o in orow.iter_mut() {
            *o -= lse;
        }
    }
}

/// Single-pass logsumexp: one sweep maintaining the running max `m` and
/// the rescaled sum `s = sum exp(x_i - m)` (when a new max arrives the
/// sum is rescaled by `exp(m_old - m_new)`). f64-accumulated, sequential
/// in index order — a pure function of the row.
pub fn logsumexp_1pass(xs: &[f32]) -> f32 {
    let mut m = f64::NEG_INFINITY;
    let mut s = 0.0f64;
    for &x in xs {
        let x = x as f64;
        // a -inf term contributes exp(-inf) = 0; skipping it also keeps
        // the -inf - -inf = NaN case out of the running-max update
        if x == f64::NEG_INFINITY {
            continue;
        }
        if x <= m {
            s += (x - m).exp();
        } else {
            // m = -inf gives exp(-inf) = 0 and s starts clean at 1
            s = s * (m - x).exp() + 1.0;
            m = x;
        }
    }
    if !m.is_finite() {
        // empty input or all -inf (fully masked row): the max is the
        // answer, matching utils::math::logsumexp
        return m as f32;
    }
    (m + s.ln()) as f32
}

/// Row-wise softmax: `out[r, :] = exp(x[r, :] - logsumexp(x[r, :]))`.
/// The subtract vectorizes (exact elementwise op); `exp` stays the same
/// scalar libm call on both paths, so the twins are bitwise identical.
pub fn softmax_rows(x: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    softmax_rows_impl(x, rows, n, out, simd_enabled());
}

/// Scalar twin of [`softmax_rows`].
pub fn softmax_rows_scalar(x: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    softmax_rows_impl(x, rows, n, out, false);
}

fn softmax_rows_impl(x: &[f32], rows: usize, n: usize, out: &mut [f32], simd: bool) {
    for r in 0..rows {
        let row = &x[r * n..(r + 1) * n];
        let lse = logsumexp_1pass(row);
        let orow = &mut out[r * n..(r + 1) * n];
        sub_scalar_into(row, lse, simd, orow);
        for o in orow.iter_mut() {
            *o = o.exp();
        }
    }
}

/// Row-wise log-softmax (no GEMM): `out[r, :] = x[r, :] - lse(x[r, :])`.
pub fn log_softmax_rows(x: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    log_softmax_rows_impl(x, rows, n, out, simd_enabled());
}

/// Scalar twin of [`log_softmax_rows`].
pub fn log_softmax_rows_scalar(x: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    log_softmax_rows_impl(x, rows, n, out, false);
}

fn log_softmax_rows_impl(x: &[f32], rows: usize, n: usize, out: &mut [f32], simd: bool) {
    for r in 0..rows {
        let row = &x[r * n..(r + 1) * n];
        let lse = logsumexp_1pass(row);
        let orow = &mut out[r * n..(r + 1) * n];
        sub_scalar_into(row, lse, simd, orow);
    }
}

/// Gather-mix kernel behind the reversal pointer-attention logits:
/// `out[v] = sum_k coef[k] * table[idx[k], v]` for `v < m`, every slot
/// `>= m` set to `fill` (the mask). `acc` is caller scratch (`len >=
/// m * LANES`, stack array on the hot path). Accumulation assigns term
/// `k` to lane `k % LANES`, ascending, then the fixed tree — shapes
/// only, per the determinism rule.
pub fn gather_mix_masked(
    coef: &[f32],
    table: &[f32],
    width: usize,
    idx: &[usize],
    m: usize,
    fill: f32,
    acc: &mut [f64],
    out: &mut [f32],
) {
    gather_mix_masked_impl(coef, table, width, idx, m, fill, acc, out, simd_enabled());
}

/// Scalar twin of [`gather_mix_masked`] (bitwise identical).
#[allow(clippy::too_many_arguments)]
pub fn gather_mix_masked_scalar(
    coef: &[f32],
    table: &[f32],
    width: usize,
    idx: &[usize],
    m: usize,
    fill: f32,
    acc: &mut [f64],
    out: &mut [f32],
) {
    gather_mix_masked_impl(coef, table, width, idx, m, fill, acc, out, false);
}

#[allow(clippy::too_many_arguments)]
fn gather_mix_masked_impl(
    coef: &[f32],
    table: &[f32],
    width: usize,
    idx: &[usize],
    m: usize,
    fill: f32,
    acc: &mut [f64],
    out: &mut [f32],
    simd: bool,
) {
    debug_assert_eq!(coef.len(), idx.len());
    debug_assert!(m <= width && out.len() >= m && acc.len() >= m * LANES);
    out.fill(fill);
    let acc = &mut acc[..m * LANES];
    acc.fill(0.0);
    gather_mix_acc(coef, table, width, idx, m, acc, simd);
    // the final tree lives in exactly one place, shared by both paths
    for v in 0..m {
        let lanes = [
            acc[v * LANES],
            acc[v * LANES + 1],
            acc[v * LANES + 2],
            acc[v * LANES + 3],
        ];
        out[v] = lane_reduce(&lanes) as f32;
    }
}

/// The accumulation phase: term `kk` lands in lane `kk % LANES` of slot
/// `v`, ascending kk — one vector add per 4 terms on the AVX2 path,
/// per-lane identical to the scalar statements.
fn gather_mix_acc(
    coef: &[f32],
    table: &[f32],
    width: usize,
    idx: &[usize],
    m: usize,
    acc: &mut [f64],
    simd: bool,
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        unsafe { crate::utils::simd::gather_mix_acc_avx2(coef, table, width, idx, m, acc) };
        return;
    }
    let _ = simd;
    for (kk, (&c, &t)) in coef.iter().zip(idx).enumerate() {
        let l = kk % LANES;
        let cv = c as f64;
        let trow = &table[t * width..t * width + m];
        for (v, &e) in trow.iter().enumerate() {
            acc[v * LANES + l] += cv * e as f64;
        }
    }
}

/// Row-major matrix-vector product, one lane-reduced dot per row:
/// `out[r] = <w[r, :], v>` in f64.
pub fn matvec_rows(w: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f64]) {
    debug_assert!(w.len() >= rows * cols && v.len() >= cols && out.len() >= rows);
    for r in 0..rows {
        out[r] = crate::utils::math::dot(&w[r * cols..(r + 1) * cols], v);
    }
}

/// `y += a * x`, elementwise f32. No reduction — each element receives
/// exactly one contribution per call, so ordering is owned by the caller
/// (sample order inside a chunk, chunk order across the batch).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Rank-1 accumulate `out[d, :] += x[d] * y[:]` over a row-major `[len(x),
/// len(y)]` buffer — the gradient scatter of both backwards, streaming
/// the output row-contiguously.
pub fn outer_acc(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), x.len() * y.len());
    for (&xv, orow) in x.iter().zip(out.chunks_exact_mut(y.len())) {
        axpy(xv, y, orow);
    }
}

/// Batched softmax-Jacobian: per row `r`,
/// `out[r, :] = alpha[r, :] * (dalpha[r, :] - <alpha[r, :], dalpha[r, :]>)`
/// with the lane-reduced dot. This is the attention backward of the
/// reversal model, applied to all `rows` attention rows in one call.
pub fn softmax_jacobian_rows(
    alpha: &[f32],
    dalpha: &[f32],
    rows: usize,
    n: usize,
    out: &mut [f32],
) {
    softmax_jacobian_rows_impl(alpha, dalpha, rows, n, out, simd_enabled());
}

/// Scalar twin of [`softmax_jacobian_rows`] (bitwise identical).
pub fn softmax_jacobian_rows_scalar(
    alpha: &[f32],
    dalpha: &[f32],
    rows: usize,
    n: usize,
    out: &mut [f32],
) {
    softmax_jacobian_rows_impl(alpha, dalpha, rows, n, out, false);
}

fn softmax_jacobian_rows_impl(
    alpha: &[f32],
    dalpha: &[f32],
    rows: usize,
    n: usize,
    out: &mut [f32],
    simd: bool,
) {
    for r in 0..rows {
        let a = &alpha[r * n..(r + 1) * n];
        let da = &dalpha[r * n..(r + 1) * n];
        // math::dot dispatches on the same runtime condition as `simd`,
        // and its twins are bit-identical, so either call is exact here;
        // the scalar twin pins the scalar path for the equivalence tests
        let d = if simd {
            crate::utils::math::dot(a, da)
        } else {
            crate::utils::math::dot_scalar(a, da)
        } as f32;
        let orow = &mut out[r * n..(r + 1) * n];
        jacobian_row(a, da, d, orow, simd);
    }
}

/// Elementwise `out[i] = a[i] * (da[i] - d)` — exact f32 ops, vectorized
/// 8-wide on the AVX2 path.
#[inline]
fn jacobian_row(a: &[f32], da: &[f32], d: f32, out: &mut [f32], simd: bool) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd {
        unsafe { crate::utils::simd::jacobian_row_avx2(a, da, d, out) };
        return;
    }
    let _ = simd;
    for i in 0..a.len() {
        out[i] = a[i] * (da[i] - d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::math::logsumexp;
    use crate::utils::rng::Pcg32;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Naive row-major reference GEMM (sequential f64 accumulation).
    fn gemm_ref(x: &[f32], rows: usize, w: &[f32], k: usize, n: usize, bias: &[f32]) -> Vec<f64> {
        let mut out = vec![0.0f64; rows * n];
        for r in 0..rows {
            for c in 0..n {
                let mut acc = bias[c] as f64;
                for kk in 0..k {
                    acc += x[r * k + kk] as f64 * w[kk * n + c] as f64;
                }
                out[r * n + c] = acc;
            }
        }
        out
    }

    #[test]
    fn pack_unpack_roundtrip_is_exact() {
        for (k, n) in [(784usize, 32usize), (32, 10), (7, 5), (3, 4), (1, 1)] {
            let w = randv(k * n, 9);
            let pack = WeightPack::new(&w, k, n, 3);
            assert_eq!(pack.unpack(), w, "k={k} n={n}");
            assert_eq!(pack.version(), 3);
        }
    }

    #[test]
    fn refill_updates_in_place_without_resizing() {
        let w = randv(12, 1);
        let mut pack = WeightPack::new(&w, 4, 3, 0);
        let cap = pack.data.capacity();
        let w2 = randv(12, 2);
        pack.refill(&w2, 7);
        assert_eq!(pack.unpack(), w2);
        assert_eq!(pack.version(), 7);
        assert_eq!(pack.data.capacity(), cap);
    }

    #[test]
    fn packs_built_counts_builds_and_refills() {
        // >= not ==: lib tests run in parallel threads and others pack
        // too; the exact once-per-step accounting is locked in isolation
        // by rust/tests/kernel_contracts.rs
        let before = packs_built();
        let w = randv(6, 4);
        let mut pack = WeightPack::new(&w, 2, 3, 0);
        pack.refill(&w, 1);
        assert!(packs_built() - before >= 2);
    }

    #[test]
    fn gemm_bias_tanh_matches_reference() {
        for (rows, k, n) in [(4usize, 784usize, 32usize), (3, 32, 10), (2, 7, 5)] {
            let x = randv(rows * k, 11);
            let w = randv(k * n, 12);
            let bias = randv(n, 13);
            let pack = WeightPack::new(&w, k, n, 0);
            let mut out = vec![0.0f32; rows * n];
            gemm_bias_tanh(&x, rows, &pack, &bias, &mut out);
            let reference = gemm_ref(&x, rows, &w, k, n, &bias);
            for i in 0..rows * n {
                let want = reference[i].tanh();
                assert!(
                    (out[i] as f64 - want).abs() < 1e-5,
                    "({rows},{k},{n})[{i}]: {} vs {}",
                    out[i],
                    want
                );
            }
        }
    }

    #[test]
    fn gemm_rows_are_independent_of_batching() {
        // the row-independence half of the determinism contract: a row
        // computed alone is bit-identical to the same row in a batch
        let (rows, k, n) = (8usize, 33usize, 10usize);
        let x = randv(rows * k, 21);
        let w = randv(k * n, 22);
        let bias = randv(n, 23);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut batched = vec![0.0f32; rows * n];
        gemm_bias_tanh(&x, rows, &pack, &bias, &mut batched);
        for r in 0..rows {
            let mut single = vec![0.0f32; n];
            gemm_bias_tanh(&x[r * k..(r + 1) * k], 1, &pack, &bias, &mut single);
            assert_eq!(&batched[r * n..(r + 1) * n], &single[..], "row {r}");
        }
        // and log-softmax epilogue the same way
        let mut batched_ls = vec![0.0f32; rows * n];
        gemm_bias_logsoftmax(&x, rows, &pack, &bias, None, &mut batched_ls);
        for r in 0..rows {
            let mut single = vec![0.0f32; n];
            gemm_bias_logsoftmax(&x[r * k..(r + 1) * k], 1, &pack, &bias, None, &mut single);
            assert_eq!(&batched_ls[r * n..(r + 1) * n], &single[..], "ls row {r}");
        }
    }

    #[test]
    fn gemm_is_pack_instance_invariant() {
        // fresh pack vs refilled pack vs another fresh pack: bit-identical
        let (rows, k, n) = (2usize, 50usize, 6usize);
        let x = randv(rows * k, 31);
        let w = randv(k * n, 32);
        let bias = vec![0.0f32; n];
        let a = WeightPack::new(&w, k, n, 0);
        let mut b = WeightPack::new(&randv(k * n, 33), k, n, 0);
        b.refill(&w, 1);
        let mut out_a = vec![0.0f32; rows * n];
        let mut out_b = vec![0.0f32; rows * n];
        gemm_bias_tanh(&x, rows, &a, &bias, &mut out_a);
        gemm_bias_tanh(&x, rows, &b, &bias, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn gemm_logsoftmax_rows_normalize_and_take_noise() {
        let (rows, k, n) = (3usize, 20usize, 7usize);
        let x = randv(rows * k, 41);
        let w = randv(k * n, 42);
        let bias = randv(n, 43);
        let noise = randv(rows * n, 44);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut out = vec![0.0f32; rows * n];
        gemm_bias_logsoftmax(&x, rows, &pack, &bias, Some(&noise), &mut out);
        let reference = gemm_ref(&x, rows, &w, k, n, &bias);
        for r in 0..rows {
            let s: f64 = out[r * n..(r + 1) * n].iter().map(|&l| (l as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
            // noise shifts the logits before normalization
            let noisy: Vec<f64> = (0..n)
                .map(|c| reference[r * n + c] + noise[r * n + c] as f64)
                .collect();
            let m = noisy.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let lse = m + noisy.iter().map(|&v| (v - m).exp()).sum::<f64>().ln();
            for c in 0..n {
                assert!((out[r * n + c] as f64 - (noisy[c] - lse)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn logsumexp_1pass_matches_two_pass() {
        let cases: Vec<Vec<f32>> = vec![
            vec![0.0, 0.0, 0.0],
            vec![1000.0, 1000.0],
            vec![-1.0, 2.0, 0.5, -3.0],
            vec![5.0],
            vec![-1.0e30, -1.0e30, 1.5, 0.2], // masked slots
            randv(64, 51),
        ];
        for xs in &cases {
            let one = logsumexp_1pass(xs);
            let two = logsumexp(xs);
            assert!(
                (one - two).abs() < 1e-4 * (1.0 + two.abs()),
                "{one} vs {two} on {xs:?}"
            );
        }
        assert_eq!(
            logsumexp_1pass(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
            f32::NEG_INFINITY
        );
        assert_eq!(logsumexp_1pass(&[]), f32::NEG_INFINITY);
        // a literal -inf mixed into a finite row contributes exactly zero
        assert_eq!(logsumexp_1pass(&[f32::NEG_INFINITY, 5.0]), logsumexp_1pass(&[5.0]));
    }

    #[test]
    fn gather_mix_matches_naive() {
        let width = 8;
        let m = 5;
        let table = randv(9 * width, 61);
        let coef = randv(8, 62);
        let idx: Vec<usize> = vec![3, 0, 8, 1, 7, 2, 5, 4];
        let mut acc = vec![0.0f64; m * LANES];
        let mut out = vec![0.0f32; width];
        gather_mix_masked(&coef, &table, width, &idx, m, -1.0e30, &mut acc, &mut out);
        for v in 0..m {
            let want: f64 = coef
                .iter()
                .zip(&idx)
                .map(|(&c, &t)| c as f64 * table[t * width + v] as f64)
                .sum();
            assert!((out[v] as f64 - want).abs() < 1e-6, "v={v}");
        }
        for v in m..width {
            assert_eq!(out[v], -1.0e30, "masked slot {v}");
        }
    }

    #[test]
    fn softmax_jacobian_matches_naive() {
        let (rows, n) = (8usize, 8usize);
        let alpha_logits = randv(rows * n, 71);
        let mut alpha = vec![0.0f32; rows * n];
        softmax_rows(&alpha_logits, rows, n, &mut alpha);
        let dalpha = randv(rows * n, 72);
        let mut out = vec![0.0f32; rows * n];
        softmax_jacobian_rows(&alpha, &dalpha, rows, n, &mut out);
        for r in 0..rows {
            let dot: f64 = (0..n)
                .map(|i| alpha[r * n + i] as f64 * dalpha[r * n + i] as f64)
                .sum();
            for i in 0..n {
                let want = alpha[r * n + i] as f64 * (dalpha[r * n + i] as f64 - dot);
                assert!(
                    (out[r * n + i] as f64 - want).abs() < 1e-5,
                    "({r},{i}): {} vs {want}",
                    out[r * n + i]
                );
            }
        }
        // softmax rows themselves normalize
        for r in 0..rows {
            let s: f32 = alpha[r * n..(r + 1) * n].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn outer_and_axpy_accumulate() {
        let x = [2.0f32, -1.0];
        let y = [1.0f32, 0.5, 3.0];
        let mut out = vec![1.0f32; 6];
        outer_acc(&x, &y, &mut out);
        assert_eq!(out, vec![3.0, 2.0, 7.0, 0.0, 0.5, -2.0]);
        let mut acc = vec![1.0f32, 1.0, 1.0];
        axpy(2.0, &y, &mut acc);
        assert_eq!(acc, vec![3.0, 2.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "weight pack shape mismatch")]
    fn pack_new_rejects_short_slice() {
        // regression: a short slice must fail loudly at the boundary, not
        // zero-fill or panic deep inside the panel loop
        let w = randv(11, 90); // one short of 3 * 4
        let _ = WeightPack::new(&w, 3, 4, 0);
    }

    #[test]
    #[should_panic(expected = "weight pack shape mismatch")]
    fn pack_new_rejects_long_slice() {
        let w = randv(13, 91);
        let _ = WeightPack::new(&w, 3, 4, 0);
    }

    #[test]
    #[should_panic(expected = "weight pack shape mismatch")]
    fn pack_refill_rejects_wrong_len() {
        let w = randv(12, 92);
        let mut pack = WeightPack::new(&w, 3, 4, 0);
        pack.refill(&w[..8], 1);
    }

    #[test]
    fn tune_file_parses_and_rejects_malformed_lines() {
        let table = parse_tune_file(
            "# shape-keyed tunes\n\
             784 32 16 8   # mnist hidden\n\
             32 10 4 2\n\
             \n\
             1 2 0 4       # zero row_block: rejected\n\
             1 2 4 0       # zero panel_block: rejected\n\
             5 5 5         # wrong field count: rejected\n\
             a b c d       # garbage: rejected\n\
             7 7 7 7 7     # too many fields: rejected\n",
        );
        assert_eq!(
            table.get(&(784, 32)),
            Some(&KernelTune { row_block: 16, panel_block: 8 })
        );
        assert_eq!(table.get(&(32, 10)), Some(&KernelTune { row_block: 4, panel_block: 2 }));
        assert_eq!(table.len(), 2);
    }

    #[test]
    fn tune_for_defaults_without_a_tune_file() {
        // the env var is unset in tests; any shape falls to DEFAULT
        assert_eq!(tune_for(784, 32), KernelTune::DEFAULT);
        assert_eq!(tune_for(1, 1), KernelTune::DEFAULT);
    }

    #[test]
    fn gemm_is_tune_invariant_bitwise() {
        // the KernelTune contract: traversal order may change, bits may
        // not — across degenerate, ragged, and oversized blockings
        let (rows, k, n) = (7usize, 33usize, 11usize);
        let x = randv(rows * k, 101);
        let w = randv(k * n, 102);
        let bias = randv(n, 103);
        let noise = randv(rows * n, 104);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut base_t = vec![0.0f32; rows * n];
        let mut base_ls = vec![0.0f32; rows * n];
        gemm_bias_tanh(&x, rows, &pack, &bias, &mut base_t);
        gemm_bias_logsoftmax(&x, rows, &pack, &bias, Some(&noise), &mut base_ls);
        for tune in [
            KernelTune { row_block: 1, panel_block: 1 },
            KernelTune { row_block: 2, panel_block: 1 },
            KernelTune { row_block: 3, panel_block: 2 },
            KernelTune { row_block: 100, panel_block: 100 },
            KernelTune::DEFAULT,
        ] {
            let mut out_t = vec![0.0f32; rows * n];
            let mut out_ls = vec![0.0f32; rows * n];
            gemm_bias_tanh_with(tune, &x, rows, &pack, &bias, &mut out_t);
            gemm_bias_logsoftmax_with(tune, &x, rows, &pack, &bias, Some(&noise), &mut out_ls);
            assert_eq!(out_t, base_t, "tanh under {tune:?}");
            assert_eq!(out_ls, base_ls, "logsoftmax under {tune:?}");
        }
    }

    #[test]
    fn f32fast_is_deterministic_close_and_distinct_axis() {
        let (rows, k, n) = (3usize, 50usize, 10usize);
        let x = randv(rows * k, 111);
        let w = randv(k * n, 112);
        let bias = randv(n, 113);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut golden = vec![0.0f32; rows * n];
        let mut fast = vec![0.0f32; rows * n];
        let mut fast2 = vec![0.0f32; rows * n];
        gemm_bias_tanh(&x, rows, &pack, &bias, &mut golden);
        gemm_bias_tanh_f32fast(&x, rows, &pack, &bias, &mut fast);
        gemm_bias_tanh_f32fast(&x, rows, &pack, &bias, &mut fast2);
        // deterministic: repeated fast evaluation is bit-identical
        assert_eq!(fast, fast2);
        // close to the golden values — but nothing asserts bit equality:
        // this is the non-golden method axis by design
        for i in 0..rows * n {
            assert!((fast[i] - golden[i]).abs() < 1e-4, "tanh[{i}]");
        }
        let mut golden_ls = vec![0.0f32; rows * n];
        let mut fast_ls = vec![0.0f32; rows * n];
        gemm_bias_logsoftmax(&x, rows, &pack, &bias, None, &mut golden_ls);
        gemm_bias_logsoftmax_f32fast(&x, rows, &pack, &bias, None, &mut fast_ls);
        for r in 0..rows {
            let s: f64 =
                fast_ls[r * n..(r + 1) * n].iter().map(|&l| (l as f64).exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "fast row {r} normalizes");
        }
        for i in 0..rows * n {
            assert!((fast_ls[i] - golden_ls[i]).abs() < 1e-3, "ls[{i}]");
        }
    }

    #[test]
    fn dispatched_kernels_are_bitwise_scalar_twins_smoke() {
        // one in-module smoke of the twin contract; the full ragged-shape
        // property suite lives in rust/tests/simd_equivalence.rs
        let (rows, k, n) = (5usize, 29usize, 10usize);
        let x = randv(rows * k, 121);
        let w = randv(k * n, 122);
        let bias = randv(n, 123);
        let pack = WeightPack::new(&w, k, n, 0);
        let (mut a, mut b) = (vec![0.0f32; rows * n], vec![0.0f32; rows * n]);
        gemm_bias_tanh(&x, rows, &pack, &bias, &mut a);
        gemm_bias_tanh_scalar(&x, rows, &pack, &bias, &mut b);
        assert_eq!(a, b);
        gemm_bias_logsoftmax(&x, rows, &pack, &bias, None, &mut a);
        gemm_bias_logsoftmax_scalar(&x, rows, &pack, &bias, None, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn matvec_rows_is_lane_dot_per_row() {
        let w = randv(3 * 10, 81);
        let v = randv(10, 82);
        let mut out = vec![0.0f64; 3];
        matvec_rows(&w, 3, 10, &v, &mut out);
        for r in 0..3 {
            assert_eq!(
                out[r].to_bits(),
                crate::utils::math::dot(&w[r * 10..(r + 1) * 10], &v).to_bits()
            );
        }
    }
}
