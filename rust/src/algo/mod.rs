//! Policy-gradient methods as per-sample weight rules.
//!
//! Every method in the paper reduces to "run the weighted backward
//! artifact with weights w": PG uses w = U, DG uses w = chi = U*ell,
//! DG-K gates first and uses w = U on the kept set (Algorithm 1 line 10),
//! PPO uses the clipped-surrogate weight U*r*1{unclipped}, PMPO (alpha=1,
//! beta_KL=0) maximizes log-likelihood of positive-advantage samples.
//! This is what lets one compiled backward serve all five methods.

pub mod baseline;

use crate::coordinator::{GateDecision, KondoGate, Priority};
use crate::utils::rng::Pcg32;
use crate::utils::stats;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// REINFORCE: w = U, backward for every sample.
    Pg,
    /// Delightful policy gradient: w = chi = U * ell, backward for every sample.
    Dg,
    /// DG with the Kondo gate: backward only for kept samples, w = U.
    DgK { gate: KondoGate, priority: Priority },
    /// PPO clipped surrogate (eps); ratio r = exp(logp_new - logp_old).
    Ppo { eps: f64 },
    /// PMPO with mixing alpha (alpha = 1 keeps only positive advantages).
    Pmpo { alpha: f64 },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Pg => "pg".into(),
            Method::Dg => "dg".into(),
            Method::DgK { gate, priority } => {
                let g = match gate.pricing {
                    crate::coordinator::Pricing::Rate(r) => format!("rho{r}"),
                    crate::coordinator::Pricing::Price(l) => format!("lam{l}"),
                };
                if *priority == Priority::Delight {
                    format!("dgk_{g}")
                } else {
                    format!("dgk_{g}_{}", priority.name())
                }
            }
            Method::Ppo { .. } => "ppo".into(),
            Method::Pmpo { .. } => "pmpo".into(),
        }
    }

    /// Does this method gate backward passes?
    pub fn is_gated(&self) -> bool {
        matches!(self, Method::DgK { .. })
    }

    /// The gate priority, for methods that have one.
    pub fn priority(&self) -> Option<Priority> {
        match self {
            Method::DgK { priority, .. } => Some(*priority),
            _ => None,
        }
    }

    /// Replace the gate priority on a DG-K method; a no-op for ungated
    /// methods (they have no score vector to re-rank). This is how the
    /// CLI/config `priority` knob composes with `method=dgk_*` names.
    pub fn with_priority(self, priority: Priority) -> Method {
        match self {
            Method::DgK { gate, .. } => Method::DgK { gate, priority },
            other => other,
        }
    }
}

/// Per-batch decision: which samples get a backward pass, with what weight.
#[derive(Debug, Clone)]
pub struct WeightDecision {
    /// weight per sample (0 for skipped)
    pub weights: Vec<f32>,
    /// samples receiving a backward pass (all samples for ungated methods)
    pub keep: Vec<usize>,
    /// gate diagnostics if gated
    pub gate: Option<GateDecision>,
}

/// Inputs to the weight rule for one batch.
pub struct BatchSignals<'a> {
    /// advantage U_t
    pub u: &'a [f64],
    /// surprisal ell_t = -log pi(a_t) under the CURRENT policy
    pub ell: &'a [f64],
    /// behaviour-policy log-probs (for PPO ratios); None means on-policy
    pub logp_old: Option<&'a [f64]>,
    /// additive noise already applied to delight upstream, if any
    pub chi_override: Option<&'a [f64]>,
}

impl Method {
    /// Compute weights/keep set for one batch (Algorithm 1 for DG-K).
    pub fn decide(&self, s: &BatchSignals, rng: &mut Pcg32) -> WeightDecision {
        let n = s.u.len();
        assert_eq!(s.ell.len(), n);
        match self {
            Method::Pg => WeightDecision {
                weights: s.u.iter().map(|&u| u as f32).collect(),
                keep: (0..n).collect(),
                gate: None,
            },
            Method::Dg => {
                let chi = delight(s);
                WeightDecision {
                    weights: chi.iter().map(|&c| c as f32).collect(),
                    keep: (0..n).collect(),
                    gate: None,
                }
            }
            Method::DgK { gate, priority } => {
                let scores = priority_scores(*priority, s, rng);
                gate_scored(gate, s.u, &scores, rng)
            }
            Method::Ppo { eps } => {
                let ones: Vec<f64>;
                let lp_old = match s.logp_old {
                    Some(l) => l,
                    None => {
                        ones = s.ell.iter().map(|&e| -e).collect();
                        &ones
                    }
                };
                let weights = s
                    .u
                    .iter()
                    .zip(s.ell.iter().zip(lp_old))
                    .map(|(&u, (&ell, &lo))| {
                        let r = (-ell - lo).exp(); // exp(logp_new - logp_old)
                        let unclipped = if u >= 0.0 { r <= 1.0 + eps } else { r >= 1.0 - eps };
                        if unclipped {
                            (u * r) as f32
                        } else {
                            0.0
                        }
                    })
                    .collect();
                WeightDecision { weights, keep: (0..n).collect(), gate: None }
            }
            Method::Pmpo { alpha } => {
                let npos = s.u.iter().filter(|&&u| u > 0.0).count().max(1) as f64;
                let nneg = s.u.iter().filter(|&&u| u < 0.0).count().max(1) as f64;
                let weights = s
                    .u
                    .iter()
                    .map(|&u| {
                        if u > 0.0 {
                            (alpha / npos) as f32
                        } else if u < 0.0 {
                            (-(1.0 - alpha) / nneg) as f32
                        } else {
                            0.0
                        }
                    })
                    .collect();
                WeightDecision { weights, keep: (0..n).collect(), gate: None }
            }
        }
    }
}

/// The score vector a DG-K gate decides on: delight (honouring any
/// noise-injected `chi_override`) for the paper's priority, the configured
/// Fig-5 ablation signal otherwise. This is THE single site that turns
/// `BatchSignals` into gate scores -- `Method::decide` and the streaming
/// `GateStage` both call it, so the price tracker can never ingest a
/// different vector than the gate ranks (and `Uniform`'s one batch-global
/// key is drawn exactly once per batch).
pub fn priority_scores(priority: Priority, s: &BatchSignals, rng: &mut Pcg32) -> Vec<f64> {
    if priority == Priority::Delight {
        delight(s)
    } else {
        priority.score_batch(s.u, s.ell, rng)
    }
}

/// Gate a precomputed score vector and weight the kept set by U
/// (Algorithm 1 line 10). Split out of `Method::decide` so callers that
/// need the scores afterwards (the streaming price tracker) gate the very
/// vector they hold instead of recomputing it.
pub fn gate_scored(gate: &KondoGate, u: &[f64], scores: &[f64], rng: &mut Pcg32) -> WeightDecision {
    debug_assert_eq!(u.len(), scores.len());
    let d = gate.decide(scores, rng);
    let mut weights = vec![0.0f32; u.len()];
    for &i in &d.keep {
        weights[i] = u[i] as f32; // Algorithm 1 line 10
    }
    WeightDecision { weights, keep: d.keep.clone(), gate: Some(d) }
}

/// chi_t = U_t * ell_t, unless overridden by a noise-injected version.
pub fn delight(s: &BatchSignals) -> Vec<f64> {
    match s.chi_override {
        Some(c) => c.to_vec(),
        None => s.u.iter().zip(s.ell).map(|(&u, &l)| u * l).collect(),
    }
}

/// Apply relative delight noise (Fig 4a): chi + N(0, (rel * std(chi))^2).
pub fn perturb_delight_rel(chi: &[f64], rel: f64, rng: &mut Pcg32) -> Vec<f64> {
    if rel == 0.0 {
        return chi.to_vec();
    }
    let sd = stats::std_dev(chi);
    chi.iter().map(|&c| c + rng.normal() * rel * sd).collect()
}

/// Apply absolute delight noise (Fig 17): chi + N(0, sigma^2).
pub fn perturb_delight_abs(chi: &[f64], sigma: f64, rng: &mut Pcg32) -> Vec<f64> {
    if sigma == 0.0 {
        return chi.to_vec();
    }
    chi.iter().map(|&c| c + rng.normal() * sigma).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pricing;

    fn rng() -> Pcg32 {
        Pcg32::seeded(5)
    }

    fn sig<'a>(u: &'a [f64], ell: &'a [f64]) -> BatchSignals<'a> {
        BatchSignals { u, ell, logp_old: None, chi_override: None }
    }

    #[test]
    fn pg_weights_are_advantages() {
        let u = [0.5, -0.3];
        let ell = [1.0, 2.0];
        let d = Method::Pg.decide(&sig(&u, &ell), &mut rng());
        assert_eq!(d.weights, vec![0.5, -0.3]);
        assert_eq!(d.keep, vec![0, 1]);
    }

    #[test]
    fn dg_weights_are_delight() {
        let u = [0.5, -0.3];
        let ell = [1.0, 2.0];
        let d = Method::Dg.decide(&sig(&u, &ell), &mut rng());
        assert_eq!(d.weights, vec![0.5, -0.6]);
    }

    #[test]
    fn dgk_zero_price_keeps_positive_delight_with_u_weights() {
        let u = [0.5, -0.3, 0.2];
        let ell = [1.0, 2.0, 0.1];
        let m = Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight };
        let d = m.decide(&sig(&u, &ell), &mut rng());
        assert_eq!(d.keep, vec![0, 2]);
        assert_eq!(d.weights, vec![0.5, 0.0, 0.2]); // U, not chi
        let g = d.gate.unwrap();
        assert_eq!(g.lambda, 0.0);
    }

    #[test]
    fn dgk_rate_keeps_top_fraction() {
        let u: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let ell = vec![1.0; 100];
        let m = Method::DgK { gate: KondoGate::rate(0.03), priority: Priority::Delight };
        let d = m.decide(&sig(&u, &ell), &mut rng());
        assert_eq!(d.keep.len(), 3);
        assert!(d.keep.iter().all(|&i| i >= 97));
    }

    #[test]
    fn ppo_on_policy_equals_pg() {
        let u = [0.5, -0.3];
        let ell = [1.0, 2.0];
        let d = Method::Ppo { eps: 0.2 }.decide(&sig(&u, &ell), &mut rng());
        for (w, &uu) in d.weights.iter().zip(&u) {
            assert!((*w as f64 - uu).abs() < 1e-6);
        }
    }

    #[test]
    fn ppo_clips_large_ratios() {
        let u = [1.0, -1.0];
        let ell = [0.5, 0.5]; // logp_new = -0.5
        let lp_old = [-2.0, -0.1]; // ratios e^{1.5} ~ 4.48 and e^{-0.4} ~ 0.67
        let s = BatchSignals { u: &u, ell: &ell, logp_old: Some(&lp_old), chi_override: None };
        let d = Method::Ppo { eps: 0.2 }.decide(&s, &mut rng());
        assert_eq!(d.weights[0], 0.0); // positive adv, ratio > 1.2 -> clipped
        assert_eq!(d.weights[1], 0.0); // negative adv, ratio < 0.8 -> clipped
    }

    #[test]
    fn pmpo_alpha1_keeps_only_positive() {
        let u = [0.5, -0.3, 0.2, 0.0];
        let ell = [1.0; 4];
        let d = Method::Pmpo { alpha: 1.0 }.decide(&sig(&u, &ell), &mut rng());
        assert!((d.weights[0] - 0.5f32).abs() < 1e-6); // 1/npos = 1/2
        assert_eq!(d.weights[1], 0.0);
        assert!((d.weights[2] - 0.5f32).abs() < 1e-6);
        assert_eq!(d.weights[3], 0.0);
    }

    #[test]
    fn dgk_non_delight_priority_ranks_on_its_signal() {
        let u = [1.0, 1.0, 1.0, 1.0];
        let ell = [4.0, 1.0, 3.0, 2.0];
        let m = Method::DgK { gate: KondoGate::rate(0.5), priority: Priority::Surprisal };
        let d = m.decide(&sig(&u, &ell), &mut rng());
        assert_eq!(d.keep, vec![0, 2], "surprisal priority keeps the high-ell half");
    }

    #[test]
    fn with_priority_rewrites_gated_methods_only() {
        let m = Method::DgK { gate: KondoGate::rate(0.1), priority: Priority::Delight };
        let m = m.with_priority(Priority::Uniform);
        assert_eq!(m.priority(), Some(Priority::Uniform));
        assert!(m.name().contains("uniform"));
        assert_eq!(Method::Pg.with_priority(Priority::Uniform), Method::Pg);
        assert_eq!(Method::Pg.priority(), None);
    }

    #[test]
    fn chi_override_feeds_gate() {
        // noise-injected delight must drive the gate, not the clean signal
        let u = [1.0, 1.0];
        let ell = [1.0, 1.0];
        let noisy = [-1.0, 2.0];
        let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: Some(&noisy) };
        let m = Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight };
        let d = m.decide(&s, &mut rng());
        assert_eq!(d.keep, vec![1]);
    }

    #[test]
    fn delight_noise_helpers() {
        let chi = vec![1.0, -1.0, 0.5, 2.0];
        let mut r = rng();
        assert_eq!(perturb_delight_rel(&chi, 0.0, &mut r), chi);
        let noisy = perturb_delight_rel(&chi, 0.5, &mut r);
        assert_ne!(noisy, chi);
        let abs = perturb_delight_abs(&chi, 1.0, &mut r);
        assert_ne!(abs, chi);
    }

    #[test]
    fn method_names() {
        assert_eq!(Method::Pg.name(), "pg");
        let m = Method::DgK { gate: KondoGate::rate(0.03), priority: Priority::Delight };
        assert_eq!(m.name(), "dgk_rho0.03");
        assert!(matches!(
            Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight },
            Method::DgK { gate: KondoGate { pricing: Pricing::Price(_), .. }, .. }
        ));
    }
}
