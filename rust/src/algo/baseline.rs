//! Advantage baselines (paper App A.1 / A.3, Figs 13-14).
//!
//! For the MNIST bandit the reward is R = 1{a = y} (+ optional noise with
//! mean zero), so the expected-confidence baseline b = sum_a pi(a) E[r(a)]
//! equals pi(y) -- the paper's main-body choice. Zero and constant
//! baselines are the robustness comparisons; Oracle is E[R | x] under the
//! true label (identical to Expected for mean-zero reward noise, kept as a
//! separate variant to mirror the paper's four-way figure).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Baseline {
    /// b = 0
    Zero,
    /// b = c (paper uses 0.5)
    Constant(f64),
    /// b = sum_a pi(a) E[r(a) | x] = pi(y) for indicator reward
    Expected,
    /// b = E[R | x] with the true label
    Oracle,
}

impl Baseline {
    /// Baseline value for one MNIST-bandit sample: full policy `pi` over
    /// actions, true label `y`.
    pub fn value(&self, pi: &[f32], y: usize) -> f64 {
        match *self {
            Baseline::Zero => 0.0,
            Baseline::Constant(c) => c,
            Baseline::Expected | Baseline::Oracle => pi[y] as f64,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Baseline::Zero => "zero".into(),
            Baseline::Constant(c) => format!("constant{c}"),
            Baseline::Expected => "expected".into(),
            Baseline::Oracle => "oracle".into(),
        }
    }
}

/// Grouped empirical baseline (paper App D.1, GRPO-style): mean reward of
/// each prompt's response group. `rewards` is episode-major with `group`
/// consecutive episodes per prompt.
pub fn grouped_baseline(rewards: &[f64], group: usize) -> Vec<f64> {
    assert!(group > 0 && rewards.len() % group == 0);
    let mut out = vec![0.0; rewards.len()];
    for g in 0..rewards.len() / group {
        let lo = g * group;
        let mean: f64 = rewards[lo..lo + group].iter().sum::<f64>() / group as f64;
        for b in out.iter_mut().skip(lo).take(group) {
            *b = mean;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_values() {
        let pi = [0.1f32, 0.7, 0.2];
        assert_eq!(Baseline::Zero.value(&pi, 1), 0.0);
        assert_eq!(Baseline::Constant(0.5).value(&pi, 1), 0.5);
        assert!((Baseline::Expected.value(&pi, 1) - 0.7).abs() < 1e-6);
        assert!((Baseline::Oracle.value(&pi, 2) - 0.2).abs() < 1e-6);
    }

    #[test]
    fn expected_baseline_gives_paper_advantages() {
        // App A.1: U(y*) = 1 - p, U(a != y*) = -p
        let pi = [0.3f32, 0.6, 0.1];
        let y = 1;
        let b = Baseline::Expected.value(&pi, y);
        let u_correct = 1.0 - b;
        let u_wrong = 0.0 - b;
        assert!((u_correct - 0.4).abs() < 1e-6);
        assert!((u_wrong + 0.6).abs() < 1e-6);
    }

    #[test]
    fn grouped_baseline_is_group_mean() {
        let r = [1.0, 0.0, 0.5, 0.5, 1.0, 1.0];
        let b = grouped_baseline(&r, 2);
        assert_eq!(b, vec![0.5, 0.5, 0.5, 0.5, 1.0, 1.0]);
    }

    #[test]
    fn grouped_baseline_centers_advantages() {
        let r = [1.0, 0.0, 0.25, 0.75];
        let b = grouped_baseline(&r, 4);
        let adv: f64 = r.iter().zip(&b).map(|(x, y)| x - y).sum();
        assert!(adv.abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn grouped_baseline_rejects_ragged() {
        grouped_baseline(&[1.0, 2.0, 3.0], 2);
    }
}
