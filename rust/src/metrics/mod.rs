//! Result emission: CSV files under `results/<exp>/` plus ASCII rendering
//! of curves and tables in the paper's own rows/series.

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A CSV writer with a fixed header.
pub struct CsvWriter {
    path: PathBuf,
    file: fs::File,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref().to_path_buf();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut file = fs::File::create(&path)
            .with_context(|| format!("creating {}", path.display()))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { path, file, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.cols, "column count mismatch in {}", self.path.display());
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, values: &[f64]) -> Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Render an ASCII table (paper-style rows).
pub fn ascii_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let parts: Vec<String> =
            cells.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}", w = w)).collect();
        let _ = writeln!(out, "| {} |", parts.join(" | "));
    };
    line(&mut out, &header.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let _ = writeln!(
        out,
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        line(&mut out, row);
    }
    out
}

/// Render a log-scale-x ASCII sparkline of (x, y) series, for terminal
/// inspection of learning curves.
pub fn ascii_curve(name: &str, xs: &[f64], ys: &[f64], width: usize) -> String {
    if xs.is_empty() {
        return format!("{name}: (empty)\n");
    }
    let ymin = ys.iter().cloned().fold(f64::INFINITY, f64::min);
    let ymax = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut s = String::new();
    let n = xs.len();
    let _ = write!(s, "{name:<24} [{ymin:.4} .. {ymax:.4}] ");
    for i in 0..width.min(n) {
        let idx = i * (n - 1) / width.max(1).min(n - 1).max(1);
        let y = ys[idx.min(n - 1)];
        let g = if (ymax - ymin).abs() < 1e-12 {
            0
        } else {
            (((y - ymin) / (ymax - ymin)) * (glyphs.len() - 1) as f64).round() as usize
        };
        s.push(glyphs[g.min(glyphs.len() - 1)]);
    }
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join(format!("kondo_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.row(&["x".into(), "y".into()]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\nx,y\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    #[should_panic]
    fn csv_rejects_wrong_arity() {
        let dir = std::env::temp_dir().join(format!("kondo_csv2_{}", std::process::id()));
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.rowf(&[1.0]);
    }

    #[test]
    fn table_renders() {
        let t = ascii_table(
            &["method", "err"],
            &[vec!["pg".into(), "0.05".into()], vec!["dgk".into(), "0.005".into()]],
        );
        assert!(t.contains("method"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn curve_renders() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| (-x / 5.0).exp()).collect();
        let s = ascii_curve("test", &xs, &ys, 40);
        assert!(s.contains("test"));
    }
}
