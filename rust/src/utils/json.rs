//! Minimal JSON parser — substrate for reading `artifacts/manifest.json`.
//!
//! No third-party JSON crate is available in the offline vendor set, so we
//! implement the subset of JSON we emit ourselves (objects, arrays,
//! strings, numbers, booleans, null). Strict enough to reject malformed
//! documents; not a general-purpose library.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize with 2-space indentation (the emit half of the parser's
    /// subset: used by the bench JSON sink to merge-write `BENCH_e2e.json`
    /// without clobbering sections other benches own).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.dump_into(&mut s, 0);
        s.push('\n');
        s
    }

    fn dump_into(&self, s: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // integers print without a trailing ".0" so round-trips
                // are stable for counters and schema versions
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{n}"));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    s.push_str("[]");
                    return;
                }
                s.push_str("[\n");
                for (i, it) in items.iter().enumerate() {
                    s.push_str(&pad_in);
                    it.dump_into(s, indent + 1);
                    s.push_str(if i + 1 == items.len() { "\n" } else { ",\n" });
                }
                s.push_str(&pad);
                s.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    s.push_str("{}");
                    return;
                }
                s.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    s.push_str(&pad_in);
                    s.push_str(&Json::Str(k.clone()).to_dumped_key());
                    s.push_str(": ");
                    v.dump_into(s, indent + 1);
                    s.push_str(if i + 1 == m.len() { "\n" } else { ",\n" });
                }
                s.push_str(&pad);
                s.push('}');
            }
        }
    }

    fn to_dumped_key(&self) -> String {
        let mut s = String::new();
        self.dump_into(&mut s, 0);
        s
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"x", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\té""#).unwrap();
        assert_eq!(j.as_str(), Some("A\t\u{e9}"));
        let j = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("caf\u{e9}"));
    }

    #[test]
    fn negative_exponent_shapes() {
        let j = Json::parse(r#"{"neg_inf": -1e+30}"#).unwrap();
        assert_eq!(j.get("neg_inf").unwrap().as_f64(), Some(-1e30));
    }

    #[test]
    fn dump_parse_roundtrip() {
        let doc = r#"{"schema": 2, "note": "a \"quoted\" note\nline2",
            "benches": {"e2e_step": {"platform": "native", "entries": []},
                        "kernels": {"entries": [{"gflops": 1.25, "n": 3}]}},
            "flags": [true, false, null, -1.5e3]}"#;
        let parsed = Json::parse(doc).unwrap();
        let dumped = parsed.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), parsed, "roundtrip drift:\n{dumped}");
        // integers stay integer-shaped, floats keep their fraction
        assert!(dumped.contains("\"schema\": 2"));
        assert!(dumped.contains("1.25"));
    }
}
