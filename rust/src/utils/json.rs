//! Minimal JSON parser — substrate for reading `artifacts/manifest.json`.
//!
//! No third-party JSON crate is available in the offline vendor set, so we
//! implement the subset of JSON we emit ourselves (objects, arrays,
//! strings, numbers, booleans, null). Strict enough to reject malformed
//! documents; not a general-purpose library.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize with 2-space indentation (the emit half of the parser's
    /// subset: used by the bench JSON sink to merge-write `BENCH_e2e.json`
    /// without clobbering sections other benches own).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.dump_into(&mut s, 0);
        s.push('\n');
        s
    }

    fn dump_into(&self, s: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // Bit-exact round-trip contract (checkpoint substrate):
                // Rust's `Display` for floats is shortest-round-trip, so
                // `format!("{n}")` already parses back to the same bits
                // for every finite value. The cases Display alone would
                // lose: -0.0 through the integer fast path (prints "0",
                // dropping the sign), and NaN/±inf (Display emits "NaN"/
                // "inf", which the strict parser must spell consistently).
                // NaN payloads are NOT preserved -- every NaN collapses to
                // the one canonical token (documented in DESIGN.md §10).
                if n.is_nan() {
                    s.push_str("NaN");
                } else if *n == f64::INFINITY {
                    s.push_str("Infinity");
                } else if *n == f64::NEG_INFINITY {
                    s.push_str("-Infinity");
                } else if *n == 0.0 && n.is_sign_negative() {
                    s.push_str("-0.0");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    // integers print without a trailing ".0" so round-trips
                    // are stable for counters and schema versions
                    s.push_str(&format!("{}", *n as i64));
                } else {
                    s.push_str(&format!("{n}"));
                }
            }
            Json::Str(v) => {
                s.push('"');
                for c in v.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    s.push_str("[]");
                    return;
                }
                s.push_str("[\n");
                for (i, it) in items.iter().enumerate() {
                    s.push_str(&pad_in);
                    it.dump_into(s, indent + 1);
                    s.push_str(if i + 1 == items.len() { "\n" } else { ",\n" });
                }
                s.push_str(&pad);
                s.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    s.push_str("{}");
                    return;
                }
                s.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    s.push_str(&pad_in);
                    s.push_str(&Json::Str(k.clone()).to_dumped_key());
                    s.push_str(": ");
                    v.dump_into(s, indent + 1);
                    s.push_str(if i + 1 == m.len() { "\n" } else { ",\n" });
                }
                s.push_str(&pad);
                s.push('}');
            }
        }
    }

    fn to_dumped_key(&self) -> String {
        let mut s = String::new();
        self.dump_into(&mut s, 0);
        s
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// open containers on the parse stack; bounded so adversarial or
    /// corrupt input (e.g. a truncated checkpoint refilled with "[[[[…")
    /// errors instead of overflowing the real stack through recursion
    depth: usize,
}

/// Maximum container nesting the recursive-descent parser accepts.
const MAX_DEPTH: usize = 200;

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            // the non-finite tokens our own dumper emits (bit-exact
            // round-trip contract); "-Infinity" enters through number()
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let raw = &self.b[self.i + 1..self.i + 5];
                            // pre-check hex digits: a multibyte char right
                            // after the escape would split mid-sequence and
                            // panic the from_utf8 below on corrupt input
                            if !raw.iter().all(u8::is_ascii_hexdigit) {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(raw).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"x", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""A\té""#).unwrap();
        assert_eq!(j.as_str(), Some("A\t\u{e9}"));
        let j = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("caf\u{e9}"));
    }

    #[test]
    fn negative_exponent_shapes() {
        let j = Json::parse(r#"{"neg_inf": -1e+30}"#).unwrap();
        assert_eq!(j.get("neg_inf").unwrap().as_f64(), Some(-1e30));
    }

    fn roundtrip(v: Json) -> Json {
        let dumped = v.dump();
        Json::parse(&dumped).unwrap_or_else(|e| panic!("reparse failed on {dumped:?}: {e}"))
    }

    #[test]
    fn special_floats_roundtrip_bit_exact() {
        // the checkpoint substrate's contract: every f64 value class
        // survives dump -> parse with its exact bit pattern (NaN collapses
        // to one canonical NaN -- payloads are explicitly out of scope)
        for v in [
            0.0,
            -0.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::MIN_POSITIVE,
            -f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
            5e-324,  // smallest subnormal
            -5e-324,
            0.1,
            1.0 / 3.0,
            std::f64::consts::PI,
            9.0e15,          // just past the integer fast path
            9007199254740993.0, // 2^53 + 1 rounds to 2^53: still exact bits
        ] {
            let got = roundtrip(Json::Num(v)).as_f64().unwrap();
            assert_eq!(got.to_bits(), v.to_bits(), "{v:e} came back as {got:e}");
        }
        let nan = roundtrip(Json::Num(f64::NAN)).as_f64().unwrap();
        assert!(nan.is_nan());
        // the tokens themselves are stable (and hence FNV-stable)
        assert_eq!(Json::Num(f64::NAN).dump().trim(), "NaN");
        assert_eq!(Json::Num(f64::INFINITY).dump().trim(), "Infinity");
        assert_eq!(Json::Num(f64::NEG_INFINITY).dump().trim(), "-Infinity");
        assert_eq!(Json::Num(-0.0).dump().trim(), "-0.0");
        // -Infinity also parses inside containers (number() entry path)
        let j = Json::parse(r#"[-Infinity, NaN, Infinity, -0.0]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), f64::NEG_INFINITY);
        assert!(a[1].as_f64().unwrap().is_nan());
        assert_eq!(a[2].as_f64().unwrap(), f64::INFINITY);
        assert_eq!(a[3].as_f64().unwrap().to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn f64_roundtrip_property_random_bit_patterns() {
        // property-style: arbitrary u64 bit patterns reinterpreted as f64
        // must survive dump -> parse bit-exactly (NaN class-preserved)
        let mut rng = crate::utils::rng::Pcg32::seeded(0x6a6f79);
        for trial in 0..2000 {
            let bits = rng.next_u64();
            let v = f64::from_bits(bits);
            let got = roundtrip(Json::Num(v)).as_f64().unwrap();
            if v.is_nan() {
                assert!(got.is_nan(), "trial {trial}: NaN {bits:#x} lost its NaN-ness");
            } else {
                assert_eq!(
                    got.to_bits(),
                    v.to_bits(),
                    "trial {trial}: {v:e} ({bits:#x}) came back as {got:e}"
                );
            }
        }
    }

    #[test]
    fn f32_via_f64_roundtrip_property() {
        // f32 tensors are stored as Json::Num(x as f64); the f32 -> f64 ->
        // dump -> parse -> f32 path must be lossless for every bit pattern
        let mut rng = crate::utils::rng::Pcg32::seeded(77);
        for trial in 0..2000 {
            let bits = rng.next_u32();
            let v = f32::from_bits(bits);
            let back = roundtrip(Json::Num(v as f64)).as_f64().unwrap() as f32;
            if v.is_nan() {
                assert!(back.is_nan(), "trial {trial}: f32 NaN {bits:#x} lost");
            } else {
                assert_eq!(
                    back.to_bits(),
                    v.to_bits(),
                    "trial {trial}: f32 {v:e} ({bits:#x}) came back as {back:e}"
                );
            }
        }
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing() {
        // a corrupt/adversarial document must error cleanly, not blow the
        // parser's recursion stack
        let deep = "[".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        let deep_obj = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&deep_obj).is_err());
        // well within the limit still parses
        let ok = format!("{}1{}", "[".repeat(50), "]".repeat(50));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn truncated_unicode_escape_is_error_not_panic() {
        // regression: a \u escape whose 4 "hex digits" split a multibyte
        // char used to panic from_utf8 -- corrupt checkpoints must error
        let bad = "\"\\u00\u{4e2d}\"";
        assert!(Json::parse(bad).is_err());
        let bad2 = "\"\\uzzzz\"";
        assert!(Json::parse(bad2).is_err());
        // valid escapes still work
        assert_eq!(Json::parse(r#""\u0041""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn dump_parse_roundtrip() {
        let doc = r#"{"schema": 2, "note": "a \"quoted\" note\nline2",
            "benches": {"e2e_step": {"platform": "native", "entries": []},
                        "kernels": {"entries": [{"gflops": 1.25, "n": 3}]}},
            "flags": [true, false, null, -1.5e3]}"#;
        let parsed = Json::parse(doc).unwrap();
        let dumped = parsed.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), parsed, "roundtrip drift:\n{dumped}");
        // integers stay integer-shaped, floats keep their fraction
        assert!(dumped.contains("\"schema\": 2"));
        assert!(dumped.contains("1.25"));
    }
}
