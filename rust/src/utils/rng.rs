//! Deterministic PCG32 RNG — the repo's single randomness source.
//!
//! Every experiment seeds one `Pcg32` per (run, seed) pair so results are
//! exactly reproducible; no global RNG state anywhere.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// cached second Box-Muller variate
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 32 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        self.next_u32() as f64 * (1.0 / 4294967296.0)
    }

    /// Uniform integer in [0, n) (Lemire-ish rejection via modulo bias guard).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let zone = u32::MAX - (u32::MAX % n);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Bernoulli draw.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized log-probabilities (Gumbel-max).
    pub fn categorical_from_logits(&mut self, logits: &[f32]) -> usize {
        let mut best = f64::NEG_INFINITY;
        let mut arg = 0;
        for (i, &l) in logits.iter().enumerate() {
            let u = self.uniform().max(1e-300);
            let g = -(-u.ln()).ln();
            let v = l as f64 + g;
            if v > best {
                best = v;
                arg = i;
            }
        }
        arg
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent stream (deterministic fork).
    pub fn split(&mut self) -> Pcg32 {
        Pcg32::new(self.next_u64(), self.next_u64() | 1)
    }

    /// Full generator state for checkpointing: `(state, inc, gauss_spare)`.
    /// `from_snapshot` of this tuple reproduces the exact output stream,
    /// including a cached Box-Muller variate if one is pending.
    pub fn snapshot(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator from a `snapshot()` tuple (checkpoint resume).
    pub fn from_snapshot(state: u64, inc: u64, gauss_spare: Option<f64>) -> Pcg32 {
        Pcg32 { state, inc, gauss_spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let mut s = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
        }
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_logits() {
        let mut r = Pcg32::seeded(6);
        // heavily favour index 2
        let logits = [0.0f32, 0.0, 5.0, 0.0];
        let n = 2000;
        let hits = (0..n).filter(|_| r.categorical_from_logits(&logits) == 2).count();
        assert!(hits as f64 / n as f64 > 0.9);
    }

    #[test]
    fn categorical_matches_softmax_frequencies() {
        let mut r = Pcg32::seeded(8);
        let logits = [1.0f32, 0.0, -1.0];
        let exps: Vec<f64> = logits.iter().map(|&l| (l as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let n = 60_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[r.categorical_from_logits(&logits)] += 1;
        }
        for i in 0..3 {
            let emp = counts[i] as f64 / n as f64;
            assert!((emp - exps[i] / z).abs() < 0.01, "idx {i}: {emp}");
        }
    }

    #[test]
    fn snapshot_resumes_exact_stream() {
        let mut a = Pcg32::seeded(11);
        for _ in 0..17 {
            a.next_u32();
        }
        a.normal(); // leave a cached Box-Muller spare pending
        let (state, inc, spare) = a.snapshot();
        let mut b = Pcg32::from_snapshot(state, inc, spare);
        for _ in 0..100 {
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Pcg32::seeded(9);
        let mut b = a.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
