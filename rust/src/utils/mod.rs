//! Shared substrates: RNG, numerics, statistics, JSON/TOML parsing.

pub mod json;
pub mod math;
pub mod rng;
pub mod stats;
pub mod toml;
