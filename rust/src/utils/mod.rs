//! Shared substrates: RNG, numerics, statistics, JSON/TOML parsing.

pub mod json;
pub mod math;
pub mod rng;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod simd;
pub mod stats;
pub mod toml;
