//! Numerics shared across the coordinator and the tabular analysis.

/// Numerically stable log-sum-exp.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax of logits.
pub fn softmax(xs: &mut [f32]) {
    let lse = logsumexp(xs);
    for x in xs.iter_mut() {
        *x = (*x - lse).exp();
    }
}

/// Softmax returning a new vector.
pub fn softmax_v(xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    softmax(&mut v);
    v
}

/// Logistic sigmoid.
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Binary entropy H(w) in nats; H(0) = H(1) = 0.
pub fn binary_entropy(w: f64) -> f64 {
    if w <= 0.0 || w >= 1.0 {
        return 0.0;
    }
    -w * w.ln() - (1.0 - w) * (1.0 - w).ln()
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// L2 norm.
pub fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0 if either vector is (numerically) zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-300 || nb < 1e-300 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Component of `a` perpendicular to `dir` (returns squared norm).
pub fn perp_norm2(a: &[f32], dir: &[f32]) -> f64 {
    let nd2 = dot(dir, dir);
    if nd2 < 1e-300 {
        return dot(a, a);
    }
    let proj = dot(a, dir) / nd2;
    a.iter()
        .zip(dir)
        .map(|(&x, &d)| {
            let p = x as f64 - proj * d as f64;
            p * p
        })
        .sum()
}

/// Standard normal CDF Phi(x) via erf.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_stable() {
        assert!((logsumexp(&[1000.0, 1000.0]) - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
        assert!((logsumexp(&[0.0, 0.0, 0.0]) - (3.0f32).ln()).abs() < 1e-6);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), f32::NEG_INFINITY);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax_v(&[1.0, 2.0, 3.0]);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn sigmoid_limits_and_symmetry() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(40.0) > 0.999999);
        assert!(sigmoid(-40.0) < 1e-6);
        for &x in &[0.3, 1.7, 5.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn binary_entropy_properties() {
        assert_eq!(binary_entropy(0.0), 0.0);
        assert_eq!(binary_entropy(1.0), 0.0);
        assert!((binary_entropy(0.5) - (2.0f64).ln().abs()).abs() < 1e-12);
        assert!(binary_entropy(0.3) > 0.0);
        assert!((binary_entropy(0.3) - binary_entropy(0.7)).abs() < 1e-12);
    }

    #[test]
    fn cosine_and_perp() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert!(cosine(&a, &a) > 0.999999);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((perp_norm2(&b, &a) - 4.0).abs() < 1e-9);
        assert!(perp_norm2(&a, &a) < 1e-12);
    }

    #[test]
    fn normal_cdf_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }
}
