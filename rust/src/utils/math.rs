//! Numerics shared across the coordinator and the tabular analysis.
//!
//! The reductions here (`dot`, `norm`, `perp_norm2`) use the same
//! **fixed-width lane reduction** as the native kernel layer
//! (`runtime/kernels.rs`): element `i` accumulates into lane `i % LANES`
//! in ascending order, and the lanes are combined by the fixed tree
//! `(l0 + l1) + (l2 + l3)`. The reduction order is a pure function of the
//! input length — never of worker count, thread, or blocking — which is
//! the determinism rule DESIGN.md §9 states for every reduction on the
//! training path (the tier-1 `DraftScreen` dot is one of these per
//! screened sample).

/// Fixed lane width shared by every lane-reduced kernel in the crate.
/// Changing this changes the accumulation tree (and therefore golden
/// values) everywhere at once; it must never vary per call site.
pub const LANES: usize = 4;

/// The fixed lane-combination tree: `(l0 + l1) + (l2 + l3)`. A pure
/// function of the lane values — the final stage of every lane-reduced
/// sum in the crate.
#[inline]
pub fn lane_reduce(acc: &[f64; LANES]) -> f64 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// Numerically stable log-sum-exp.
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// In-place softmax of logits.
pub fn softmax(xs: &mut [f32]) {
    let lse = logsumexp(xs);
    for x in xs.iter_mut() {
        *x = (*x - lse).exp();
    }
}

/// Softmax returning a new vector.
pub fn softmax_v(xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    softmax(&mut v);
    v
}

/// Logistic sigmoid.
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Binary entropy H(w) in nats; H(0) = H(1) = 0.
pub fn binary_entropy(w: f64) -> f64 {
    if w <= 0.0 || w >= 1.0 {
        return 0.0;
    }
    -w * w.ln() - (1.0 - w) * (1.0 - w).ln()
}

/// Dot product, f64-accumulated over `LANES` fixed-width lanes (element
/// `i` goes to lane `i % LANES`, ascending) and combined by
/// [`lane_reduce`]. The value is a pure function of the inputs and their
/// length; see the module docs for why the order is fixed.
///
/// With `--features simd` on an AVX2 host this routes through the vector
/// lowering in `utils::simd`, which performs the identical per-lane
/// operations and tree and is therefore bit-identical to [`dot_scalar`]
/// (locked by `rust/tests/simd_equivalence.rs`).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd::avx2() {
        return unsafe { super::simd::dot_avx2(a, b) };
    }
    dot_scalar(a, b)
}

/// The scalar twin of [`dot`]: always the plain lane-accumulated loop,
/// regardless of features. Exposed so equivalence tests (and callers that
/// want the reference path explicitly) can compare against it.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f64; LANES];
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[l] += a[base + l] as f64 * b[base + l] as f64;
        }
    }
    let base = chunks * LANES;
    for l in 0..(n - base) {
        acc[l] += a[base + l] as f64 * b[base + l] as f64;
    }
    lane_reduce(&acc)
}

/// The fixed lane tree in f32 — only for the explicitly non-golden
/// `f32-fast` method axis (DESIGN.md §13). Never on the golden path.
#[inline]
pub fn lane_reduce_f32(acc: &[f32; LANES]) -> f32 {
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// `f32-fast` dot: same lane assignment and tree as [`dot`] but the
/// accumulators stay f32, halving accumulator bandwidth at the cost of
/// precision. **Non-golden**: screen/forward-tier only, never the gated
/// backward, never checkpoint or contract values. Deterministic (the
/// order is still shape-keyed) but not bit-comparable to [`dot`].
pub fn dot_f32fast(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; LANES];
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            acc[l] += a[base + l] * b[base + l];
        }
    }
    let base = chunks * LANES;
    for l in 0..(n - base) {
        acc[l] += a[base + l] * b[base + l];
    }
    lane_reduce_f32(&acc)
}

/// L2 norm.
pub fn norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0 if either vector is (numerically) zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm(a);
    let nb = norm(b);
    if na < 1e-300 || nb < 1e-300 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Component of `a` perpendicular to `dir` (returns squared norm). Same
/// fixed lane reduction as [`dot`]; dispatches to the AVX2 lowering under
/// the same conditions and with the same bit-identity guarantee.
pub fn perp_norm2(a: &[f32], dir: &[f32]) -> f64 {
    let nd2 = dot(dir, dir);
    if nd2 < 1e-300 {
        return dot(a, a);
    }
    let proj = dot(a, dir) / nd2;
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd::avx2() {
        return unsafe { super::simd::perp_acc_avx2(a, dir, proj) };
    }
    perp_acc_scalar(a, dir, proj)
}

/// The scalar twin of [`perp_norm2`], entered after the shared projection
/// computation (which itself uses the dispatched [`dot`], whose twins are
/// bit-identical).
pub fn perp_norm2_scalar(a: &[f32], dir: &[f32]) -> f64 {
    let nd2 = dot_scalar(dir, dir);
    if nd2 < 1e-300 {
        return dot_scalar(a, a);
    }
    let proj = dot_scalar(a, dir) / nd2;
    perp_acc_scalar(a, dir, proj)
}

fn perp_acc_scalar(a: &[f32], dir: &[f32], proj: f64) -> f64 {
    let n = a.len().min(dir.len());
    let mut acc = [0.0f64; LANES];
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        for l in 0..LANES {
            let p = a[base + l] as f64 - proj * dir[base + l] as f64;
            acc[l] += p * p;
        }
    }
    let base = chunks * LANES;
    for l in 0..(n - base) {
        let p = a[base + l] as f64 - proj * dir[base + l] as f64;
        acc[l] += p * p;
    }
    lane_reduce(&acc)
}

/// Standard normal CDF Phi(x) via erf.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Error function, Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_stable() {
        assert!((logsumexp(&[1000.0, 1000.0]) - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
        assert!((logsumexp(&[0.0, 0.0, 0.0]) - (3.0f32).ln()).abs() < 1e-6);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), f32::NEG_INFINITY);
    }

    #[test]
    fn softmax_normalizes() {
        let p = softmax_v(&[1.0, 2.0, 3.0]);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn sigmoid_limits_and_symmetry() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
        assert!(sigmoid(40.0) > 0.999999);
        assert!(sigmoid(-40.0) < 1e-6);
        for &x in &[0.3, 1.7, 5.0] {
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn binary_entropy_properties() {
        assert_eq!(binary_entropy(0.0), 0.0);
        assert_eq!(binary_entropy(1.0), 0.0);
        assert!((binary_entropy(0.5) - (2.0f64).ln().abs()).abs() < 1e-12);
        assert!(binary_entropy(0.3) > 0.0);
        assert!((binary_entropy(0.3) - binary_entropy(0.7)).abs() < 1e-12);
    }

    #[test]
    fn cosine_and_perp() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert!(cosine(&a, &a) > 0.999999);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((perp_norm2(&b, &a) - 4.0).abs() < 1e-9);
        assert!(perp_norm2(&a, &a) < 1e-12);
    }

    /// Sequential scalar reference the lane-reduced `dot` must agree with
    /// (up to reassociation error: both are exact-f64-product sums, so the
    /// difference is bounded by a few ulps of the running magnitude).
    fn dot_seq(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    #[test]
    fn lane_dot_matches_scalar_reference() {
        let mut state = 0x1234_5678u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
        };
        // lengths straddling every tail case of the LANES blocking
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 31, 784] {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            let lane = dot(&a, &b);
            let seq = dot_seq(&a, &b);
            let scale = 1.0 + a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum::<f64>();
            assert!(
                (lane - seq).abs() <= 1e-12 * scale,
                "n={n}: lane {lane} vs seq {seq}"
            );
        }
    }

    #[test]
    fn lane_dot_is_deterministic_and_length_keyed() {
        // the determinism rule: the value depends only on the inputs, and
        // repeated evaluation is bit-identical
        let a: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).cos()).collect();
        assert_eq!(dot(&a, &b).to_bits(), dot(&a, &b).to_bits());
        assert_eq!(
            perp_norm2(&a, &b).to_bits(),
            perp_norm2(&a, &b).to_bits()
        );
    }

    #[test]
    fn lane_perp_norm2_matches_scalar_reference() {
        let a: Vec<f32> = (0..29).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let d: Vec<f32> = (0..29).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
        let nd2 = dot_seq(&d, &d);
        let proj = dot_seq(&a, &d) / nd2;
        let seq: f64 = a
            .iter()
            .zip(&d)
            .map(|(&x, &v)| {
                let p = x as f64 - proj * v as f64;
                p * p
            })
            .sum();
        assert!((perp_norm2(&a, &d) - seq).abs() < 1e-9 * (1.0 + seq));
    }

    #[test]
    fn dispatched_dot_and_perp_are_bitwise_scalar_twins() {
        // holds in every build configuration: without `simd` the dispatch
        // IS the scalar twin; with it, the AVX2 lowering must reproduce
        // the twin bit for bit (the §13 contract)
        for n in [0usize, 1, 3, 4, 5, 8, 31, 784] {
            let a: Vec<f32> = (0..n).map(|i| ((i * 13 % 17) as f32 - 8.0) * 0.37).collect();
            let b: Vec<f32> = (0..n).map(|i| ((i * 7 % 19) as f32 - 9.0) * 0.11).collect();
            assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(), "dot n={n}");
            assert_eq!(
                perp_norm2(&a, &b).to_bits(),
                perp_norm2_scalar(&a, &b).to_bits(),
                "perp n={n}"
            );
        }
    }

    #[test]
    fn dot_f32fast_is_deterministic_and_close_but_non_golden() {
        let a: Vec<f32> = (0..100).map(|i| (i as f32 * 0.7).sin()).collect();
        let b: Vec<f32> = (0..100).map(|i| (i as f32 * 0.3).cos()).collect();
        // deterministic: repeated evaluation is bit-identical
        assert_eq!(dot_f32fast(&a, &b).to_bits(), dot_f32fast(&a, &b).to_bits());
        // close to the f64 golden value, but nothing asserts bit equality
        assert!((dot_f32fast(&a, &b) as f64 - dot(&a, &b)).abs() < 1e-3);
    }

    #[test]
    fn normal_cdf_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }
}
