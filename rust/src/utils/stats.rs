//! Statistics helpers: summaries, quantiles, ECDF, least-squares fits.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Standard error of the mean.
pub fn sem(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    std_dev(xs) / (xs.len() as f64).sqrt()
}

/// q-quantile (linear interpolation on sorted copy), q in [0,1].
/// total_cmp, not partial_cmp().unwrap(): a NaN score (diverged draft,
/// 0 * inf delight) must order deterministically instead of panicking a
/// training run mid-step.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Same for f32 slices, returning f32 (used on delight batches).
pub fn quantile_f32(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty());
    let v: Vec<f64> = xs.iter().map(|&x| x as f64).collect();
    quantile(&v, q) as f32
}

/// Empirical CDF evaluated at sorted sample points: returns (xs_sorted, F(x)).
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len() as f64;
    let f = (1..=v.len()).map(|i| i as f64 / n).collect();
    (v, f)
}

/// Ordinary least squares y = a + b x; returns (a, b).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let mx = mean(x);
    let my = mean(y);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        sxx += (xi - mx) * (xi - mx);
        sxy += (xi - mx) * (yi - my);
    }
    let b = if sxx.abs() < 1e-300 { 0.0 } else { sxy / sxx };
    (my - b * mx, b)
}

/// Power-law fit y = c * x^alpha via log-log OLS; returns (c, alpha).
/// Non-positive points are dropped.
pub fn powerlaw_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    let pts: Vec<(f64, f64)> = x
        .iter()
        .zip(y)
        .filter(|(&a, &b)| a > 0.0 && b > 0.0)
        .map(|(&a, &b)| (a.ln(), b.ln()))
        .collect();
    assert!(pts.len() >= 2, "need >= 2 positive points");
    let lx: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ly: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let (a, b) = linreg(&lx, &ly);
    (a.exp(), b)
}

/// Summary of repeated measurements across seeds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub mean: f64,
    pub sem: f64,
    pub n: usize,
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary { mean: mean(xs), sem: sem(xs), n: xs.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_tolerates_nan() {
        // regression: the partial_cmp().unwrap() sort panicked on NaN;
        // total_cmp ranks NaN above every finite value deterministically
        let xs = [1.0, f64::NAN, -2.0, 0.5];
        let q = quantile(&xs, 0.25);
        assert!(q.is_finite(), "low quantile must come from the finite values");
        assert_eq!(
            quantile(&xs, 0.0).to_bits(),
            (-2.0f64).to_bits(),
            "minimum is the smallest finite value"
        );
        // repeated calls agree bitwise (total order, no tie-break races)
        assert_eq!(quantile(&xs, 0.5).to_bits(), quantile(&xs, 0.5).to_bits());
        let _ = ecdf(&xs);
    }

    #[test]
    fn mean_std_sem() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
        assert!((sem(&xs) - 2.13809 / (8.0f64).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
        // interpolation
        let ys = [0.0, 10.0];
        assert!((quantile(&ys, 0.3) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_is_gate_price() {
        // the (1-rho)-quantile used by the adaptive Kondo gate: rho=0.25 of
        // 4 values keeps exactly the top one above the price.
        let chi = [0.1f32, 0.5, -0.3, 0.9];
        let lam = quantile_f32(&chi, 0.75);
        let kept = chi.iter().filter(|&&c| c > lam).count();
        assert_eq!(kept, 1);
    }

    #[test]
    fn ecdf_monotone() {
        let (x, f) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(x, vec![1.0, 2.0, 3.0]);
        assert_eq!(f, vec![1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn linreg_recovers_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linreg(&x, &y);
        assert!((a - 1.0).abs() < 1e-9 && (b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn powerlaw_recovers_exponent() {
        let x = [1.0, 2.0, 4.0, 8.0, 16.0];
        let y: Vec<f64> = x.iter().map(|&v: &f64| 3.0 * v.powf(-1.5)).collect();
        let (c, alpha) = powerlaw_fit(&x, &y);
        assert!((c - 3.0).abs() < 1e-6);
        assert!((alpha + 1.5).abs() < 1e-9);
    }
}
