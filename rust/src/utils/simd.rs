//! AVX2 lowering of the lane-tree kernels (`--features simd`, x86_64).
//!
//! This module exists only under `cfg(all(feature = "simd", target_arch =
//! "x86_64"))`; every caller dispatches at runtime through [`avx2`] and
//! falls back to its scalar twin when the host lacks AVX2 (or on other
//! architectures, where this module is compiled out entirely).
//!
//! **Why these functions are bit-identical to their scalar twins.** The
//! determinism contract (DESIGN.md §9, §13) fixes element `i` into lane
//! `i % LANES` in ascending index order, combined by the tree
//! `(l0 + l1) + (l2 + l3)`. A `LANES`-wide f64 vector register *is* that
//! lane array: one vector add per chunk performs the four scalar
//! `acc[l] += x * w` statements with identical IEEE-754 rounding, because
//! vector `mul_pd`/`add_pd` are exactly rounded per element just like
//! their scalar counterparts. Three rules keep it exact:
//!
//! 1. **Never fuse.** Multiplies and adds stay separate instructions
//!    (`_mm256_mul_pd` then `_mm256_add_pd`); an FMA would skip the
//!    intermediate rounding the scalar code performs. (The debug-vs-
//!    release CI step would catch an accidental contraction.)
//! 2. **Never reassociate.** Horizontal reduction uses the same
//!    `(l0 + l1) + (l2 + l3)` tree as `utils::math::lane_reduce` —
//!    either literally (store + `lane_reduce`) or via the
//!    `hadd`/`permute2f128` sequence whose adds are that exact tree.
//!    (IEEE-754 addition is commutative in value for non-NaN operands,
//!    so `hadd`'s `hi + lo` pair order equals `l0 + l1` bitwise.)
//! 3. **Transcendentals stay scalar.** `tanh`/`exp`/`ln` go through the
//!    same libm calls as the scalar path; only loads, converts, `mul`,
//!    `sub`, and `add` are vectorized.
//!
//! Ragged tails (`len % LANES != 0`) run the scalar twin's own tail
//! statements, so every length — not just vector-friendly ones — reduces
//! in the contract order.

use core::arch::x86_64::*;
use std::sync::OnceLock;

use super::math::{lane_reduce, LANES};

/// Runtime CPU-feature dispatch, detected once per process. `true` means
/// the `*_avx2` entry points in this module are safe to call.
pub fn avx2() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// One register tile of the GEMM microkernel: `sums[j]` receives the
/// lane-reduced `sum_kk x[kk] * panel[kk * PANEL + j]` for the four panel
/// columns (`PANEL == LANES == 4`). Bitwise equal to `panel_dot` +
/// `lane_reduce` per column.
///
/// # Safety
/// Caller must ensure `avx2()` is true, `xr.len() >= k`, and
/// `panel.len() >= k * 4` (the packed-panel layout guarantees the
/// latter exactly).
#[target_feature(enable = "avx2")]
pub unsafe fn panel_dot_avx2(xr: &[f32], panel: &[f32], k: usize, sums: &mut [f64; 4]) {
    debug_assert!(xr.len() >= k && panel.len() >= k * 4);
    let xp = xr.as_ptr();
    let pp = panel.as_ptr();
    let mut a0 = _mm256_setzero_pd();
    let mut a1 = _mm256_setzero_pd();
    let mut a2 = _mm256_setzero_pd();
    let mut a3 = _mm256_setzero_pd();
    let chunks = k / 4;
    for c in 0..chunks {
        let base = c * 4;
        let xv = _mm256_cvtps_pd(_mm_loadu_ps(xp.add(base)));
        // four packed rows kk = base..base+4, each holding the 4 panel
        // columns for that kk
        let r0 = _mm_loadu_ps(pp.add(base * 4));
        let r1 = _mm_loadu_ps(pp.add(base * 4 + 4));
        let r2 = _mm_loadu_ps(pp.add(base * 4 + 8));
        let r3 = _mm_loadu_ps(pp.add(base * 4 + 12));
        // 4x4 f32 transpose: after this, c_j lane l = panel[(base+l)*4+j]
        let t0 = _mm_unpacklo_ps(r0, r1);
        let t1 = _mm_unpackhi_ps(r0, r1);
        let t2 = _mm_unpacklo_ps(r2, r3);
        let t3 = _mm_unpackhi_ps(r2, r3);
        let c0 = _mm_movelh_ps(t0, t2);
        let c1 = _mm_movehl_ps(t2, t0);
        let c2 = _mm_movelh_ps(t1, t3);
        let c3 = _mm_movehl_ps(t3, t1);
        // separate mul + add (rule 1): lane l performs exactly the scalar
        // `acc[j][l] += xr[base+l] as f64 * panel[(base+l)*4+j] as f64`
        a0 = _mm256_add_pd(a0, _mm256_mul_pd(xv, _mm256_cvtps_pd(c0)));
        a1 = _mm256_add_pd(a1, _mm256_mul_pd(xv, _mm256_cvtps_pd(c1)));
        a2 = _mm256_add_pd(a2, _mm256_mul_pd(xv, _mm256_cvtps_pd(c2)));
        a3 = _mm256_add_pd(a3, _mm256_mul_pd(xv, _mm256_cvtps_pd(c3)));
    }
    let base = chunks * 4;
    if base == k {
        // horizontal (l0+l1)+(l2+l3) for all four columns at once:
        // hadd_pd pairs lanes {0,1} and {2,3} within each 128-bit half,
        // the permutes gather the (l0+l1) terms into `lo` and the
        // (l2+l3) terms into `hi`, and one add_pd finishes the tree
        let h01 = _mm256_hadd_pd(a0, a1);
        let h23 = _mm256_hadd_pd(a2, a3);
        let lo = _mm256_permute2f128_pd(h01, h23, 0x20);
        let hi = _mm256_permute2f128_pd(h01, h23, 0x31);
        _mm256_storeu_pd(sums.as_mut_ptr(), _mm256_add_pd(lo, hi));
    } else {
        // ragged k: spill the lanes and run the scalar twin's own tail +
        // tree so the reduction order is the contract's, not a shortcut
        let mut acc = [[0.0f64; 4]; 4];
        _mm256_storeu_pd(acc[0].as_mut_ptr(), a0);
        _mm256_storeu_pd(acc[1].as_mut_ptr(), a1);
        _mm256_storeu_pd(acc[2].as_mut_ptr(), a2);
        _mm256_storeu_pd(acc[3].as_mut_ptr(), a3);
        for l in 0..(k - base) {
            let xv = *xp.add(base + l) as f64;
            for (j, accj) in acc.iter_mut().enumerate() {
                accj[l] += xv * *pp.add((base + l) * 4 + j) as f64;
            }
        }
        for (s, accj) in sums.iter_mut().zip(acc.iter()) {
            *s = lane_reduce(accj);
        }
    }
}

/// Lane-reduced dot product; bitwise equal to `utils::math::dot_scalar`.
///
/// # Safety
/// Caller must ensure `avx2()` is true.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut accv = _mm256_setzero_pd();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        let av = _mm256_cvtps_pd(_mm_loadu_ps(ap.add(base)));
        let bv = _mm256_cvtps_pd(_mm_loadu_ps(bp.add(base)));
        accv = _mm256_add_pd(accv, _mm256_mul_pd(av, bv));
    }
    let mut acc = [0.0f64; LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), accv);
    let base = chunks * LANES;
    for l in 0..(n - base) {
        acc[l] += *ap.add(base + l) as f64 * *bp.add(base + l) as f64;
    }
    lane_reduce(&acc)
}

/// The perpendicular-component accumulation of `utils::math::perp_norm2`
/// given the already-computed projection coefficient: lane-reduced
/// `sum (a[i] - proj * dir[i])^2`. Bitwise equal to the scalar loop.
///
/// # Safety
/// Caller must ensure `avx2()` is true.
#[target_feature(enable = "avx2")]
pub unsafe fn perp_acc_avx2(a: &[f32], dir: &[f32], proj: f64) -> f64 {
    let n = a.len().min(dir.len());
    let ap = a.as_ptr();
    let dp = dir.as_ptr();
    let projv = _mm256_set1_pd(proj);
    let mut accv = _mm256_setzero_pd();
    let chunks = n / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        let av = _mm256_cvtps_pd(_mm_loadu_ps(ap.add(base)));
        let dv = _mm256_cvtps_pd(_mm_loadu_ps(dp.add(base)));
        let pv = _mm256_sub_pd(av, _mm256_mul_pd(projv, dv));
        accv = _mm256_add_pd(accv, _mm256_mul_pd(pv, pv));
    }
    let mut acc = [0.0f64; LANES];
    _mm256_storeu_pd(acc.as_mut_ptr(), accv);
    let base = chunks * LANES;
    for l in 0..(n - base) {
        let p = *ap.add(base + l) as f64 - proj * *dp.add(base + l) as f64;
        acc[l] += p * p;
    }
    lane_reduce(&acc)
}

/// The accumulation phase of `gather_mix_masked`: `acc[v * LANES + l] +=
/// coef[kk] * table[idx[kk] * width + v]` for `kk % LANES == l`, ascending
/// kk. The caller zeroes `acc` first and performs the shared scalar
/// lane-reduce afterwards, so the tree stays in exactly one place.
///
/// # Safety
/// Caller must ensure `avx2()` is true, `acc.len() >= m * LANES`,
/// `idx[kk] * width + m <= table.len()` for all kk, and
/// `idx.len() == coef.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn gather_mix_acc_avx2(
    coef: &[f32],
    table: &[f32],
    width: usize,
    idx: &[usize],
    m: usize,
    acc: &mut [f64],
) {
    debug_assert!(acc.len() >= m * LANES && idx.len() == coef.len());
    let chunks = coef.len() / LANES;
    for c in 0..chunks {
        let base = c * LANES;
        let cv = _mm256_cvtps_pd(_mm_loadu_ps(coef.as_ptr().add(base)));
        let t0 = table.as_ptr().add(idx[base] * width);
        let t1 = table.as_ptr().add(idx[base + 1] * width);
        let t2 = table.as_ptr().add(idx[base + 2] * width);
        let t3 = table.as_ptr().add(idx[base + 3] * width);
        for v in 0..m {
            // set_pd takes lanes high-to-low: lane l = row base+l, slot v
            let tv = _mm256_set_pd(
                *t3.add(v) as f64,
                *t2.add(v) as f64,
                *t1.add(v) as f64,
                *t0.add(v) as f64,
            );
            let av = _mm256_loadu_pd(acc.as_ptr().add(v * LANES));
            _mm256_storeu_pd(
                acc.as_mut_ptr().add(v * LANES),
                _mm256_add_pd(av, _mm256_mul_pd(cv, tv)),
            );
        }
    }
    // ragged tail: the scalar twin's own statements
    for kk in chunks * LANES..coef.len() {
        let l = kk % LANES;
        let cv = coef[kk] as f64;
        let trow = &table[idx[kk] * width..idx[kk] * width + m];
        for (v, &e) in trow.iter().enumerate() {
            acc[v * LANES + l] += cv * e as f64;
        }
    }
}

/// Elementwise softmax-Jacobian row `out[i] = a[i] * (da[i] - d)`, all in
/// f32 exactly like the scalar statement (no reduction involved, so
/// 8-wide f32 is bitwise exact).
///
/// # Safety
/// Caller must ensure `avx2()` is true and `da.len() >= a.len()`,
/// `out.len() >= a.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn jacobian_row_avx2(a: &[f32], da: &[f32], d: f32, out: &mut [f32]) {
    let n = a.len();
    debug_assert!(da.len() >= n && out.len() >= n);
    let d8 = _mm256_set1_ps(d);
    let chunks = n / 8;
    for c in 0..chunks {
        let base = c * 8;
        let av = _mm256_loadu_ps(a.as_ptr().add(base));
        let dv = _mm256_loadu_ps(da.as_ptr().add(base));
        _mm256_storeu_ps(
            out.as_mut_ptr().add(base),
            _mm256_mul_ps(av, _mm256_sub_ps(dv, d8)),
        );
    }
    for i in chunks * 8..n {
        out[i] = a[i] * (da[i] - d);
    }
}

/// `out[i] = src[i] - s`, elementwise f32 (the log-softmax normalization
/// subtract). Bitwise equal to the scalar loop.
///
/// # Safety
/// Caller must ensure `avx2()` is true and `out.len() >= src.len()`.
#[target_feature(enable = "avx2")]
pub unsafe fn sub_scalar_avx2(src: &[f32], s: f32, out: &mut [f32]) {
    let n = src.len();
    debug_assert!(out.len() >= n);
    let s8 = _mm256_set1_ps(s);
    let chunks = n / 8;
    for c in 0..chunks {
        let base = c * 8;
        let v = _mm256_loadu_ps(src.as_ptr().add(base));
        _mm256_storeu_ps(out.as_mut_ptr().add(base), _mm256_sub_ps(v, s8));
    }
    for i in chunks * 8..n {
        out[i] = src[i] - s;
    }
}

/// In-place `xs[i] -= s` (the fused-GEMM log-softmax second pass).
///
/// # Safety
/// Caller must ensure `avx2()` is true.
#[target_feature(enable = "avx2")]
pub unsafe fn sub_scalar_inplace_avx2(xs: &mut [f32], s: f32) {
    let s8 = _mm256_set1_ps(s);
    let n = xs.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let base = c * 8;
        let v = _mm256_loadu_ps(xs.as_ptr().add(base));
        _mm256_storeu_ps(xs.as_mut_ptr().add(base), _mm256_sub_ps(v, s8));
    }
    for x in &mut xs[chunks * 8..] {
        *x -= s;
    }
}

#[cfg(test)]
mod tests {
    //! Direct intrinsic-level twins; the public dispatched-vs-scalar
    //! property suite lives in rust/tests/simd_equivalence.rs and runs in
    //! both feature configurations.
    use super::*;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..n)
            .map(|_| {
                state = state
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((state >> 33) as f64 / (1u64 << 31) as f64 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn dot_avx2_is_bitwise_scalar_dot() {
        if !avx2() {
            return; // host without AVX2: dispatch never reaches these paths
        }
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 31, 784] {
            let a = randv(n, 1 + n as u64);
            let b = randv(n, 100 + n as u64);
            let simd = unsafe { dot_avx2(&a, &b) };
            let scalar = crate::utils::math::dot_scalar(&a, &b);
            assert_eq!(simd.to_bits(), scalar.to_bits(), "n={n}");
        }
    }

    #[test]
    fn panel_dot_avx2_matches_scalar_tree_both_reduce_paths() {
        if !avx2() {
            return;
        }
        // k % 4 == 0 exercises the hadd tree, the rest the spill + tail
        for k in [4usize, 8, 784, 1, 2, 3, 5, 7, 33] {
            let xr = randv(k, 7 + k as u64);
            let panel = randv(k * 4, 200 + k as u64);
            let mut sums = [0.0f64; 4];
            unsafe { panel_dot_avx2(&xr, &panel, k, &mut sums) };
            for (j, &s) in sums.iter().enumerate() {
                let mut acc = [0.0f64; LANES];
                for (kk, &x) in xr.iter().enumerate() {
                    acc[kk % LANES] += x as f64 * panel[kk * 4 + j] as f64;
                }
                assert_eq!(s.to_bits(), lane_reduce(&acc).to_bits(), "k={k} j={j}");
            }
        }
    }
}
