//! Minimal TOML-subset parser — substrate for `configs/*.toml` presets.
//!
//! Supports: `[section]` / `[section.sub]` headers, `key = value` with
//! string / integer / float / boolean / flat-array values, `#` comments.
//! That is the whole subset the config system emits and reads; anything
//! else is a hard error so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_arr(&self) -> Option<Vec<f64>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Flat document: keys are "section.key" (dotted) or bare "key".
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(stripped) = line.strip_prefix('[') {
                let name = stripped
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(|v| v.as_i64())
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    if !s.contains(['.', 'e', 'E']) {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    s.parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("cannot parse value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            name = "fig1"          # trailing comment
            seeds = 30
            [gate]
            rho = 0.03
            adaptive = true
            caps = [4, 8, 16]
            [gate.inner]
            eta = 1e-3
            "#,
        )
        .unwrap();
        assert_eq!(doc.str("name"), Some("fig1"));
        assert_eq!(doc.i64("seeds"), Some(30));
        assert_eq!(doc.f64("gate.rho"), Some(0.03));
        assert_eq!(doc.bool("gate.adaptive"), Some(true));
        assert_eq!(
            doc.get("gate.caps").unwrap().as_f64_arr().unwrap(),
            vec![4.0, 8.0, 16.0]
        );
        assert_eq!(doc.f64("gate.inner.eta"), Some(1e-3));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str("k"), Some("a#b"));
    }

    #[test]
    fn later_keys_override() {
        let doc = TomlDoc::parse("k = 1\nk = 2").unwrap();
        assert_eq!(doc.i64("k"), Some(2));
    }
}
