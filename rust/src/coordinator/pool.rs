//! Worker pool: the sharded execution substrate of the L3 coordinator.
//!
//! A batch is split into contiguous shards; workers (std::thread + mpsc
//! channels) run forward execution, delight scoring, and bucketed backward
//! chunks concurrently. Everything here is built around one invariant,
//! the **determinism contract** (DESIGN.md §"L3 parallelism"):
//!
//!   the training trajectory is a pure function of the seed, independent
//!   of the `workers` knob.
//!
//! Three mechanisms enforce it:
//! 1. `run` returns results in *task order*, no matter which worker
//!    finished first -- merges (chi scores, gradients) always happen in a
//!    fixed order on the caller's thread.
//! 2. Per-sample randomness comes from `unit_rng(seed, step, i)` streams
//!    keyed by the sample's batch index, not from a shared sequential
//!    generator -- shard boundaries cannot shift anybody's draws.
//! 3. Batch-global decisions (the Kondo gate's quantile price) are taken
//!    on the merged score vector, never per shard.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::{mpsc, Mutex};

use crate::utils::rng::Pcg32;

/// One contiguous slice of a batch, assigned to a logical shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub start: usize,
    pub end: usize,
}

impl Shard {
    /// The whole batch as a single shard.
    pub fn full(n: usize) -> Shard {
        Shard { index: 0, start: 0, end: n }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }
}

/// Deterministic contiguous split of `n` items into at most `workers`
/// shards (sizes differ by at most one, larger shards first). Depends only
/// on `(n, workers)`.
pub fn split_shards(n: usize, workers: usize) -> Vec<Shard> {
    let w = workers.max(1).min(n.max(1));
    let base = n / w;
    let rem = n % w;
    let mut shards = Vec::with_capacity(w);
    let mut start = 0;
    for index in 0..w {
        let len = base + usize::from(index < rem);
        shards.push(Shard { index, start, end: start + len });
        start += len;
    }
    shards
}

/// Per-(seed, step, unit) RNG stream. All per-sample randomness (action
/// sampling, reward noise) draws from these streams so that the draw a
/// sample sees is a function of its batch index alone -- the heart of the
/// determinism contract.
pub fn unit_rng(seed: u64, step: u64, unit: u64) -> Pcg32 {
    let stream = unit.wrapping_mul(2).wrapping_add(1);
    Pcg32::new(seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15), stream)
}

/// Fixed-size worker pool over scoped threads. Stateless between calls:
/// each `run` spawns up to `workers` scoped threads that drain a shared
/// task queue and send `(index, result)` pairs back over an mpsc channel;
/// the caller reassembles results in task order.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool { workers: workers.max(1) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every task, returning results in task order. With one
    /// worker (or at most one task) this degenerates to an inline loop on
    /// the caller's thread -- the `workers = 1` baseline path that sharded
    /// runs must reproduce bit for bit.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = tasks.len();
        if self.workers == 1 || n <= 1 {
            return tasks.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }

        let queue: Mutex<VecDeque<(usize, T)>> =
            Mutex::new(tasks.into_iter().enumerate().collect());
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        let n_threads = self.workers.min(n);
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                let tx = tx.clone();
                let queue = &queue;
                let f = &f;
                scope.spawn(move || loop {
                    let task = queue.lock().unwrap().pop_front();
                    let Some((i, t)) = task else { break };
                    if tx.send((i, f(i, t))).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter()
                .map(|r| r.expect("pool worker terminated before returning its result"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_shards_covers_batch_exactly() {
        for (n, w) in [(32, 4), (33, 4), (10, 3), (5, 8), (1, 4), (100, 7)] {
            let shards = split_shards(n, w);
            assert!(shards.len() <= w);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, n);
            let total: usize = shards.iter().map(Shard::len).sum();
            assert_eq!(total, n, "n={n} w={w}");
            for pair in shards.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
                // sizes differ by at most one, monotonically non-increasing
                assert!(pair[0].len() >= pair[1].len());
                assert!(pair[0].len() - pair[1].len() <= 1);
            }
            for (i, s) in shards.iter().enumerate() {
                assert_eq!(s.index, i);
                assert!(!s.is_empty());
            }
        }
    }

    #[test]
    fn split_shards_empty_batch() {
        let shards = split_shards(0, 4);
        assert_eq!(shards.len(), 1);
        assert!(shards[0].is_empty());
    }

    #[test]
    fn run_preserves_task_order() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<usize> = (0..64).collect();
        let out = pool.run(tasks, |i, t| {
            assert_eq!(i, t);
            // stagger completion to scramble any accidental order reliance
            std::thread::sleep(std::time::Duration::from_micros(((64 - t) % 7) as u64 * 50));
            t * 10
        });
        assert_eq!(out, (0..64).map(|t| t * 10).collect::<Vec<_>>());
    }

    #[test]
    fn run_single_worker_is_inline() {
        let pool = WorkerPool::new(1);
        let tid = std::thread::current().id();
        let out = pool.run(vec![1, 2, 3], |_, t| {
            assert_eq!(std::thread::current().id(), tid);
            t + 1
        });
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn run_executes_every_task_once() {
        let pool = WorkerPool::new(8);
        let count = AtomicUsize::new(0);
        let out = pool.run((0..200).collect::<Vec<_>>(), |_, t: i32| {
            count.fetch_add(1, Ordering::SeqCst);
            t
        });
        assert_eq!(count.load(Ordering::SeqCst), 200);
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn run_results_independent_of_worker_count() {
        let tasks: Vec<u64> = (0..50).collect();
        let f = |_: usize, t: u64| {
            // deterministic per-task work with its own rng stream
            let mut rng = unit_rng(9, 3, t);
            rng.next_u32() as u64 + t
        };
        let a = WorkerPool::new(1).run(tasks.clone(), f);
        let b = WorkerPool::new(4).run(tasks.clone(), f);
        let c = WorkerPool::new(16).run(tasks, f);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn unit_rng_streams_are_stable_and_distinct() {
        let mut a = unit_rng(1, 2, 3);
        let mut b = unit_rng(1, 2, 3);
        assert_eq!(a.next_u32(), b.next_u32());
        let mut c = unit_rng(1, 2, 4);
        let mut d = unit_rng(1, 3, 3);
        let x = unit_rng(1, 2, 3).next_u32();
        assert_ne!(x, c.next_u32());
        assert_ne!(x, d.next_u32());
    }
}
