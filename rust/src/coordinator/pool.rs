//! Worker pool: the sharded execution substrate of the L3 coordinator.
//!
//! A batch is split into contiguous shards; workers run forward execution,
//! delight scoring, and bucketed backward chunks concurrently. The pool is
//! **persistent**: `WorkerPool::new(workers)` spawns `workers` long-lived
//! threads once (owned by `trainers::GatedLoop`, so they live for a whole
//! training run); every `run` call feeds them type-erased jobs over a
//! shared mpsc channel, and `Drop` closes the channel and joins every
//! thread. Spawn cost is therefore paid once per run, not three times per
//! training step (the PR-1 scoped-thread pool's hot-path churn).
//!
//! Everything here is built around one invariant, the **determinism
//! contract** (DESIGN.md §"L3 parallelism"):
//!
//!   the training trajectory is a pure function of the seed, independent
//!   of the `workers` knob.
//!
//! Three mechanisms enforce it:
//! 1. `run` returns results in *task order*, no matter which worker
//!    finished first -- merges (chi scores, gradients) always happen in a
//!    fixed order on the caller's thread.
//! 2. Per-sample randomness comes from `unit_rng(seed, step, i)` streams
//!    keyed by the sample's batch index, not from a shared sequential
//!    generator -- shard boundaries cannot shift anybody's draws.
//! 3. Batch-global decisions (the Kondo gate's quantile price) are taken
//!    on the merged score vector, never per shard.
//!
//! A task that panics does not kill its worker thread or hang the caller:
//! the panic payload is captured, the remaining queue is cancelled, and
//! `run` re-raises the panic on the calling thread once every in-flight
//! task has finished.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::utils::rng::Pcg32;

/// One contiguous slice of a batch, assigned to a logical shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: usize,
    pub start: usize,
    pub end: usize,
}

impl Shard {
    /// The whole batch as a single shard.
    pub fn full(n: usize) -> Shard {
        Shard { index: 0, start: 0, end: n }
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    pub fn range(&self) -> Range<usize> {
        self.start..self.end
    }
}

/// Deterministic contiguous split of `n` items into at most `workers`
/// shards (sizes differ by at most one, larger shards first). Depends only
/// on `(n, workers)`. For `n = 0` this returns a single empty shard (the
/// split always covers the batch); dispatch layers must skip empty shards
/// rather than hand them to workers as tasks (`GatedLoop::shards`).
pub fn split_shards(n: usize, workers: usize) -> Vec<Shard> {
    let w = workers.max(1).min(n.max(1));
    let base = n / w;
    let rem = n % w;
    let mut shards = Vec::with_capacity(w);
    let mut start = 0;
    for index in 0..w {
        let len = base + usize::from(index < rem);
        shards.push(Shard { index, start, end: start + len });
        start += len;
    }
    shards
}

/// The dispatch-layer view of `split_shards`: empty shards (n = 0 yields
/// one) are dropped so they are never handed to workers as tasks. This is
/// THE rule every dispatch/planning site shares (`GatedLoop::shards`,
/// `ForwardStage::plan`); change it here, not in copies.
pub fn non_empty_shards(n: usize, workers: usize) -> Vec<Shard> {
    split_shards(n, workers).into_iter().filter(|s| !s.is_empty()).collect()
}

/// Per-(seed, step, unit) RNG stream. All per-sample randomness (action
/// sampling, reward noise) draws from these streams so that the draw a
/// sample sees is a function of its batch index alone -- the heart of the
/// determinism contract.
pub fn unit_rng(seed: u64, step: u64, unit: u64) -> Pcg32 {
    let stream = unit.wrapping_mul(2).wrapping_add(1);
    Pcg32::new(seed ^ step.wrapping_mul(0x9e37_79b9_7f4a_7c15), stream)
}

/// A type-erased unit of work shipped to a persistent worker thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// THE pool-wide mutex-poisoning policy: absorb poison, take the guard.
/// A task panic is captured and re-raised through the `panic` slot of its
/// run, so a poisoned lock never carries information of its own here; one
/// policy at every lock site keeps a recoverable panic from cascading
/// into a secondary `PoisonError` panic (the bug class this replaces:
/// `drain` used `.unwrap()` while the wait path absorbed poison).
fn lock_ok<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Shared state of one `run` call. Lives on the caller's stack; workers
/// reach it through the lifetime-erased job closures, which is sound
/// because `run` blocks on the completion barrier (`finished` /
/// `all_done`) until every dispatched job has finished touching it.
struct RunState<T, R, F> {
    /// unclaimed `(task_index, task)` pairs, drained by workers
    queue: Mutex<VecDeque<(usize, T)>>,
    /// results slotted by task index -- the task-order merge
    out: Mutex<Vec<Option<R>>>,
    /// first captured panic payload from a task, re-raised by the caller
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    /// how many dispatched jobs have fully finished (completion barrier)
    finished: Mutex<usize>,
    all_done: Condvar,
    f: F,
}

impl<T, R, F> RunState<T, R, F>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    /// Worker-side body of one job: drain the task queue until empty. A
    /// panicking task records its payload, cancels the remaining queue,
    /// and keeps the worker thread alive for future runs. The `Finish`
    /// guard bumps the completion barrier even if this frame unwinds, so
    /// the caller can never be left waiting on a dead job.
    fn drain(&self) {
        struct Finish<'a>(&'a Mutex<usize>, &'a Condvar);
        impl Drop for Finish<'_> {
            fn drop(&mut self) {
                let mut fin = lock_ok(self.0);
                *fin += 1;
                self.1.notify_all();
            }
        }
        let _finish = Finish(&self.finished, &self.all_done);

        loop {
            // every lock site in this run-state is poison-tolerant: a task
            // panic is already captured and propagated via the `panic`
            // slot, so a poisoned mutex carries no extra information --
            // treating it as fatal would turn one recoverable panic into a
            // secondary panic on whichever thread touches the lock next
            let task = lock_ok(&self.queue).pop_front();
            let Some((i, t)) = task else { break };
            match catch_unwind(AssertUnwindSafe(|| (self.f)(i, t))) {
                Ok(r) => {
                    lock_ok(&self.out)[i] = Some(r);
                }
                Err(payload) => {
                    // cancel undispatched tasks; keep the first payload
                    lock_ok(&self.queue).clear();
                    let mut slot = lock_ok(&self.panic);
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            }
        }
    }
}

/// Fixed-size pool of persistent worker threads. Threads are spawned once
/// in `new`, blocked on a shared job channel between `run` calls, and
/// joined when the pool drops. `workers = 1` spawns no threads at all --
/// every `run` degenerates to an inline loop on the caller's thread, the
/// serial baseline that sharded runs must reproduce bit for bit.
pub struct WorkerPool {
    workers: usize,
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// worker threads currently running (observability + drop-join tests)
    alive: Arc<AtomicUsize>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("threads", &self.handles.len())
            .field("alive", &self.alive.load(Ordering::SeqCst))
            .finish()
    }
}

thread_local! {
    /// True on pool worker threads. A nested `run` (a task that itself
    /// calls `run` on some pool) executes inline on the worker instead of
    /// queueing jobs behind workers that are all busy running its parent
    /// -- the scoped-thread pool tolerated reentrancy and the persistent
    /// pool must not turn it into a silent deadlock.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn worker_main(rx: Arc<Mutex<mpsc::Receiver<Job>>>, alive: Arc<AtomicUsize>) {
    IN_POOL_WORKER.with(|flag| flag.set(true));
    alive.fetch_add(1, Ordering::SeqCst);
    loop {
        // hold the receiver lock only to pull one job; execution runs
        // unlocked so idle workers can grab the next job immediately
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break,
        };
        match job {
            // jobs capture their own panics (RunState::drain); this outer
            // catch is a belt-and-braces guard keeping the thread alive
            Ok(job) => {
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
            // channel closed: the pool is dropping
            Err(_) => break,
        }
    }
    // park this worker's tensor-arena freelist in the shared pool so the
    // buffers a finished run warmed up serve the next run's (fresh)
    // worker threads instead of dying with this one
    crate::runtime::tensor::flush_local_arena_to_shared();
    alive.fetch_sub(1, Ordering::SeqCst);
}

impl WorkerPool {
    /// Spawn the pool. Thread-spawn failure (resource exhaustion) is an
    /// error, not a panic: callers (`GatedLoop::new`, and through it both
    /// trainers and the distrib learner) surface it as a clean run
    /// failure -- the disable-don't-panic policy of DESIGN.md §11. Any
    /// threads already spawned before the failing one are shut down and
    /// joined before the error returns, so a failed construction leaks
    /// nothing.
    pub fn new(workers: usize) -> Result<WorkerPool> {
        let workers = workers.max(1);
        let alive = Arc::new(AtomicUsize::new(0));
        if workers == 1 {
            return Ok(WorkerPool { workers, tx: None, handles: Vec::new(), alive });
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            let alive = Arc::clone(&alive);
            match std::thread::Builder::new()
                .name(format!("kondo-pool-{i}"))
                .spawn(move || worker_main(rx, alive))
            {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // close the channel so the already-spawned workers see
                    // RecvError and exit, then join them before erroring
                    drop(tx);
                    for handle in handles {
                        let _ = handle.join();
                    }
                    return Err(e).with_context(|| {
                        format!("spawning persistent pool worker {i} of {workers}")
                    });
                }
            }
        }
        Ok(WorkerPool { workers, tx: Some(tx), handles, alive })
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `f` over every task, returning results in task order. With one
    /// worker (or at most one task) this is an inline loop on the caller's
    /// thread -- the `workers = 1` baseline path that sharded runs must
    /// reproduce bit for bit. Otherwise up to `workers` persistent threads
    /// drain a shared queue and slot results by task index; the caller
    /// blocks until every dispatched job has finished. If a task panicked,
    /// the panic is re-raised here (on the calling thread) after all
    /// in-flight tasks completed, and the pool remains usable.
    ///
    /// A nested `run` -- called from inside a task already executing on a
    /// pool worker -- runs inline on that worker (same results, task
    /// order preserved) rather than queueing behind workers that may all
    /// be busy with its parent, which would deadlock.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = tasks.len();
        if self.handles.is_empty() || n <= 1 || IN_POOL_WORKER.with(|flag| flag.get()) {
            return tasks.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }

        let n_jobs = self.handles.len().min(n);
        let state = RunState {
            queue: Mutex::new(tasks.into_iter().enumerate().collect()),
            out: Mutex::new((0..n).map(|_| None).collect()),
            panic: Mutex::new(None),
            finished: Mutex::new(0usize),
            all_done: Condvar::new(),
            f,
        };
        let send_failed = {
            let state_ref = &state;
            let tx = self.tx.as_ref().expect("pool with threads must hold its channel");
            let mut sent = 0usize;
            let mut send_failed = false;
            for _ in 0..n_jobs {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || state_ref.drain());
                // SAFETY: the job borrows `state`, which lives on this
                // stack frame. The barrier below blocks until every job
                // actually sent has bumped `finished` (guaranteed even on
                // task unwind by the `Finish` drop guard), and nothing on
                // this path between the first send and the barrier can
                // unwind (send failure is counted, poison is absorbed), so
                // no worker can touch `state` after `run` returns; erasing
                // the lifetime to ship the box through the 'static channel
                // is therefore sound.
                let job: Job = unsafe { std::mem::transmute(job) };
                match tx.send(job) {
                    Ok(()) => sent += 1,
                    // all workers gone (cannot happen while the pool is
                    // alive, but never leave borrowed jobs unaccounted):
                    // the unsent job was dropped inside the SendError
                    Err(_) => {
                        send_failed = true;
                        break;
                    }
                }
            }
            let mut fin = lock_ok(&state.finished);
            while *fin < sent {
                fin = state.all_done.wait(fin).unwrap_or_else(|e| e.into_inner());
            }
            send_failed
        };
        if send_failed {
            panic!("persistent pool channel closed with live workers expected");
        }
        if let Some(payload) = lock_ok(&state.panic).take() {
            resume_unwind(payload);
        }
        state
            .out
            .into_inner()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .into_iter()
            .map(|r| r.expect("pool worker terminated before returning its result"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the channel wakes every idle worker with RecvError
        drop(self.tx.take());
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn split_shards_covers_batch_exactly() {
        for (n, w) in [(32, 4), (33, 4), (10, 3), (5, 8), (1, 4), (100, 7)] {
            let shards = split_shards(n, w);
            assert!(shards.len() <= w);
            assert_eq!(shards[0].start, 0);
            assert_eq!(shards.last().unwrap().end, n);
            let total: usize = shards.iter().map(Shard::len).sum();
            assert_eq!(total, n, "n={n} w={w}");
            for pair in shards.windows(2) {
                assert_eq!(pair[0].end, pair[1].start);
                // sizes differ by at most one, monotonically non-increasing
                assert!(pair[0].len() >= pair[1].len());
                assert!(pair[0].len() - pair[1].len() <= 1);
            }
            for (i, s) in shards.iter().enumerate() {
                assert_eq!(s.index, i);
                assert!(!s.is_empty());
            }
        }
    }

    #[test]
    fn split_shards_empty_batch() {
        // contract: the split always covers the batch, so n = 0 yields one
        // empty shard; dispatch layers (GatedLoop::shards) must skip it
        let shards = split_shards(0, 4);
        assert_eq!(shards.len(), 1);
        assert!(shards[0].is_empty());
    }

    #[test]
    fn run_preserves_task_order() {
        let pool = WorkerPool::new(4).unwrap();
        let tasks: Vec<usize> = (0..64).collect();
        let out = pool.run(tasks, |i, t| {
            assert_eq!(i, t);
            // stagger completion to scramble any accidental order reliance
            std::thread::sleep(std::time::Duration::from_micros(((64 - t) % 7) as u64 * 50));
            t * 10
        });
        assert_eq!(out, (0..64).map(|t| t * 10).collect::<Vec<_>>());
    }

    #[test]
    fn run_single_worker_is_inline() {
        let pool = WorkerPool::new(1).unwrap();
        assert!(pool.handles.is_empty(), "workers = 1 must not spawn threads");
        let tid = std::thread::current().id();
        let out = pool.run(vec![1, 2, 3], |_, t| {
            assert_eq!(std::thread::current().id(), tid);
            t + 1
        });
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn run_executes_every_task_once() {
        let pool = WorkerPool::new(8).unwrap();
        let count = AtomicUsize::new(0);
        let out = pool.run((0..200).collect::<Vec<_>>(), |_, t: i32| {
            count.fetch_add(1, Ordering::SeqCst);
            t
        });
        assert_eq!(count.load(Ordering::SeqCst), 200);
        assert_eq!(out.len(), 200);
    }

    #[test]
    fn run_results_independent_of_worker_count() {
        let tasks: Vec<u64> = (0..50).collect();
        let f = |_: usize, t: u64| {
            // deterministic per-task work with its own rng stream
            let mut rng = unit_rng(9, 3, t);
            rng.next_u32() as u64 + t
        };
        let a = WorkerPool::new(1).unwrap().run(tasks.clone(), f);
        let b = WorkerPool::new(4).unwrap().run(tasks.clone(), f);
        let c = WorkerPool::new(16).unwrap().run(tasks, f);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn pool_threads_persist_across_runs() {
        // the tentpole property: many run() calls reuse the same threads.
        // The scoped-spawn pool minted fresh ThreadIds every call; the
        // persistent pool's id set stays bounded by the worker count.
        let pool = WorkerPool::new(4).unwrap();
        let mut ids: HashSet<std::thread::ThreadId> = HashSet::new();
        for _ in 0..10 {
            let out = pool.run((0..16).collect::<Vec<usize>>(), |_, _t| {
                std::thread::current().id()
            });
            assert_eq!(out.len(), 16);
            ids.extend(out);
        }
        assert!(
            ids.len() <= 4,
            "10 runs used {} distinct threads; persistent workers must reuse threads",
            ids.len()
        );
    }

    #[test]
    fn run_returns_correct_results_across_many_reuses() {
        let pool = WorkerPool::new(4).unwrap();
        for round in 0..25usize {
            let out = pool.run((0..20).collect::<Vec<usize>>(), |i, t| {
                assert_eq!(i, t);
                t * 3 + round
            });
            assert_eq!(out, (0..20).map(|t| t * 3 + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn drop_joins_all_worker_threads() {
        let pool = WorkerPool::new(6).unwrap();
        let alive = Arc::clone(&pool.alive);
        let out = pool.run((0..32).collect::<Vec<usize>>(), |_, t| t);
        assert_eq!(out.len(), 32);
        drop(pool);
        // drop joined every handle, and each worker decrements `alive` on
        // exit, so a nonzero count here means a leaked thread
        assert_eq!(alive.load(Ordering::SeqCst), 0, "worker threads leaked past Drop");
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4).unwrap();
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run((0..8).collect::<Vec<usize>>(), |_, t| {
                if t == 3 {
                    panic!("boom");
                }
                t
            })
        }));
        let payload = result.expect_err("a panicking task must propagate, not hang");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("<non-str payload>");
        assert_eq!(msg, "boom");
        // the panic cancelled the run but not the pool: workers survive
        // and later runs are correct
        let out = pool.run((0..8).collect::<Vec<usize>>(), |_, t| t * 2);
        assert_eq!(out, (0..8).map(|t| t * 2).collect::<Vec<_>>());
    }

    #[test]
    fn run_with_no_tasks_is_empty() {
        let pool = WorkerPool::new(4).unwrap();
        let out = pool.run(Vec::<usize>::new(), |_, t| t);
        assert!(out.is_empty());
    }

    #[test]
    fn drain_survives_poisoned_run_state_locks() {
        // regression (PR 8): `drain` used `.lock().unwrap()` on queue/out
        // while the wait path absorbed poison -- a panic while holding
        // either guard turned one recoverable panic into a secondary
        // PoisonError panic. Poison both locks, then prove drain still
        // completes its work and bumps the completion barrier.
        let state = RunState {
            queue: Mutex::new(vec![(0usize, 5u64)].into_iter().collect::<VecDeque<_>>()),
            out: Mutex::new(vec![None]),
            panic: Mutex::new(None),
            finished: Mutex::new(0usize),
            all_done: Condvar::new(),
            f: |_, t: u64| t * 2,
        };
        for poison in [0, 1] {
            let result = std::thread::scope(|s| {
                s.spawn(|| {
                    let _guard_q;
                    let _guard_o;
                    if poison == 0 {
                        _guard_q = state.queue.lock().unwrap();
                    } else {
                        _guard_o = state.out.lock().unwrap();
                    }
                    panic!("poison the lock");
                })
                .join()
            });
            assert!(result.is_err(), "the poisoning thread must have panicked");
        }
        assert!(state.queue.lock().is_err(), "queue lock must be poisoned for this test");
        assert!(state.out.lock().is_err(), "out lock must be poisoned for this test");
        state.drain();
        assert_eq!(lock_ok(&state.out)[0], Some(10));
        assert_eq!(*lock_ok(&state.finished), 1, "Finish guard must bump the barrier");
        assert!(lock_ok(&state.panic).is_none(), "no task panicked; slot must stay empty");
    }

    #[test]
    fn spawn_failure_is_an_error_not_a_panic() {
        // the happy path of the fallible constructor: Ok for every worker
        // count, including the clamped 0 -> 1 case (no threads at all).
        // Forcing a real spawn failure needs resource exhaustion, which a
        // unit test must not do; the error path is exercised by review of
        // the join-before-error cleanup and by GatedLoop::new propagating
        // the Result (trainers surface it instead of panicking mid-run).
        assert_eq!(WorkerPool::new(0).unwrap().workers(), 1);
        assert_eq!(WorkerPool::new(3).unwrap().workers(), 3);
    }

    #[test]
    fn unit_rng_streams_are_stable_and_distinct() {
        let mut a = unit_rng(1, 2, 3);
        let mut b = unit_rng(1, 2, 3);
        assert_eq!(a.next_u32(), b.next_u32());
        let mut c = unit_rng(1, 2, 4);
        let mut d = unit_rng(1, 3, 3);
        let x = unit_rng(1, 2, 3).next_u32();
        assert_ne!(x, c.next_u32());
        assert_ne!(x, d.next_u32());
    }
}
