//! Speculative delight screening (paper §3.2 / §7 "distilled delight
//! predictors"): a cheap draft model predicts each sample's surprisal
//! before the expensive forward/backward, mirroring speculative decoding
//! but for training.
//!
//! The draft here is an online linear probe trained to regress the full
//! model's per-sample surprisal ell. It costs one [D]·[D] dot per sample
//! — orders of magnitude below the policy forward — and §3.2 of the paper
//! shows the gate tolerates exactly this kind of approximation. The
//! production consumer is `pipeline::ScreenStage` (tier 1 of the two-tier
//! gate), which owns the warm-up policy and the advantage weighting;
//! `screening_precision` quantifies screening quality as precision of the
//! draft's top-rho set against the true top-rho set.

use anyhow::{bail, Result};

use crate::utils::rng::Pcg32;
use crate::utils::stats::quantile;

/// Online linear surprisal predictor: ell_hat = w·x + b, SGD on squared
/// error against the observed surprisal from the full forward.
#[derive(Debug, Clone)]
pub struct DraftScreen {
    w: Vec<f32>,
    b: f32,
    lr: f32,
    /// samples seen (for the cold-start guard)
    seen: u64,
    /// score with the non-golden f32-fast dot (DESIGN.md §13). Config,
    /// not state: excluded from `weights()`/`restore()` exactly like `lr`.
    f32_fast: bool,
}

impl DraftScreen {
    pub fn new(dim: usize, lr: f32) -> DraftScreen {
        DraftScreen { w: vec![0.0; dim], b: 0.0, lr, seen: 0, f32_fast: false }
    }

    /// Select the screen's scoring tier. The screen is the textbook home
    /// for the f32-fast axis: §3.2 shows the gate tolerates approximate
    /// delight scores, and the draft's predictions never touch a gradient.
    pub fn with_f32_fast(mut self, on: bool) -> DraftScreen {
        self.f32_fast = on;
        self
    }

    pub fn dim(&self) -> usize {
        self.w.len()
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Predict surprisal for one input row. This is the per-screened-
    /// sample dot of the tier-1 screen, routed through the shared
    /// lane-reduced `utils::math::dot` (the same fixed reduction tree the
    /// kernel layer uses, so the screen's scores carry the same
    /// shape-only ordering guarantee as every other reduction). Under the
    /// f32-fast tier the accumulation runs in f32 instead — still
    /// deterministic per shape, but a distinct method axis, never
    /// bit-comparable to the golden path.
    pub fn predict(&self, x: &[f32]) -> f64 {
        debug_assert_eq!(x.len(), self.w.len());
        if self.f32_fast {
            return self.b as f64 + crate::utils::math::dot_f32fast(&self.w, x) as f64;
        }
        self.b as f64 + crate::utils::math::dot(&self.w, x)
    }

    /// One SGD step against a single observed surprisal.
    pub fn update_row(&mut self, row: &[f32], target: f64) {
        let err = (self.predict(row) - target) as f32;
        let g = self.lr * err;
        for (w, &v) in self.w.iter_mut().zip(row) {
            *w -= g * v;
        }
        self.b -= g;
        self.seen += 1;
    }

    /// Learned state for checkpointing: `(weights, bias)`. `seen` travels
    /// separately via [`DraftScreen::seen`]; `lr` is config, not state.
    pub fn weights(&self) -> (&[f32], f32) {
        (&self.w, self.b)
    }

    /// Restore learned state from a checkpoint, keeping the construction-
    /// time learning rate. A dimension mismatch (the checkpoint came from
    /// a different model) is a clean error, never a panic.
    pub fn restore(&mut self, w: &[f32], b: f32, seen: u64) -> Result<()> {
        if w.len() != self.w.len() {
            bail!(
                "draft screen dim mismatch: checkpoint {} vs model {}",
                w.len(),
                self.w.len()
            );
        }
        self.w.copy_from_slice(w);
        self.b = b;
        self.seen = seen;
        Ok(())
    }

    /// One SGD pass against observed surprisals. (Warm-up policy and
    /// delight weighting live in `pipeline::ScreenStage`, the only
    /// production consumer -- not here.)
    pub fn update(&mut self, xs: &[f32], ell: &[f64]) {
        let d = self.w.len();
        for (i, &target) in ell.iter().enumerate() {
            self.update_row(&xs[i * d..(i + 1) * d], target);
        }
    }
}

/// Screening agreement: precision of the approximate top-rho set against
/// the exact top-rho set (both sets of size ceil(rho * n)).
pub fn screening_precision(chi_true: &[f64], chi_hat: &[f64], rho: f64) -> f64 {
    assert_eq!(chi_true.len(), chi_hat.len());
    let n = chi_true.len();
    if n == 0 {
        return 1.0;
    }
    let k = ((rho * n as f64).ceil() as usize).clamp(1, n);
    let top = |xs: &[f64]| -> std::collections::HashSet<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        // total_cmp: NaN chi (a diverged draft or poisoned advantage) must
        // order deterministically instead of panicking mid-run
        idx.sort_by(|&a, &b| xs[b].total_cmp(&xs[a]));
        idx[..k].iter().copied().collect()
    };
    let t = top(chi_true);
    let h = top(chi_hat);
    t.intersection(&h).count() as f64 / k as f64
}

/// Spearman-style rank correlation between true and approximate delight
/// (diagnostic reported by the `spec` experiment driver).
pub fn rank_correlation(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ranks = |xs: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..n).collect();
        // total_cmp, not partial_cmp().unwrap(): NaN must rank, not panic
        idx.sort_by(|&i, &j| xs[i].total_cmp(&xs[j]));
        let mut r = vec![0.0; n];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    };
    let ra = ranks(a);
    let rb = ranks(b);
    let d2: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - y) * (x - y)).sum();
    1.0 - 6.0 * d2 / (n as f64 * (n as f64 * n as f64 - 1.0))
}

/// Synthetic sanity harness: how good must the draft be (noise level on
/// chi) for top-rho screening to retain a given precision? Used by the
/// ablation driver to trace the paper's approximate-delight story without
/// a trainer in the loop.
pub fn precision_under_noise(n: usize, rho: f64, rel_noise: f64, rng: &mut Pcg32) -> f64 {
    let chi: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let sd = {
        let q75 = quantile(&chi, 0.75);
        let q25 = quantile(&chi, 0.25);
        (q75 - q25) / 1.349
    };
    let chi_hat: Vec<f64> =
        chi.iter().map(|&c| c + rng.normal() * rel_noise * sd).collect();
    screening_precision(&chi, &chi_hat, rho)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draft_learns_linear_surprisal() {
        // ground truth ell = 2*x0 - x1 + 0.5 is exactly representable
        let mut rng = Pcg32::seeded(1);
        let mut draft = DraftScreen::new(2, 0.05);
        for _ in 0..300 {
            let xs: Vec<f32> = (0..20 * 2).map(|_| rng.normal() as f32).collect();
            let ell: Vec<f64> = (0..20)
                .map(|i| 2.0 * xs[i * 2] as f64 - xs[i * 2 + 1] as f64 + 0.5)
                .collect();
            draft.update(&xs, &ell);
        }
        let x = [1.0f32, 1.0];
        assert!((draft.predict(&x) - 1.5).abs() < 0.05, "{}", draft.predict(&x));
        assert_eq!(draft.seen(), 300 * 20);
    }

    #[test]
    fn perfect_screen_has_precision_one() {
        let chi = vec![0.1, 0.9, -0.5, 0.7, 0.2];
        assert_eq!(screening_precision(&chi, &chi, 0.4), 1.0);
    }

    #[test]
    fn anti_correlated_screen_has_low_precision() {
        let chi: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let neg: Vec<f64> = chi.iter().map(|&c| -c).collect();
        assert_eq!(screening_precision(&chi, &neg, 0.1), 0.0);
    }

    #[test]
    fn precision_degrades_smoothly_with_noise() {
        let mut rng = Pcg32::seeded(2);
        let p0 = precision_under_noise(1000, 0.05, 0.0, &mut rng);
        let p1 = precision_under_noise(1000, 0.05, 0.5, &mut rng);
        let p2 = precision_under_noise(1000, 0.05, 3.0, &mut rng);
        assert_eq!(p0, 1.0);
        assert!(p1 > 0.3 && p1 < 1.0, "p1 = {p1}");
        assert!(p2 < p1, "p2 = {p2}");
    }

    #[test]
    fn screening_stats_tolerate_nan_chi() {
        // regression: the old partial_cmp(..).unwrap() sorts panicked the
        // moment a NaN chi reached a diagnostic (diverged draft, 0 * inf
        // advantage); total_cmp must rank NaN deterministically instead
        let chi = vec![1.0, f64::NAN, 0.5, 2.0, f64::NAN, -1.0];
        let hat = vec![0.9, 0.4, f64::NAN, 1.8, -0.5, f64::NAN];
        let p = screening_precision(&chi, &hat, 0.5);
        assert!((0.0..=1.0).contains(&p), "precision {p} out of range");
        // deterministic under repetition (total order, no tie-break races)
        assert_eq!(p.to_bits(), screening_precision(&chi, &hat, 0.5).to_bits());
        let r = rank_correlation(&chi, &hat);
        assert!(r.is_finite(), "rank correlation {r} not finite");
        assert_eq!(r.to_bits(), rank_correlation(&chi, &hat).to_bits());
        // all-NaN input is the worst case and must still not panic
        let nan = vec![f64::NAN; 4];
        let _ = screening_precision(&nan, &nan, 0.25);
        let _ = rank_correlation(&nan, &nan);
    }

    #[test]
    fn update_row_matches_batched_update() {
        let mut a = DraftScreen::new(2, 0.05);
        let mut b = DraftScreen::new(2, 0.05);
        let xs = [1.0f32, -0.5, 0.25, 2.0];
        let ell = [0.7, -0.2];
        a.update(&xs, &ell);
        b.update_row(&xs[0..2], ell[0]);
        b.update_row(&xs[2..4], ell[1]);
        assert_eq!(a.seen(), b.seen());
        assert_eq!(a.predict(&[0.3, 0.9]).to_bits(), b.predict(&[0.3, 0.9]).to_bits());
    }

    #[test]
    fn f32_fast_draft_is_deterministic_and_survives_restore() {
        let mut rng = Pcg32::seeded(7);
        let dim = 33; // ragged on purpose: not a multiple of LANES
        let mut exact = DraftScreen::new(dim, 0.05);
        let xs: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        for _ in 0..50 {
            exact.update_row(&xs, 1.25);
        }
        let (w, b) = exact.weights();
        let (w, b, seen) = (w.to_vec(), b, exact.seen());
        let mut fast = DraftScreen::new(dim, 0.05).with_f32_fast(true);
        fast.restore(&w, b, seen).unwrap();
        let pe = exact.predict(&xs);
        let pf = fast.predict(&xs);
        // close (the screen tolerates this much, per §3.2) but a distinct
        // method axis — and bit-stable under repetition
        assert!((pe - pf).abs() < 1e-3 * pe.abs().max(1.0), "{pe} vs {pf}");
        assert_eq!(pf.to_bits(), fast.predict(&xs).to_bits());
    }

    #[test]
    fn rank_correlation_bounds() {
        let a: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let b: Vec<f64> = a.iter().map(|&x| x * 2.0 + 1.0).collect();
        let c: Vec<f64> = a.iter().rev().cloned().collect();
        assert!((rank_correlation(&a, &b) - 1.0).abs() < 1e-9);
        assert!((rank_correlation(&a, &c) + 1.0).abs() < 1e-9);
    }
}
