//! P² streaming quantile estimator (Jain & Chlamtac 1985).
//!
//! Used for cross-batch adaptive pricing: instead of re-sorting every
//! batch, the coordinator can maintain a running (1-rho)-quantile of
//! delight over the whole stream and price against it. O(1) memory and
//! update; this is the ablation "streaming lambda" mode of the gate.

#[derive(Debug, Clone)]
pub struct P2Quantile {
    q: f64,
    /// marker heights
    h: [f64; 5],
    /// marker positions (1-based, as in the paper)
    n: [f64; 5],
    /// desired positions
    np: [f64; 5],
    /// desired position increments
    dn: [f64; 5],
    count: usize,
    init: Vec<f64>,
}

impl P2Quantile {
    pub fn new(q: f64) -> P2Quantile {
        assert!((0.0..=1.0).contains(&q));
        P2Quantile {
            q,
            h: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            dn: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
            count: 0,
            init: Vec::with_capacity(5),
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn update(&mut self, x: f64) {
        self.count += 1;
        if self.init.len() < 5 {
            self.init.push(x);
            if self.init.len() == 5 {
                // total_cmp: NaN input must not panic the sort (same
                // class of fix as speculative.rs); NaNs order after +inf
                self.init.sort_by(|a, b| a.total_cmp(b));
                for i in 0..5 {
                    self.h[i] = self.init[i];
                }
            }
            return;
        }

        // find cell k
        let k = if x < self.h[0] {
            self.h[0] = x;
            0
        } else if x >= self.h[4] {
            self.h[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= self.h[i] && x < self.h[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };

        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }

        // adjust interior markers
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let ds = d.signum();
                let hp = self.parabolic(i, ds);
                if self.h[i - 1] < hp && hp < self.h[i + 1] {
                    self.h[i] = hp;
                } else {
                    self.h[i] = self.linear(i, ds);
                }
                self.n[i] += ds;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (n0, n1, n2) = (self.n[i - 1], self.n[i], self.n[i + 1]);
        let (h0, h1, h2) = (self.h[i - 1], self.h[i], self.h[i + 1]);
        h1 + d / (n2 - n0)
            * ((n1 - n0 + d) * (h2 - h1) / (n2 - n1) + (n2 - n1 - d) * (h1 - h0) / (n1 - n0))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = (i as f64 + d) as usize;
        self.h[i] + d * (self.h[j] - self.h[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate; exact for < 5 observations.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.init.len() < 5 {
            let mut v = self.init.clone();
            v.sort_by(|a, b| a.total_cmp(b));
            let pos = self.q * (v.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            return if lo == hi { v[lo] } else { v[lo] + (pos - lo as f64) * (v[hi] - v[lo]) };
        }
        self.h[2]
    }
}

/// Exponentially-weighted quantile tracker (Robbins-Monro stochastic
/// approximation). Unlike P² it follows *drifting* distributions -- the
/// relevant case for a streaming gate price, since the delight
/// distribution collapses toward zero as the policy improves. The step
/// size self-scales with a running mean absolute deviation.
#[derive(Debug, Clone)]
pub struct EwQuantile {
    q: f64,
    lam: f64,
    /// running mean absolute deviation (scale estimate)
    mad: f64,
    rate: f64,
    count: usize,
}

impl EwQuantile {
    pub fn new(q: f64, rate: f64) -> EwQuantile {
        assert!((0.0..=1.0).contains(&q) && rate > 0.0);
        EwQuantile { q, lam: 0.0, mad: 1.0, rate, count: 0 }
    }

    pub fn update(&mut self, x: f64) {
        self.count += 1;
        if self.count == 1 {
            self.lam = x;
            self.mad = x.abs().max(1e-9);
            return;
        }
        self.mad = 0.99 * self.mad + 0.01 * (x - self.lam).abs().max(1e-12);
        let step = self.rate * self.mad;
        if x > self.lam {
            self.lam += step * self.q;
        } else {
            self.lam -= step * (1.0 - self.q);
        }
    }

    pub fn value(&self) -> f64 {
        self.lam
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Mutable tracker state for checkpointing: `(lam, mad, count)`.
    /// `q` and `rate` are construction-time config, not state.
    pub fn snapshot(&self) -> (f64, f64, usize) {
        (self.lam, self.mad, self.count)
    }

    /// Restore tracker state from a `snapshot()` tuple (checkpoint resume).
    pub fn restore(&mut self, lam: f64, mad: f64, count: usize) {
        self.lam = lam;
        self.mad = mad;
        self.count = count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::rng::Pcg32;
    use crate::utils::stats::quantile;

    #[test]
    fn tracks_uniform_quantiles() {
        for &q in &[0.25, 0.5, 0.9, 0.97] {
            let mut est = P2Quantile::new(q);
            let mut rng = Pcg32::seeded(2);
            let xs: Vec<f64> = (0..20_000).map(|_| rng.uniform()).collect();
            for &x in &xs {
                est.update(x);
            }
            let exact = quantile(&xs, q);
            assert!((est.value() - exact).abs() < 0.02, "q={q}: {} vs {exact}", est.value());
        }
    }

    #[test]
    fn tracks_normal_quantiles() {
        let mut est = P2Quantile::new(0.97);
        let mut rng = Pcg32::seeded(3);
        let xs: Vec<f64> = (0..50_000).map(|_| rng.normal()).collect();
        for &x in &xs {
            est.update(x);
        }
        // Phi^-1(0.97) ~ 1.8808
        assert!((est.value() - 1.8808).abs() < 0.08, "{}", est.value());
    }

    #[test]
    fn exact_for_few_samples() {
        let mut est = P2Quantile::new(0.5);
        for x in [3.0, 1.0, 2.0] {
            est.update(x);
        }
        assert_eq!(est.value(), 2.0);
        assert_eq!(est.count(), 3);
    }

    #[test]
    fn nan_input_does_not_panic_the_p2_sorts() {
        // regression: both init-phase sorts used partial_cmp().unwrap(),
        // so one NaN score (a diverged run) panicked the estimator
        let mut est = P2Quantile::new(0.5);
        est.update(1.0);
        est.update(f64::NAN);
        est.update(3.0);
        // value() sorts the partial init buffer -- must not panic
        let _ = est.value();
        for x in [2.0, 4.0, 0.5, 1.5, 2.5] {
            est.update(x); // crosses the 5-element init sort
        }
        for i in 0..100 {
            est.update(i as f64 / 50.0);
        }
        assert!(est.value().is_finite(), "finite markers survive one NaN");
        assert_eq!(est.count(), 108);
    }

    #[test]
    fn ew_quantile_tracks_stationary() {
        let mut est = EwQuantile::new(0.9, 0.05);
        let mut rng = Pcg32::seeded(5);
        for _ in 0..50_000 {
            est.update(rng.normal());
        }
        // Phi^-1(0.9) ~ 1.2816
        assert!((est.value() - 1.2816).abs() < 0.15, "{}", est.value());
    }

    #[test]
    fn ew_quantile_adapts_to_drift() {
        // the gate-price use case: delight distribution collapses toward
        // zero as the policy improves; the tracker must follow.
        let mut est = EwQuantile::new(0.9, 0.05);
        let mut rng = Pcg32::seeded(4);
        for _ in 0..5000 {
            est.update(rng.normal() + 10.0);
        }
        assert!(est.value() > 9.0);
        for _ in 0..20_000 {
            est.update(rng.normal());
        }
        assert!(est.value() < 2.5, "stale estimate {}", est.value());
    }

    #[test]
    fn p2_is_for_stationary_streams() {
        // documents the P2/EW split: P2 nails the stationary quantile but
        // (by design) does not forget an early regime.
        let mut p2 = P2Quantile::new(0.9);
        let mut rng = Pcg32::seeded(6);
        for _ in 0..5000 {
            p2.update(rng.normal() + 10.0);
        }
        for _ in 0..20_000 {
            p2.update(rng.normal());
        }
        assert!(p2.value() > 2.5, "P2 unexpectedly forgot: {}", p2.value());
    }
}
