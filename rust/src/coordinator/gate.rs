//! The Kondo gate (paper §2.1, Algorithm 1).
//!
//! For each sample the gate compares a priority score chi against a price
//! lambda and draws G ~ Ber(sigma((chi - lambda)/eta)). Two pricing modes:
//!
//! - `Rate(rho)`  — Algorithm 1 line 5: lambda is the per-batch
//!   (1-rho)-quantile of chi, targeting a fraction rho of backward passes.
//! - `Price(lambda)` — fixed price; `Price(0.0)` is the adaptive
//!   sign-gate of §5 (DG-K lambda=0), whose keep-rate tracks the policy's
//!   own success rate (Prop 1: it keeps exactly the positive-delight set).
//!
//! eta -> 0 gives the hard threshold I{chi > lambda}; eta -> inf gives the
//! constant gate w = 1/2 (standard PG up to uniform rescaling).

use crate::utils::math::sigmoid;
use crate::utils::rng::Pcg32;
use crate::utils::stats::quantile;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pricing {
    /// Target gate rate rho in (0, 1]: per-batch quantile pricing.
    Rate(f64),
    /// Fixed price lambda.
    Price(f64),
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KondoGate {
    pub pricing: Pricing,
    /// Temperature eta >= 0. 0 means hard threshold (the eta->0 limit).
    pub eta: f64,
}

/// Outcome of gating one batch.
#[derive(Debug, Clone)]
pub struct GateDecision {
    /// indices of samples that receive a backward pass
    pub keep: Vec<usize>,
    /// gate probability per sample (diagnostics / Fig 15)
    pub probs: Vec<f64>,
    /// the price actually used
    pub lambda: f64,
}

impl KondoGate {
    pub fn rate(rho: f64) -> KondoGate {
        assert!(rho > 0.0 && rho <= 1.0, "rho must be in (0,1]");
        KondoGate { pricing: Pricing::Rate(rho), eta: 0.0 }
    }

    pub fn price(lambda: f64) -> KondoGate {
        KondoGate { pricing: Pricing::Price(lambda), eta: 0.0 }
    }

    pub fn with_eta(mut self, eta: f64) -> KondoGate {
        assert!(eta >= 0.0);
        self.eta = eta;
        self
    }

    /// Resolve the price for a batch of priority scores.
    ///
    /// Rate mode prices from the *finite* scores only: `quantile` orders by
    /// `total_cmp`, which sorts NaN above every finite value, so one poisoned
    /// sample would silently shift exactly the high quantiles that
    /// small-rho pricing reads. A batch with no finite score prices at
    /// +inf — nothing in it is worth a backward pass.
    pub fn resolve_lambda(&self, chi: &[f64]) -> f64 {
        match self.pricing {
            Pricing::Price(l) => l,
            Pricing::Rate(rho) => {
                let finite: Vec<f64> =
                    chi.iter().cloned().filter(|c| c.is_finite()).collect();
                if finite.is_empty() {
                    f64::INFINITY
                } else if rho >= 1.0 {
                    // keep everything: price below the minimum
                    finite.iter().cloned().fold(f64::INFINITY, f64::min) - 1.0
                } else {
                    quantile(&finite, 1.0 - rho)
                }
            }
        }
    }

    /// Gate probability for one score at a given price.
    pub fn prob(&self, chi: f64, lambda: f64) -> f64 {
        // A non-finite score is corrupt data, not high priority: its gate
        // probability is 0, and (since p = 0 draws nothing in `decide`) it
        // consumes no randomness — the rng stream stays aligned with the
        // same batch minus the corrupt sample.
        if !chi.is_finite() {
            return 0.0;
        }
        if self.eta == 0.0 {
            if chi > lambda {
                1.0
            } else {
                0.0
            }
        } else {
            sigmoid((chi - lambda) / self.eta)
        }
    }

    /// Algorithm 1: decide which samples in the batch get a backward pass.
    pub fn decide(&self, chi: &[f64], rng: &mut Pcg32) -> GateDecision {
        if chi.is_empty() {
            return GateDecision { keep: vec![], probs: vec![], lambda: 0.0 };
        }
        let lambda = self.resolve_lambda(chi);
        let probs: Vec<f64> = chi.iter().map(|&c| self.prob(c, lambda)).collect();
        let keep = probs
            .iter()
            .enumerate()
            .filter(|(_, &p)| p >= 1.0 || (p > 0.0 && rng.bernoulli(p)))
            .map(|(i, _)| i)
            .collect();
        GateDecision { keep, probs, lambda }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::seeded(1)
    }

    #[test]
    fn rate_mode_keep_count_matches_quantile_definition() {
        // Deterministic, derived from the definition instead of a loose
        // tolerance band: at eta = 0 the gate keeps exactly the samples
        // with chi above the (1-rho)-quantile price, and for distinct
        // scores that count is within one sample of rho * n.
        let mut r = rng();
        let n = 1000;
        let chi: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        for &rho in &[0.01, 0.03, 0.1, 0.5] {
            let gate = KondoGate::rate(rho);
            let d = gate.decide(&chi, &mut r);
            let lambda = gate.resolve_lambda(&chi);
            assert_eq!(d.lambda, lambda, "rho={rho}");
            let expected: Vec<usize> =
                (0..n).filter(|&i| chi[i] > lambda).collect();
            assert_eq!(d.keep, expected, "rho={rho}: keep set != {{i : chi_i > lambda}}");
            // quantile(chi, 1-rho) interpolates at position (1-rho)(n-1),
            // so the strict-above count is within one of the rho target
            let target = rho * n as f64;
            assert!(
                (d.keep.len() as f64 - target).abs() <= 1.0,
                "rho={rho}: kept {} vs target {target}",
                d.keep.len()
            );
        }
    }

    #[test]
    fn rate_one_recovers_full_dg() {
        let mut r = rng();
        let chi: Vec<f64> = (0..64).map(|_| r.normal()).collect();
        let d = KondoGate::rate(1.0).decide(&chi, &mut r);
        assert_eq!(d.keep.len(), 64);
    }

    #[test]
    fn zero_price_hard_gate_keeps_positive_delight_only() {
        // Prop 1 setup: gate at lambda=0 keeps exactly chi > 0.
        let mut r = rng();
        let chi = vec![0.5, -0.1, 0.0, 2.0, -3.0];
        let d = KondoGate::price(0.0).decide(&chi, &mut r);
        assert_eq!(d.keep, vec![0, 3]);
    }

    #[test]
    fn hard_gate_keeps_top_scores() {
        let mut r = rng();
        let chi = vec![0.1, 0.9, 0.5, 0.7, 0.3, 0.2, 0.8, 0.4, 0.6, 1.0];
        let d = KondoGate::rate(0.2).decide(&chi, &mut r);
        // top 20% of 10 samples = indices of the 2 largest (0.9, 1.0)
        assert_eq!(d.keep, vec![1, 9]);
    }

    #[test]
    fn eta_zero_is_hard_threshold() {
        let g = KondoGate::price(0.5);
        assert_eq!(g.prob(0.6, 0.5), 1.0);
        assert_eq!(g.prob(0.4, 0.5), 0.0);
        assert_eq!(g.prob(0.5, 0.5), 0.0); // strict
    }

    #[test]
    fn eta_large_is_constant_half() {
        // eta -> inf limit: the gate forgets chi (standard PG rescaled).
        let g = KondoGate::price(0.0).with_eta(1e12);
        for &c in &[-5.0, 0.0, 5.0] {
            assert!((g.prob(c, 0.0) - 0.5).abs() < 1e-9);
        }
    }

    #[test]
    fn soft_gate_probability_matches_sigmoid() {
        let g = KondoGate::price(1.0).with_eta(2.0);
        let p = g.prob(2.0, 1.0);
        assert!((p - sigmoid(0.5)).abs() < 1e-12);
    }

    #[test]
    fn soft_gate_empirical_rate_matches_prob() {
        let g = KondoGate::price(0.0).with_eta(1.0);
        let mut r = rng();
        let chi = vec![0.7; 4000];
        let d = g.decide(&chi, &mut r);
        let want = sigmoid(0.7);
        let got = d.keep.len() as f64 / 4000.0;
        assert!((got - want).abs() < 0.02, "got {got} want {want}");
    }

    #[test]
    fn gate_is_monotone_in_chi() {
        let g = KondoGate::price(0.3).with_eta(0.5);
        let mut last = -1.0;
        for i in 0..20 {
            let c = -2.0 + 0.2 * i as f64;
            let p = g.prob(c, 0.3);
            assert!(p >= last);
            last = p;
        }
    }

    #[test]
    fn empty_batch() {
        let mut r = rng();
        let d = KondoGate::rate(0.5).decide(&[], &mut r);
        assert!(d.keep.is_empty());
    }

    #[test]
    fn non_finite_scores_do_not_corrupt_the_quantile_price() {
        let g = KondoGate::rate(0.5);
        let clean = vec![1.0, 2.0, 3.0, 4.0];
        let lam = g.resolve_lambda(&clean);
        // NaN sorts above every finite score under total_cmp; without the
        // finite filter it would shift the (1-rho)-quantile upward.
        let poisoned =
            vec![1.0, f64::NAN, 2.0, 3.0, f64::INFINITY, 4.0, f64::NEG_INFINITY];
        assert_eq!(g.resolve_lambda(&poisoned), lam);
    }

    #[test]
    fn non_finite_scores_are_never_kept_and_consume_no_rng() {
        // Soft gate so every finite sample costs one Bernoulli draw: the
        // rng stream after deciding the poisoned batch must match the
        // stream after deciding only its finite scores.
        let g = KondoGate::price(0.0).with_eta(1.0);
        let chi =
            vec![f64::NAN, 5.0, f64::INFINITY, -1.0, f64::NEG_INFINITY, 0.3];
        let mut r_full = Pcg32::seeded(7);
        let d = g.decide(&chi, &mut r_full);
        assert!(d.keep.iter().all(|&i| chi[i].is_finite()));
        for (i, &c) in chi.iter().enumerate() {
            if !c.is_finite() {
                assert_eq!(d.probs[i], 0.0, "sample {i}");
            }
        }
        let finite: Vec<f64> =
            chi.iter().cloned().filter(|c| c.is_finite()).collect();
        let mut r_fin = Pcg32::seeded(7);
        let d_fin = g.decide(&finite, &mut r_fin);
        assert_eq!(r_full.snapshot(), r_fin.snapshot());
        assert_eq!(d.keep.len(), d_fin.keep.len());
    }

    #[test]
    fn all_non_finite_batch_keeps_nothing() {
        let mut r = rng();
        let chi = vec![f64::NAN, f64::INFINITY, f64::NEG_INFINITY];
        // rho >= 1.0 branch: even "keep everything" keeps no corrupt data
        let d = KondoGate::rate(1.0).decide(&chi, &mut r);
        assert!(d.keep.is_empty());
        assert_eq!(d.lambda, f64::INFINITY);
    }
}
