//! L4 speculative screening pipeline: the gated training step as four
//! explicit stages, Screen -> Forward -> Gate -> Backward (DESIGN.md §8).
//!
//! The paper's closing claim (§3.2/§7) is that the Kondo gate tolerates
//! approximate delight, so *a cheap forward pass can screen samples before
//! expensive backpropagation* -- speculative decoding for training. Here
//! that becomes a first-class **two-tier gate**:
//!
//! - **Tier 1, `ScreenStage`** -- a warm [`DraftScreen`] pre-gates the
//!   batch at rate `rho_screen` using one dot product per sample. Only the
//!   survivors get the full forward. Cold-draft batches (and degenerate
//!   all-tied score batches) fall back to the full-forward path, and the
//!   draft trains online on whatever exact surprisals the surviving
//!   forwards produce.
//! - **Tier 2, `GateStage`** -- exact delight is computed on survivors and
//!   the Kondo gate prices the backward exactly as before.
//!
//! `ForwardStage` turns the survivor set into an execution plan: the
//! unscreened batch keeps the contiguous-shard path, while a screened
//! survivor set is packed densely through the forward capacity ladder
//! (the same `BucketSet` machinery the backward has always used), so
//! skipped forwards are *real* skipped compute on fixed-shape hardware.
//! `BackwardStage` owns the bucketed backward executor and the
//! run-persistent gradient accumulator.
//!
//! Determinism contract extension (DESIGN.md §8): every screen decision is
//! a pure function of the draft state and the merged score vector -- the
//! per-sample dot products are sharded across the pool but merged in batch
//! order, the `(1 - rho_screen)` quantile threshold is resolved once on
//! the caller's thread, and the draft updates on worker-invariant exact
//! surprisals -- so at `eta = 0` screened trajectories stay bit-identical
//! for every worker count (locked by rust/tests/gated_e2e.rs).

use anyhow::Result;

use crate::algo::{gate_scored, priority_scores, BatchSignals, Method, WeightDecision};
use crate::coordinator::accounting::ShardedLedger;
use crate::coordinator::batcher::{BucketSet, PackedChunk};
use crate::coordinator::gate::{KondoGate, Pricing};
use crate::coordinator::pool::{non_empty_shards, Shard, WorkerPool};
use crate::coordinator::quantile::EwQuantile;
use crate::coordinator::speculative::DraftScreen;
use crate::model::{accumulate_recycle, ParamStore};
use crate::optim::Optimizer;
use crate::runtime::{tensor, Engine, HostTensor};
use crate::utils::rng::Pcg32;
use crate::utils::stats::quantile;

/// Knobs of the tier-1 speculative screen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScreenCfg {
    /// fraction of the batch surviving the screen; screening engages only
    /// for rates in (0, 1) -- 1.0 (the default) and any out-of-range
    /// value disable it (the tier-2 gate then sees the whole batch),
    /// matching the config layer's disable-don't-panic policy
    pub rho_screen: f64,
    /// SGD learning rate of the online linear draft
    pub draft_lr: f64,
    /// batches of exact surprisal the draft must absorb before it screens
    /// (the cold-start guard: a zero-initialized draft ranks nothing)
    pub warmup_batches: u64,
}

impl Default for ScreenCfg {
    fn default() -> Self {
        ScreenCfg { rho_screen: 1.0, draft_lr: 1e-3, warmup_batches: 20 }
    }
}

impl ScreenCfg {
    /// Screening at `rho_screen` with default draft knobs.
    pub fn at_rate(rho_screen: f64) -> ScreenCfg {
        ScreenCfg { rho_screen, ..Default::default() }
    }

    /// Does this configuration screen at all? Only rates strictly inside
    /// (0, 1) screen; everything else -- including a (nonsensical)
    /// non-positive rate -- is treated as "screening off", the same rule
    /// `ExpConfig` applies, so no layer can panic on an out-of-range knob.
    pub fn active(&self) -> bool {
        self.rho_screen > 0.0 && self.rho_screen < 1.0
    }
}

/// Tier-1 outcome for one batch.
#[derive(Debug, Clone)]
pub enum ScreenVerdict {
    /// No screening applied: screening off, draft still cold, or the score
    /// distribution was degenerate (all tied). Every sample proceeds to
    /// the forward -- the current full-forward path.
    Full,
    /// The warm draft pre-gated the batch: only `survivors` (original
    /// batch indices, ascending) proceed to the full forward. `scores` is
    /// the full batch's predicted-delight vector (diagnostics / precision
    /// tracking) and `lambda` the tier-1 quantile price actually used.
    Screened { survivors: Vec<usize>, scores: Vec<f64>, lambda: f64 },
}

impl ScreenVerdict {
    pub fn is_screened(&self) -> bool {
        matches!(self, ScreenVerdict::Screened { .. })
    }

    /// Survivor indices, or the identity `0..n` when nothing was screened.
    pub fn survivors_or_all(&self, n: usize) -> Vec<usize> {
        match self {
            ScreenVerdict::Full => (0..n).collect(),
            ScreenVerdict::Screened { survivors, .. } => survivors.clone(),
        }
    }

    /// The full batch's predicted scores, when a screen actually ran.
    pub fn scores(&self) -> Option<&[f64]> {
        match self {
            ScreenVerdict::Full => None,
            ScreenVerdict::Screened { scores, .. } => Some(scores),
        }
    }
}

/// Stage 1: the speculative pre-gate (tier 1 of the two-tier gate).
pub struct ScreenStage {
    cfg: ScreenCfg,
    draft: DraftScreen,
    /// samples per batch, the unit of the warm-up threshold
    unit: usize,
}

impl ScreenStage {
    /// Construction follows the same disable-don't-panic policy as
    /// `ScreenCfg::active()`: an out-of-range `rho_screen` builds a stage
    /// whose `screen()` always returns `ScreenVerdict::Full`, it never
    /// panics -- the knob is CLI-exposed, so every layer must degrade.
    pub fn new(dim: usize, unit: usize, cfg: ScreenCfg) -> ScreenStage {
        assert!(dim > 0, "draft feature dimension must be positive");
        ScreenStage {
            cfg,
            draft: DraftScreen::new(dim, cfg.draft_lr as f32),
            unit: unit.max(1),
        }
    }

    /// Route the draft's scoring dot through the non-golden f32-fast tier
    /// (DESIGN.md §13). Screen scores feed a rank threshold, never a
    /// gradient, so this is the designed consumer of that axis; the knob
    /// is config (threaded from `Engine::f32_fast`), not checkpoint state.
    pub fn with_f32_fast(mut self, on: bool) -> ScreenStage {
        self.draft = self.draft.clone().with_f32_fast(on);
        self
    }

    pub fn cfg(&self) -> &ScreenCfg {
        &self.cfg
    }

    pub fn draft(&self) -> &DraftScreen {
        &self.draft
    }

    /// Mutable draft access for checkpoint restore.
    pub fn draft_mut(&mut self) -> &mut DraftScreen {
        &mut self.draft
    }

    /// Has the draft absorbed enough exact surprisal to screen?
    pub fn warm(&self) -> bool {
        self.draft.seen() >= self.cfg.warmup_batches * self.unit as u64
    }

    /// Tier-1 verdict for one batch of `n` draft-feature rows (`feats` is
    /// `[n, dim]` row-major). `u_hint` supplies advantages known *before*
    /// the full forward (reversal: the grouped baseline), weighting the
    /// predicted surprisal into predicted delight `u * ell_hat`; `None`
    /// screens on predicted surprisal alone (MNIST, where U needs the
    /// forward). One dot product per sample, sharded across the pool and
    /// merged in batch order; the quantile threshold is resolved on the
    /// caller's thread, so the decision is batch-global and
    /// worker-invariant.
    pub fn screen(
        &self,
        pool: &WorkerPool,
        shards: &[Shard],
        feats: &[f32],
        n: usize,
        u_hint: Option<&[f64]>,
        acct: &mut ShardedLedger,
    ) -> ScreenVerdict {
        if !self.cfg.active() || n == 0 || !self.warm() {
            return ScreenVerdict::Full;
        }
        let d = self.draft.dim();
        debug_assert_eq!(feats.len(), n * d, "screen features must be [n, dim]");
        let parts: Vec<Vec<f64>> = pool.run(shards.to_vec(), |_, shard: Shard| {
            shard
                .range()
                .map(|i| {
                    let ell_hat = self.draft.predict(&feats[i * d..(i + 1) * d]);
                    match u_hint {
                        Some(u) => u[i] * ell_hat,
                        None => ell_hat,
                    }
                })
                .collect()
        });
        let mut scores = Vec::with_capacity(n);
        for part in parts {
            scores.extend(part);
        }
        for shard in shards {
            acct.shard_mut(shard.index).record_screen(shard.len());
        }
        // a diverged draft (inf/NaN predictions) must degrade to the
        // full-forward path, never poison the survivor set or panic the
        // run -- the same batch-global, worker-invariant fallback as a
        // degenerate score distribution
        if scores.iter().any(|s| !s.is_finite()) {
            return ScreenVerdict::Full;
        }
        let lambda = quantile(&scores, 1.0 - self.cfg.rho_screen);
        let survivors: Vec<usize> = (0..n).filter(|&i| scores[i] > lambda).collect();
        if survivors.is_empty() || survivors.len() == n {
            // degenerate score distribution (ties at the threshold): the
            // screen cannot pick a strict top set, so fall back whole
            return ScreenVerdict::Full;
        }
        ScreenVerdict::Screened { survivors, scores, lambda }
    }

    /// Online draft update on the exact surprisals the surviving forwards
    /// produced: `rows[s]` is the batch index of survivor slot `s`,
    /// `ell[s]` its exact surprisal.
    pub fn observe(&mut self, feats: &[f32], rows: &[usize], ell: &[f64]) {
        debug_assert_eq!(rows.len(), ell.len());
        let d = self.draft.dim();
        for (s, &i) in rows.iter().enumerate() {
            self.draft.update_row(&feats[i * d..(i + 1) * d], ell[s]);
        }
    }
}

/// Stage 2 plan: how the survivor set executes on the forward artifacts.
#[derive(Debug, Clone, PartialEq)]
pub enum ForwardPlan {
    /// One full-batch artifact call over every row. Used for the serial
    /// unscreened path, and as the fallback when a screened batch has no
    /// capacity ladder to pack into (the forward then runs whole and the
    /// survivor rows are gathered from its output -- nothing is skipped,
    /// and nothing is recorded as skipped).
    FullBatch,
    /// Unscreened multi-worker path: contiguous shards, each executed at
    /// its smallest fitting capacity.
    Sharded(Vec<(Shard, usize)>),
    /// Screened path: survivors packed densely through the forward
    /// capacity ladder, exactly like the backward bucket executor. This is
    /// where skipped forwards become real skipped compute.
    Packed(Vec<PackedChunk>),
}

/// Stage 2: forward execution planning over the (possibly screened) batch.
pub struct ForwardStage {
    caps: Option<BucketSet>,
}

impl ForwardStage {
    pub fn new(caps: Option<BucketSet>) -> ForwardStage {
        ForwardStage { caps }
    }

    pub fn caps(&self) -> Option<&BucketSet> {
        self.caps.as_ref()
    }

    /// Choose the execution plan for `survivors` out of a `batch_n`-row
    /// batch on a `workers`-wide pool. Pure function of its arguments (and
    /// the capacity ladder), so the plan -- like every other batch-global
    /// decision -- cannot depend on scheduling. The plan's chunking (and
    /// hence executed padding) legitimately varies with `workers`, exactly
    /// like the unscreened shard path; the survivor/sample counts it
    /// records do not.
    pub fn plan(&self, survivors: &[usize], batch_n: usize, workers: usize) -> ForwardPlan {
        let screened = survivors.len() < batch_n;
        match &self.caps {
            Some(caps) if screened => {
                // slice the survivor set across the pool, then pack each
                // slice through the ladder -- screened forwards must
                // parallelize like backward chunks, not serialize into one
                // big capacity call that idles every other worker
                let mut chunks = Vec::new();
                for shard in non_empty_shards(survivors.len(), workers) {
                    chunks.extend(caps.pack(&survivors[shard.range()]));
                }
                ForwardPlan::Packed(chunks)
            }
            Some(caps) if workers > 1 => {
                let shards = non_empty_shards(batch_n, workers);
                match shards
                    .iter()
                    .map(|s| caps.smallest_fitting(s.len()).map(|c| (*s, c)))
                    .collect::<Option<Vec<_>>>()
                {
                    Some(pairs) => ForwardPlan::Sharded(pairs),
                    None => ForwardPlan::FullBatch,
                }
            }
            _ => ForwardPlan::FullBatch,
        }
    }
}

/// Stage 3: the exact Kondo decision over the survivor set -- scored by
/// the method's configured `Priority` (delight, or a Fig-5 ablation
/// signal) -- including the streaming-lambda pricing ablation that
/// previously lived inside the MNIST trainer.
pub struct GateStage {
    /// cross-batch EW quantile price tracker (ablation of Alg 1 line 5)
    stream: Option<EwQuantile>,
    /// tracked scores required before the streaming price applies (one
    /// full batch; until then the gate keeps nothing)
    min_count: usize,
}

impl GateStage {
    /// `streaming_lambda` only engages for rate-priced DG-K methods; every
    /// other configuration is a pass-through to `Method::decide`.
    pub fn new(method: &Method, streaming_lambda: bool, min_count: usize) -> GateStage {
        let stream = match (streaming_lambda, method) {
            (true, Method::DgK { gate, .. }) => match gate.pricing {
                Pricing::Rate(rho) => Some(EwQuantile::new(1.0 - rho, 0.05)),
                Pricing::Price(_) => None,
            },
            _ => None,
        };
        GateStage { stream, min_count }
    }

    /// Inert stage: plain `Method::decide` pass-through.
    pub fn passthrough() -> GateStage {
        GateStage { stream: None, min_count: 0 }
    }

    /// The streaming price tracker, when this configuration has one
    /// (checkpoint capture).
    pub fn stream(&self) -> Option<&EwQuantile> {
        self.stream.as_ref()
    }

    /// Mutable tracker access for checkpoint restore.
    pub fn stream_mut(&mut self) -> Option<&mut EwQuantile> {
        self.stream.as_mut()
    }

    /// Decide which survivors get a backward pass. Indices in the returned
    /// decision are relative to the signal vectors (survivor slots when a
    /// screen is active -- the caller maps them back to batch indices).
    pub fn decide(
        &mut self,
        method: &Method,
        signals: &BatchSignals,
        rng: &mut Pcg32,
    ) -> WeightDecision {
        if let (Some(tracker), Method::DgK { gate, priority }) = (self.stream.as_mut(), method) {
            // the gate's own score vector -- delight or the configured
            // ablation priority, chi_override honoured -- computed ONCE,
            // then used for both the priced decision and the tracker
            // update, so the cross-batch price can never drift into
            // different units than the scores it gates
            let scores = priority_scores(*priority, signals, rng);
            let lam =
                if tracker.count() >= self.min_count { tracker.value() } else { f64::INFINITY };
            // the streamed price replaces the rate; eta carries over so a
            // soft gate stays soft under streaming pricing
            let priced = KondoGate { pricing: Pricing::Price(lam), eta: gate.eta };
            let d = gate_scored(&priced, signals.u, &scores, rng);
            // non-finite scores never reach the tracker: one NaN would
            // poison the EW quantile state for every later batch (the
            // cross-batch version of the quantile-price corruption the
            // gate itself now rejects -- see KondoGate::resolve_lambda)
            for &c in &scores {
                if c.is_finite() {
                    tracker.update(c);
                }
            }
            d
        } else {
            method.decide(signals, rng)
        }
    }
}

/// Stage 4: the bucketed backward executor and optimizer step. Owns the
/// backward capacity ladder and the run-persistent gradient accumulator.
pub struct BackwardStage {
    buckets: BucketSet,
    /// gradient accumulator reused across steps (sized on first backward)
    grad_acc: Vec<Vec<f32>>,
}

impl BackwardStage {
    pub fn new(bwd_caps: Vec<usize>) -> Result<BackwardStage> {
        Ok(BackwardStage { buckets: BucketSet::new(bwd_caps)?, grad_acc: Vec::new() })
    }

    pub fn buckets(&self) -> &BucketSet {
        &self.buckets
    }

    /// Execute packed backward chunks across the pool and apply one
    /// optimizer step. Each worker produces its chunk's partial gradient
    /// buffers (the backward artifact's output tensors); the caller merges
    /// them into the run-persistent accumulator in **chunk order** (the
    /// pool returns results in task order, never completion order), so the
    /// f32 reduction order is identical to the serial `workers = 1` path.
    /// The merged gradient is normalized by `denom` before the step.
    ///
    /// `param_inputs` is the step's marshalled parameter list, shared by
    /// reference across every chunk call; `extra_inputs` builds only the
    /// non-parameter inputs of chunk `c` for artifact `artifact(c.cap)`.
    #[allow(clippy::too_many_arguments)]
    pub fn run<F, N>(
        &mut self,
        eng: &Engine,
        pool: &WorkerPool,
        params: &mut ParamStore,
        param_inputs: &[HostTensor],
        opt: &mut dyn Optimizer,
        chunks: &[PackedChunk],
        artifact: N,
        extra_inputs: F,
        denom: f32,
    ) -> Result<()>
    where
        F: Fn(&PackedChunk) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        if chunks.is_empty() {
            return Ok(());
        }
        // the zero-copy contract: callers re-marshal after every optimizer
        // step. Cheap to get wrong silently, so verify under debug builds
        // (the dev-profile test runs keep this armed). The same check
        // covers the pack cache: a weight pack built at an older param
        // version means the marshal (which refills packs) was skipped.
        debug_assert!(
            param_inputs.len() == params.n_tensors()
                && (0..params.n_tensors()).all(|i| {
                    param_inputs[i].as_f32().map(|d| d == params.tensor(i)).unwrap_or(false)
                        && param_inputs[i]
                            .pack()
                            .map(|p| p.version() == params.version())
                            .unwrap_or(true)
                }),
            "BackwardStage::run: param_inputs (or its weight packs) is stale relative to \
             params (re-marshal after every optimizer step)"
        );
        let tasks: Vec<&PackedChunk> = chunks.iter().collect();
        let results: Vec<Result<Vec<HostTensor>>> = pool.run(tasks, |_, chunk| {
            let extras = extra_inputs(chunk);
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(param_inputs.len() + extras.len());
            inputs.extend(param_inputs.iter());
            inputs.extend(extras.iter());
            let out = eng.execute_refs(&artifact(chunk.cap), &inputs)?;
            // the gathered chunk inputs were taken from this worker's
            // arena; hand them straight back now that the call is done
            for t in extras {
                tensor::recycle_tensor(t);
            }
            // out[0] is the loss scalar; the rest are gradients
            let mut out = out.into_iter();
            if let Some(loss) = out.next() {
                tensor::recycle_tensor(loss);
            }
            Ok(out.collect())
        });
        // reuse the run-persistent accumulator when the layout matches
        // (steady state after the first backward of a run)
        let n = params.n_tensors();
        if self.grad_acc.len() == n
            && (0..n).all(|i| self.grad_acc[i].len() == params.tensor(i).len())
        {
            for tensor in self.grad_acc.iter_mut() {
                tensor.fill(0.0);
            }
        } else {
            self.grad_acc = params.zeros_like();
        }
        // ordered reduction: chunk order, not completion order; the
        // accumulator hands each gradient buffer back to the arena pool
        for result in results {
            let grads = result?;
            accumulate_recycle(&mut self.grad_acc, grads)?;
        }
        for tensor in self.grad_acc.iter_mut() {
            for v in tensor.iter_mut() {
                *v /= denom;
            }
        }
        opt.step(params, &self.grad_acc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Priority;

    fn shards_of(n: usize, w: usize) -> Vec<Shard> {
        non_empty_shards(n, w)
    }

    // ---- ForwardStage planning ----

    #[test]
    fn plan_unscreened_serial_is_full_batch() {
        let f = ForwardStage::new(Some(BucketSet::new(vec![4, 8, 16]).unwrap()));
        let all: Vec<usize> = (0..32).collect();
        assert_eq!(f.plan(&all, 32, 1), ForwardPlan::FullBatch);
    }

    #[test]
    fn plan_unscreened_sharded_resolves_capacities() {
        let f = ForwardStage::new(Some(BucketSet::new(vec![4, 8, 16]).unwrap()));
        let all: Vec<usize> = (0..32).collect();
        match f.plan(&all, 32, 4) {
            ForwardPlan::Sharded(pairs) => {
                assert_eq!(pairs.len(), 4);
                for (shard, cap) in &pairs {
                    assert_eq!(shard.len(), 8);
                    assert_eq!(*cap, 8);
                }
            }
            other => panic!("expected sharded plan, got {other:?}"),
        }
    }

    #[test]
    fn plan_screened_packs_survivors_through_the_ladder() {
        let f = ForwardStage::new(Some(BucketSet::new(vec![4, 8, 16]).unwrap()));
        let survivors = vec![3, 7, 11, 20, 21];
        match f.plan(&survivors, 32, 1) {
            ForwardPlan::Packed(chunks) => {
                assert_eq!(chunks.len(), 1);
                assert_eq!(chunks[0].cap, 8);
                assert_eq!(chunks[0].idx, survivors);
            }
            other => panic!("expected packed plan, got {other:?}"),
        }
    }

    #[test]
    fn plan_screened_slices_survivors_across_workers() {
        // the screened forward must parallelize: a multi-worker pool gets
        // one (or more) chunks per survivor slice, never a single big
        // capacity call that idles the other workers
        let f = ForwardStage::new(Some(BucketSet::new(vec![4, 8, 16]).unwrap()));
        let survivors: Vec<usize> = (0..16).map(|i| 2 * i).collect();
        match f.plan(&survivors, 32, 4) {
            ForwardPlan::Packed(chunks) => {
                assert_eq!(chunks.len(), 4, "16 survivors on 4 workers -> 4 chunks");
                assert!(chunks.iter().all(|c| c.cap == 4));
                // chunk order preserves survivor order end to end
                let merged: Vec<usize> = chunks.iter().flat_map(|c| c.idx.clone()).collect();
                assert_eq!(merged, survivors);
            }
            other => panic!("expected packed plan, got {other:?}"),
        }
        // the survivor count (the worker-invariant ledger axis) is the
        // same for every worker count; only the chunking varies
        for w in [1, 2, 4, 7] {
            match f.plan(&survivors, 32, w) {
                ForwardPlan::Packed(chunks) => {
                    let merged: Vec<usize> =
                        chunks.iter().flat_map(|c| c.idx.clone()).collect();
                    assert_eq!(merged, survivors, "workers={w}");
                }
                other => panic!("expected packed plan, got {other:?}"),
            }
        }
    }

    #[test]
    fn plan_without_caps_falls_back_to_full_batch() {
        let f = ForwardStage::new(None);
        let survivors = vec![1, 2];
        assert_eq!(f.plan(&survivors, 32, 4), ForwardPlan::FullBatch);
        let all: Vec<usize> = (0..32).collect();
        assert_eq!(f.plan(&all, 32, 4), ForwardPlan::FullBatch);
    }

    #[test]
    fn plan_oversized_shard_falls_back_to_full_batch() {
        // a shard bigger than the largest capacity cannot run sharded
        let f = ForwardStage::new(Some(BucketSet::new(vec![4]).unwrap()));
        let all: Vec<usize> = (0..32).collect();
        assert_eq!(f.plan(&all, 32, 2), ForwardPlan::FullBatch);
        // but the screened path splits greedily instead of falling back
        let survivors: Vec<usize> = (0..9).collect();
        match f.plan(&survivors, 32, 2) {
            ForwardPlan::Packed(chunks) => {
                assert_eq!(chunks.iter().map(|c| c.cap).collect::<Vec<_>>(), vec![4, 4, 4]);
            }
            other => panic!("expected packed plan, got {other:?}"),
        }
    }

    // ---- ScreenStage ----

    fn warm_stage(dim: usize, unit: usize, rho: f64) -> ScreenStage {
        let cfg = ScreenCfg { rho_screen: rho, draft_lr: 0.05, warmup_batches: 1 };
        let mut st = ScreenStage::new(dim, unit, cfg);
        // teach the draft ell = x0 exactly (identity on the first feature)
        let mut rng = crate::utils::rng::Pcg32::seeded(7);
        for _ in 0..400 {
            let xs: Vec<f32> = (0..unit * dim).map(|_| rng.normal() as f32).collect();
            let ell: Vec<f64> = (0..unit).map(|i| xs[i * dim] as f64).collect();
            let rows: Vec<usize> = (0..unit).collect();
            st.observe(&xs, &rows, &ell);
        }
        assert!(st.warm());
        st
    }

    #[test]
    fn cold_screen_passes_everything_and_records_nothing() {
        let st = ScreenStage::new(4, 8, ScreenCfg { warmup_batches: 5, ..ScreenCfg::at_rate(0.5) });
        assert!(!st.warm());
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        let feats = vec![0.0f32; 8 * 4];
        let v = st.screen(&pool, &shards_of(8, 1), &feats, 8, None, &mut acct);
        assert!(!v.is_screened());
        assert_eq!(v.survivors_or_all(8), (0..8).collect::<Vec<_>>());
        assert_eq!(acct.total().screen_samples, 0, "cold batches pay no screen dots");
    }

    #[test]
    fn inactive_screen_cfg_never_screens() {
        let st = ScreenStage::new(4, 8, ScreenCfg::default());
        assert!(!st.cfg().active());
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        let v = st.screen(&pool, &shards_of(8, 1), &vec![0.0; 32], 8, None, &mut acct);
        assert!(!v.is_screened());
        // out-of-range rates are "off", not a panic waiting to happen:
        // active() is the single gate every attach site checks
        assert!(!ScreenCfg::at_rate(0.0).active());
        assert!(!ScreenCfg::at_rate(-0.5).active());
        assert!(!ScreenCfg::at_rate(1.0).active());
        assert!(!ScreenCfg::at_rate(1.5).active());
        assert!(ScreenCfg::at_rate(0.25).active());
    }

    #[test]
    fn warm_screen_keeps_the_top_rho_set_in_batch_order() {
        let dim = 3;
        let n = 16;
        let st = warm_stage(dim, n, 0.25);
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        // feature x0 = i scrambled so the top set is not a suffix
        let order = [5usize, 12, 0, 9, 3, 15, 7, 1, 11, 4, 13, 2, 8, 6, 14, 10];
        let mut feats = vec![0.0f32; n * dim];
        for (i, &rank) in order.iter().enumerate() {
            feats[i * dim] = rank as f32;
        }
        let v = st.screen(&pool, &shards_of(n, 1), &feats, n, None, &mut acct);
        let ScreenVerdict::Screened { survivors, scores, lambda } = v else {
            panic!("warm screen must engage")
        };
        // survivors are the rank >= 12 rows, in ascending batch order
        let expect: Vec<usize> =
            (0..n).filter(|&i| order[i] >= 12).collect();
        assert_eq!(survivors, expect);
        assert!(survivors.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(scores.len(), n);
        assert!(survivors.iter().all(|&i| scores[i] > lambda));
        assert_eq!(acct.total().screen_samples, n as u64);
    }

    #[test]
    fn screen_verdict_is_worker_invariant() {
        let dim = 2;
        let n = 24;
        let st = warm_stage(dim, n, 0.5);
        let mut rng = crate::utils::rng::Pcg32::seeded(3);
        let feats: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
        let survivors_at = |w: usize| {
            let pool = WorkerPool::new(w).unwrap();
            let mut acct = ShardedLedger::new(w);
            let v = st.screen(&pool, &shards_of(n, w), &feats, n, None, &mut acct);
            assert_eq!(acct.total().screen_samples, n as u64);
            v.survivors_or_all(n)
        };
        let s1 = survivors_at(1);
        assert_eq!(s1, survivors_at(2));
        assert_eq!(s1, survivors_at(7));
    }

    #[test]
    fn u_hint_weights_predictions_into_delight() {
        let dim = 2;
        let n = 8;
        let st = warm_stage(dim, n, 0.25);
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        // all rows predict the same surprisal; u alone decides survival
        let mut feats = vec![0.0f32; n * dim];
        for i in 0..n {
            feats[i * dim] = 1.0;
        }
        let u: Vec<f64> = (0..n).map(|i| i as f64 - 3.0).collect();
        let v = st.screen(&pool, &shards_of(n, 1), &feats, n, Some(&u), &mut acct);
        let ScreenVerdict::Screened { survivors, .. } = v else {
            panic!("screen must engage")
        };
        assert_eq!(survivors, vec![6, 7], "largest advantages must survive");
    }

    #[test]
    fn degenerate_tied_scores_fall_back_to_full() {
        let dim = 2;
        let n = 8;
        let st = warm_stage(dim, n, 0.5);
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        // identical rows -> identical predictions -> no strict top set
        let feats = vec![1.0f32; n * dim];
        let v = st.screen(&pool, &shards_of(n, 1), &feats, n, None, &mut acct);
        assert!(!v.is_screened(), "tied scores must fall back to the full path");
    }

    #[test]
    fn diverged_draft_falls_back_to_full_instead_of_panicking() {
        // regression: a draft pushed to inf/NaN weights (unbounded
        // draft_lr is CLI-exposed) must not panic the quantile sort or
        // emit a poisoned survivor set -- it degrades to the full path
        let cfg = ScreenCfg { rho_screen: 0.5, draft_lr: 1e12, warmup_batches: 1 };
        let mut st = ScreenStage::new(2, 4, cfg);
        let feats = vec![1.0e3f32; 4 * 2];
        let rows = [0usize, 1, 2, 3];
        // two huge-lr updates blow the weights out to inf/NaN
        st.observe(&feats, &rows, &[1.0, -1.0, 2.0, -2.0]);
        st.observe(&feats, &rows, &[1.0, -1.0, 2.0, -2.0]);
        assert!(st.warm());
        assert!(
            !st.draft().predict(&feats[0..2]).is_finite(),
            "setup failed to diverge the draft"
        );
        let pool = WorkerPool::new(1).unwrap();
        let mut acct = ShardedLedger::new(1);
        let v = st.screen(&pool, &shards_of(4, 1), &feats, 4, None, &mut acct);
        assert!(!v.is_screened(), "non-finite scores must fall back to the full path");
        // the u_hint path (0 * inf = NaN) degrades the same way
        let u = [0.0f64; 4];
        let v = st.screen(&pool, &shards_of(4, 1), &feats, 4, Some(&u), &mut acct);
        assert!(!v.is_screened());
    }

    #[test]
    fn observe_warms_the_draft() {
        let cfg = ScreenCfg { warmup_batches: 2, ..ScreenCfg::at_rate(0.5) };
        let mut st = ScreenStage::new(2, 4, cfg);
        assert!(!st.warm());
        let feats = vec![0.5f32; 4 * 2];
        let rows = [0usize, 1, 2, 3];
        st.observe(&feats, &rows, &[1.0, 2.0, 0.5, 0.0]);
        assert!(!st.warm(), "one batch of four is below the two-batch warmup");
        st.observe(&feats, &rows, &[1.0, 2.0, 0.5, 0.0]);
        assert!(st.warm());
        assert_eq!(st.draft().seen(), 8);
    }

    // ---- GateStage ----

    #[test]
    fn passthrough_gate_stage_matches_method_decide() {
        let mut gs = GateStage::passthrough();
        let m = Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight };
        let u = [0.5, -0.3, 0.2];
        let ell = [1.0, 2.0, 0.1];
        let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
        let mut r1 = Pcg32::seeded(9);
        let mut r2 = Pcg32::seeded(9);
        let a = gs.decide(&m, &s, &mut r1);
        let b = m.decide(&s, &mut r2);
        assert_eq!(a.keep, b.keep);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn streaming_gate_stage_warms_up_then_prices() {
        let m = Method::DgK { gate: KondoGate::rate(0.5), priority: Priority::Delight };
        let mut gs = GateStage::new(&m, true, 4);
        let mut rng = Pcg32::seeded(1);
        let u = [1.0, 1.0, 1.0, 1.0];
        let ell = [1.0, 2.0, 3.0, 4.0];
        let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
        // batch 1: tracker below min_count -> infinite price, keep nothing
        let d1 = gs.decide(&m, &s, &mut rng);
        assert!(d1.keep.is_empty());
        // batch 2: tracker warm -> finite price, keeps the high-chi tail
        let d2 = gs.decide(&m, &s, &mut rng);
        assert!(!d2.keep.is_empty());
        assert!(d2.keep.len() < 4);
    }

    #[test]
    fn screen_stage_construction_honors_disable_dont_panic() {
        // regression: ScreenStage::new used to assert rho in (0,1] while
        // ScreenCfg::active() documents that out-of-range rates disable
        // screening -- a CLI-supplied rho_screen=1.5 or 0.0 panicked at
        // construction. Construction now follows active().
        for rho in [1.5, 0.0, -0.5, 2.0, 1.0] {
            let st = ScreenStage::new(4, 8, ScreenCfg::at_rate(rho));
            assert!(!st.cfg().active(), "rho={rho} must be screening-off");
            let pool = WorkerPool::new(1).unwrap();
            let mut acct = ShardedLedger::new(1);
            let v = st.screen(&pool, &shards_of(8, 1), &vec![0.0; 32], 8, None, &mut acct);
            assert!(!v.is_screened(), "rho={rho} must never screen");
        }
    }

    #[test]
    fn streaming_tracker_ingests_gate_scores_not_delight() {
        // regression: the streaming path priced every priority against
        // delight(signals). The tracker must evolve from the exact score
        // vector the gate decided on -- here surprisal, chosen so that
        // delight (u*ell) and the gate scores (ell) differ.
        let m = Method::DgK { gate: KondoGate::rate(0.5), priority: Priority::Surprisal };
        let mut gs = GateStage::new(&m, true, 4);
        let u = [2.0, -1.0, 0.5, 3.0];
        let ell = [1.0, 4.0, 2.0, 3.0];
        let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
        let mut rng = Pcg32::seeded(21);
        gs.decide(&m, &s, &mut rng);
        // the expected tracker saw the gate's own inputs: the surprisals
        let mut expect = EwQuantile::new(0.5, 0.05);
        for &e in &ell {
            expect.update(e);
        }
        assert_eq!(gs.stream().unwrap().snapshot(), expect.snapshot());
        // and provably NOT delight: a delight-fed twin diverges
        let mut wrong = EwQuantile::new(0.5, 0.05);
        for (&a, &e) in u.iter().zip(&ell) {
            wrong.update(a * e);
        }
        assert_ne!(gs.stream().unwrap().snapshot(), wrong.snapshot());
    }

    #[test]
    fn streaming_decision_matches_priced_method_decide() {
        // once warm, the streaming stage must decide exactly like a
        // price-mode DG-K at the tracker's lambda over the same priority
        let m = Method::DgK { gate: KondoGate::rate(0.5), priority: Priority::AbsAdvantage };
        let mut gs = GateStage::new(&m, true, 2);
        let u = [0.5, -2.0, 1.0, -0.25];
        let ell = [1.0, 1.0, 1.0, 1.0];
        let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
        let mut rng = Pcg32::seeded(3);
        gs.decide(&m, &s, &mut rng); // warmup batch: infinite price
        let lam = gs.stream().unwrap().value();
        let d = gs.decide(&m, &s, &mut Pcg32::seeded(4));
        let priced = Method::DgK { gate: KondoGate::price(lam), priority: Priority::AbsAdvantage };
        let e = priced.decide(&s, &mut Pcg32::seeded(4));
        assert_eq!(d.keep, e.keep);
        assert_eq!(d.weights, e.weights);
    }

    #[test]
    fn streaming_gate_stage_is_inert_for_price_mode_and_ungated() {
        let price = Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight };
        let gs = GateStage::new(&price, true, 4);
        assert!(gs.stream.is_none());
        let gs = GateStage::new(&Method::Pg, true, 4);
        assert!(gs.stream.is_none());
    }
}
