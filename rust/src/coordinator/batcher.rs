//! Bucketed backward executor: turning the stochastic gate into static
//! shape choice (DESIGN.md §4, "gating = shape specialization").
//!
//! Backward artifacts are compiled at a fixed set of capacities. The kept
//! samples of a batch are packed densely into the smallest bucket that
//! fits (splitting across several buckets when necessary); unused slots
//! are padded with zero weight, which is exact because the weighted
//! objective is linear in the weights (tested in python/tests/test_mlp.py
//! ::test_padding_samples_with_zero_weight_is_exact).

use anyhow::{bail, Result};

use crate::runtime::tensor;

/// A set of compiled backward capacities, ascending.
#[derive(Debug, Clone)]
pub struct BucketSet {
    caps: Vec<usize>,
}

/// One backward execution: which kept samples go in which bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedChunk {
    /// compiled capacity to execute
    pub cap: usize,
    /// sample indices occupying the first `idx.len()` slots (rest padded)
    pub idx: Vec<usize>,
}

impl PackedChunk {
    /// Executed sample-slots (the real backward cost of this chunk).
    pub fn executed(&self) -> usize {
        self.cap
    }

    pub fn padding(&self) -> usize {
        self.cap - self.idx.len()
    }
}

impl BucketSet {
    pub fn new(mut caps: Vec<usize>) -> Result<BucketSet> {
        if caps.is_empty() {
            bail!("bucket set cannot be empty");
        }
        caps.sort_unstable();
        caps.dedup();
        if caps[0] == 0 {
            bail!("bucket capacity 0 is invalid");
        }
        Ok(BucketSet { caps })
    }

    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    pub fn max_cap(&self) -> usize {
        *self.caps.last().unwrap()
    }

    /// Smallest capacity >= n, or None if n exceeds the largest bucket.
    pub fn smallest_fitting(&self, n: usize) -> Option<usize> {
        self.caps.iter().copied().find(|&c| c >= n)
    }

    /// Pack `kept` sample indices into chunks. Greedy: fill max-cap chunks
    /// while the remainder exceeds the largest bucket, then one
    /// smallest-fitting chunk for the tail. Returns no chunks for no kept
    /// samples (skipping the backward entirely -- the whole point).
    pub fn pack(&self, kept: &[usize]) -> Vec<PackedChunk> {
        let mut chunks = Vec::new();
        let mut rest = kept;
        let maxc = self.max_cap();
        while rest.len() > maxc {
            chunks.push(PackedChunk { cap: maxc, idx: rest[..maxc].to_vec() });
            rest = &rest[maxc..];
        }
        if !rest.is_empty() {
            let cap = self.smallest_fitting(rest.len()).unwrap();
            chunks.push(PackedChunk { cap, idx: rest.to_vec() });
        }
        chunks
    }

    /// Total executed sample-slots for a kept-count (cost model helper).
    pub fn executed_slots(&self, kept: usize) -> usize {
        let fake: Vec<usize> = (0..kept).collect();
        self.pack(&fake).iter().map(|c| c.cap).sum()
    }
}

/// Gather rows of a flat [n, row] matrix into a padded [cap, row] buffer.
/// The buffer comes from the tensor arena (zero-filled, so padding slots
/// stay exact zeros); the per-chunk consumers recycle it after the
/// artifact call, which is what keeps chunk gathering allocation-free in
/// the steady state.
pub fn gather_rows_f32(src: &[f32], row: usize, idx: &[usize], cap: usize) -> Vec<f32> {
    assert!(idx.len() <= cap);
    let mut out = tensor::take_f32_zeroed(cap * row);
    for (slot, &i) in idx.iter().enumerate() {
        out[slot * row..(slot + 1) * row].copy_from_slice(&src[i * row..(i + 1) * row]);
    }
    out
}

/// Same for i32 rows (tokens / actions).
pub fn gather_rows_i32(src: &[i32], row: usize, idx: &[usize], cap: usize) -> Vec<i32> {
    assert!(idx.len() <= cap);
    let mut out = tensor::take_i32_zeroed(cap * row);
    for (slot, &i) in idx.iter().enumerate() {
        out[slot * row..(slot + 1) * row].copy_from_slice(&src[i * row..(i + 1) * row]);
    }
    out
}

/// Gather scalars with zero padding.
pub fn gather_f32(src: &[f32], idx: &[usize], cap: usize) -> Vec<f32> {
    gather_rows_f32(src, 1, idx, cap)
}

pub fn gather_i32(src: &[i32], idx: &[usize], cap: usize) -> Vec<i32> {
    gather_rows_i32(src, 1, idx, cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buckets() -> BucketSet {
        BucketSet::new(vec![4, 8, 16, 32, 64, 100]).unwrap()
    }

    #[test]
    fn smallest_fitting_picks_tightest() {
        let b = buckets();
        assert_eq!(b.smallest_fitting(1), Some(4));
        assert_eq!(b.smallest_fitting(4), Some(4));
        assert_eq!(b.smallest_fitting(5), Some(8));
        assert_eq!(b.smallest_fitting(100), Some(100));
        assert_eq!(b.smallest_fitting(101), None);
    }

    #[test]
    fn pack_empty_is_no_backward() {
        assert!(buckets().pack(&[]).is_empty());
    }

    #[test]
    fn pack_small_uses_one_tight_bucket() {
        let c = buckets().pack(&[7, 2, 9]);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].cap, 4);
        assert_eq!(c[0].idx, vec![7, 2, 9]);
        assert_eq!(c[0].padding(), 1);
    }

    #[test]
    fn pack_oversized_splits() {
        let kept: Vec<usize> = (0..230).collect();
        let c = buckets().pack(&kept);
        assert_eq!(c.len(), 3);
        assert_eq!(c[0].cap, 100);
        assert_eq!(c[1].cap, 100);
        assert_eq!(c[2].cap, 32);
        let total: usize = c.iter().map(|x| x.idx.len()).sum();
        assert_eq!(total, 230);
        // every index exactly once
        let mut all: Vec<usize> = c.iter().flat_map(|x| x.idx.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, kept);
    }

    #[test]
    fn executed_slots_cost_model() {
        let b = buckets();
        assert_eq!(b.executed_slots(0), 0);
        assert_eq!(b.executed_slots(3), 4);
        assert_eq!(b.executed_slots(100), 100);
        assert_eq!(b.executed_slots(104), 104); // 100 + 4
    }

    #[test]
    fn gate_rate_3pct_of_100_costs_4_slots() {
        // the paper's headline rho=0.03 on B=100: 3 kept -> bucket 4, a 25x
        // backward-compute reduction at bucket granularity.
        assert_eq!(buckets().executed_slots(3), 4);
    }

    #[test]
    fn gather_pads_with_zeros() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows of 2
        let out = gather_rows_f32(&src, 2, &[2, 0], 4);
        assert_eq!(out, vec![5.0, 6.0, 1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn gather_i32_matches() {
        let src = vec![10, 20, 30];
        assert_eq!(gather_i32(&src, &[1], 2), vec![20, 0]);
    }

    #[test]
    fn rejects_bad_bucket_sets() {
        assert!(BucketSet::new(vec![]).is_err());
        assert!(BucketSet::new(vec![0, 4]).is_err());
    }

    #[test]
    fn dedups_and_sorts() {
        let b = BucketSet::new(vec![16, 4, 16, 8]).unwrap();
        assert_eq!(b.caps(), &[4, 8, 16]);
    }
}
