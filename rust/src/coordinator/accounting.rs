//! Compute ledger: the bookkeeping behind every "forward-pass space" /
//! "backward-pass space" axis in the paper and the Fig 3 cost model
//! total = forward + r * backward (r = backward/forward cost ratio).
//!
//! Two backward counters are kept: `backward_kept` (samples the gate chose,
//! the paper's idealized x-axis) and `backward_executed` (sample-slots the
//! bucketed executor actually ran, including padding -- the honest cost on
//! real hardware).
//!
//! The L4 screening pipeline (coordinator/pipeline.rs) adds two more:
//! `screen_samples` (draft dot products the tier-1 screen evaluated) and
//! `forward_skipped` (samples the screen spared from the full forward),
//! plus the three-term cost model total = s*screen + forward + r*backward.
//! Both screen counters are batch-global decisions and therefore
//! worker-invariant -- inside the determinism contract, unlike
//! `forward_executed`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub forward_samples: u64,
    /// forward sample-slots actually executed, including shard padding
    /// (== forward_samples on unsharded runs; the honest hardware cost,
    /// mirroring backward_executed). NOT part of the determinism contract:
    /// it legitimately varies with the worker count.
    pub forward_executed: u64,
    pub forward_calls: u64,
    /// draft dot products evaluated by the tier-1 speculative screen
    /// (worker-invariant: every screened batch screens every sample)
    pub screen_samples: u64,
    /// samples the screen spared from the full forward (worker-invariant;
    /// only counted when forwards were actually avoided -- a screened
    /// batch with no capacity ladder still forwards everything and
    /// records nothing here)
    pub forward_skipped: u64,
    pub backward_kept: u64,
    pub backward_executed: u64,
    pub backward_calls: u64,
    /// executed-bucket histogram: capacity -> count
    pub bucket_hist: BTreeMap<usize, u64>,
    /// samples the admission path rejected for corrupt content (non-finite
    /// surprisal/advantage/feature, out-of-range action) -- quarantined,
    /// never trained on (distrib learner; see distrib/learner.rs)
    pub quarantined_samples: u64,
    /// whole batches rejected before per-sample inspection (shape or
    /// policy-fingerprint mismatch)
    pub quarantined_batches: u64,
    /// admitted samples generated against a stale policy snapshot
    /// (snapshot version < learner step)
    pub stale_samples: u64,
    /// stale samples the gate still chose for a backward pass
    pub stale_kept: u64,
    /// deliveries dropped under backlog/degradation (duplicate or
    /// late-arriving work for steps already completed)
    pub shed_samples: u64,
    /// actor deaths observed by the supervisor (panic or injected crash)
    pub actor_crashes: u64,
    /// actor respawns performed by the supervisor (bounded backoff)
    pub actor_restarts: u64,
    /// heartbeat timeouts (actor alive but silent past the deadline)
    pub actor_timeouts: u64,
    /// wire frames dropped as damaged (torn mid-flight or checksum
    /// mismatch) -- the byte-level tier of quarantine-don't-crash
    /// (distrib/wire.rs); zero on in-process transports
    pub wire_corrupt_frames: u64,
    /// actor connections re-established after a sever (distinct from
    /// `actor_restarts`, which counts announced deaths)
    pub wire_reconnects: u64,
    /// actor connection attempts rejected at the handshake (wrong
    /// magic/version/run-fingerprint)
    pub handshake_rejects: u64,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    pub fn record_forward(&mut self, samples: usize) {
        self.record_forward_padded(samples, samples);
    }

    /// Forward execution whose compiled capacity exceeded the live sample
    /// count (sharded forward padded up to a capacity bucket).
    pub fn record_forward_padded(&mut self, samples: usize, executed_slots: usize) {
        debug_assert!(samples <= executed_slots);
        self.forward_samples += samples as u64;
        self.forward_executed += executed_slots as u64;
        self.forward_calls += 1;
    }

    pub fn record_backward(&mut self, cap: usize, kept: usize) {
        debug_assert!(kept <= cap);
        self.backward_kept += kept as u64;
        self.backward_executed += cap as u64;
        self.backward_calls += 1;
        *self.bucket_hist.entry(cap).or_insert(0) += 1;
    }

    /// Tier-1 screen work: one draft dot product per sample.
    pub fn record_screen(&mut self, samples: usize) {
        self.screen_samples += samples as u64;
    }

    /// Samples the screen spared from the full forward.
    pub fn record_forward_skipped(&mut self, samples: usize) {
        self.forward_skipped += samples as u64;
    }

    /// Corrupt samples rejected by the admission path (never trained on).
    pub fn record_quarantined(&mut self, samples: usize) {
        self.quarantined_samples += samples as u64;
    }

    /// A whole batch rejected before per-sample inspection (shape or
    /// fingerprint mismatch). Counts the batch AND its samples.
    pub fn record_quarantined_batch(&mut self, samples: usize) {
        self.quarantined_batches += 1;
        self.quarantined_samples += samples as u64;
    }

    /// Admitted samples from a stale snapshot; `kept` of them survived
    /// the gate (the staleness-vs-admission axis of arxiv 2603.20521).
    pub fn record_stale(&mut self, samples: usize, kept: usize) {
        debug_assert!(kept <= samples);
        self.stale_samples += samples as u64;
        self.stale_kept += kept as u64;
    }

    /// Deliveries dropped under backlog (duplicate/late work).
    pub fn record_shed(&mut self, samples: usize) {
        self.shed_samples += samples as u64;
    }

    /// An actor death observed by the supervisor.
    pub fn record_actor_crash(&mut self) {
        self.actor_crashes += 1;
    }

    /// A supervisor respawn of a dead actor.
    pub fn record_actor_restart(&mut self) {
        self.actor_restarts += 1;
    }

    /// A heartbeat timeout on a silent actor.
    pub fn record_actor_timeout(&mut self) {
        self.actor_timeouts += 1;
    }

    /// A wire frame dropped as damaged (torn or checksum-failed).
    pub fn record_wire_corrupt_frame(&mut self) {
        self.wire_corrupt_frames += 1;
    }

    /// An actor connection re-established after a sever.
    pub fn record_wire_reconnect(&mut self) {
        self.wire_reconnects += 1;
    }

    /// Handshake rejections, drained in bulk from the transport's
    /// accept loop at the end of a run.
    pub fn record_handshake_rejects(&mut self, n: u64) {
        self.handshake_rejects += n;
    }

    /// Fig 3 cost model in forward-sample equivalents, using the gate's
    /// idealized backward count.
    pub fn total_compute(&self, cost_ratio: f64) -> f64 {
        self.forward_samples as f64 + cost_ratio * self.backward_kept as f64
    }

    /// Same but charging the padded slots the executor actually ran.
    pub fn total_compute_executed(&self, cost_ratio: f64) -> f64 {
        self.forward_samples as f64 + cost_ratio * self.backward_executed as f64
    }

    /// Three-term cost model of the screening pipeline, idealized:
    /// `screen_ratio * screen + forward + cost_ratio * backward_kept`,
    /// where `screen_ratio` is the cost of one draft dot product in
    /// forward-sample equivalents (one [D]-dot vs the full forward's
    /// FLOPs). Degenerates to `total_compute` on unscreened runs.
    pub fn total_compute_screened(&self, screen_ratio: f64, cost_ratio: f64) -> f64 {
        screen_ratio * self.screen_samples as f64 + self.total_compute(cost_ratio)
    }

    /// Same three-term model but charging the padded slots both executors
    /// actually ran (`forward_executed`, `backward_executed`) -- the
    /// honest fixed-shape hardware cost of a screened run.
    pub fn total_compute_screened_executed(&self, screen_ratio: f64, cost_ratio: f64) -> f64 {
        screen_ratio * self.screen_samples as f64
            + self.forward_executed as f64
            + cost_ratio * self.backward_executed as f64
    }

    /// Fraction of screened samples the tier-1 gate spared from the full
    /// forward (0 when nothing was screened).
    pub fn screen_skip_rate(&self) -> f64 {
        if self.screen_samples == 0 {
            return 0.0;
        }
        self.forward_skipped as f64 / self.screen_samples as f64
    }

    /// Fraction of executed backward slots that were padding.
    pub fn padding_overhead(&self) -> f64 {
        if self.backward_executed == 0 {
            return 0.0;
        }
        1.0 - self.backward_kept as f64 / self.backward_executed as f64
    }

    /// Empirical gate rate: kept backward samples per forward sample.
    pub fn gate_rate(&self) -> f64 {
        if self.forward_samples == 0 {
            return 0.0;
        }
        self.backward_kept as f64 / self.forward_samples as f64
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.forward_samples += other.forward_samples;
        self.forward_executed += other.forward_executed;
        self.forward_calls += other.forward_calls;
        self.screen_samples += other.screen_samples;
        self.forward_skipped += other.forward_skipped;
        self.backward_kept += other.backward_kept;
        self.backward_executed += other.backward_executed;
        self.backward_calls += other.backward_calls;
        for (&cap, &n) in &other.bucket_hist {
            *self.bucket_hist.entry(cap).or_insert(0) += n;
        }
        self.quarantined_samples += other.quarantined_samples;
        self.quarantined_batches += other.quarantined_batches;
        self.stale_samples += other.stale_samples;
        self.stale_kept += other.stale_kept;
        self.shed_samples += other.shed_samples;
        self.actor_crashes += other.actor_crashes;
        self.actor_restarts += other.actor_restarts;
        self.actor_timeouts += other.actor_timeouts;
        self.wire_corrupt_frames += other.wire_corrupt_frames;
        self.wire_reconnects += other.wire_reconnects;
        self.handshake_rejects += other.handshake_rejects;
    }
}

/// Shard-aware ledger: one `Ledger` per logical shard of the worker pool,
/// merged deterministically (ascending shard index) into batch totals.
/// Forward/backward work is attributed to the shard that logically owns it
/// -- sample shards for forward scoring, `chunk_index % n_shards` for
/// backward chunks -- so the attribution is a function of the batch alone,
/// not of which OS thread happened to run the work.
#[derive(Debug, Clone)]
pub struct ShardedLedger {
    shards: Vec<Ledger>,
}

impl ShardedLedger {
    pub fn new(n_shards: usize) -> ShardedLedger {
        ShardedLedger { shards: vec![Ledger::new(); n_shards.max(1)] }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> &Ledger {
        &self.shards[i]
    }

    pub fn shard_mut(&mut self, i: usize) -> &mut Ledger {
        &mut self.shards[i]
    }

    /// Shard that owns packed chunk `chunk_index` (round-robin; shared by
    /// the packed forward path of the screening pipeline and the bucketed
    /// backward executor).
    pub fn chunk_owner(&self, chunk_index: usize) -> usize {
        chunk_index % self.shards.len()
    }

    /// Shard that owns backward chunk `chunk_index` (round-robin).
    pub fn backward_owner(&self, chunk_index: usize) -> usize {
        self.chunk_owner(chunk_index)
    }

    /// Merge all shards into one total ledger, in shard order.
    pub fn total(&self) -> Ledger {
        let mut t = Ledger::new();
        for s in &self.shards {
            t.merge(s);
        }
        t
    }

    /// Load imbalance of executed backward slots: max-shard / mean-shard
    /// (1.0 = perfectly balanced; 0.0 when no backward work ran).
    pub fn backward_imbalance(&self) -> f64 {
        let per: Vec<u64> = self.shards.iter().map(|s| s.backward_executed).collect();
        let total: u64 = per.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let mean = total as f64 / per.len() as f64;
        *per.iter().max().unwrap() as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut l = Ledger::new();
        l.record_forward(100);
        l.record_forward(100);
        l.record_backward(4, 3);
        l.record_backward(8, 8);
        assert_eq!(l.forward_samples, 200);
        assert_eq!(l.forward_executed, 200);
        assert_eq!(l.forward_calls, 2);
        assert_eq!(l.backward_kept, 11);
        assert_eq!(l.backward_executed, 12);
        assert_eq!(l.bucket_hist[&4], 1);
        assert_eq!(l.bucket_hist[&8], 1);
    }

    #[test]
    fn cost_model_matches_fig3() {
        let mut l = Ledger::new();
        l.record_forward(100);
        l.record_backward(4, 3);
        // ratio 0: backward free -> cost is pure forward
        assert_eq!(l.total_compute(0.0), 100.0);
        // ratio 4: the paper's "typical" point
        assert_eq!(l.total_compute(4.0), 112.0);
        assert_eq!(l.total_compute_executed(4.0), 116.0);
    }

    #[test]
    fn gate_rate_and_padding() {
        let mut l = Ledger::new();
        l.record_forward(100);
        l.record_backward(4, 3);
        assert!((l.gate_rate() - 0.03).abs() < 1e-12);
        assert!((l.padding_overhead() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn pg_vs_gated_backward_ratio() {
        // PG: every sample backward; DG-K rho=0.03: ~3 per 100.
        let mut pg = Ledger::new();
        let mut kg = Ledger::new();
        for _ in 0..100 {
            pg.record_forward(100);
            pg.record_backward(100, 100);
            kg.record_forward(100);
            kg.record_backward(4, 3);
        }
        let ratio = pg.backward_kept as f64 / kg.backward_kept as f64;
        assert!((ratio - 100.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn padded_forward_counts_slots_separately() {
        let mut l = Ledger::new();
        // shards of 5 samples executed in capacity-8 artifacts
        l.record_forward_padded(5, 8);
        l.record_forward_padded(5, 8);
        assert_eq!(l.forward_samples, 10);
        assert_eq!(l.forward_executed, 16);
        let mut t = Ledger::new();
        t.merge(&l);
        assert_eq!(t.forward_executed, 16);
    }

    #[test]
    fn screen_counters_accumulate_and_merge() {
        let mut l = Ledger::new();
        l.record_screen(32);
        l.record_forward_skipped(24);
        l.record_forward_padded(8, 8);
        assert_eq!(l.screen_samples, 32);
        assert_eq!(l.forward_skipped, 24);
        // the screened-batch invariant: survivors + skipped = batch
        assert_eq!(l.forward_samples + l.forward_skipped, 32);
        assert!((l.screen_skip_rate() - 0.75).abs() < 1e-12);
        let mut t = Ledger::new();
        t.merge(&l);
        t.merge(&l);
        assert_eq!(t.screen_samples, 64);
        assert_eq!(t.forward_skipped, 48);
        // an unscreened ledger has rate 0, not NaN
        assert_eq!(Ledger::new().screen_skip_rate(), 0.0);
    }

    #[test]
    fn three_term_cost_model_screened_vs_unscreened() {
        // screened batch of 32: 32 screen dots, 8 survivors forwarded in a
        // capacity-8 chunk, 3 kept backward in a capacity-4 bucket
        let mut s = Ledger::new();
        s.record_screen(32);
        s.record_forward_skipped(24);
        s.record_forward_padded(8, 8);
        s.record_backward(4, 3);
        // idealized: 0.05 * 32 + 8 + 4 * 3 = 21.6
        assert!((s.total_compute_screened(0.05, 4.0) - 21.6).abs() < 1e-12);
        // padded/executed: 0.05 * 32 + 8 + 4 * 4 = 25.6
        assert!((s.total_compute_screened_executed(0.05, 4.0) - 25.6).abs() < 1e-12);

        // the unscreened equivalent pays the full 32-sample forward
        let mut u = Ledger::new();
        u.record_forward(32);
        u.record_backward(4, 3);
        assert_eq!(u.total_compute(4.0), 44.0);
        // with no screen work the three-term model degenerates exactly
        assert_eq!(u.total_compute_screened(0.05, 4.0), u.total_compute(4.0));
        assert_eq!(
            u.total_compute_screened_executed(0.05, 4.0),
            u.total_compute_executed(4.0)
        );
        // and the screened run is cheaper end to end
        assert!(s.total_compute_screened_executed(0.05, 4.0) < u.total_compute_executed(4.0));
    }

    #[test]
    fn sharded_ledger_screen_counters_merge_in_totals() {
        let mut sl = ShardedLedger::new(3);
        // a 10-sample batch screened across 3 shards (4 + 3 + 3)
        sl.shard_mut(0).record_screen(4);
        sl.shard_mut(1).record_screen(3);
        sl.shard_mut(2).record_screen(3);
        sl.shard_mut(0).record_forward_skipped(7);
        let t = sl.total();
        assert_eq!(t.screen_samples, 10);
        assert_eq!(t.forward_skipped, 7);
        // chunk ownership is shared by packed forward and backward paths
        assert_eq!(sl.chunk_owner(4), 1);
        assert_eq!(sl.backward_owner(4), sl.chunk_owner(4));
    }

    #[test]
    fn sharded_ledger_total_matches_manual_merge() {
        let mut sl = ShardedLedger::new(4);
        assert_eq!(sl.n_shards(), 4);
        for i in 0..4 {
            sl.shard_mut(i).record_forward(25);
        }
        // 3 chunks round-robin over 4 shards
        for (ci, (cap, kept)) in [(8usize, 8usize), (8, 8), (4, 1)].iter().enumerate() {
            let owner = sl.backward_owner(ci);
            assert_eq!(owner, ci % 4);
            sl.shard_mut(owner).record_backward(*cap, *kept);
        }
        let t = sl.total();
        assert_eq!(t.forward_samples, 100);
        assert_eq!(t.forward_calls, 4);
        assert_eq!(t.backward_kept, 17);
        assert_eq!(t.backward_executed, 20);
        assert_eq!(t.bucket_hist[&8], 2);
        assert_eq!(t.bucket_hist[&4], 1);
    }

    #[test]
    fn sharded_ledger_imbalance() {
        let mut sl = ShardedLedger::new(2);
        assert_eq!(sl.backward_imbalance(), 0.0);
        sl.shard_mut(0).record_backward(30, 30);
        sl.shard_mut(1).record_backward(10, 10);
        // max 30 over mean 20
        assert!((sl.backward_imbalance() - 1.5).abs() < 1e-12);
        // zero-shard guard: constructor clamps to one shard
        assert_eq!(ShardedLedger::new(0).n_shards(), 1);
    }

    #[test]
    fn fault_counters_accumulate_and_merge() {
        let mut l = Ledger::new();
        l.record_quarantined(2);
        l.record_quarantined_batch(8); // batch reject counts its samples too
        l.record_stale(16, 3);
        l.record_shed(4);
        l.record_actor_crash();
        l.record_actor_restart();
        l.record_actor_timeout();
        l.record_actor_timeout();
        l.record_wire_corrupt_frame();
        l.record_wire_corrupt_frame();
        l.record_wire_reconnect();
        l.record_handshake_rejects(3);
        assert_eq!(l.quarantined_samples, 10);
        assert_eq!(l.quarantined_batches, 1);
        assert_eq!(l.stale_samples, 16);
        assert_eq!(l.stale_kept, 3);
        assert_eq!(l.shed_samples, 4);
        assert_eq!(l.actor_crashes, 1);
        assert_eq!(l.actor_restarts, 1);
        assert_eq!(l.actor_timeouts, 2);
        assert_eq!(l.wire_corrupt_frames, 2);
        assert_eq!(l.wire_reconnects, 1);
        assert_eq!(l.handshake_rejects, 3);
        let mut t = Ledger::new();
        t.merge(&l);
        t.merge(&l);
        assert_eq!(t.quarantined_samples, 20);
        assert_eq!(t.quarantined_batches, 2);
        assert_eq!(t.stale_samples, 32);
        assert_eq!(t.stale_kept, 6);
        assert_eq!(t.shed_samples, 8);
        assert_eq!(t.actor_crashes, 2);
        assert_eq!(t.actor_restarts, 2);
        assert_eq!(t.actor_timeouts, 4);
        assert_eq!(t.wire_corrupt_frames, 4);
        assert_eq!(t.wire_reconnects, 2);
        assert_eq!(t.handshake_rejects, 6);
    }

    #[test]
    fn merge_combines() {
        let mut a = Ledger::new();
        a.record_forward(10);
        a.record_backward(4, 2);
        let mut b = Ledger::new();
        b.record_forward(5);
        b.record_backward(4, 4);
        a.merge(&b);
        assert_eq!(a.forward_samples, 15);
        assert_eq!(a.backward_kept, 6);
        assert_eq!(a.bucket_hist[&4], 2);
    }
}
