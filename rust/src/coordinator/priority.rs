//! Priority signals for screening backward passes (paper §2.2, Fig 5).
//!
//! Delight chi = U * ell is the paper's signal; the alternatives here are
//! the comparison set of Fig 5 / Proposition 2: advantage-only,
//! surprisal-only, |advantage|, uniform random, and the additive family
//! f_alpha = alpha*U + (1-alpha)*ell that Prop 2 shows can mis-rank.

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::pool::unit_rng;
use crate::utils::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Priority {
    /// chi = U * ell (the paper's delight)
    Delight,
    /// U alone: usefulness without rarity
    Advantage,
    /// ell alone: rarity without usefulness
    Surprisal,
    /// |U|: magnitude of usefulness, sign-blind
    AbsAdvantage,
    /// uniform random subsampling (control)
    Uniform,
    /// alpha*U + (1-alpha)*ell (UCB-style additive mix)
    Additive { alpha: f64 },
}

impl Priority {
    /// Score one sample. `u` advantage, `ell` surprisal (= -log pi(a)).
    /// Uniform draws its score from `rng` so thresholding keeps a random
    /// subset of the requested size.
    pub fn score(&self, u: f64, ell: f64, rng: &mut Pcg32) -> f64 {
        match *self {
            Priority::Delight => u * ell,
            Priority::Advantage => u,
            Priority::Surprisal => ell,
            Priority::AbsAdvantage => u.abs(),
            Priority::Uniform => rng.uniform(),
            Priority::Additive { alpha } => alpha * u + (1.0 - alpha) * ell,
        }
    }

    /// Score a whole batch. `Uniform` draws ONE batch-global key from the
    /// caller's `rng` and scores sample `i` from the keyed stream
    /// `unit_rng(key, 0, i)` -- the same per-unit keying rule the screen
    /// and the trainers use -- so the main stream advances by exactly one
    /// draw regardless of batch size and no per-sample draw can depend on
    /// how the batch is sharded. Callers uphold the determinism contract
    /// by invoking this on the caller's thread only (every gate decision
    /// is batch-global; see DESIGN.md §11).
    pub fn score_batch(&self, u: &[f64], ell: &[f64], rng: &mut Pcg32) -> Vec<f64> {
        assert_eq!(u.len(), ell.len());
        if matches!(self, Priority::Uniform) {
            let key = rng.next_u64();
            return (0..u.len()).map(|i| unit_rng(key, 0, i as u64).uniform()).collect();
        }
        u.iter().zip(ell).map(|(&a, &l)| self.score(a, l, rng)).collect()
    }

    /// Parse a CLI/TOML priority name: `delight`, `advantage`,
    /// `surprisal`, `abs_advantage`, `uniform`, or `additive:<alpha>`
    /// (the `additive_a<alpha>` form `name()` prints is also accepted, so
    /// names round-trip). The additive alpha must parse and be finite --
    /// a typo'd knob fails loudly instead of silently running delight.
    pub fn parse(text: &str) -> Result<Priority> {
        let t = text.trim();
        Ok(match t {
            "delight" => Priority::Delight,
            "advantage" => Priority::Advantage,
            "surprisal" => Priority::Surprisal,
            "abs_advantage" => Priority::AbsAdvantage,
            "uniform" => Priority::Uniform,
            _ => {
                let alpha = t
                    .strip_prefix("additive:")
                    .or_else(|| t.strip_prefix("additive_a"))
                    .ok_or_else(|| {
                        anyhow!(
                            "unknown priority '{t}' (delight|advantage|surprisal|\
                             abs_advantage|uniform|additive:<alpha>)"
                        )
                    })?;
                let alpha: f64 = alpha
                    .parse()
                    .map_err(|e| anyhow!("bad additive alpha '{alpha}': {e}"))?;
                ensure!(alpha.is_finite(), "additive alpha must be finite, got {alpha}");
                Priority::Additive { alpha }
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            Priority::Delight => "delight".into(),
            Priority::Advantage => "advantage".into(),
            Priority::Surprisal => "surprisal".into(),
            Priority::AbsAdvantage => "abs_advantage".into(),
            Priority::Uniform => "uniform".into(),
            Priority::Additive { alpha } => format!("additive_a{alpha:.2}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::seeded(0)
    }

    #[test]
    fn delight_is_product() {
        let mut r = rng();
        assert_eq!(Priority::Delight.score(0.5, 2.0, &mut r), 1.0);
        assert_eq!(Priority::Delight.score(-0.5, 2.0, &mut r), -1.0);
    }

    #[test]
    fn delight_sign_follows_advantage() {
        // Prop 2 part 1: sgn(chi) = sgn(U) since ell > 0 always.
        let mut r = rng();
        for &(u, ell) in &[(0.3, 0.1), (0.3, 5.0), (-0.9, 0.1), (-0.01, 9.0)] {
            let chi = Priority::Delight.score(u, ell, &mut r);
            assert_eq!(chi > 0.0, u > 0.0);
        }
    }

    #[test]
    fn additive_can_flip_sign() {
        // Prop 2 part 2: adding a positive surprisal can make a negative-
        // advantage sample outrank a positive one.
        let mut r = rng();
        let alpha = 0.2;
        let bad = Priority::Additive { alpha }.score(-0.1, 8.0, &mut r); // rare failure
        let good = Priority::Additive { alpha }.score(0.9, 0.05, &mut r); // common success
        assert!(bad > good, "additive mis-ranks: bad={bad} good={good}");
        // delight ranks them correctly
        let db = Priority::Delight.score(-0.1, 8.0, &mut r);
        let dg = Priority::Delight.score(0.9, 0.05, &mut r);
        assert!(dg > db);
    }

    #[test]
    fn alpha_limits_recover_pure_signals() {
        let mut r = rng();
        let u = 0.37;
        let ell = 1.3;
        assert_eq!(Priority::Additive { alpha: 1.0 }.score(u, ell, &mut r), u);
        assert_eq!(Priority::Additive { alpha: 0.0 }.score(u, ell, &mut r), ell);
    }

    #[test]
    fn uniform_is_random_but_deterministic_in_seed() {
        let mut r1 = rng();
        let mut r2 = rng();
        let s1 = Priority::Uniform.score_batch(&[0.0; 5], &[0.0; 5], &mut r1);
        let s2 = Priority::Uniform.score_batch(&[0.0; 5], &[0.0; 5], &mut r2);
        assert_eq!(s1, s2);
        assert!(s1.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn uniform_batch_costs_one_main_stream_draw() {
        // the batch-global keying rule: scoring n samples advances the
        // caller's stream by exactly one draw, independent of n, so the
        // trajectory after the gate cannot depend on the survivor count
        let mut small = rng();
        let mut large = rng();
        Priority::Uniform.score_batch(&[0.0; 3], &[0.0; 3], &mut small);
        Priority::Uniform.score_batch(&[0.0; 64], &[0.0; 64], &mut large);
        assert_eq!(small.next_u64(), large.next_u64());
    }

    #[test]
    fn uniform_scores_are_prefix_stable_under_one_key() {
        // per-sample keyed streams: sample i's score is a function of
        // (batch key, i) alone, so a shorter batch scored under the same
        // key is a prefix of the longer one
        let mut r1 = rng();
        let mut r2 = rng();
        let s3 = Priority::Uniform.score_batch(&[0.0; 3], &[0.0; 3], &mut r1);
        let s8 = Priority::Uniform.score_batch(&[0.0; 8], &[0.0; 8], &mut r2);
        assert_eq!(s3[..], s8[..3]);
    }

    #[test]
    fn parse_round_trips_every_variant() {
        for p in [
            Priority::Delight,
            Priority::Advantage,
            Priority::Surprisal,
            Priority::AbsAdvantage,
            Priority::Uniform,
            Priority::Additive { alpha: 0.25 },
        ] {
            assert_eq!(Priority::parse(&p.name()).unwrap(), p, "{}", p.name());
        }
        assert_eq!(
            Priority::parse("additive:0.2").unwrap(),
            Priority::Additive { alpha: 0.2 }
        );
        assert_eq!(Priority::parse(" delight ").unwrap(), Priority::Delight);
    }

    #[test]
    fn parse_rejects_junk_loudly() {
        assert!(Priority::parse("delite").is_err());
        assert!(Priority::parse("additive:").is_err());
        assert!(Priority::parse("additive:abc").is_err());
        assert!(Priority::parse("additive:nan").is_err());
        assert!(Priority::parse("additive:inf").is_err());
        // out-of-[0,1] alphas are unusual but well-defined arithmetic --
        // allowed, the gate cannot panic on them
        assert_eq!(Priority::parse("additive:1.5").unwrap(), Priority::Additive { alpha: 1.5 });
    }
}
