//! Priority signals for screening backward passes (paper §2.2, Fig 5).
//!
//! Delight chi = U * ell is the paper's signal; the alternatives here are
//! the comparison set of Fig 5 / Proposition 2: advantage-only,
//! surprisal-only, |advantage|, uniform random, and the additive family
//! f_alpha = alpha*U + (1-alpha)*ell that Prop 2 shows can mis-rank.

use crate::utils::rng::Pcg32;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Priority {
    /// chi = U * ell (the paper's delight)
    Delight,
    /// U alone: usefulness without rarity
    Advantage,
    /// ell alone: rarity without usefulness
    Surprisal,
    /// |U|: magnitude of usefulness, sign-blind
    AbsAdvantage,
    /// uniform random subsampling (control)
    Uniform,
    /// alpha*U + (1-alpha)*ell (UCB-style additive mix)
    Additive { alpha: f64 },
}

impl Priority {
    /// Score one sample. `u` advantage, `ell` surprisal (= -log pi(a)).
    /// Uniform draws its score from `rng` so thresholding keeps a random
    /// subset of the requested size.
    pub fn score(&self, u: f64, ell: f64, rng: &mut Pcg32) -> f64 {
        match *self {
            Priority::Delight => u * ell,
            Priority::Advantage => u,
            Priority::Surprisal => ell,
            Priority::AbsAdvantage => u.abs(),
            Priority::Uniform => rng.uniform(),
            Priority::Additive { alpha } => alpha * u + (1.0 - alpha) * ell,
        }
    }

    /// Score a whole batch.
    pub fn score_batch(&self, u: &[f64], ell: &[f64], rng: &mut Pcg32) -> Vec<f64> {
        assert_eq!(u.len(), ell.len());
        u.iter().zip(ell).map(|(&a, &l)| self.score(a, l, rng)).collect()
    }

    pub fn name(&self) -> String {
        match self {
            Priority::Delight => "delight".into(),
            Priority::Advantage => "advantage".into(),
            Priority::Surprisal => "surprisal".into(),
            Priority::AbsAdvantage => "abs_advantage".into(),
            Priority::Uniform => "uniform".into(),
            Priority::Additive { alpha } => format!("additive_a{alpha:.2}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Pcg32 {
        Pcg32::seeded(0)
    }

    #[test]
    fn delight_is_product() {
        let mut r = rng();
        assert_eq!(Priority::Delight.score(0.5, 2.0, &mut r), 1.0);
        assert_eq!(Priority::Delight.score(-0.5, 2.0, &mut r), -1.0);
    }

    #[test]
    fn delight_sign_follows_advantage() {
        // Prop 2 part 1: sgn(chi) = sgn(U) since ell > 0 always.
        let mut r = rng();
        for &(u, ell) in &[(0.3, 0.1), (0.3, 5.0), (-0.9, 0.1), (-0.01, 9.0)] {
            let chi = Priority::Delight.score(u, ell, &mut r);
            assert_eq!(chi > 0.0, u > 0.0);
        }
    }

    #[test]
    fn additive_can_flip_sign() {
        // Prop 2 part 2: adding a positive surprisal can make a negative-
        // advantage sample outrank a positive one.
        let mut r = rng();
        let alpha = 0.2;
        let bad = Priority::Additive { alpha }.score(-0.1, 8.0, &mut r); // rare failure
        let good = Priority::Additive { alpha }.score(0.9, 0.05, &mut r); // common success
        assert!(bad > good, "additive mis-ranks: bad={bad} good={good}");
        // delight ranks them correctly
        let db = Priority::Delight.score(-0.1, 8.0, &mut r);
        let dg = Priority::Delight.score(0.9, 0.05, &mut r);
        assert!(dg > db);
    }

    #[test]
    fn alpha_limits_recover_pure_signals() {
        let mut r = rng();
        let u = 0.37;
        let ell = 1.3;
        assert_eq!(Priority::Additive { alpha: 1.0 }.score(u, ell, &mut r), u);
        assert_eq!(Priority::Additive { alpha: 0.0 }.score(u, ell, &mut r), ell);
    }

    #[test]
    fn uniform_is_random_but_deterministic_in_seed() {
        let mut r1 = rng();
        let mut r2 = rng();
        let s1 = Priority::Uniform.score_batch(&[0.0; 5], &[0.0; 5], &mut r1);
        let s2 = Priority::Uniform.score_batch(&[0.0; 5], &[0.0; 5], &mut r2);
        assert_eq!(s1, s2);
        assert!(s1.windows(2).any(|w| w[0] != w[1]));
    }
}
