//! L3 coordinator — the paper's system contribution.
//!
//! `priority` scores samples from the forward pass, `gate` decides which
//! backward passes to pay for (Algorithm 1), `batcher` packs the kept
//! samples into compiled capacity buckets so skipped compute is real
//! skipped compute, `accounting` keeps the (shard-aware) forward/backward
//! ledger every paper axis is drawn from, `quantile` provides the
//! streaming-price variant of the adaptive gate, `pool` is the worker
//! pool that shards each batch across threads under the determinism
//! contract of DESIGN.md §"L3 parallelism", and `pipeline` structures the
//! gated step into the explicit Screen -> Forward -> Gate -> Backward
//! stages of the L4 speculative screening pipeline (DESIGN.md §8).

pub mod accounting;
pub mod batcher;
pub mod gate;
pub mod pipeline;
pub mod pool;
pub mod priority;
pub mod quantile;
pub mod speculative;

pub use accounting::{Ledger, ShardedLedger};
pub use batcher::{BucketSet, PackedChunk};
pub use gate::{GateDecision, KondoGate, Pricing};
pub use pipeline::{
    BackwardStage, ForwardPlan, ForwardStage, GateStage, ScreenCfg, ScreenStage, ScreenVerdict,
};
pub use pool::{non_empty_shards, split_shards, unit_rng, Shard, WorkerPool};
pub use priority::Priority;
pub use quantile::{EwQuantile, P2Quantile};
pub use speculative::{rank_correlation, screening_precision, DraftScreen};
