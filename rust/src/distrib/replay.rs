//! Recorded actor streams: persist every rollout the learner ingested,
//! replay it later through the identical admission path.
//!
//! Replay extends the eta=0 bit-identity contract to the distributed
//! path: the learner's trajectory is a fold over (context, rollout)
//! pairs, contexts are regenerated from the seed (they are a pure
//! function of it, so the file never stores pixels), and the rollouts
//! come from this stream in ingest order — one per step. Values
//! round-trip through the bit-exact `Json` codec (`NaN`/`Infinity`
//! tokens included), so even a *poisoned* stream replays into the exact
//! quarantine counters of the live run. Supervisor counters (crashes,
//! restarts, timeouts, shed) are runtime events, not stream content, and
//! are documented as excluded from replay comparison.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::checkpoint::{field, ju64, obj, pu64, write_atomic};
use crate::utils::json::Json;

use super::transport::RolloutBatch;

const STREAM_KIND: &str = "kondo-actor-stream";
const STREAM_VERSION: u64 = 1;

fn jf64_bits_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

fn pf64_bits_arr(j: &Json, what: &str) -> Result<Vec<f64>> {
    let Json::Arr(a) = j else {
        bail!("actor stream field '{what}': expected an array");
    };
    a.iter()
        .map(|v| v.as_f64().with_context(|| format!("actor stream field '{what}'")))
        .collect()
}

fn rollout_to_json(rb: &RolloutBatch) -> Json {
    obj(vec![
        ("actor", ju64(rb.actor as u64)),
        ("step", ju64(rb.step)),
        ("snapshot_version", ju64(rb.snapshot_version)),
        ("fingerprint", ju64(rb.fingerprint)),
        ("n", ju64(rb.n as u64)),
        // i32 -> f64 is exact, so actions survive the Num round-trip
        ("actions", Json::Arr(rb.actions.iter().map(|&a| Json::Num(a as f64)).collect())),
        ("u", jf64_bits_arr(&rb.u)),
        ("ell", jf64_bits_arr(&rb.ell)),
    ])
}

fn rollout_from_json(j: &Json) -> Result<RolloutBatch> {
    let actions = match field(j, "actions")? {
        Json::Arr(a) => a
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|x| x as i32)
                    .context("actor stream field 'actions'")
            })
            .collect::<Result<Vec<i32>>>()?,
        _ => bail!("actor stream field 'actions': expected an array"),
    };
    Ok(RolloutBatch {
        actor: pu64(field(j, "actor")?, "actor")? as usize,
        step: pu64(field(j, "step")?, "step")?,
        snapshot_version: pu64(field(j, "snapshot_version")?, "snapshot_version")?,
        fingerprint: pu64(field(j, "fingerprint")?, "fingerprint")?,
        n: pu64(field(j, "n")?, "n")? as usize,
        actions,
        u: pf64_bits_arr(field(j, "u")?, "u")?,
        ell: pf64_bits_arr(field(j, "ell")?, "ell")?,
    })
}

/// Write an ingest-ordered stream atomically. `fingerprint` is the run's
/// fingerprint hash: replay refuses a stream recorded under a different
/// config, same as checkpoint resume does.
pub fn write_stream(
    path: &str,
    fingerprint: u64,
    batch: usize,
    rollouts: &[RolloutBatch],
) -> Result<()> {
    let doc = obj(vec![
        ("kind", Json::Str(STREAM_KIND.into())),
        ("version", ju64(STREAM_VERSION)),
        ("fingerprint", ju64(fingerprint)),
        ("batch", ju64(batch as u64)),
        ("steps", ju64(rollouts.len() as u64)),
        ("rollouts", Json::Arr(rollouts.iter().map(rollout_to_json).collect())),
    ]);
    write_atomic(Path::new(path), &doc.dump())
        .with_context(|| format!("writing actor stream '{path}'"))
}

/// Load a stream and check it is what it claims: right kind/version,
/// matching run fingerprint, and exactly one rollout per step in order
/// (`rollouts[t].step == t`), so replay is a straight fold.
pub fn read_stream(path: &str, expect_fingerprint: u64) -> Result<Vec<RolloutBatch>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading actor stream '{path}'"))?;
    let doc = Json::parse(&text).with_context(|| format!("parsing actor stream '{path}'"))?;
    match field(&doc, "kind")? {
        Json::Str(k) if k == STREAM_KIND => {}
        other => bail!("'{path}' is not an actor stream (kind {})", other.dump().trim()),
    }
    let version = pu64(field(&doc, "version")?, "version")?;
    if version != STREAM_VERSION {
        bail!("actor stream '{path}' is v{version}, this build reads v{STREAM_VERSION}");
    }
    let fp = pu64(field(&doc, "fingerprint")?, "fingerprint")?;
    if fp != expect_fingerprint {
        bail!(
            "actor stream '{path}' was recorded under a different run fingerprint \
             ({fp:#x} != {expect_fingerprint:#x}); config must match the recording"
        );
    }
    let Json::Arr(arr) = field(&doc, "rollouts")? else {
        bail!("actor stream field 'rollouts': expected an array");
    };
    let steps = pu64(field(&doc, "steps")?, "steps")? as usize;
    if arr.len() != steps {
        bail!("actor stream '{path}': steps claims {steps}, found {}", arr.len());
    }
    let rollouts: Vec<RolloutBatch> =
        arr.iter().map(rollout_from_json).collect::<Result<_>>()?;
    for (t, rb) in rollouts.iter().enumerate() {
        if rb.step != t as u64 {
            bail!(
                "actor stream '{path}': rollout {t} is for step {} (must be ingest-ordered)",
                rb.step
            );
        }
    }
    Ok(rollouts)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(step: u64) -> RolloutBatch {
        RolloutBatch {
            actor: (step % 2) as usize,
            step,
            snapshot_version: step.saturating_sub(1),
            fingerprint: 0xabcd,
            n: 3,
            actions: vec![0, -1, 9],
            u: vec![0.5, f64::NAN, -0.25],
            ell: vec![1.5, f64::INFINITY, 0.0],
        }
    }

    #[test]
    fn streams_round_trip_bit_exactly_including_non_finite_values() {
        let dir = std::env::temp_dir().join("kondo_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.json");
        let path = path.to_str().unwrap();

        let rollouts = vec![sample(0), sample(1)];
        write_stream(path, 0xabcd, 3, &rollouts).unwrap();
        let back = read_stream(path, 0xabcd).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in rollouts.iter().zip(&back) {
            assert_eq!(a.actor, b.actor);
            assert_eq!(a.step, b.step);
            assert_eq!(a.snapshot_version, b.snapshot_version);
            assert_eq!(a.fingerprint, b.fingerprint);
            assert_eq!(a.n, b.n);
            assert_eq!(a.actions, b.actions);
            // bit-exact, NaN included
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
            assert_eq!(bits(&a.u), bits(&b.u));
            assert_eq!(bits(&a.ell), bits(&b.ell));
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn wrong_fingerprint_and_bad_order_are_clean_errors() {
        let dir = std::env::temp_dir().join("kondo_replay_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.json");
        let path = path.to_str().unwrap();

        write_stream(path, 7, 3, &[sample(0)]).unwrap();
        let err = read_stream(path, 8).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");

        // out-of-order stream: step 1 in slot 0
        write_stream(path, 7, 3, &[sample(1)]).unwrap();
        let err = read_stream(path, 7).unwrap_err().to_string();
        assert!(err.contains("ingest-ordered"), "{err}");

        std::fs::remove_file(path).unwrap();
    }
}
