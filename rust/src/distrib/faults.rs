//! Deterministic, seeded fault injection for the actor–learner runtime.
//!
//! A `FaultPlan` is a schedule of failures keyed by *learner step*: the
//! actor that ends up computing step `s` consumes the event for `s` (at
//! most once — respawns and re-dispatches never re-fire it), so every
//! counter a plan implies is exact regardless of which thread raced
//! where. Keying by step rather than by actor slot is what makes the
//! schedule unambiguous under supervisor churn: slot assignment shifts
//! when actors die, but each step is first attempted exactly once.
//!
//! The spec grammar (the `fault_spec` config knob) is a comma-separated
//! list of
//!
//! ```text
//! crash@STEP              actor computing STEP dies before replying
//! stall@STEP:MS           actor sleeps MS ms, then delivers late
//! poison@STEP:KIND[:N]    corrupt the rollout for STEP (N samples, default 1)
//! torn@STEP               cut STEP's rollout frame mid-flight, then hang up
//! partial@STEP:BYTES      send only the first BYTES bytes, then hang up
//! bitflip@STEP:OFFSET     flip one payload bit (checksum-caught, link survives)
//! disconnect@STEP         close the connection instead of replying
//! lag=N                   override the snapshot-lag knob for this run
//! ```
//!
//! with poison kinds `nan_u | nan_ell | bad_action` (per-sample corruption
//! the admission path quarantines sample-by-sample) and `shape |
//! fingerprint` (batch-level corruption quarantining the whole delivery).
//! The last four are *wire-level* faults: they damage the encoded bytes
//! (via `wire::WireFaults`) rather than the rollout contents, so they
//! only exist on a transport with real bytes — `transport=socket`
//! rejects nothing, everything else rejects the spec up front. At most
//! one event per step: a duplicate step is a config error, not a silent
//! precedence rule.

use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::transport::RolloutBatch;

/// The pool-wide poisoned-mutex policy (coordinator/pool.rs): absorb the
/// poison and take the guard — consumed-flag state stays consistent even
/// if some other thread panicked while holding it.
fn lock_ok<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoisonKind {
    /// non-finite advantage on the first N samples
    NanU,
    /// non-finite surprisal on the first N samples
    NanEll,
    /// out-of-range action id on the first N samples
    BadAction,
    /// truncated sample vectors (claimed n != actual) — batch-level
    Shape,
    /// wrong policy/config fingerprint — batch-level
    Fingerprint,
}

impl PoisonKind {
    pub fn parse(s: &str) -> Result<PoisonKind> {
        Ok(match s {
            "nan_u" => PoisonKind::NanU,
            "nan_ell" => PoisonKind::NanEll,
            "bad_action" => PoisonKind::BadAction,
            "shape" => PoisonKind::Shape,
            "fingerprint" => PoisonKind::Fingerprint,
            other => bail!(
                "unknown poison kind '{other}' (nan_u|nan_ell|bad_action|shape|fingerprint)"
            ),
        })
    }

    /// Batch-level kinds quarantine the whole delivery before per-sample
    /// inspection; the rest are caught sample-by-sample.
    pub fn is_batch_level(&self) -> bool {
        matches!(self, PoisonKind::Shape | PoisonKind::Fingerprint)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    Crash,
    Stall { ms: u64 },
    Poison { kind: PoisonKind, count: usize },
    /// Cut the rollout frame mid-flight and hang up (wire-level).
    Torn,
    /// Send only the first `bytes` bytes of the frame, then hang up.
    Partial { bytes: usize },
    /// Flip one payload bit; the checksum catches it, the link survives.
    BitFlip { offset: usize },
    /// Close the connection instead of replying.
    Disconnect,
}

impl FaultKind {
    /// Wire-level faults damage encoded bytes rather than rollout
    /// contents; they require a transport with real bytes.
    pub fn is_wire(&self) -> bool {
        matches!(
            self,
            FaultKind::Torn
                | FaultKind::Partial { .. }
                | FaultKind::BitFlip { .. }
                | FaultKind::Disconnect
        )
    }

    /// Wire faults that end the connection (the learner must reconnect);
    /// `BitFlip` is the one that damages a frame while the link lives.
    pub fn severs_connection(&self) -> bool {
        matches!(
            self,
            FaultKind::Torn | FaultKind::Partial { .. } | FaultKind::Disconnect
        )
    }
}

#[derive(Debug, Clone, Copy)]
pub struct FaultEvent {
    pub step: u64,
    pub kind: FaultKind,
}

/// Ledger totals a plan implies, for exact-match assertions (tests and
/// the `dist` experiment report). `restarts` assumes the supervisor's
/// respawn budget is not exhausted (the default); runs that exhaust it
/// assert their counters directly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExpectedCounts {
    pub crashes: u64,
    pub restarts: u64,
    pub stalls: u64,
    pub quarantined_samples: u64,
    pub quarantined_batches: u64,
    /// Frames the learner dropped as damaged: one per torn/partial
    /// (detected mid-frame) and one per bitflip (checksum-caught).
    pub wire_corrupt_frames: u64,
    /// Connections re-established after a sever: one per torn/partial/
    /// disconnect (a bitflip leaves the link up).
    pub wire_reconnects: u64,
}

/// A seeded failure schedule, shared (`&FaultPlan`) across actor threads.
#[derive(Debug)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    consumed: Mutex<Vec<bool>>,
    lag_override: Option<usize>,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan { events: vec![], consumed: Mutex::new(vec![]), lag_override: None }
    }

    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut events: Vec<FaultEvent> = Vec::new();
        let mut lag_override = None;
        for raw in spec.split(',') {
            let tok = raw.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some(v) = tok.strip_prefix("lag=") {
                lag_override =
                    Some(v.parse().with_context(|| format!("bad lag override '{tok}'"))?);
                continue;
            }
            let (what, rest) = tok
                .split_once('@')
                .with_context(|| format!("bad fault token '{tok}' (KIND@STEP...)"))?;
            let kind = match what {
                "crash" => {
                    let step = rest.parse().with_context(|| format!("bad step in '{tok}'"))?;
                    FaultEvent { step, kind: FaultKind::Crash }
                }
                "stall" => {
                    let (s, ms) = rest
                        .split_once(':')
                        .with_context(|| format!("stall needs '@STEP:MS' in '{tok}'"))?;
                    FaultEvent {
                        step: s.parse().with_context(|| format!("bad step in '{tok}'"))?,
                        kind: FaultKind::Stall {
                            ms: ms.parse().with_context(|| format!("bad ms in '{tok}'"))?,
                        },
                    }
                }
                "poison" => {
                    let mut parts = rest.split(':');
                    let step: u64 = parts
                        .next()
                        .unwrap_or("")
                        .parse()
                        .with_context(|| format!("bad step in '{tok}'"))?;
                    let kind = PoisonKind::parse(
                        parts.next().with_context(|| format!("poison needs a kind in '{tok}'"))?,
                    )?;
                    let count = match parts.next() {
                        None => 1,
                        Some(c) => {
                            c.parse().with_context(|| format!("bad count in '{tok}'"))?
                        }
                    };
                    FaultEvent { step, kind: FaultKind::Poison { kind, count } }
                }
                "torn" => {
                    let step = rest.parse().with_context(|| format!("bad step in '{tok}'"))?;
                    FaultEvent { step, kind: FaultKind::Torn }
                }
                "partial" => {
                    let (s, b) = rest
                        .split_once(':')
                        .with_context(|| format!("partial needs '@STEP:BYTES' in '{tok}'"))?;
                    FaultEvent {
                        step: s.parse().with_context(|| format!("bad step in '{tok}'"))?,
                        kind: FaultKind::Partial {
                            bytes: b.parse().with_context(|| format!("bad bytes in '{tok}'"))?,
                        },
                    }
                }
                "bitflip" => {
                    let (s, o) = rest
                        .split_once(':')
                        .with_context(|| format!("bitflip needs '@STEP:OFFSET' in '{tok}'"))?;
                    FaultEvent {
                        step: s.parse().with_context(|| format!("bad step in '{tok}'"))?,
                        kind: FaultKind::BitFlip {
                            offset: o
                                .parse()
                                .with_context(|| format!("bad offset in '{tok}'"))?,
                        },
                    }
                }
                "disconnect" => {
                    let step = rest.parse().with_context(|| format!("bad step in '{tok}'"))?;
                    FaultEvent { step, kind: FaultKind::Disconnect }
                }
                other => bail!(
                    "unknown fault '{other}' in '{tok}' \
                     (crash|stall|poison|torn|partial|bitflip|disconnect)"
                ),
            };
            if events.iter().any(|e| e.step == kind.step) {
                bail!("duplicate fault at step {} (one event per step)", kind.step);
            }
            events.push(kind);
        }
        let n = events.len();
        Ok(FaultPlan { events, consumed: Mutex::new(vec![false; n]), lag_override })
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn lag_override(&self) -> Option<usize> {
        self.lag_override
    }

    /// Whether any scheduled event is wire-level (needs a byte-carrying
    /// transport); the config layer gates `transport=` choices on this.
    pub fn has_wire_events(&self) -> bool {
        self.events.iter().any(|e| e.kind.is_wire())
    }

    /// Consume the event scheduled for `step`, if any and not yet fired.
    /// Whoever computes the step's first attempt gets it; re-dispatches
    /// after a crash/timeout find it already consumed.
    pub fn take(&self, step: u64) -> Option<FaultKind> {
        let idx = self.events.iter().position(|e| e.step == step)?;
        let mut consumed = lock_ok(&self.consumed);
        if consumed[idx] {
            return None;
        }
        consumed[idx] = true;
        Some(self.events[idx].kind)
    }

    /// The exact ledger totals this plan implies for a run of batches of
    /// size `batch` whose steps cover every event.
    pub fn expected_counts(&self, batch: usize) -> ExpectedCounts {
        let mut c = ExpectedCounts::default();
        for e in &self.events {
            match e.kind {
                FaultKind::Crash => {
                    c.crashes += 1;
                    c.restarts += 1;
                }
                FaultKind::Stall { .. } => c.stalls += 1,
                FaultKind::Poison { kind, count } => {
                    if kind.is_batch_level() {
                        c.quarantined_batches += 1;
                        c.quarantined_samples += batch as u64;
                    } else {
                        c.quarantined_samples += count.min(batch) as u64;
                    }
                }
                // a torn/partial frame is both a detected corruption and
                // a severed link the learner must re-establish
                FaultKind::Torn | FaultKind::Partial { .. } => {
                    c.wire_corrupt_frames += 1;
                    c.wire_reconnects += 1;
                }
                FaultKind::BitFlip { .. } => c.wire_corrupt_frames += 1,
                FaultKind::Disconnect => c.wire_reconnects += 1,
            }
        }
        c
    }
}

/// Corrupt a computed rollout in place, deterministically: the first
/// `count` samples for the per-sample kinds, a structural lie for the
/// batch-level ones. The corruption is a pure function of (kind, count),
/// so a replayed poisoned stream is bit-identical to the live one.
pub fn apply_poison(rb: &mut RolloutBatch, kind: PoisonKind, count: usize) {
    match kind {
        PoisonKind::NanU => {
            for v in rb.u.iter_mut().take(count) {
                *v = f64::NAN;
            }
        }
        PoisonKind::NanEll => {
            for v in rb.ell.iter_mut().take(count) {
                *v = f64::NAN;
            }
        }
        PoisonKind::BadAction => {
            for a in rb.actions.iter_mut().take(count) {
                *a = -1;
            }
        }
        PoisonKind::Shape => {
            // claimed n stays; the vectors lie about it
            rb.actions.pop();
            rb.u.pop();
        }
        PoisonKind::Fingerprint => {
            rb.fingerprint ^= 0x5eed_bad_f00d;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rollout(n: usize) -> RolloutBatch {
        RolloutBatch {
            actor: 0,
            step: 3,
            snapshot_version: 3,
            fingerprint: 42,
            n,
            actions: vec![1; n],
            u: vec![0.5; n],
            ell: vec![1.0; n],
        }
    }

    #[test]
    fn parses_the_full_grammar() {
        let p = FaultPlan::parse("crash@5, stall@7:1500, poison@8:nan_u:3, lag=2").unwrap();
        assert_eq!(p.lag_override(), Some(2));
        assert!(!p.is_empty());
        assert_eq!(p.take(5), Some(FaultKind::Crash));
        assert_eq!(p.take(7), Some(FaultKind::Stall { ms: 1500 }));
        assert_eq!(
            p.take(8),
            Some(FaultKind::Poison { kind: PoisonKind::NanU, count: 3 })
        );
        assert_eq!(p.take(9), None, "no event scheduled");
        // empty / whitespace specs are a valid no-fault plan
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
        assert!(FaultPlan::none().take(0).is_none());
    }

    #[test]
    fn events_fire_at_most_once() {
        let p = FaultPlan::parse("crash@5").unwrap();
        assert_eq!(p.take(5), Some(FaultKind::Crash));
        assert_eq!(p.take(5), None, "a re-dispatched step must not re-fire");
    }

    #[test]
    fn malformed_specs_are_errors() {
        for bad in [
            "crash@x",
            "crash5",
            "stall@3",          // ms required
            "poison@3",         // kind required
            "poison@3:weird",
            "explode@3",
            "lag=abc",
            "crash@5,poison@5:nan_u", // duplicate step
            "torn@x",
            "partial@3",  // bytes required
            "bitflip@3",  // offset required
            "bitflip@3:x",
            "disconnect@",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn wire_grammar_parses_and_classifies() {
        let p =
            FaultPlan::parse("torn@2,partial@3:13,bitflip@6:17,disconnect@9,crash@11").unwrap();
        assert!(p.has_wire_events());
        assert_eq!(p.take(2), Some(FaultKind::Torn));
        assert_eq!(p.take(3), Some(FaultKind::Partial { bytes: 13 }));
        assert_eq!(p.take(6), Some(FaultKind::BitFlip { offset: 17 }));
        assert_eq!(p.take(9), Some(FaultKind::Disconnect));

        assert!(FaultKind::Torn.is_wire() && FaultKind::Torn.severs_connection());
        assert!(FaultKind::Partial { bytes: 1 }.severs_connection());
        assert!(FaultKind::Disconnect.severs_connection());
        assert!(
            FaultKind::BitFlip { offset: 0 }.is_wire()
                && !FaultKind::BitFlip { offset: 0 }.severs_connection()
        );
        assert!(!FaultKind::Crash.is_wire());
        assert!(!FaultPlan::parse("crash@5,stall@6:10").unwrap().has_wire_events());
    }

    #[test]
    fn wire_events_count_into_expected_totals() {
        let p = FaultPlan::parse("torn@1,partial@2:9,bitflip@3:4,disconnect@5,crash@6").unwrap();
        let c = p.expected_counts(16);
        // torn + partial + bitflip each drop one frame
        assert_eq!(c.wire_corrupt_frames, 3);
        // torn + partial + disconnect each sever the link once
        assert_eq!(c.wire_reconnects, 3);
        assert_eq!(c.crashes, 1);
        assert_eq!(c.restarts, 1);
        assert_eq!(c.quarantined_samples, 0, "wire damage never reaches admission");
    }

    #[test]
    fn expected_counts_match_the_plan() {
        let p = FaultPlan::parse(
            "crash@1,stall@2:900,poison@3:nan_ell:4,poison@4:shape,poison@5:fingerprint",
        )
        .unwrap();
        let c = p.expected_counts(16);
        assert_eq!(c.crashes, 1);
        assert_eq!(c.restarts, 1);
        assert_eq!(c.stalls, 1);
        // 4 per-sample + two whole batches of 16
        assert_eq!(c.quarantined_samples, 4 + 32);
        assert_eq!(c.quarantined_batches, 2);
    }

    #[test]
    fn poison_corrupts_deterministically() {
        let mut rb = rollout(8);
        apply_poison(&mut rb, PoisonKind::NanU, 3);
        assert!(rb.u[..3].iter().all(|v| v.is_nan()));
        assert!(rb.u[3..].iter().all(|v| v.is_finite()));

        let mut rb = rollout(8);
        apply_poison(&mut rb, PoisonKind::BadAction, 2);
        assert_eq!(&rb.actions[..3], &[-1, -1, 1]);

        let mut rb = rollout(8);
        apply_poison(&mut rb, PoisonKind::Shape, 1);
        assert_eq!(rb.n, 8, "the claim stands while the vectors lie");
        assert_eq!(rb.actions.len(), 7);

        let mut rb = rollout(8);
        let fp = rb.fingerprint;
        apply_poison(&mut rb, PoisonKind::Fingerprint, 1);
        assert_ne!(rb.fingerprint, fp);
    }
}
