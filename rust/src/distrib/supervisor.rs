//! Supervision policy for the actor fleet: who is alive, who computes
//! which step, and what happens when a slot dies.
//!
//! The policy is deliberately separated from the transport/thread
//! machinery so it is a pure, unit-testable state machine:
//!
//! - **Assignment** is static round-robin by step, skipping dead slots.
//!   With every slot alive, `assign(t) = t % n` — which is exactly how
//!   the inline reference stamps rollouts, so a zero-fault threaded run
//!   records a byte-identical actor stream.
//! - **Respawn** is per-slot budgeted with bounded exponential backoff:
//!   a flapping actor costs at most `max_respawns` restarts, after which
//!   the slot stays dead and its work re-routes to survivors (graceful
//!   degradation — training continues as long as one slot lives).

use std::time::Duration;

/// What the runtime should do about a slot that just died.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RespawnVerdict {
    /// Respawn the slot after sleeping `backoff`.
    Respawn { backoff: Duration },
    /// Budget exhausted: leave the slot dead.
    GiveUp,
}

#[derive(Debug)]
pub struct Supervisor {
    alive: Vec<bool>,
    respawns: Vec<u32>,
    max_respawns: u32,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
}

impl Supervisor {
    pub fn new(n_actors: usize, max_respawns: u32) -> Supervisor {
        assert!(n_actors > 0, "need at least one actor slot");
        Supervisor {
            alive: vec![true; n_actors],
            respawns: vec![0; n_actors],
            max_respawns,
            backoff_base_ms: 5,
            backoff_cap_ms: 100,
        }
    }

    /// Override the backoff schedule (base doubles per consecutive
    /// respawn of a slot, saturating at `cap_ms`). The socket transport
    /// uses this to stretch the in-process defaults to reconnect scale.
    pub fn with_backoff(mut self, base_ms: u64, cap_ms: u64) -> Supervisor {
        self.backoff_base_ms = base_ms.max(1);
        self.backoff_cap_ms = cap_ms.max(self.backoff_base_ms);
        self
    }

    pub fn n_slots(&self) -> usize {
        self.alive.len()
    }

    pub fn n_live(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    pub fn is_alive(&self, slot: usize) -> bool {
        self.alive[slot]
    }

    /// Total respawns granted so far (the ledger's `actor_restarts`).
    pub fn total_respawns(&self) -> u64 {
        self.respawns.iter().map(|&r| r as u64).sum()
    }

    /// The slot that should compute `step`: round-robin over all slots,
    /// walking forward past dead ones. `None` when the whole fleet is
    /// dead.
    pub fn assign(&self, step: u64) -> Option<usize> {
        let n = self.alive.len();
        let start = (step % n as u64) as usize;
        (0..n).map(|k| (start + k) % n).find(|&a| self.alive[a])
    }

    /// The next live slot after `slot` (wrapping), for re-dispatching
    /// work away from a stalled or dead actor. May return `slot` itself
    /// when it is the only survivor.
    pub fn next_live_after(&self, slot: usize) -> Option<usize> {
        let n = self.alive.len();
        (1..=n).map(|k| (slot + k) % n).find(|&a| self.alive[a])
    }

    /// Record a death and decide the slot's fate. On `Respawn` the
    /// caller sleeps the backoff, restarts the actor, then confirms with
    /// [`Supervisor::on_respawn`].
    pub fn on_death(&mut self, slot: usize) -> RespawnVerdict {
        self.alive[slot] = false;
        if self.respawns[slot] >= self.max_respawns {
            return RespawnVerdict::GiveUp;
        }
        self.respawns[slot] += 1;
        let shift = (self.respawns[slot] - 1).min(10);
        let ms = (self.backoff_base_ms << shift).min(self.backoff_cap_ms);
        RespawnVerdict::Respawn { backoff: Duration::from_millis(ms) }
    }

    pub fn on_respawn(&mut self, slot: usize) {
        self.alive[slot] = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_round_robin_skipping_dead_slots() {
        let mut sup = Supervisor::new(3, 0);
        assert_eq!(sup.assign(0), Some(0));
        assert_eq!(sup.assign(4), Some(1));
        assert_eq!(sup.assign(5), Some(2));
        sup.on_death(1);
        // slot 1's steps roll forward to slot 2
        assert_eq!(sup.assign(4), Some(2));
        assert_eq!(sup.assign(0), Some(0));
        assert_eq!(sup.n_live(), 2);
    }

    #[test]
    fn backoff_grows_and_saturates_until_budget_exhausts() {
        let mut sup = Supervisor::new(1, 6);
        let mut last = Duration::ZERO;
        for i in 0..6 {
            match sup.on_death(0) {
                RespawnVerdict::Respawn { backoff } => {
                    assert!(backoff >= last, "death {i}: backoff must not shrink");
                    assert!(backoff <= Duration::from_millis(100), "death {i}: capped");
                    last = backoff;
                    sup.on_respawn(0);
                }
                RespawnVerdict::GiveUp => panic!("budget not yet exhausted at death {i}"),
            }
        }
        assert_eq!(sup.total_respawns(), 6);
        assert_eq!(sup.on_death(0), RespawnVerdict::GiveUp);
        assert_eq!(sup.n_live(), 0);
        assert_eq!(sup.assign(3), None, "a dead fleet assigns nothing");
    }

    #[test]
    fn with_backoff_rescales_the_schedule() {
        let mut sup = Supervisor::new(1, 4).with_backoff(50, 200);
        let mut seen = Vec::new();
        for _ in 0..4 {
            match sup.on_death(0) {
                RespawnVerdict::Respawn { backoff } => {
                    seen.push(backoff.as_millis() as u64);
                    sup.on_respawn(0);
                }
                RespawnVerdict::GiveUp => panic!("budget not exhausted"),
            }
        }
        assert_eq!(seen, vec![50, 100, 200, 200], "doubles from base, saturates at cap");
        // degenerate knobs are clamped, not panicked on
        let mut sup = Supervisor::new(1, 1).with_backoff(0, 0);
        assert!(matches!(sup.on_death(0), RespawnVerdict::Respawn { .. }));
    }

    #[test]
    fn zero_budget_means_no_respawns() {
        let mut sup = Supervisor::new(2, 0);
        assert_eq!(sup.on_death(0), RespawnVerdict::GiveUp);
        assert!(!sup.is_alive(0));
        assert!(sup.is_alive(1));
        // survivor keeps the fleet serving
        assert_eq!(sup.assign(0), Some(1));
        assert_eq!(sup.next_live_after(0), Some(1));
        assert_eq!(sup.next_live_after(1), Some(1), "sole survivor re-routes to itself");
    }
}
