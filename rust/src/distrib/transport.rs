//! Message types and the channel transport between learner and actors.
//!
//! The `Transport` trait is deliberately shaped like a socket: the
//! learner addresses actors by slot index, receives from a single
//! multiplexed inbox with a timeout, and never touches thread handles.
//! A TCP/IPC implementation can slot in behind the same trait; the
//! in-process `ChannelTransport` is the reference implementation and the
//! one the test suite runs against.
//!
//! Everything an actor needs to compute a rollout travels in the
//! `WorkItem` — contexts and the policy snapshot — so actors are
//! stateless between items apart from a param cache keyed on snapshot
//! version. Everything the learner needs to admit the result travels in
//! the `RolloutBatch`; contexts are *not* echoed back (the learner keeps
//! its pending set), which is what a bandwidth-conscious socket transport
//! would do too.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use super::faults::FaultKind;

/// Immutable policy snapshot shipped to actors. `version` counts
/// optimizer steps applied; `fingerprint` is the run fingerprint hash the
/// admission path checks echoes against.
#[derive(Debug)]
pub struct PolicySnapshot {
    pub version: u64,
    /// one Vec<f32> per model tensor, in ParamStore order
    pub params: Arc<Vec<Vec<f32>>>,
    pub fingerprint: u64,
}

/// One unit of rollout work: compute step `step` on `snapshot`.
#[derive(Debug)]
pub struct WorkItem {
    pub step: u64,
    /// flattened context batch, `[b * obs_dim]`
    pub x: Vec<f32>,
    /// labels (actors need them only to score rewards)
    pub y: Vec<usize>,
    pub snapshot: Arc<PolicySnapshot>,
    /// Injected fault order for this step, if any. The learner owns the
    /// consume-once `FaultPlan` and ships the order with the work, so a
    /// cross-process actor needs no plan of its own and re-dispatches
    /// can explicitly choose whether the fault rides along.
    pub fault: Option<FaultKind>,
}

/// An actor's reply for one step. `n` is the *claimed* sample count; the
/// admission path cross-checks it against the vector lengths, so a buggy
/// or malicious actor cannot smuggle a short batch past accounting.
#[derive(Debug, Clone)]
pub struct RolloutBatch {
    pub actor: usize,
    pub step: u64,
    pub snapshot_version: u64,
    pub fingerprint: u64,
    pub n: usize,
    pub actions: Vec<i32>,
    pub u: Vec<f64>,
    pub ell: Vec<f64>,
}

pub enum ToActor {
    Generate(Box<WorkItem>),
    Shutdown,
}

#[derive(Debug)]
pub enum FromActor {
    Rollout(RolloutBatch),
    /// Actor announced its own death (injected crash or compute error).
    /// `step` is the work item it was holding, so the supervisor can
    /// re-dispatch it without waiting for a heartbeat timeout.
    Died { actor: usize, step: u64, reason: String },
}

/// What a `recv_timeout` call can yield. Splitting "quiet" from "dead"
/// lets the supervisor stop arming heartbeat clocks against a fleet
/// that can never answer, and the wire events let a byte-carrying
/// transport report damage without pretending it was silence.
#[derive(Debug)]
pub enum Recv {
    Msg(FromActor),
    /// A frame from `actor` failed its checksum; the connection
    /// survives, the frame is gone. The learner re-dispatches whatever
    /// the frame was carrying.
    CorruptFrame { actor: usize },
    /// `actor`'s connection died. `mid_frame` distinguishes a torn
    /// frame (bytes lost in flight — counts as corruption too) from a
    /// close at a frame boundary.
    ConnectionLost { actor: usize, mid_frame: bool },
    /// Nothing arrived within the timeout; the fleet may still answer.
    Timeout,
    /// Every slot is permanently gone — no message can ever arrive.
    Disconnected,
}

/// Which transport implementation carries the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (the reference implementation).
    Channel,
    /// Unix-domain sockets to actor subprocesses (distrib/socket.rs).
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "" | "channel" => TransportKind::Channel,
            "socket" => TransportKind::Socket,
            other => bail!("unknown transport '{other}' (channel|socket)"),
        })
    }
}

/// Learner-side view of the actor fleet.
pub trait Transport: Send + Sync {
    fn n_actors(&self) -> usize;
    /// Send work to one actor slot. Fails if the slot has no live
    /// endpoint (never registered, deregistered, or hung up).
    fn send_to(&self, actor: usize, msg: ToActor) -> Result<()>;
    /// Wait up to `timeout` for any actor's next message or wire event.
    fn recv_timeout(&self, timeout: Duration) -> Recv;
}

/// The pool-wide poisoned-mutex policy (coordinator/pool.rs): absorb the
/// poison and take the guard; channel endpoints stay usable.
fn lock_ok<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// In-process transport over std mpsc channels: one inbox channel per
/// actor slot, one shared outbox back to the learner. Respawning an
/// actor re-registers its slot, which drops the dead actor's inbox (and
/// any work queued behind the crash — the supervisor re-dispatches it).
pub struct ChannelTransport {
    to: Mutex<Vec<Option<Sender<ToActor>>>>,
    from_tx: Mutex<Sender<FromActor>>,
    from_rx: Mutex<Receiver<FromActor>>,
}

impl ChannelTransport {
    pub fn new(n_actors: usize) -> ChannelTransport {
        let (from_tx, from_rx) = channel();
        ChannelTransport {
            to: Mutex::new(vec![None; n_actors]),
            from_tx: Mutex::new(from_tx),
            from_rx: Mutex::new(from_rx),
        }
    }

    /// Create (or replace, on respawn) the endpoint pair for slot
    /// `actor`: the actor-side inbox receiver and a clone of the shared
    /// outbox sender. An out-of-range slot is a clean error (loud in
    /// debug builds): a supervisor holding a corrupted slot id must not
    /// take the learner down with an index panic.
    pub fn register_actor(
        &self,
        actor: usize,
    ) -> Result<(Receiver<ToActor>, Sender<FromActor>)> {
        let mut to = lock_ok(&self.to);
        #[cfg(debug_assertions)]
        if actor >= to.len() {
            eprintln!("[transport] register_actor: slot {actor} out of range (fleet of {})", to.len());
        }
        match to.get_mut(actor) {
            Some(slot) => {
                let (tx, rx) = channel();
                *slot = Some(tx);
                drop(to);
                Ok((rx, lock_ok(&self.from_tx).clone()))
            }
            None => bail!("register_actor: slot {actor} out of range (fleet of {})", to.len()),
        }
    }

    /// Drop slot `actor`'s inbox sender; its receive loop ends once the
    /// queue drains. Used for shutdown and for abandoning a dead slot.
    /// Deregistering an out-of-range slot is a no-op (loud in debug
    /// builds): there is nothing to tear down.
    pub fn deregister(&self, actor: usize) {
        let mut to = lock_ok(&self.to);
        #[cfg(debug_assertions)]
        if actor >= to.len() {
            eprintln!("[transport] deregister: slot {actor} out of range (fleet of {})", to.len());
        }
        if let Some(slot) = to.get_mut(actor) {
            *slot = None;
        }
    }
}

impl Transport for ChannelTransport {
    fn n_actors(&self) -> usize {
        lock_ok(&self.to).len()
    }

    fn send_to(&self, actor: usize, msg: ToActor) -> Result<()> {
        let to = lock_ok(&self.to);
        match to.get(actor) {
            Some(Some(tx)) => {
                if tx.send(msg).is_err() {
                    bail!("actor {actor} hung up");
                }
                Ok(())
            }
            Some(None) => bail!("actor {actor} not registered"),
            None => bail!("actor slot {actor} out of range"),
        }
    }

    fn recv_timeout(&self, timeout: Duration) -> Recv {
        match lock_ok(&self.from_rx).recv_timeout(timeout) {
            Ok(msg) => Recv::Msg(msg),
            // the learner holds its own from_tx clone, so mpsc never
            // reports Disconnected here; infer a dead fleet from the
            // slot table instead: a timeout with zero live endpoints
            // means no reply can ever arrive
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                if lock_ok(&self.to).iter().all(|s| s.is_none()) {
                    Recv::Disconnected
                } else {
                    Recv::Timeout
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_and_slot_errors() {
        let tp = ChannelTransport::new(2);
        assert_eq!(tp.n_actors(), 2);
        // unregistered slots and out-of-range slots fail cleanly
        assert!(tp.send_to(0, ToActor::Shutdown).is_err());
        assert!(tp.send_to(7, ToActor::Shutdown).is_err());

        let (rx, tx) = tp.register_actor(0).unwrap();
        tp.send_to(0, ToActor::Shutdown).unwrap();
        assert!(matches!(rx.recv().unwrap(), ToActor::Shutdown));

        tx.send(FromActor::Died { actor: 0, step: 3, reason: "test".into() }).unwrap();
        match tp.recv_timeout(Duration::from_millis(200)) {
            Recv::Msg(FromActor::Died { actor, step, .. }) => {
                assert_eq!((actor, step), (0, 3));
            }
            other => panic!("expected Died, got {other:?}"),
        }
        // empty inbox with a live slot: a quiet fleet, not a dead one
        assert!(matches!(tp.recv_timeout(Duration::from_millis(10)), Recv::Timeout));
    }

    #[test]
    fn reregistering_replaces_the_endpoint() {
        let tp = ChannelTransport::new(1);
        let (old_rx, _tx) = tp.register_actor(0).unwrap();
        let (new_rx, _tx2) = tp.register_actor(0).unwrap();
        tp.send_to(0, ToActor::Shutdown).unwrap();
        // the replaced inbox sees a hangup, the fresh one gets the message
        assert!(old_rx.recv().is_err());
        assert!(matches!(new_rx.recv().unwrap(), ToActor::Shutdown));

        tp.deregister(0);
        assert!(tp.send_to(0, ToActor::Shutdown).is_err());
        assert!(new_rx.recv().is_err(), "deregister hangs up the actor");
    }

    #[test]
    fn corrupted_slot_ids_never_panic() {
        // regression (satellite): a supervisor respawning with a
        // corrupted slot id must not take the learner down — both
        // registration paths degrade to a clean error / no-op
        let tp = ChannelTransport::new(2);
        assert!(tp.register_actor(7).is_err());
        tp.deregister(7); // must not panic
        // the fleet is untouched: in-range slots still work
        let (rx, _tx) = tp.register_actor(1).unwrap();
        tp.send_to(1, ToActor::Shutdown).unwrap();
        assert!(matches!(rx.recv().unwrap(), ToActor::Shutdown));
    }

    #[test]
    fn quiet_fleet_vs_dead_fleet() {
        let tp = ChannelTransport::new(2);
        let (_rx0, _tx0) = tp.register_actor(0).unwrap();
        assert!(matches!(tp.recv_timeout(Duration::from_millis(5)), Recv::Timeout));
        // deregister every slot: no reply can ever arrive
        tp.deregister(0);
        assert!(matches!(tp.recv_timeout(Duration::from_millis(5)), Recv::Disconnected));
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("").unwrap(), TransportKind::Channel);
        assert_eq!(TransportKind::parse("channel").unwrap(), TransportKind::Channel);
        assert_eq!(TransportKind::parse("socket").unwrap(), TransportKind::Socket);
        assert!(TransportKind::parse("tcp").is_err());
    }
}
