//! Actor workers: long-lived rollout generators over a `Transport`.
//!
//! Each actor owns a scratch `ParamStore` it restores policy snapshots
//! into (re-marshalling only when the snapshot version changes, so a
//! lagging learner costs one marshal per *new* snapshot, not per step)
//! and an identical copy of the bandit environment. Per-sample
//! randomness comes from `unit_rng(seed, step, i)` — a pure function of
//! (run seed, learner step, sample index) — so the rollout for a step is
//! bit-identical no matter which actor slot computes it, which worker
//! count the learner runs, or whether the step was re-dispatched after a
//! crash. That invariance is the whole determinism story of the
//! distributed path: the learner's trajectory is a fold over per-step
//! rollouts that nobody's scheduling can perturb.
//!
//! Fault injection executes here too: the learner owns the consume-once
//! `FaultPlan` and ships each step's fault order inside the `WorkItem`,
//! so the actor just obeys — crash, stall, or poison its own reply.
//! Wire-level fault kinds (torn/partial/bitflip/disconnect) are byte
//! damage; they only mean something to a transport that carries bytes
//! and are ignored by this in-process loop (the socket actor in
//! distrib/socket.rs executes them).

use std::sync::mpsc::{Receiver, Sender};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::algo::baseline::Baseline;
use crate::coordinator::pool::unit_rng;
use crate::envs::mnist::{MnistBandit, RewardNoise};
use crate::model::ParamStore;
use crate::runtime::{tensor, Engine, HostTensor};

use super::faults::{apply_poison, FaultKind, FaultPlan};
use super::transport::{FromActor, PolicySnapshot, RolloutBatch, ToActor, WorkItem};

/// One actor's compute state. Also used directly (without a thread) by
/// the inline learner mode, which is the bit-identity reference.
pub struct ActorCtx<'e> {
    eng: &'e Engine,
    env: MnistBandit,
    seed: u64,
    b: usize,
    n_act: usize,
    scratch: ParamStore,
    param_inputs: Vec<HostTensor>,
    loaded_version: Option<u64>,
    /// zero logit-noise matrix `[b, n_act]`; the distributed path runs
    /// the clean-forward variant of the figures
    noise: HostTensor,
}

impl<'e> ActorCtx<'e> {
    pub fn new(eng: &'e Engine, seed: u64) -> Result<ActorCtx<'e>> {
        let man = eng.manifest();
        let b = man.constants.mnist_batch;
        let n_act = man.constants.mnist_actions;
        let rules = man.model("mnist")?.to_vec();
        // rule-shaped placeholder; every rollout restores real params over it
        let scratch = ParamStore::init(&rules, 0);
        Ok(ActorCtx {
            eng,
            // same fixed corpus seed as the single-process trainer
            env: MnistBandit::new(1234, b, RewardNoise::clean()),
            seed,
            b,
            n_act,
            scratch,
            param_inputs: Vec::new(),
            loaded_version: None,
            noise: HostTensor::f32(&[b, n_act], vec![0.0; b * n_act]),
        })
    }

    /// Compute the rollout for one step: forward the snapshot policy on
    /// the shipped contexts, sample actions, score rewards, and emit
    /// per-sample advantage `u` and surprisal `ell`.
    pub fn rollout(
        &mut self,
        actor: usize,
        snapshot: &PolicySnapshot,
        step: u64,
        x: &[f32],
        y: &[usize],
    ) -> Result<RolloutBatch> {
        let b = self.b;
        if self.loaded_version != Some(snapshot.version) {
            self.scratch
                .restore_tensors(&snapshot.params)
                .with_context(|| format!("actor {actor}: snapshot v{}", snapshot.version))?;
            self.scratch.marshal_into(&mut self.param_inputs);
            self.loaded_version = Some(snapshot.version);
        }
        let xs = HostTensor::f32(&[b, self.env.obs_dim()], x.to_vec());
        let mut inputs: Vec<&HostTensor> = self.param_inputs.iter().collect();
        inputs.push(&xs);
        inputs.push(&self.noise);
        let out = self.eng.execute_refs("mnist_fwd", &inputs)?;
        let logp = out[0].as_f32()?;

        let mut actions = Vec::with_capacity(b);
        let mut u = Vec::with_capacity(b);
        let mut ell = Vec::with_capacity(b);
        for i in 0..b {
            // same stream as the single-process trainer's scoring stage
            let mut srng = unit_rng(self.seed, step, i as u64);
            let row = &logp[i * self.n_act..(i + 1) * self.n_act];
            let a = srng.categorical_from_logits(row);
            let pi: Vec<f32> = row.iter().map(|&l| l.exp()).collect();
            let r = self.env.reward(a, y[i], &mut srng);
            let bval = Baseline::Expected.value(&pi, y[i]);
            actions.push(a as i32);
            u.push(r - bval);
            ell.push(-(row[a] as f64));
        }
        tensor::recycle_tensor(xs);
        for t in out {
            tensor::recycle_tensor(t);
        }
        Ok(RolloutBatch {
            actor,
            step,
            snapshot_version: snapshot.version,
            fingerprint: snapshot.fingerprint,
            n: b,
            actions,
            u,
            ell,
        })
    }
}

/// Thread body for one actor slot: receive work until shutdown (explicit
/// message or learner hangup), executing any fault order the work item
/// carries. Crashes and compute errors announce themselves with a `Died`
/// message carrying the orphaned step so the supervisor can re-dispatch
/// without waiting out a heartbeat.
pub fn actor_loop(
    eng: &Engine,
    actor: usize,
    seed: u64,
    rx: Receiver<ToActor>,
    tx: Sender<FromActor>,
) {
    let mut ctx = match ActorCtx::new(eng, seed) {
        Ok(c) => c,
        Err(e) => {
            let _ = tx.send(FromActor::Died {
                actor,
                step: 0,
                reason: format!("actor init failed: {e:#}"),
            });
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        let item = match msg {
            ToActor::Shutdown => return,
            ToActor::Generate(item) => item,
        };
        // wire kinds are byte damage — meaningless on an mpsc channel —
        // and train_distrib refuses them before a channel fleet starts;
        // matching only process faults keeps this loop honest anyway
        let fault = item.fault;
        if let Some(FaultKind::Crash) = fault {
            let _ = tx.send(FromActor::Died {
                actor,
                step: item.step,
                reason: "injected crash".into(),
            });
            return;
        }
        if let Some(FaultKind::Stall { ms }) = fault {
            // a slow actor, not a dead one: sleep, then deliver late —
            // the learner's heartbeat will have re-dispatched by then and
            // its dedup path sheds whichever copy loses the race
            std::thread::sleep(Duration::from_millis(ms));
        }
        match ctx.rollout(actor, &item.snapshot, item.step, &item.x, &item.y) {
            Ok(mut rb) => {
                if let Some(FaultKind::Poison { kind, count }) = fault {
                    apply_poison(&mut rb, kind, count);
                }
                if tx.send(FromActor::Rollout(rb)).is_err() {
                    return; // learner gone
                }
            }
            Err(e) => {
                let _ = tx.send(FromActor::Died {
                    actor,
                    step: item.step,
                    reason: format!("{e:#}"),
                });
                return;
            }
        }
    }
}

/// Convenience for the inline path: apply the plan's non-process faults
/// (poison) to a locally computed rollout. Crash/stall events make no
/// sense without a separate actor process and are ignored — inline mode
/// documents itself as the zero-churn reference.
pub fn apply_inline_fault(plan: &FaultPlan, rb: &mut RolloutBatch) {
    if let Some(FaultKind::Poison { kind, count }) = plan.take(rb.step) {
        apply_poison(rb, kind, count);
    }
}

// Exercised end-to-end (threads, faults, replay) in tests/distrib_e2e.rs;
// unit tests here would need an Engine fixture and would duplicate those.
