//! Hardened wire protocol for the cross-process actor fleet.
//!
//! Everything that crosses a process boundary travels in a
//! length-prefixed frame:
//!
//! ```text
//! [len: u32 LE][len_check: u32 LE][kind: u8][crc: u64 LE][payload...]
//! ```
//!
//! `len` counts everything after the 8-byte header (kind + crc +
//! payload, so `len >= 9`). `len_check = len ^ LEN_XOR` lets the reader
//! validate the header *before* trusting `len` — a corrupted length
//! field is detected without allocating, and without it a single flipped
//! length byte would silently desynchronize the stream. `crc` is FNV-1a
//! (the checkpoint module's checksum) over `kind || payload`, so a
//! flipped byte anywhere past the header is caught by the checksum while
//! the framing survives: the learner drops the frame, counts it, and
//! keeps reading. Header corruption, by contrast, is connection-fatal —
//! the byte stream can no longer be trusted to be frame-aligned — and
//! drains into the reconnect path instead.
//!
//! Decoding is bounds-checked end to end (`Rd`): a crc-valid frame whose
//! payload still fails to decode is `Malformed`, which is fatal by
//! policy (it means a protocol bug or an adversarial peer, not line
//! noise). Float payloads round-trip bitwise via `to_bits`/`from_bits`,
//! so NaN/±inf survive the wire exactly — the admission path, not the
//! codec, decides what to do with them.
//!
//! The module is pure bytes-in/bytes-out (generic over `Read`), so every
//! robustness case — truncation at arbitrary offsets, flipped header vs
//! payload bytes, allocation-bomb lengths — is testable over a `Cursor`
//! without a socket in sight.

use std::io::{ErrorKind, Read};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::faults::{FaultKind, PoisonKind};
use super::transport::{PolicySnapshot, RolloutBatch};

/// "KWR0" — Kondo WiRe, revision 0.
pub const WIRE_MAGIC: u32 = 0x4b57_5230;
/// Bumped on any frame-layout or payload-codec change.
pub const WIRE_VERSION: u32 = 1;
/// XOR mask relating `len` to `len_check` in the frame header.
pub const LEN_XOR: u32 = 0x5a5a_a5a5;
/// Hard ceiling on a claimed frame length (64 MiB): anything larger is
/// header corruption or an allocation bomb, rejected before `Vec::with_capacity`.
pub const MAX_FRAME: usize = 1 << 26;
/// Bytes of header before the checksummed region.
pub const HDR: usize = 8;
/// kind (1) + crc (8): the minimum legal `len`.
pub const OVERHEAD: usize = 9;
/// How long a blocking read waits before reporting `Idle` at a frame
/// boundary; also the granularity of the mid-frame deadline clock.
pub const READ_POLL: Duration = Duration::from_millis(100);

pub const K_HELLO: u8 = 1;
pub const K_HELLO_ACK: u8 = 2;
pub const K_HELLO_REJECT: u8 = 3;
pub const K_SNAPSHOT: u8 = 4;
pub const K_GENERATE: u8 = 5;
pub const K_ROLLOUT: u8 = 6;
pub const K_DIED: u8 = 7;
pub const K_SHUTDOWN: u8 = 8;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over `kind || payload` — the same fold the checkpoint format
/// uses (`checkpoint::fnv1a64`), inlined here so the frame checksum
/// never allocates a concatenated buffer.
pub fn crc_frame(kind: u8, payload: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    h ^= kind as u64;
    h = h.wrapping_mul(FNV_PRIME);
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Everything that can go wrong reading one frame. `fatal()` encodes the
/// drop-frame vs drop-connection policy in one place.
#[derive(Debug)]
pub enum WireError {
    /// No bytes arrived within one poll interval at a frame boundary —
    /// the benign "nothing to read yet" case.
    Idle,
    /// Clean EOF at a frame boundary (peer closed between frames).
    Closed,
    /// EOF or deadline expiry *mid-frame*: the peer died or stalled
    /// while a frame was in flight.
    Torn,
    /// Header self-check failed (`len_check` mismatch or `len` out of
    /// range): the stream is no longer frame-aligned. Fatal.
    Header(String),
    /// Checksum mismatch on an intact frame: line noise. The framing
    /// survives, so this is recoverable — drop the frame, keep reading.
    Corrupt(String),
    /// Checksum-valid payload that fails to decode: a protocol bug or a
    /// hostile peer, not line noise. Fatal.
    Malformed(String),
    Io(std::io::Error),
}

impl WireError {
    /// Whether the connection itself can no longer be trusted. `Idle`
    /// and `Corrupt` are the only survivable cases; `Closed`/`Torn` end
    /// the connection by definition.
    pub fn fatal(&self) -> bool {
        !matches!(self, WireError::Idle | WireError::Corrupt(_))
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Idle => write!(f, "idle (no frame within poll interval)"),
            WireError::Closed => write!(f, "connection closed at frame boundary"),
            WireError::Torn => write!(f, "torn frame (EOF or deadline mid-frame)"),
            WireError::Header(m) => write!(f, "frame header corrupt: {m}"),
            WireError::Corrupt(m) => write!(f, "frame checksum mismatch: {m}"),
            WireError::Malformed(m) => write!(f, "malformed payload: {m}"),
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Encode one complete frame: header + kind + crc + payload.
pub fn encode_frame(kind: u8, payload: &[u8]) -> Vec<u8> {
    let len = (OVERHEAD + payload.len()) as u32;
    let mut out = Vec::with_capacity(HDR + OVERHEAD + payload.len());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&(len ^ LEN_XOR).to_le_bytes());
    out.push(kind);
    out.extend_from_slice(&crc_frame(kind, payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// Fill `buf` from `r`, honoring the frame deadline. `*total` counts
/// bytes read across the whole frame: zero-byte EOF is `Closed`, EOF
/// after any byte is `Torn`. A would-block with zero bytes read is
/// `Idle` (frame-boundary poll); once a byte has arrived, `clock` arms
/// and would-blocks only fail after `deadline` elapses.
fn fill(
    r: &mut impl Read,
    buf: &mut [u8],
    total: &mut usize,
    clock: &mut Option<Instant>,
    deadline: Duration,
) -> Result<(), WireError> {
    let mut off = 0;
    while off < buf.len() {
        match r.read(&mut buf[off..]) {
            Ok(0) => {
                return Err(if *total == 0 { WireError::Closed } else { WireError::Torn })
            }
            Ok(n) => {
                off += n;
                *total += n;
                if clock.is_none() {
                    *clock = Some(Instant::now());
                }
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                match clock {
                    // nothing read yet: a quiet peer, not a torn frame
                    None => return Err(WireError::Idle),
                    Some(t0) if t0.elapsed() >= deadline => return Err(WireError::Torn),
                    Some(_) => {}
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame. The caller is expected to have set a short read
/// timeout (`READ_POLL`) on the underlying stream; this function turns
/// those polls into `Idle` at a frame boundary and enforces `deadline`
/// wall-clock from the first byte of a frame to its last.
pub fn read_frame(r: &mut impl Read, deadline: Duration) -> Result<(u8, Vec<u8>), WireError> {
    let mut total = 0usize;
    let mut clock: Option<Instant> = None;
    let mut hdr = [0u8; HDR];
    fill(r, &mut hdr, &mut total, &mut clock, deadline)?;
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
    let check = u32::from_le_bytes([hdr[4], hdr[5], hdr[6], hdr[7]]);
    if len ^ LEN_XOR != check {
        return Err(WireError::Header(format!(
            "len={len:#010x} len_check={check:#010x} (xor mask violated)"
        )));
    }
    let len = len as usize;
    if len < OVERHEAD || len > MAX_FRAME {
        // reject before allocating: an oversized claim is either header
        // corruption the xor check missed or an allocation bomb
        return Err(WireError::Header(format!(
            "claimed length {len} outside [{OVERHEAD}, {MAX_FRAME}]"
        )));
    }
    let mut kind_crc = [0u8; OVERHEAD];
    fill(r, &mut kind_crc, &mut total, &mut clock, deadline)?;
    let kind = kind_crc[0];
    let crc = u64::from_le_bytes([
        kind_crc[1], kind_crc[2], kind_crc[3], kind_crc[4], kind_crc[5], kind_crc[6],
        kind_crc[7], kind_crc[8],
    ]);
    let mut payload = vec![0u8; len - OVERHEAD];
    fill(r, &mut payload, &mut total, &mut clock, deadline)?;
    let want = crc_frame(kind, &payload);
    if want != crc {
        return Err(WireError::Corrupt(format!(
            "kind={kind} len={len}: crc {crc:#018x} != computed {want:#018x}"
        )));
    }
    Ok((kind, payload))
}

/// Bounds-checked payload reader: every primitive read is checked, so a
/// truncated or lying payload becomes `Malformed`, never a panic.
pub struct Rd<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Rd<'a> {
    pub fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, p: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        let end = self.p.checked_add(n).filter(|&e| e <= self.b.len()).ok_or_else(|| {
            WireError::Malformed(format!(
                "{what}: need {n} bytes at offset {}, payload has {}",
                self.p,
                self.b.len()
            ))
        })?;
        let s = &self.b[self.p..end];
        self.p = end;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32, WireError> {
        let s = self.take(4, what)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    pub fn f32(&mut self, what: &str) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    pub fn f64(&mut self, what: &str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// A `u32` length prefix validated against the bytes actually
    /// remaining, so a lying count cannot trigger an oversized
    /// allocation: `per_item` is the minimum encoded size of one
    /// element.
    pub fn len_prefix(&mut self, per_item: usize, what: &str) -> Result<usize, WireError> {
        let n = self.u32(what)? as usize;
        let remaining = self.b.len() - self.p;
        if n.checked_mul(per_item).map_or(true, |need| need > remaining) {
            return Err(WireError::Malformed(format!(
                "{what}: claimed {n} items x {per_item}B but only {remaining}B remain"
            )));
        }
        Ok(n)
    }

    pub fn str(&mut self, what: &str) -> Result<String, WireError> {
        let n = self.len_prefix(1, what)?;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| WireError::Malformed(format!("{what}: invalid utf-8")))
    }

    /// All bytes consumed? Trailing garbage in a crc-valid frame means
    /// an encoder/decoder mismatch — surfaced loudly, not ignored.
    pub fn done(&self) -> Result<(), WireError> {
        if self.p != self.b.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.p
            )));
        }
        Ok(())
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------------------
// Fault codec: `FaultKind` crosses the wire inside Generate frames (the
// learner owns the consume-once `FaultPlan`; actors just execute orders).

fn put_fault(out: &mut Vec<u8>, f: Option<FaultKind>) {
    match f {
        None => out.push(0),
        Some(FaultKind::Crash) => out.push(1),
        Some(FaultKind::Stall { ms }) => {
            out.push(2);
            out.extend_from_slice(&ms.to_le_bytes());
        }
        Some(FaultKind::Poison { kind, count }) => {
            out.push(3);
            out.push(match kind {
                PoisonKind::NanU => 0,
                PoisonKind::NanEll => 1,
                PoisonKind::BadAction => 2,
                PoisonKind::Shape => 3,
                PoisonKind::Fingerprint => 4,
            });
            out.extend_from_slice(&(count as u32).to_le_bytes());
        }
        Some(FaultKind::Torn) => out.push(4),
        Some(FaultKind::Partial { bytes }) => {
            out.push(5);
            out.extend_from_slice(&(bytes as u32).to_le_bytes());
        }
        Some(FaultKind::BitFlip { offset }) => {
            out.push(6);
            out.extend_from_slice(&(offset as u32).to_le_bytes());
        }
        Some(FaultKind::Disconnect) => out.push(7),
    }
}

fn get_fault(rd: &mut Rd) -> Result<Option<FaultKind>, WireError> {
    Ok(match rd.u8("fault tag")? {
        0 => None,
        1 => Some(FaultKind::Crash),
        2 => Some(FaultKind::Stall { ms: rd.u64("stall ms")? }),
        3 => {
            let kind = match rd.u8("poison kind")? {
                0 => PoisonKind::NanU,
                1 => PoisonKind::NanEll,
                2 => PoisonKind::BadAction,
                3 => PoisonKind::Shape,
                4 => PoisonKind::Fingerprint,
                k => {
                    return Err(WireError::Malformed(format!("unknown poison kind tag {k}")))
                }
            };
            Some(FaultKind::Poison { kind, count: rd.u32("poison count")? as usize })
        }
        4 => Some(FaultKind::Torn),
        5 => Some(FaultKind::Partial { bytes: rd.u32("partial bytes")? as usize }),
        6 => Some(FaultKind::BitFlip { offset: rd.u32("bitflip offset")? as usize }),
        7 => Some(FaultKind::Disconnect),
        t => return Err(WireError::Malformed(format!("unknown fault tag {t}"))),
    })
}

// ---------------------------------------------------------------------------
// Message encoders. All borrow — snapshot params are never cloned to
// build a frame.

/// The actor's opening frame; the learner validates it before anything
/// else crosses the link.
pub fn encode_hello(fingerprint: u64, slot: u32) -> Vec<u8> {
    let mut p = Vec::with_capacity(20);
    p.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    p.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    p.extend_from_slice(&fingerprint.to_le_bytes());
    p.extend_from_slice(&slot.to_le_bytes());
    encode_frame(K_HELLO, &p)
}

pub fn encode_hello_ack() -> Vec<u8> {
    encode_frame(K_HELLO_ACK, &[])
}

pub fn encode_hello_reject(reason: &str) -> Vec<u8> {
    let mut p = Vec::new();
    put_str(&mut p, reason);
    encode_frame(K_HELLO_REJECT, &p)
}

pub fn encode_snapshot(s: &PolicySnapshot) -> Vec<u8> {
    let total: usize = s.params.iter().map(|t| 4 + 4 * t.len()).sum();
    let mut p = Vec::with_capacity(20 + total);
    p.extend_from_slice(&s.version.to_le_bytes());
    p.extend_from_slice(&s.fingerprint.to_le_bytes());
    p.extend_from_slice(&(s.params.len() as u32).to_le_bytes());
    for t in s.params.iter() {
        p.extend_from_slice(&(t.len() as u32).to_le_bytes());
        for &v in t {
            p.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }
    encode_frame(K_SNAPSHOT, &p)
}

/// Work order: contexts + labels + the snapshot *version* to compute
/// against (the snapshot itself ships once per link in its own frame).
pub fn encode_generate(
    step: u64,
    x: &[f32],
    y: &[usize],
    snapshot_version: u64,
    fault: Option<FaultKind>,
) -> Vec<u8> {
    let mut p = Vec::with_capacity(25 + 4 * x.len() + 4 * y.len());
    p.extend_from_slice(&step.to_le_bytes());
    p.extend_from_slice(&snapshot_version.to_le_bytes());
    p.extend_from_slice(&(x.len() as u32).to_le_bytes());
    for &v in x {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    p.extend_from_slice(&(y.len() as u32).to_le_bytes());
    for &v in y {
        p.extend_from_slice(&(v as u32).to_le_bytes());
    }
    put_fault(&mut p, fault);
    encode_frame(K_GENERATE, &p)
}

pub fn encode_rollout(rb: &RolloutBatch) -> Vec<u8> {
    let mut p =
        Vec::with_capacity(40 + 4 * rb.actions.len() + 8 * rb.u.len() + 8 * rb.ell.len());
    p.extend_from_slice(&(rb.actor as u32).to_le_bytes());
    p.extend_from_slice(&rb.step.to_le_bytes());
    p.extend_from_slice(&rb.snapshot_version.to_le_bytes());
    p.extend_from_slice(&rb.fingerprint.to_le_bytes());
    p.extend_from_slice(&(rb.n as u32).to_le_bytes());
    p.extend_from_slice(&(rb.actions.len() as u32).to_le_bytes());
    for &a in &rb.actions {
        p.extend_from_slice(&a.to_le_bytes());
    }
    p.extend_from_slice(&(rb.u.len() as u32).to_le_bytes());
    for &v in &rb.u {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    p.extend_from_slice(&(rb.ell.len() as u32).to_le_bytes());
    for &v in &rb.ell {
        p.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    encode_frame(K_ROLLOUT, &p)
}

pub fn encode_died(actor: usize, step: u64, reason: &str) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&(actor as u32).to_le_bytes());
    p.extend_from_slice(&step.to_le_bytes());
    put_str(&mut p, reason);
    encode_frame(K_DIED, &p)
}

pub fn encode_shutdown() -> Vec<u8> {
    encode_frame(K_SHUTDOWN, &[])
}

// ---------------------------------------------------------------------------
// Decoder: one owned enum the receive loops match on.

#[derive(Debug)]
pub enum WireMsg {
    Hello { magic: u32, version: u32, fingerprint: u64, slot: u32 },
    HelloAck,
    HelloReject { reason: String },
    Snapshot(PolicySnapshot),
    Generate {
        step: u64,
        snapshot_version: u64,
        x: Vec<f32>,
        y: Vec<usize>,
        fault: Option<FaultKind>,
    },
    Rollout(RolloutBatch),
    Died { actor: usize, step: u64, reason: String },
    Shutdown,
}

pub fn decode_payload(kind: u8, payload: &[u8]) -> Result<WireMsg, WireError> {
    let mut rd = Rd::new(payload);
    let msg = match kind {
        K_HELLO => WireMsg::Hello {
            magic: rd.u32("hello magic")?,
            version: rd.u32("hello version")?,
            fingerprint: rd.u64("hello fingerprint")?,
            slot: rd.u32("hello slot")?,
        },
        K_HELLO_ACK => WireMsg::HelloAck,
        K_HELLO_REJECT => WireMsg::HelloReject { reason: rd.str("reject reason")? },
        K_SNAPSHOT => {
            let version = rd.u64("snapshot version")?;
            let fingerprint = rd.u64("snapshot fingerprint")?;
            let n_tensors = rd.len_prefix(4, "snapshot tensor count")?;
            let mut params = Vec::with_capacity(n_tensors);
            for i in 0..n_tensors {
                let n = rd.len_prefix(4, "snapshot tensor len")?;
                let mut t = Vec::with_capacity(n);
                for _ in 0..n {
                    t.push(rd.f32(&format!("snapshot tensor {i}"))?);
                }
                params.push(t);
            }
            WireMsg::Snapshot(PolicySnapshot {
                version,
                params: Arc::new(params),
                fingerprint,
            })
        }
        K_GENERATE => {
            let step = rd.u64("generate step")?;
            let snapshot_version = rd.u64("generate snapshot version")?;
            let nx = rd.len_prefix(4, "generate x len")?;
            let mut x = Vec::with_capacity(nx);
            for _ in 0..nx {
                x.push(rd.f32("generate x")?);
            }
            let ny = rd.len_prefix(4, "generate y len")?;
            let mut y = Vec::with_capacity(ny);
            for _ in 0..ny {
                y.push(rd.u32("generate y")? as usize);
            }
            let fault = get_fault(&mut rd)?;
            WireMsg::Generate { step, snapshot_version, x, y, fault }
        }
        K_ROLLOUT => {
            let actor = rd.u32("rollout actor")? as usize;
            let step = rd.u64("rollout step")?;
            let snapshot_version = rd.u64("rollout snapshot version")?;
            let fingerprint = rd.u64("rollout fingerprint")?;
            let n = rd.u32("rollout n")? as usize;
            let na = rd.len_prefix(4, "rollout actions len")?;
            let mut actions = Vec::with_capacity(na);
            for _ in 0..na {
                actions.push(rd.u32("rollout action")? as i32);
            }
            let nu = rd.len_prefix(8, "rollout u len")?;
            let mut u = Vec::with_capacity(nu);
            for _ in 0..nu {
                u.push(rd.f64("rollout u")?);
            }
            let ne = rd.len_prefix(8, "rollout ell len")?;
            let mut ell = Vec::with_capacity(ne);
            for _ in 0..ne {
                ell.push(rd.f64("rollout ell")?);
            }
            WireMsg::Rollout(RolloutBatch {
                actor,
                step,
                snapshot_version,
                fingerprint,
                n,
                actions,
                u,
                ell,
            })
        }
        K_DIED => WireMsg::Died {
            actor: rd.u32("died actor")? as usize,
            step: rd.u64("died step")?,
            reason: rd.str("died reason")?,
        },
        K_SHUTDOWN => WireMsg::Shutdown,
        k => return Err(WireError::Malformed(format!("unknown frame kind {k}"))),
    };
    rd.done()?;
    Ok(msg)
}

/// Validate an actor's Hello against this run. Returns the claimed slot,
/// or a human-readable rejection reason the learner echoes back in a
/// `HelloReject` frame before closing the link.
pub fn validate_hello(msg: &WireMsg, expect_fingerprint: u64) -> Result<u32, String> {
    match msg {
        WireMsg::Hello { magic, version, fingerprint, slot } => {
            if *magic != WIRE_MAGIC {
                return Err(format!("bad magic {magic:#010x} (want {WIRE_MAGIC:#010x})"));
            }
            if *version != WIRE_VERSION {
                return Err(format!("wire version {version} (want {WIRE_VERSION})"));
            }
            if *fingerprint != expect_fingerprint {
                return Err(format!(
                    "run fingerprint {fingerprint:#018x} does not match learner {expect_fingerprint:#018x}"
                ));
            }
            Ok(*slot)
        }
        other => Err(format!("expected Hello as first frame, got {other:?}")),
    }
}

// ---------------------------------------------------------------------------
// WireFaults: the byte-level damage shim. Applied actor-side to the
// encoded rollout frame for the one step the learner ordered damaged, so
// injected corruption counts are exact and deterministic — same bytes,
// same damage, every run.

pub struct WireFaults;

impl WireFaults {
    /// Damage an encoded frame per `fault`. Returns the bytes to write
    /// and whether to sever the connection immediately after, or `None`
    /// for fault kinds that are not wire-level (the caller handles those
    /// before encoding).
    pub fn damage(frame: &[u8], fault: FaultKind) -> Option<(Vec<u8>, bool)> {
        match fault {
            FaultKind::Torn => {
                // cut mid-frame (past the header, before the end) and hang up:
                // the learner sees a frame that starts and never finishes
                let cut = (frame.len() / 2).max(HDR + 1).min(frame.len() - 1);
                Some((frame[..cut].to_vec(), true))
            }
            FaultKind::Partial { bytes } => {
                let cut = bytes.clamp(1, frame.len() - 1);
                Some((frame[..cut].to_vec(), true))
            }
            FaultKind::BitFlip { offset } => {
                // flip one payload bit: always checksum-caught, never
                // header-desyncing, so the connection survives
                let payload_len = frame.len() - HDR - OVERHEAD;
                let mut out = frame.to_vec();
                if payload_len > 0 {
                    let byte = HDR + OVERHEAD + (offset % payload_len);
                    out[byte] ^= 1 << (offset % 8);
                } else {
                    // degenerate empty payload: flip the crc instead
                    out[HDR + 1] ^= 1 << (offset % 8);
                }
                Some((out, false))
            }
            FaultKind::Disconnect => Some((Vec::new(), true)),
            FaultKind::Crash | FaultKind::Stall { .. } | FaultKind::Poison { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const DL: Duration = Duration::from_millis(200);

    fn batch() -> RolloutBatch {
        RolloutBatch {
            actor: 1,
            step: 7,
            snapshot_version: 5,
            fingerprint: 0xdead_beef,
            n: 3,
            actions: vec![0, 4, 9],
            u: vec![0.5, f64::NAN, f64::NEG_INFINITY],
            ell: vec![2.302, -0.0, f64::INFINITY],
        }
    }

    #[test]
    fn rollout_round_trips_bitwise() {
        let rb = batch();
        let frame = encode_rollout(&rb);
        let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
        assert_eq!(kind, K_ROLLOUT);
        match decode_payload(kind, &payload).unwrap() {
            WireMsg::Rollout(got) => {
                assert_eq!(got.actor, rb.actor);
                assert_eq!(got.step, rb.step);
                assert_eq!(got.n, rb.n);
                assert_eq!(got.actions, rb.actions);
                // bitwise, not ==: NaN payloads must survive exactly
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got.u), bits(&rb.u));
                assert_eq!(bits(&got.ell), bits(&rb.ell));
            }
            other => panic!("expected Rollout, got {other:?}"),
        }
    }

    #[test]
    fn generate_and_snapshot_round_trip() {
        let snap = PolicySnapshot {
            version: 9,
            params: Arc::new(vec![vec![1.0, -0.0, f32::NAN], vec![]]),
            fingerprint: 77,
        };
        let frame = encode_snapshot(&snap);
        let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
        match decode_payload(kind, &payload).unwrap() {
            WireMsg::Snapshot(got) => {
                assert_eq!(got.version, 9);
                assert_eq!(got.fingerprint, 77);
                assert_eq!(got.params.len(), 2);
                assert_eq!(got.params[0][0].to_bits(), 1.0f32.to_bits());
                assert_eq!(got.params[0][1].to_bits(), (-0.0f32).to_bits());
                assert!(got.params[0][2].is_nan());
                assert!(got.params[1].is_empty());
            }
            other => panic!("expected Snapshot, got {other:?}"),
        }

        let frame = encode_generate(
            3,
            &[0.25, 0.5],
            &[7, 0],
            2,
            Some(FaultKind::Poison { kind: PoisonKind::Shape, count: 2 }),
        );
        let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
        match decode_payload(kind, &payload).unwrap() {
            WireMsg::Generate { step, snapshot_version, x, y, fault } => {
                assert_eq!((step, snapshot_version), (3, 2));
                assert_eq!(x, vec![0.25, 0.5]);
                assert_eq!(y, vec![7, 0]);
                assert_eq!(
                    fault,
                    Some(FaultKind::Poison { kind: PoisonKind::Shape, count: 2 })
                );
            }
            other => panic!("expected Generate, got {other:?}"),
        }
    }

    #[test]
    fn fault_tags_round_trip() {
        for f in [
            None,
            Some(FaultKind::Crash),
            Some(FaultKind::Stall { ms: 1500 }),
            Some(FaultKind::Poison { kind: PoisonKind::NanEll, count: 4 }),
            Some(FaultKind::Torn),
            Some(FaultKind::Partial { bytes: 13 }),
            Some(FaultKind::BitFlip { offset: 17 }),
            Some(FaultKind::Disconnect),
        ] {
            let mut p = Vec::new();
            put_fault(&mut p, f);
            let mut rd = Rd::new(&p);
            assert_eq!(get_fault(&mut rd).unwrap(), f);
            rd.done().unwrap();
        }
    }

    #[test]
    fn empty_stream_is_closed_and_header_prefix_is_torn() {
        let frame = encode_shutdown();
        // no bytes at all: clean close
        assert!(matches!(
            read_frame(&mut Cursor::new(&[][..]), DL),
            Err(WireError::Closed)
        ));
        // any strict prefix: torn, never a panic or a silent truncation
        for cut in 1..frame.len() {
            match read_frame(&mut Cursor::new(&frame[..cut]), DL) {
                Err(WireError::Torn) => {}
                other => panic!("cut at {cut}: expected Torn, got {other:?}"),
            }
        }
    }

    #[test]
    fn header_corruption_is_fatal_payload_corruption_is_not() {
        let frame = encode_rollout(&batch());
        // flip a bit in each header byte: len/len_check disagree -> Header
        for i in 0..HDR {
            let mut bad = frame.clone();
            bad[i] ^= 0x10;
            match read_frame(&mut Cursor::new(&bad), DL) {
                Err(e @ WireError::Header(_)) => assert!(e.fatal()),
                other => panic!("header byte {i}: expected Header, got {other:?}"),
            }
        }
        // flip the kind byte, a crc byte, and payload bytes: crc catches
        // all of them, and the error is the recoverable kind
        for i in [HDR, HDR + 1, HDR + 5, HDR + OVERHEAD, frame.len() - 1] {
            let mut bad = frame.clone();
            bad[i] ^= 0x10;
            match read_frame(&mut Cursor::new(&bad), DL) {
                Err(e @ WireError::Corrupt(_)) => assert!(!e.fatal()),
                other => panic!("byte {i}: expected Corrupt, got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupt_frame_does_not_desync_the_stream() {
        // a checksum-failed frame is dropped and the NEXT frame decodes:
        // the framing layer survives payload noise
        let mut bad = encode_rollout(&batch());
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        let mut stream = bad;
        stream.extend_from_slice(&encode_shutdown());
        let mut cur = Cursor::new(&stream);
        assert!(matches!(read_frame(&mut cur, DL), Err(WireError::Corrupt(_))));
        let (kind, payload) = read_frame(&mut cur, DL).unwrap();
        assert_eq!(kind, K_SHUTDOWN);
        assert!(matches!(decode_payload(kind, &payload).unwrap(), WireMsg::Shutdown));
    }

    #[test]
    fn oversized_claimed_length_is_rejected_before_allocation() {
        // a header claiming 3 GiB must fail the range check, not OOM;
        // keep len_check consistent so only the range guard can catch it
        let len: u32 = 3 << 30;
        let mut bad = Vec::new();
        bad.extend_from_slice(&len.to_le_bytes());
        bad.extend_from_slice(&(len ^ LEN_XOR).to_le_bytes());
        bad.extend_from_slice(&[0u8; 32]);
        match read_frame(&mut Cursor::new(&bad), DL) {
            Err(WireError::Header(m)) => assert!(m.contains("outside"), "{m}"),
            other => panic!("expected Header, got {other:?}"),
        }
        // same guard for under-length claims
        let len: u32 = 3;
        let mut bad = Vec::new();
        bad.extend_from_slice(&len.to_le_bytes());
        bad.extend_from_slice(&(len ^ LEN_XOR).to_le_bytes());
        bad.extend_from_slice(&[0u8; 32]);
        assert!(matches!(
            read_frame(&mut Cursor::new(&bad), DL),
            Err(WireError::Header(_))
        ));
    }

    #[test]
    fn lying_interior_counts_are_malformed_not_panics() {
        // crc-valid frame whose payload claims more items than it holds:
        // the len_prefix guard rejects it before any oversized allocation
        let mut p = Vec::new();
        p.extend_from_slice(&1u64.to_le_bytes()); // version
        p.extend_from_slice(&2u64.to_le_bytes()); // fingerprint
        p.extend_from_slice(&0xffff_ffffu32.to_le_bytes()); // tensor count lie
        let frame = encode_frame(K_SNAPSHOT, &p);
        let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
        match decode_payload(kind, &payload) {
            Err(e @ WireError::Malformed(_)) => assert!(e.fatal()),
            other => panic!("expected Malformed, got {other:?}"),
        }
        // trailing garbage after a valid message is also loud
        let mut p = Vec::new();
        put_str(&mut p, "done");
        p.push(0xaa);
        let frame = encode_frame(K_HELLO_REJECT, &p);
        let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
        assert!(matches!(decode_payload(kind, &payload), Err(WireError::Malformed(_))));
    }

    #[test]
    fn hello_validation_rejects_mismatches() {
        let ok = |fp: u64, frame: Vec<u8>| {
            let (kind, payload) = read_frame(&mut Cursor::new(&frame), DL).unwrap();
            let msg = decode_payload(kind, &payload).unwrap();
            validate_hello(&msg, fp)
        };
        assert_eq!(ok(42, encode_hello(42, 3)), Ok(3));
        // wrong fingerprint
        assert!(ok(43, encode_hello(42, 3)).unwrap_err().contains("fingerprint"));
        // wrong magic / version: craft the payload by hand
        let mut p = Vec::new();
        p.extend_from_slice(&0x6261_6421u32.to_le_bytes());
        p.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        p.extend_from_slice(&42u64.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        let msg = decode_payload(K_HELLO, &p).unwrap();
        assert!(validate_hello(&msg, 42).unwrap_err().contains("magic"));
        let mut p = Vec::new();
        p.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        p.extend_from_slice(&(WIRE_VERSION + 9).to_le_bytes());
        p.extend_from_slice(&42u64.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        let msg = decode_payload(K_HELLO, &p).unwrap();
        assert!(validate_hello(&msg, 42).unwrap_err().contains("version"));
        // not a Hello at all
        let msg = decode_payload(K_SHUTDOWN, &[]).unwrap();
        assert!(validate_hello(&msg, 42).is_err());
    }

    #[test]
    fn wire_faults_damage_deterministically() {
        let frame = encode_rollout(&batch());

        let (torn, sever) = WireFaults::damage(&frame, FaultKind::Torn).unwrap();
        assert!(sever);
        assert!(torn.len() > HDR && torn.len() < frame.len());
        assert_eq!(&torn[..], &frame[..torn.len()]);
        assert!(matches!(read_frame(&mut Cursor::new(&torn), DL), Err(WireError::Torn)));

        let (part, sever) = WireFaults::damage(&frame, FaultKind::Partial { bytes: 5 }).unwrap();
        assert!(sever);
        assert_eq!(part.len(), 5);

        let (flip, sever) = WireFaults::damage(&frame, FaultKind::BitFlip { offset: 17 }).unwrap();
        assert!(!sever, "a bitflip leaves the connection up");
        assert_eq!(flip.len(), frame.len());
        assert_eq!(flip.iter().zip(&frame).filter(|(a, b)| a != b).count(), 1);
        // the flip always lands past the header: checksum-caught, recoverable
        assert!(matches!(
            read_frame(&mut Cursor::new(&flip), DL),
            Err(WireError::Corrupt(_))
        ));

        let (empty, sever) = WireFaults::damage(&frame, FaultKind::Disconnect).unwrap();
        assert!(sever);
        assert!(empty.is_empty());

        // non-wire kinds are not this shim's business
        assert!(WireFaults::damage(&frame, FaultKind::Crash).is_none());

        // determinism: same frame + same fault -> same bytes
        let again = WireFaults::damage(&frame, FaultKind::BitFlip { offset: 17 }).unwrap();
        assert_eq!(again.0, flip);
    }
}
