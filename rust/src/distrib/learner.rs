//! The distributed learner: dispatch, hardened admission, staleness
//! pricing, and the three execution modes (inline / threaded / replay)
//! that share one ingest path.
//!
//! # Determinism (the eta=0 contract, distributed)
//!
//! The learner's trajectory is a fold over per-step (context, rollout)
//! pairs. Contexts for step `t` come from `unit_rng(seed ^ CTX_SALT, t,
//! 0)` — a pure function of the run seed. Rollouts are computed by
//! actors whose per-sample randomness is `unit_rng(seed, t, i)`, so the
//! rollout for a step is bit-identical no matter which actor slot
//! computes it or how many times it is re-dispatched. Ingestion is
//! strictly step-ordered (out-of-order deliveries park in a reorder
//! buffer). Hence: **inline, threaded (any actor count), and replay all
//! produce the same trajectory bit-for-bit at eta = 0**, and runtime
//! events (crashes, timeouts, respawns) perturb only the runtime
//! counters, never the weights. Inline mode is the reference; threaded
//! and replay are locked against it in rust/tests/distrib_e2e.rs.
//!
//! # Admission (the screen's slot in the distributed pipeline)
//!
//! The single-process pipeline screens on predicted surprisal before
//! spending forward compute. Distributed, the actors have already spent
//! the forward — what the learner screens is *trust*: a batch-level
//! structural check (fingerprint echo, claimed-vs-actual shape, sane
//! snapshot version) quarantines a whole delivery, then a per-sample
//! check (finite u/ell, in-range action) quarantines individual samples.
//! Quarantine is bookkeeping, not a panic: the step advances, the
//! ledger's `quarantined_*` counters record exactly what was dropped,
//! and the gate then prices whatever was admitted. Staleness is priced
//! rather than rejected (arxiv 2603.20521): a rollout computed on a
//! snapshot `k` steps behind has its gate rate tightened to
//! `rho * stale_penalty^k`, so stale samples must be *delightful* to
//! earn a backward pass.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::algo::{BatchSignals, Method};
use crate::checkpoint::{self, CheckpointCfg, TrainCheckpoint};
use crate::coordinator::batcher::{gather_f32, gather_i32, gather_rows_f32};
use crate::coordinator::pool::unit_rng;
use crate::coordinator::{KondoGate, Ledger, Pricing, ShardedLedger};
use crate::envs::mnist::{ContextBatch, MnistBandit, RewardNoise};
use crate::model::ParamStore;
use crate::optim::Adam;
use crate::runtime::{Engine, HostTensor, InitRule};
use crate::trainers::mnist::eval_test_error;
use crate::trainers::{priority_key, EvalPoint, GatedLoop};
use crate::utils::json::Json;
use crate::utils::rng::Pcg32;

use super::actor::{actor_loop, apply_inline_fault, ActorCtx};
use super::faults::{FaultKind, FaultPlan};
use super::replay;
use super::socket::{SocketCfg, SocketTransport};
use super::supervisor::{RespawnVerdict, Supervisor};
use super::transport::{
    ChannelTransport, FromActor, PolicySnapshot, Recv, RolloutBatch, ToActor, Transport,
    TransportKind, WorkItem,
};

/// Keeps the context stream disjoint from the per-sample action/reward
/// streams (which use the raw seed).
const CTX_SALT: u64 = 0x6374_7821_6374_7821;

/// Inbox poll granularity; heartbeat timeouts resolve to within this.
const POLL: Duration = Duration::from_millis(20);

#[derive(Debug, Clone, PartialEq)]
pub enum DistribMode {
    /// Single-thread reference: the learner drives one `ActorCtx`
    /// directly. No churn faults (crash/stall are ignored), but the
    /// same snapshot-lag ring and admission path — this is the
    /// bit-identity anchor the concurrent modes are tested against.
    Inline,
    /// A supervised actor fleet behind the `Transport` trait: actor
    /// threads over mpsc channels, or actor *processes* over Unix
    /// sockets, per `DistribCfg::transport`. Same driver either way.
    Threaded,
    /// Re-ingest a recorded actor stream (see `record_to`).
    Replay(String),
}

#[derive(Debug, Clone)]
pub struct DistribCfg {
    pub method: Method,
    pub lr: f64,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_size: usize,
    pub seed: u64,
    /// actor slots (threaded mode); inline/replay stamp `t % actors`
    pub actors: usize,
    /// learner workers for the backward stage
    pub workers: usize,
    /// snapshot staleness: step `t` is computed on policy version
    /// `t - lag` (clamped at 0), and up to `lag + 1` steps are in
    /// flight at once
    pub lag: usize,
    /// per-lag-step gate-rate decay; 1.0 = staleness priced like fresh
    pub stale_penalty: f64,
    /// seeded fault schedule (see distrib::faults grammar); may carry a
    /// `lag=N` override
    pub fault_spec: String,
    /// silent-actor timeout before re-dispatch (threaded mode)
    pub heartbeat_ms: u64,
    /// per-slot respawn budget before a slot is left dead
    pub max_respawns: u32,
    /// record the ingested actor stream to this path
    pub record_to: Option<String>,
    pub checkpoint: Option<CheckpointCfg>,
    pub resume_from: Option<String>,
    /// what carries the fleet in threaded mode: in-process channels or
    /// Unix sockets to actor subprocesses. NOT in the fingerprint — the
    /// trajectory is transport-invariant by contract.
    pub transport: TransportKind,
    /// artifacts dir actor subprocesses open their own `Engine` from
    pub artifacts_dir: String,
    /// directory for the learner's socket file (default: the system
    /// temp dir)
    pub socket_dir: Option<String>,
    /// per-frame read/write deadline on every blocking wire call
    pub wire_deadline_ms: u64,
    /// base reconnect backoff (doubles per consecutive loss on a slot,
    /// capped at `max(8 * base, 100)` ms, plus seeded jitter)
    pub reconnect_backoff_ms: u64,
    /// actor executable to spawn (default: this binary)
    pub actor_bin: Option<String>,
}

impl Default for DistribCfg {
    fn default() -> DistribCfg {
        DistribCfg {
            method: Method::DgK {
                gate: KondoGate::rate(0.25),
                priority: crate::coordinator::Priority::Delight,
            },
            lr: 1e-2,
            steps: 50,
            eval_every: 25,
            eval_size: 500,
            seed: 0,
            actors: 2,
            workers: 1,
            lag: 0,
            stale_penalty: 1.0,
            fault_spec: String::new(),
            heartbeat_ms: 1000,
            max_respawns: 2,
            record_to: None,
            checkpoint: None,
            resume_from: None,
            transport: TransportKind::Channel,
            artifacts_dir: "native".into(),
            socket_dir: None,
            wire_deadline_ms: 2000,
            reconnect_backoff_ms: 25,
            actor_bin: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DistribRunResult {
    pub curve: Vec<EvalPoint>,
    pub ledger: Ledger,
    pub final_test_err: f64,
    pub final_train_err: f64,
}

/// Trajectory-contract fingerprint. Scheduling knobs (actors, workers,
/// heartbeat, mode, respawn budget) are deliberately excluded: they may
/// not change the trajectory, so a recording or checkpoint from one
/// fleet shape resumes under another. `lag`, `stale_penalty`, and the
/// fault spec DO shape the trajectory and are pinned — a wrong-lag
/// resume rejects with an error naming 'lag'.
fn fingerprint(cfg: &DistribCfg, lag: usize, f32_fast: bool, rules: &[InitRule]) -> Json {
    checkpoint::obj(vec![
        ("trainer", Json::Str("distrib".into())),
        ("seed", checkpoint::ju64(cfg.seed)),
        ("method", Json::Str(format!("{:?}", cfg.method))),
        ("priority", Json::Str(priority_key(&cfg.method))),
        // forward-tier knob: pinned like a learning rate (DESIGN.md §13)
        ("f32_fast", Json::Bool(f32_fast)),
        ("lr", Json::Num(cfg.lr)),
        ("lag", checkpoint::ju64(lag as u64)),
        ("stale_penalty", Json::Num(cfg.stale_penalty)),
        ("fault_spec", Json::Str(cfg.fault_spec.clone())),
        ("eval_every", checkpoint::ju64(cfg.eval_every as u64)),
        ("eval_size", checkpoint::ju64(cfg.eval_size as u64)),
        (
            "shapes",
            Json::Str(
                rules
                    .iter()
                    .map(|r| format!("{}:{:?}", r.name, r.shape))
                    .collect::<Vec<_>>()
                    .join(","),
            ),
        ),
    ])
}

/// Tighten the gate for a stale rollout: `rho -> rho * penalty^k`.
/// Fixed-price gates and ungated methods pass through — staleness
/// pricing is a Kondo-rate concept.
fn stale_priced(method: &Method, lag_actual: u64, penalty: f64) -> Method {
    if lag_actual == 0 || penalty >= 1.0 {
        return *method;
    }
    match method {
        Method::DgK { gate, priority } => match gate.pricing {
            Pricing::Rate(rho) => {
                let rho_eff = (rho * penalty.powi(lag_actual.min(64) as i32)).max(1e-9);
                Method::DgK {
                    gate: KondoGate { pricing: Pricing::Rate(rho_eff), eta: gate.eta },
                    priority: *priority,
                }
            }
            Pricing::Price(_) => *method,
        },
        m => *m,
    }
}

/// Rolling train-error window, same semantics as the single-process
/// trainer's (which keeps its own private copy).
struct ErrWindow {
    buf: Vec<f64>,
    cap: usize,
}

impl ErrWindow {
    fn new(cap: usize) -> ErrWindow {
        ErrWindow { buf: vec![], cap }
    }
    fn push(&mut self, v: f64) {
        if self.buf.len() == self.cap {
            self.buf.remove(0);
        }
        self.buf.push(v);
    }
    fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 1.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }
    fn restore(&mut self, vals: Vec<f64>) {
        self.buf = vals;
        if self.buf.len() > self.cap {
            let excess = self.buf.len() - self.cap;
            self.buf.drain(..excess);
        }
    }
}

/// All learner-side state shared by the three modes. `ingest` is the
/// single admission + gate + backward path; the mode drivers only differ
/// in how (context, rollout) pairs are produced.
struct LearnerState<'e> {
    eng: &'e Engine,
    cfg: &'e DistribCfg,
    b: usize,
    img: usize,
    n_act: usize,
    eval_b: usize,
    env: MnistBandit,
    params: ParamStore,
    opt: Adam,
    gl: GatedLoop<'e>,
    param_inputs: Vec<HostTensor>,
    /// master rng: consumed only by soft-gate draws (nothing at eta=0)
    rng: Pcg32,
    acct: ShardedLedger,
    curve: Vec<EvalPoint>,
    window: ErrWindow,
    test: ContextBatch,
    fp: Json,
    fp_hash: u64,
    /// effective snapshot lag (config knob or fault-plan override)
    lag: usize,
    /// snapshots for versions `completed-lag ..= completed`
    ring: VecDeque<Arc<PolicySnapshot>>,
    /// steps ingested so far == current policy version
    completed: usize,
    w_batch: Vec<f32>,
    a_batch: Vec<i32>,
    recorded: Option<Vec<RolloutBatch>>,
}

impl<'e> LearnerState<'e> {
    fn new(eng: &'e Engine, cfg: &'e DistribCfg, lag: usize) -> Result<LearnerState<'e>> {
        let man = eng.manifest();
        let b = man.constants.mnist_batch;
        let n_act = man.constants.mnist_actions;
        let img = man.constants.mnist_in;
        let eval_b = man.constants.mnist_eval_batch;
        let rules = man.model("mnist")?.to_vec();
        // same init stream as the single-process trainer so a distrib run
        // and a train_mnist run start from identical weights per seed
        let mut params = ParamStore::init(&rules, cfg.seed.wrapping_mul(0x51ed) ^ 0xbeef);
        let mut opt = Adam::new(cfg.lr, &params);
        // no forward ladder and no screen: actors own the forward, and
        // the admission path is the distributed analogue of the screen
        let mut gl = GatedLoop::new(eng, cfg.workers, man.constants.mnist_bwd_caps.clone())?
            .with_gate(&cfg.method, false, b);
        let env = MnistBandit::new(1234, b, RewardNoise::clean());
        let mut rng = Pcg32::new(cfg.seed, 0x6469_7374); // "dist"
        let test = env.test_set(cfg.eval_size.max(eval_b));
        let mut acct = ShardedLedger::new(gl.workers());
        let mut curve = Vec::new();
        let mut window = ErrWindow::new(10);
        let fp = fingerprint(cfg, lag, eng.f32_fast(), &rules);
        let fp_hash = checkpoint::fnv1a64(fp.dump().as_bytes());

        let mut ring: VecDeque<Arc<PolicySnapshot>> = VecDeque::new();
        let mut completed = 0usize;
        if let Some(path) = &cfg.resume_from {
            let ck = TrainCheckpoint::load(Path::new(path))?;
            checkpoint::validate_fingerprint(&ck.fingerprint, &fp)?;
            checkpoint::restore(
                &ck, &mut params, &mut opt, &mut rng, &mut gl, &mut acct, &mut curve,
            )?;
            window.restore(checkpoint::pf64_arr(
                checkpoint::field(&ck.extra, "train_window")?,
                "extra.train_window",
            )?);
            // rebuild the snapshot ring so lagged dispatch resumes against
            // the exact historical policies the interrupted run would use
            let versions = match checkpoint::field(&ck.extra, "ring_versions")? {
                Json::Arr(a) => a
                    .iter()
                    .map(|v| checkpoint::pu64(v, "extra.ring_versions"))
                    .collect::<Result<Vec<u64>>>()?,
                _ => bail!("checkpoint field 'extra.ring_versions': expected an array"),
            };
            let Json::Arr(snaps) = checkpoint::field(&ck.extra, "ring")? else {
                bail!("checkpoint field 'extra.ring': expected an array");
            };
            if versions.len() != snaps.len() {
                bail!("checkpoint ring_versions/ring length mismatch");
            }
            for (version, snap) in versions.into_iter().zip(snaps) {
                let Json::Arr(tensors) = snap else {
                    bail!("checkpoint field 'extra.ring': expected tensor arrays");
                };
                let tensors: Vec<Vec<f32>> = tensors
                    .iter()
                    .map(|t| checkpoint::pf32_arr(t, "extra.ring"))
                    .collect::<Result<_>>()?;
                ring.push_back(Arc::new(PolicySnapshot {
                    version,
                    params: Arc::new(tensors),
                    fingerprint: fp_hash,
                }));
            }
            completed = ck.step as usize;
            if completed > cfg.steps {
                bail!(
                    "checkpoint is at step {completed}, beyond this run's {} steps",
                    cfg.steps
                );
            }
        }

        let mut l = LearnerState {
            eng,
            cfg,
            b,
            img,
            n_act,
            eval_b,
            env,
            params,
            opt,
            gl,
            param_inputs: Vec::new(),
            rng,
            acct,
            curve,
            window,
            test,
            fp,
            fp_hash,
            lag,
            ring,
            completed,
            w_batch: vec![0.0f32; b],
            a_batch: vec![0i32; b],
            recorded: cfg.record_to.as_ref().map(|_| Vec::new()),
        };
        if l.ring.is_empty() {
            l.push_snapshot(0);
        }
        Ok(l)
    }

    /// Contexts for step `t`: a pure function of (seed, t), so every
    /// mode — and a resumed run — regenerates the identical batch.
    fn context_for(&self, t: usize) -> ContextBatch {
        let mut r = unit_rng(self.cfg.seed ^ CTX_SALT, t as u64, 0);
        self.env.sample_contexts(&mut r)
    }

    /// The snapshot step `t` must be computed on: version
    /// `t - lag` (clamped at 0). The ring retains exactly the window the
    /// dispatch rule can ask for.
    fn snapshot_for(&self, t: usize) -> Result<Arc<PolicySnapshot>> {
        let version = t.saturating_sub(self.lag) as u64;
        let front = self.ring.front().map(|s| s.version).unwrap_or(0);
        let idx = version
            .checked_sub(front)
            .map(|i| i as usize)
            .filter(|&i| i < self.ring.len());
        match idx {
            Some(i) => Ok(self.ring[i].clone()),
            None => bail!(
                "snapshot v{version} for step {t} not in ring (front v{front}, len {})",
                self.ring.len()
            ),
        }
    }

    fn push_snapshot(&mut self, version: u64) {
        let tensors: Vec<Vec<f32>> =
            (0..self.params.n_tensors()).map(|i| self.params.tensor(i).to_vec()).collect();
        self.ring.push_back(Arc::new(PolicySnapshot {
            version,
            params: Arc::new(tensors),
            fingerprint: self.fp_hash,
        }));
        while self.ring.len() > self.lag + 1 {
            self.ring.pop_front();
        }
    }

    fn ledger(&self) -> Ledger {
        self.acct.total()
    }

    /// Ingest the rollout for step `completed`: admission, staleness
    /// pricing, gate, backward, eval/checkpoint cadence. This is THE
    /// shared path — all three modes fold through it, which is what
    /// makes their trajectories structurally comparable.
    fn ingest(&mut self, rb: RolloutBatch, ctx: &ContextBatch) -> Result<()> {
        debug_assert_eq!(rb.step as usize, self.completed, "ingest must be step-ordered");
        if let Some(rec) = self.recorded.as_mut() {
            rec.push(rb.clone());
        }
        let b = self.b;

        // ---- batch-level admission: is the delivery structurally what
        // it claims to be, from the policy we think it is from?
        let structurally_ok = rb.fingerprint == self.fp_hash
            && rb.n == b
            && rb.actions.len() == b
            && rb.u.len() == b
            && rb.ell.len() == b
            && rb.snapshot_version <= rb.step;
        if !structurally_ok {
            self.acct.shard_mut(0).record_quarantined_batch(b);
            return self.after_step();
        }
        self.acct.shard_mut(0).record_forward(b);

        // ---- per-sample admission: quarantine non-finite signals and
        // out-of-range actions instead of letting them near the gate
        let mut admitted: Vec<usize> = Vec::with_capacity(b);
        for i in 0..b {
            let a = rb.actions[i];
            if rb.u[i].is_finite()
                && rb.ell[i].is_finite()
                && a >= 0
                && (a as usize) < self.n_act
            {
                admitted.push(i);
            }
        }
        if admitted.len() < b {
            self.acct.shard_mut(0).record_quarantined(b - admitted.len());
        }

        // ---- staleness pricing: high effective surprisal is exactly
        // what the Kondo gate screens for, so staleness folds into the
        // gate rate rather than a separate rejection rule
        let lag_actual = rb.step - rb.snapshot_version;
        let method_eff = stale_priced(&self.cfg.method, lag_actual, self.cfg.stale_penalty);

        let decision = if admitted.is_empty() {
            None
        } else {
            let u: Vec<f64> = admitted.iter().map(|&i| rb.u[i]).collect();
            let ell: Vec<f64> = admitted.iter().map(|&i| rb.ell[i]).collect();
            let signals = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
            Some(self.gl.decide(&method_eff, &signals, &mut self.rng))
        };
        let kept = decision.as_ref().map(|d| d.keep.len()).unwrap_or(0);
        if lag_actual > 0 {
            self.acct.shard_mut(0).record_stale(admitted.len(), kept);
        }

        // train metric: sampled-action error over the admitted set
        if !admitted.is_empty() {
            let wrong =
                admitted.iter().filter(|&&i| rb.actions[i] as usize != ctx.y[i]).count();
            self.window.push(wrong as f64 / admitted.len() as f64);
        }

        // ---- backward over the kept set (admitted-slot indices -> the
        // original batch indices the chunk gathers use)
        if let Some(d) = &decision {
            if !d.keep.is_empty() {
                let keep_orig: Vec<usize> = d.keep.iter().map(|&s| admitted[s]).collect();
                let chunks = self.gl.buckets().pack(&keep_orig);
                self.gl.record_backward_chunks(&mut self.acct, &chunks, 1, |c| c.idx.len());
                self.w_batch.fill(0.0);
                self.a_batch.fill(0);
                for (s, &i) in admitted.iter().enumerate() {
                    self.w_batch[i] = d.weights[s];
                    self.a_batch[i] = rb.actions[i];
                }
                self.params.marshal_into(&mut self.param_inputs);
                let img = self.img;
                let x = &ctx.x;
                let w_batch = &self.w_batch;
                let a_batch = &self.a_batch;
                self.gl.backward(
                    &mut self.params,
                    &self.param_inputs,
                    &mut self.opt,
                    &chunks,
                    |cap| format!("mnist_bwd_c{cap}"),
                    |chunk| {
                        let cap = chunk.cap;
                        vec![
                            HostTensor::f32(
                                &[cap, img],
                                gather_rows_f32(x, img, &chunk.idx, cap),
                            ),
                            HostTensor::i32(&[cap], gather_i32(a_batch, &chunk.idx, cap)),
                            HostTensor::f32(&[cap], gather_f32(w_batch, &chunk.idx, cap)),
                        ]
                    },
                    // sum/B over the full nominal batch, quarantined or
                    // not: dropped samples contribute zero gradient, they
                    // do not re-scale their survivors
                    b as f32,
                )?;
            }
        }
        self.after_step()
    }

    /// Advance the step cursor: eval cadence, snapshot publication,
    /// checkpoint cadence. Runs for quarantined steps too — a rejected
    /// delivery still advances time (its snapshot is just unchanged
    /// weights), so the schedule stays a pure function of step count.
    fn after_step(&mut self) -> Result<()> {
        let t1 = self.completed + 1;
        let last = t1 == self.cfg.steps;
        if t1 % self.cfg.eval_every == 0 || last {
            let test_err = eval_test_error(
                self.eng,
                &self.params,
                &self.test.x,
                &self.test.y,
                self.eval_b,
                self.img,
                self.n_act,
            )?;
            let totals = self.acct.total();
            self.curve.push(EvalPoint {
                step: t1,
                forward_samples: totals.forward_samples,
                screen_samples: totals.screen_samples,
                forward_skipped: totals.forward_skipped,
                backward_kept: totals.backward_kept,
                backward_executed: totals.backward_executed,
                metric: self.window.mean(),
                metric2: test_err,
            });
        }
        self.push_snapshot(t1 as u64);
        if let Some(ck_cfg) = &self.cfg.checkpoint {
            if ck_cfg.every > 0 && t1 % ck_cfg.every == 0 {
                // the threaded driver's dispatch barrier guarantees the
                // pipeline is quiescent here (nothing in flight), so the
                // ring + scalar state IS the whole distributed state
                let ring_versions =
                    Json::Arr(self.ring.iter().map(|s| checkpoint::ju64(s.version)).collect());
                let ring_tensors = Json::Arr(
                    self.ring
                        .iter()
                        .map(|s| {
                            Json::Arr(
                                s.params.iter().map(|t| checkpoint::jf32_arr(t)).collect(),
                            )
                        })
                        .collect(),
                );
                let extra = checkpoint::obj(vec![
                    ("train_window", checkpoint::jf64_arr(&self.window.buf)),
                    ("ring_versions", ring_versions),
                    ("ring", ring_tensors),
                ]);
                checkpoint::capture(
                    self.fp.clone(),
                    t1 as u64,
                    &self.params,
                    &self.opt,
                    &self.rng,
                    &self.gl,
                    &self.acct,
                    &self.curve,
                    extra,
                )
                .save(Path::new(&ck_cfg.path))?;
            }
        }
        self.completed = t1;
        Ok(())
    }

    fn into_result(self) -> Result<DistribRunResult> {
        if let Some(path) = &self.cfg.record_to {
            let recorded = self.recorded.as_deref().unwrap_or(&[]);
            replay::write_stream(path, self.fp_hash, self.b, recorded)?;
        }
        let final_test = self.curve.last().map(|p| p.metric2).unwrap_or(1.0);
        let final_train = self.curve.last().map(|p| p.metric).unwrap_or(1.0);
        Ok(DistribRunResult {
            ledger: self.acct.total(),
            curve: self.curve,
            final_test_err: final_test,
            final_train_err: final_train,
        })
    }
}

/// Inline reference: one `ActorCtx`, driven synchronously, same lag ring
/// and admission path. Poison faults apply; crash/stall are meaningless
/// without a separate actor and are ignored.
fn run_inline(l: &mut LearnerState<'_>, plan: &FaultPlan) -> Result<()> {
    let mut actor = ActorCtx::new(l.eng, l.cfg.seed)?;
    let n_slots = l.cfg.actors.max(1);
    while l.completed < l.cfg.steps {
        let t = l.completed;
        let ctx = l.context_for(t);
        let snap = l.snapshot_for(t)?;
        let mut rb = actor.rollout(t % n_slots, &snap, t as u64, &ctx.x, &ctx.y)?;
        apply_inline_fault(plan, &mut rb);
        l.ingest(rb, &ctx)?;
    }
    Ok(())
}

/// Replay: fold a recorded stream through the identical ingest path.
/// Contexts are regenerated from the seed; the stream must carry exactly
/// the steps this run ingests (resume-from mid-stream works because the
/// fold is step-indexed).
fn run_replay(l: &mut LearnerState<'_>, path: &str) -> Result<()> {
    let rollouts = replay::read_stream(path, l.fp_hash)?;
    if rollouts.len() < l.cfg.steps {
        bail!(
            "actor stream '{path}' has {} steps, run wants {}",
            rollouts.len(),
            l.cfg.steps
        );
    }
    while l.completed < l.cfg.steps {
        let t = l.completed;
        let ctx = l.context_for(t);
        l.ingest(rollouts[t].clone(), &ctx)?;
    }
    Ok(())
}

/// The fleet driver: dispatch over ANY `Transport`, with supervision.
/// `run_threaded` (channel) and `run_socket` (subprocesses) both run
/// through this one loop — which is what makes "socket == channel ==
/// inline, bit for bit" a structural property instead of a coincidence.
///
/// Scheduling rules, all deterministic in (step, alive-set):
/// - step `t` goes to slot `t % actors`, walking past dead slots;
/// - at most `lag + 1` steps in flight (`t <= completed + lag`), and
///   never across a checkpoint boundary (saves happen quiescent);
/// - the learner consumes the `FaultPlan` at FIRST dispatch of a step
///   and ships the order with the work; a fault that has not yet fired
///   rides along on re-dispatch, one that has (crash announced, frame
///   damaged, connection severed) is retired so it fires exactly once;
/// - a dead slot is respawned (via `respawn`, with bounded backoff plus
///   seeded jitter when `jitter` is armed) until its budget runs out,
///   then retired for good (via `retire`); every step it was holding is
///   re-dispatched either way;
/// - a corrupt frame costs the frame, not the link: the step it carried
///   is re-sent to the same slot;
/// - a silent actor (no delivery for `heartbeat_ms` while its step heads
///   the ingest queue) counts one timeout and its step is re-dispatched
///   to the next live slot; the superseded delivery is shed on arrival.
///   The clock never arms against a slot already known dead — that work
///   re-routes immediately.
fn drive_fleet<T, FR, FT>(
    l: &mut LearnerState<'_>,
    tp: &T,
    sup: &mut Supervisor,
    plan: &FaultPlan,
    jitter: Option<Pcg32>,
    respawn: FR,
    retire: FT,
) -> Result<()>
where
    T: Transport + ?Sized,
    FR: FnMut(usize) -> Result<()>,
    FT: FnMut(usize),
{
    let mut respawn = respawn;
    let mut retire = retire;
    let mut jitter = jitter;
    let actors = tp.n_actors();
    let steps = l.cfg.steps;
    let lag = l.lag;
    let heartbeat = Duration::from_millis(l.cfg.heartbeat_ms.max(1));
    let ckpt_every = l.cfg.checkpoint.as_ref().map(|c| c.every).unwrap_or(0);

    // pending contexts (shipped to actors, kept for admission), reorder
    // buffer, dispatch bookkeeping, and the consume-once fault orders
    // that have been taken from the plan but have not provably fired
    let mut pending_ctx: BTreeMap<usize, ContextBatch> = BTreeMap::new();
    let mut buffered: BTreeMap<u64, RolloutBatch> = BTreeMap::new();
    let mut in_flight: BTreeMap<u64, usize> = BTreeMap::new();
    let mut pending_faults: BTreeMap<u64, FaultKind> = BTreeMap::new();
    let mut timeout_counted: BTreeSet<u64> = BTreeSet::new();
    let mut next_dispatch = l.completed;
    // the head step's wait clock arms when it BECOMES the head, so a
    // queue behind a slow actor can't rack up spurious timeouts
    let mut awaited: Option<(usize, Instant)> = None;

    let send_step = |l: &LearnerState<'_>,
                     pending_ctx: &BTreeMap<usize, ContextBatch>,
                     t: usize,
                     a: usize,
                     fault: Option<FaultKind>|
     -> Result<()> {
        let ctx = &pending_ctx[&t];
        let item = WorkItem {
            step: t as u64,
            x: ctx.x.clone(),
            y: ctx.y.clone(),
            snapshot: l.snapshot_for(t)?,
            fault,
        };
        // a failed send means the slot is mid-death; its Died message or
        // loss event is already in the inbox and will re-route this step
        // via the orphan scan
        let _ = tp.send_to(a, ToActor::Generate(Box::new(item)));
        Ok(())
    };

    // shared death handling: budgeted backoff (+ jitter when armed),
    // then respawn-or-retire; true means the slot lives again
    let mut revive = |sup: &mut Supervisor, actor: usize| -> bool {
        match sup.on_death(actor) {
            RespawnVerdict::Respawn { backoff } => {
                let extra = jitter
                    .as_mut()
                    .map(|r| {
                        let half = (backoff.as_millis() as u64 / 2).max(1);
                        Duration::from_millis(r.next_u64() % half)
                    })
                    .unwrap_or(Duration::ZERO);
                std::thread::sleep(backoff + extra);
                match respawn(actor) {
                    Ok(()) => {
                        sup.on_respawn(actor);
                        true
                    }
                    Err(e) => {
                        eprintln!("[distrib] respawning actor {actor} failed: {e:#}");
                        retire(actor);
                        false
                    }
                }
            }
            RespawnVerdict::GiveUp => {
                retire(actor);
                false
            }
        }
    };

    while l.completed < steps {
        // ---- dispatch window
        let barrier = if ckpt_every == 0 {
            usize::MAX
        } else {
            (l.completed / ckpt_every + 1) * ckpt_every
        };
        while next_dispatch < steps
            && next_dispatch <= l.completed + lag
            && next_dispatch < barrier
        {
            let t = next_dispatch;
            if !pending_ctx.contains_key(&t) {
                let c = l.context_for(t);
                pending_ctx.insert(t, c);
            }
            let Some(a) = sup.assign(t as u64) else {
                bail!("no live actor slot to dispatch step {t}");
            };
            let fault = plan.take(t as u64);
            if let Some(f) = fault {
                pending_faults.insert(t as u64, f);
            }
            send_step(l, &pending_ctx, t, a, fault)?;
            in_flight.insert(t as u64, a);
            next_dispatch += 1;
        }

        // ---- ingest the head if it has arrived
        let head = l.completed;
        if let Some(rb) = buffered.remove(&(head as u64)) {
            let ctx = pending_ctx
                .remove(&head)
                .context("pending context missing for buffered step")?;
            awaited = None;
            l.ingest(rb, &ctx)?;
            continue;
        }
        if let Some(&holder) = in_flight.get(&(head as u64)) {
            if !sup.is_alive(holder) {
                // never arm a heartbeat clock against a permanently-dead
                // slot — no delivery can come; re-route immediately
                let refire = pending_faults.get(&(head as u64)).copied();
                let target =
                    sup.assign(head as u64).context("no live actor for re-dispatch")?;
                send_step(l, &pending_ctx, head, target, refire)?;
                in_flight.insert(head as u64, target);
                awaited = None;
                continue;
            }
        }
        if awaited.map(|(t, _)| t) != Some(head) {
            awaited = Some((head, Instant::now()));
        }

        // ---- wait for news
        match tp.recv_timeout(POLL) {
            Recv::Msg(FromActor::Rollout(rb)) => {
                let step = rb.step;
                let fresh = (step as usize) >= l.completed
                    && in_flight.contains_key(&step)
                    && !buffered.contains_key(&step);
                if fresh {
                    in_flight.remove(&step);
                    pending_faults.remove(&step);
                    buffered.insert(step, rb);
                }
                // else: superseded or duplicate — already shed at
                // re-dispatch time
            }
            Recv::Msg(FromActor::Died { actor, step, reason }) => {
                eprintln!("[distrib] actor {actor} died at step {step}: {reason}");
                l.acct.shard_mut(0).record_actor_crash();
                // the crash order (if this death was injected) has fired
                pending_faults.remove(&step);
                let respawned = revive(sup, actor);
                if respawned {
                    l.acct.shard_mut(0).record_actor_restart();
                }
                if sup.n_live() == 0 {
                    bail!("all {actors} actor slots dead (respawn budget exhausted)");
                }
                // every step the dead actor held — the announced one AND
                // anything queued behind it — re-routes, un-fired fault
                // orders riding along
                let orphans: Vec<u64> = in_flight
                    .iter()
                    .filter(|&(_, &slot)| slot == actor)
                    .map(|(&st, _)| st)
                    .collect();
                for st in orphans {
                    let target = if respawned {
                        actor
                    } else {
                        sup.assign(st).context("no live actor for re-dispatch")?
                    };
                    let refire = pending_faults.get(&st).copied();
                    send_step(l, &pending_ctx, st as usize, target, refire)?;
                    in_flight.insert(st, target);
                    if st as usize == head {
                        awaited = None; // restart the head clock
                    }
                }
            }
            Recv::CorruptFrame { actor } => {
                // a frame from this slot failed its checksum: the link
                // survives, whatever the frame carried did not
                l.acct.shard_mut(0).record_wire_corrupt_frame();
                let slot_steps: Vec<u64> = in_flight
                    .iter()
                    .filter(|&(_, &slot)| slot == actor)
                    .map(|(&st, _)| st)
                    .collect();
                // under a seeded plan the damaged frame is exactly the
                // step carrying a pending bitflip order; real line noise
                // has no such marker, so re-send everything the slot holds
                let flipped: Vec<u64> = slot_steps
                    .iter()
                    .copied()
                    .filter(|st| {
                        matches!(pending_faults.get(st), Some(FaultKind::BitFlip { .. }))
                    })
                    .collect();
                let resend = if flipped.is_empty() { slot_steps } else { flipped };
                for st in resend {
                    pending_faults.remove(&st); // the damage fired
                    send_step(l, &pending_ctx, st as usize, actor, None)?;
                    if st as usize == head {
                        awaited = None;
                    }
                }
            }
            Recv::ConnectionLost { actor, mid_frame } => {
                eprintln!(
                    "[distrib] actor {actor} connection lost{}",
                    if mid_frame { " mid-frame" } else { "" }
                );
                if mid_frame {
                    // bytes of a frame died with the link
                    l.acct.shard_mut(0).record_wire_corrupt_frame();
                }
                let slot_steps: Vec<u64> = in_flight
                    .iter()
                    .filter(|&(_, &slot)| slot == actor)
                    .map(|(&st, _)| st)
                    .collect();
                // a severing wire order (torn/partial/disconnect) on this
                // slot has now fired; non-severing orders ride along on
                // the re-dispatch below
                for st in &slot_steps {
                    if pending_faults.get(st).is_some_and(|f| f.severs_connection()) {
                        pending_faults.remove(st);
                    }
                }
                let respawned = revive(sup, actor);
                if respawned {
                    l.acct.shard_mut(0).record_wire_reconnect();
                }
                if sup.n_live() == 0 {
                    bail!("all {actors} actor slots dead (respawn budget exhausted)");
                }
                for st in slot_steps {
                    let target = if respawned {
                        actor
                    } else {
                        sup.assign(st).context("no live actor for re-dispatch")?
                    };
                    let refire = pending_faults.get(&st).copied();
                    send_step(l, &pending_ctx, st as usize, target, refire)?;
                    in_flight.insert(st, target);
                    if st as usize == head {
                        awaited = None;
                    }
                }
            }
            Recv::Timeout => {
                // ---- heartbeat: the head has been silent too long
                if let Some((t, since)) = awaited {
                    if since.elapsed() >= heartbeat {
                        if let Some(&slot) = in_flight.get(&(t as u64)) {
                            if timeout_counted.insert(t as u64) {
                                l.acct.shard_mut(0).record_actor_timeout();
                            }
                            // the superseded dispatch's output is
                            // load-shed (dropped on arrival, or never
                            // seen if the run ends first); its fault (if
                            // any) fired on the slow slot, so the fresh
                            // copy computes clean
                            l.acct.shard_mut(0).record_shed(l.b);
                            let target = sup
                                .next_live_after(slot)
                                .context("no live actor for re-dispatch")?;
                            send_step(l, &pending_ctx, t, target, None)?;
                            in_flight.insert(t as u64, target);
                            awaited = Some((t, Instant::now()));
                        }
                    }
                }
            }
            Recv::Disconnected => {
                bail!(
                    "transport disconnected with {} of {steps} steps ingested",
                    l.completed
                );
            }
        }
    }
    Ok(())
}

/// Threaded mode over in-process channels: one thread per actor slot.
fn run_threaded(l: &mut LearnerState<'_>, plan: &FaultPlan) -> Result<()> {
    let actors = l.cfg.actors.max(1);
    let seed = l.cfg.seed;
    let eng = l.eng;
    let max_respawns = l.cfg.max_respawns;
    let tp = ChannelTransport::new(actors);

    std::thread::scope(|s| -> Result<()> {
        let mut sup = Supervisor::new(actors, max_respawns);
        for a in 0..actors {
            let (rx, tx) = tp.register_actor(a)?;
            s.spawn(move || actor_loop(eng, a, seed, rx, tx));
        }

        let result = drive_fleet(
            l,
            &tp,
            &mut sup,
            plan,
            None,
            |a| {
                let (rx, tx) = tp.register_actor(a)?;
                s.spawn(move || actor_loop(eng, a, seed, rx, tx));
                Ok(())
            },
            |a| tp.deregister(a),
        );

        // graceful or not, unblock every actor so the scope can join:
        // deregistering drops the inbox sender, ending each recv loop
        for a in 0..actors {
            if result.is_ok() && sup.is_alive(a) {
                let _ = tp.send_to(a, ToActor::Shutdown);
            }
            tp.deregister(a);
        }
        result
    })
}

/// Threaded mode over Unix sockets: one subprocess per actor slot,
/// spawned from the `repro actor` subcommand and supervised exactly like
/// the channel fleet — the respawn budget now buys process respawns and
/// reconnects, with stretched, jittered backoff (reconnect storms from a
/// flapping peer should not synchronize).
fn run_socket(l: &mut LearnerState<'_>, plan: &FaultPlan) -> Result<()> {
    let cfg = l.cfg;
    let actors = cfg.actors.max(1);
    let bin = match &cfg.actor_bin {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe()
            .context("resolving this executable for actor spawn (set actor_bin=)")?,
    };
    let deadline_ms = cfg.wire_deadline_ms.max(1);
    let scfg = SocketCfg {
        dir: cfg.socket_dir.as_ref().map(PathBuf::from).unwrap_or_else(std::env::temp_dir),
        n_actors: actors,
        fingerprint: l.fp_hash,
        deadline: Duration::from_millis(deadline_ms),
        accept_timeout: Duration::from_secs(30),
        bin,
        args: vec![
            format!("seed={}", cfg.seed),
            format!("fingerprint={:016x}", l.fp_hash),
            format!("artifacts_dir={}", cfg.artifacts_dir),
            format!("f32_fast={}", if l.eng.f32_fast() { 1 } else { 0 }),
            format!("deadline_ms={deadline_ms}"),
        ],
    };
    let tp = SocketTransport::bind(scfg)?;
    tp.start()?;

    let base = cfg.reconnect_backoff_ms.max(1);
    let mut sup =
        Supervisor::new(actors, cfg.max_respawns).with_backoff(base, (base * 8).max(100));
    let jitter = Pcg32::new(cfg.seed, 0x6a69_7474); // "jitt"
    let result = drive_fleet(
        l,
        &tp,
        &mut sup,
        plan,
        Some(jitter),
        |a| tp.respawn_slot(a),
        |a| tp.retire_slot(a),
    );

    // handshake rejections accumulate inside the transport; fold them
    // into the ledger once, whatever the run's outcome
    let rejects = tp.handshake_rejects();
    if rejects > 0 {
        l.acct.shard_mut(0).record_handshake_rejects(rejects);
    }
    tp.shutdown(|slot| result.is_ok() && sup.is_alive(slot));
    result
}

/// Entry point: build the learner, run the configured mode, optionally
/// persist the recorded stream.
pub fn train_distrib(eng: &Engine, cfg: &DistribCfg, mode: &DistribMode) -> Result<DistribRunResult> {
    let plan = FaultPlan::parse(&cfg.fault_spec)?;
    if plan.has_wire_events()
        && !(matches!(mode, DistribMode::Threaded) && cfg.transport == TransportKind::Socket)
    {
        bail!(
            "fault_spec schedules wire-level faults (torn/partial/bitflip/disconnect): \
             they damage bytes in flight and need mode=threaded with transport=socket"
        );
    }
    let lag = plan.lag_override().unwrap_or(cfg.lag);
    let mut l = LearnerState::new(eng, cfg, lag)?;
    match mode {
        DistribMode::Inline => run_inline(&mut l, &plan)?,
        DistribMode::Threaded => match cfg.transport {
            TransportKind::Channel => run_threaded(&mut l, &plan)?,
            TransportKind::Socket => run_socket(&mut l, &plan)?,
        },
        DistribMode::Replay(path) => run_replay(&mut l, path)?,
    }
    l.into_result()
}
