//! Cross-process transport: Unix-domain sockets to actor subprocesses.
//!
//! `SocketTransport` implements the same `Transport` trait the in-process
//! `ChannelTransport` does, but each actor slot is an OS process (spawned
//! from the `repro actor` subcommand) connected over a UDS carrying the
//! hardened frame protocol of `distrib::wire`. The learner owns the full
//! lifecycle: it binds the socket, spawns and reaps the children,
//! validates each connection's magic/version/run-fingerprint handshake,
//! and re-establishes links that die (the supervisor's respawn budget
//! decides whether; this module just does the work).
//!
//! One reader thread per link turns frames into events. Events carry the
//! link's *generation*: respawning a slot bumps its generation, so
//! corruption/loss noise from a replaced connection can never be
//! attributed to its successor. The learner drains events serially
//! through `recv_timeout`, which filters stale generations — the same
//! single-consumer discipline that makes the channel path deterministic.
//!
//! Policy snapshots ship per-link, at most once per version: `send_to`
//! prepends a Snapshot frame before the first Generate that references a
//! version this link has not seen, and a reconnected link starts over
//! (its cache died with the process). The actor caches snapshots by
//! version and reports a cache miss as a `Died` frame — which is also the
//! *terminal* frame by protocol: after announcing death, nothing else is
//! valid on the link, so the reader exits without synthesizing a
//! connection-loss event and a crash is never double-counted as a
//! reconnect.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::net::Shutdown as NetShutdown;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::Engine;

use super::actor::ActorCtx;
use super::faults::{apply_poison, FaultKind};
use super::transport::{FromActor, PolicySnapshot, Recv, ToActor, Transport};
use super::wire::{
    decode_payload, encode_died, encode_generate, encode_hello, encode_hello_ack,
    encode_hello_reject, encode_rollout, encode_shutdown, encode_snapshot, read_frame,
    validate_hello, WireError, WireFaults, WireMsg, READ_POLL,
};

/// Distinguishes socket files when several transports share a directory
/// (parallel tests in one process share a pid).
static SOCKET_SEQ: AtomicU64 = AtomicU64::new(0);

/// How many snapshot versions an actor keeps cached before evicting.
const SNAPSHOT_CACHE: u64 = 256;

/// An actor that hears nothing at all for this long assumes the learner
/// is gone and exits rather than lingering as an orphan process.
const IDLE_EXIT: Duration = Duration::from_secs(120);

fn lock_ok<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[derive(Debug, Clone)]
pub struct SocketCfg {
    /// directory the socket file is created in (created if missing)
    pub dir: PathBuf,
    pub n_actors: usize,
    /// run fingerprint every Hello must echo
    pub fingerprint: u64,
    /// per-frame read/write deadline on every blocking wire call
    pub deadline: Duration,
    /// how long to wait for a spawned child to connect and handshake
    pub accept_timeout: Duration,
    /// actor executable (the `repro` binary)
    pub bin: PathBuf,
    /// extra `k=v` args appended after `actor --slot N --socket PATH`
    pub args: Vec<String>,
}

/// Reader-thread -> learner events, tagged with the link generation that
/// produced them so events from a replaced connection are discardable.
enum Event {
    From(FromActor),
    Corrupt { slot: usize, gen: u64 },
    Lost { slot: usize, gen: u64, mid_frame: bool },
}

struct Shared {
    events: Mutex<VecDeque<Event>>,
    cv: Condvar,
    /// current generation per slot; bumped by every (re)install
    gens: Vec<AtomicU64>,
}

/// Learner-side state for one live connection.
struct Link {
    stream: UnixStream,
    /// snapshot versions already shipped on THIS connection
    sent_versions: BTreeSet<u64>,
    gen: u64,
}

pub struct SocketTransport {
    cfg: SocketCfg,
    path: PathBuf,
    listener: UnixListener,
    shared: Arc<Shared>,
    links: Mutex<Vec<Option<Link>>>,
    children: Mutex<Vec<Option<Child>>>,
    handshake_rejects: AtomicU64,
}

impl SocketTransport {
    /// Bind the listener (unique filename per transport instance). No
    /// children are spawned yet; call [`SocketTransport::start`].
    pub fn bind(cfg: SocketCfg) -> Result<SocketTransport> {
        assert!(cfg.n_actors > 0, "need at least one actor slot");
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("creating socket dir {}", cfg.dir.display()))?;
        let name = format!(
            "kondo-{}-{}.sock",
            std::process::id(),
            SOCKET_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let path = cfg.dir.join(name);
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding {}", path.display()))?;
        // accept() is polled with a sleep so accept_timeout is enforceable
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            events: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            gens: (0..cfg.n_actors).map(|_| AtomicU64::new(0)).collect(),
        });
        let links = Mutex::new((0..cfg.n_actors).map(|_| None).collect());
        let children = Mutex::new((0..cfg.n_actors).map(|_| None).collect());
        Ok(SocketTransport {
            cfg,
            path,
            listener,
            shared,
            links,
            children,
            handshake_rejects: AtomicU64::new(0),
        })
    }

    pub fn socket_path(&self) -> &Path {
        &self.path
    }

    /// Handshake rejections counted so far (drained into the ledger once
    /// at the end of a run).
    pub fn handshake_rejects(&self) -> u64 {
        self.handshake_rejects.load(Ordering::Relaxed)
    }

    /// Spawn every actor process and accept their handshakes.
    pub fn start(&self) -> Result<()> {
        for slot in 0..self.cfg.n_actors {
            let child = self.spawn_child(slot)?;
            lock_ok(&self.children)[slot] = Some(child);
        }
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        while seen.len() < self.cfg.n_actors {
            let (slot, stream) = self.accept_one(|s| !seen.contains(&s))?;
            seen.insert(slot);
            self.install_link(slot, stream);
        }
        Ok(())
    }

    fn spawn_child(&self, slot: usize) -> Result<Child> {
        let mut cmd = Command::new(&self.cfg.bin);
        cmd.arg("actor")
            .arg("--slot")
            .arg(slot.to_string())
            .arg("--socket")
            .arg(&self.path)
            .stdin(Stdio::null());
        for a in &self.cfg.args {
            cmd.arg(a);
        }
        cmd.spawn().with_context(|| {
            format!("spawning actor {slot} from {}", self.cfg.bin.display())
        })
    }

    /// Accept connections until one presents a valid Hello for a slot
    /// `want` accepts. Invalid handshakes (bad magic/version/fingerprint,
    /// out-of-range or unwanted slot, undecodable first frame) are
    /// rejected with a reason frame, counted, and the wait continues.
    fn accept_one(&self, want: impl Fn(usize) -> bool) -> Result<(usize, UnixStream)> {
        let t0 = Instant::now();
        loop {
            if t0.elapsed() >= self.cfg.accept_timeout {
                bail!(
                    "no valid actor handshake within {:?} on {}",
                    self.cfg.accept_timeout,
                    self.path.display()
                );
            }
            let mut stream = match self.listener.accept() {
                Ok((s, _)) => s,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
                Err(e) => return Err(e.into()),
            };
            stream.set_read_timeout(Some(READ_POLL))?;
            stream.set_write_timeout(Some(self.cfg.deadline))?;
            // read the Hello, bounded by the remaining accept budget
            let hello_deadline = self
                .cfg
                .accept_timeout
                .saturating_sub(t0.elapsed())
                .max(Duration::from_millis(50));
            let hello_t0 = Instant::now();
            let verdict: std::result::Result<u32, String> = loop {
                match read_frame(&mut stream, self.cfg.deadline) {
                    Ok((kind, payload)) => {
                        break match decode_payload(kind, &payload) {
                            Ok(msg) => validate_hello(&msg, self.cfg.fingerprint),
                            Err(e) => Err(format!("undecodable first frame: {e}")),
                        }
                    }
                    Err(WireError::Idle) if hello_t0.elapsed() < hello_deadline => continue,
                    Err(e) => break Err(format!("no Hello frame: {e}")),
                }
            };
            match verdict {
                Ok(slot) if (slot as usize) < self.cfg.n_actors && want(slot as usize) => {
                    let _ = stream.write_all(&encode_hello_ack());
                    return Ok((slot as usize, stream));
                }
                Ok(slot) => {
                    self.reject(&mut stream, &format!("unexpected slot {slot}"));
                }
                Err(reason) => {
                    self.reject(&mut stream, &reason);
                }
            }
        }
    }

    fn reject(&self, stream: &mut UnixStream, reason: &str) {
        self.handshake_rejects.fetch_add(1, Ordering::Relaxed);
        eprintln!("[distrib] handshake rejected: {reason}");
        let _ = stream.write_all(&encode_hello_reject(reason));
        let _ = stream.shutdown(NetShutdown::Both);
    }

    /// Install an accepted connection as slot `slot`'s live link and
    /// start its reader thread. Bumps the slot generation, so any event
    /// still queued from a previous connection is recognizably stale.
    fn install_link(&self, slot: usize, stream: UnixStream) {
        let gen = self.shared.gens[slot].fetch_add(1, Ordering::SeqCst) + 1;
        let reader = stream.try_clone().expect("cloning UDS for reader");
        let shared = self.shared.clone();
        let deadline = self.cfg.deadline;
        std::thread::spawn(move || reader_loop(reader, slot, gen, deadline, shared));
        lock_ok(&self.links)[slot] = Some(Link { stream, sent_versions: BTreeSet::new(), gen });
    }

    /// Reap the dead child on `slot`, spawn a fresh one, and wait for its
    /// handshake. On failure the slot is left unlinked (the caller
    /// retires it).
    pub fn respawn_slot(&self, slot: usize) -> Result<()> {
        self.reap_child(slot);
        lock_ok(&self.links)[slot] = None;
        let child = self.spawn_child(slot)?;
        lock_ok(&self.children)[slot] = Some(child);
        let (got, stream) = self.accept_one(|s| s == slot)?;
        debug_assert_eq!(got, slot);
        self.install_link(slot, stream);
        Ok(())
    }

    /// Abandon a slot for good: kill + reap its child, drop its link.
    pub fn retire_slot(&self, slot: usize) {
        self.reap_child(slot);
        lock_ok(&self.links)[slot] = None;
    }

    fn reap_child(&self, slot: usize) {
        if let Some(mut child) = lock_ok(&self.children)[slot].take() {
            // usually already dead (crash/sever exits the process); kill
            // is a no-op then, and wait reaps the zombie either way
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Orderly teardown: Shutdown frame to every slot `keep` approves,
    /// then close links and reap every child (waiting briefly for clean
    /// exits before killing).
    pub fn shutdown(&self, keep: impl Fn(usize) -> bool) {
        for slot in 0..self.cfg.n_actors {
            if keep(slot) {
                let _ = self.send_to(slot, ToActor::Shutdown);
            }
            lock_ok(&self.links)[slot] = None;
        }
        let t0 = Instant::now();
        for slot in 0..self.cfg.n_actors {
            let mut done = false;
            if let Some(child) = lock_ok(&self.children)[slot].as_mut() {
                while t0.elapsed() < Duration::from_secs(5) {
                    match child.try_wait() {
                        Ok(Some(_)) => {
                            done = true;
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(10)),
                        Err(_) => break,
                    }
                }
            }
            if done {
                lock_ok(&self.children)[slot] = None;
            } else {
                self.reap_child(slot);
            }
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        for slot in 0..self.cfg.n_actors {
            self.reap_child(slot);
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Per-link reader: frames -> events, until the link ends. The policy
/// split lives here: recoverable damage (checksum failure) emits
/// `Corrupt` and keeps reading; everything fatal emits `Lost` exactly
/// once and exits; a `Died` frame is terminal by protocol and exits
/// WITHOUT a `Lost` (the death is the whole story — the respawn it
/// triggers must not also count as a reconnect).
fn reader_loop(
    mut stream: UnixStream,
    slot: usize,
    gen: u64,
    deadline: Duration,
    shared: Arc<Shared>,
) {
    let push = |ev: Event| {
        lock_ok(&shared.events).push_back(ev);
        shared.cv.notify_one();
    };
    loop {
        match read_frame(&mut stream, deadline) {
            Ok((kind, payload)) => match decode_payload(kind, &payload) {
                Ok(WireMsg::Rollout(rb)) => push(Event::From(FromActor::Rollout(rb))),
                Ok(WireMsg::Died { actor, step, reason }) => {
                    push(Event::From(FromActor::Died { actor, step, reason }));
                    return;
                }
                Ok(other) => {
                    eprintln!("[distrib] actor {slot}: protocol violation: {other:?}");
                    push(Event::Lost { slot, gen, mid_frame: false });
                    return;
                }
                Err(e) => {
                    eprintln!("[distrib] actor {slot}: {e}");
                    push(Event::Lost { slot, gen, mid_frame: false });
                    return;
                }
            },
            Err(WireError::Idle) => continue,
            Err(WireError::Closed) => {
                push(Event::Lost { slot, gen, mid_frame: false });
                return;
            }
            Err(e @ WireError::Corrupt(_)) => {
                // checksum noise: drop the frame, keep the link
                eprintln!("[distrib] actor {slot}: {e}");
                push(Event::Corrupt { slot, gen });
            }
            Err(e) => {
                // Torn / Header / Malformed / Io: the byte stream can no
                // longer be trusted — a frame died with it for the
                // mid-frame cases
                let mid_frame =
                    matches!(e, WireError::Torn | WireError::Header(_));
                eprintln!("[distrib] actor {slot}: {e}");
                push(Event::Lost { slot, gen, mid_frame });
                return;
            }
        }
    }
}

impl Transport for SocketTransport {
    fn n_actors(&self) -> usize {
        self.cfg.n_actors
    }

    fn send_to(&self, actor: usize, msg: ToActor) -> Result<()> {
        let mut links = lock_ok(&self.links);
        let link = match links.get_mut(actor) {
            Some(Some(l)) => l,
            Some(None) => bail!("actor {actor} not connected"),
            None => bail!("actor slot {actor} out of range"),
        };
        match msg {
            ToActor::Shutdown => {
                link.stream.write_all(&encode_shutdown())?;
            }
            ToActor::Generate(item) => {
                // first reference to this snapshot version on this link:
                // ship the snapshot itself ahead of the work order
                let v = item.snapshot.version;
                if !link.sent_versions.contains(&v) {
                    link.stream.write_all(&encode_snapshot(&item.snapshot))?;
                    link.sent_versions.insert(v);
                }
                link.stream.write_all(&encode_generate(
                    item.step, &item.x, &item.y, v, item.fault,
                ))?;
            }
        }
        link.stream.flush()?;
        Ok(())
    }

    fn recv_timeout(&self, timeout: Duration) -> Recv {
        let deadline = Instant::now() + timeout;
        let mut q = lock_ok(&self.shared.events);
        loop {
            while let Some(ev) = q.pop_front() {
                match ev {
                    Event::From(m) => return Recv::Msg(m),
                    Event::Corrupt { slot, gen } => {
                        if gen == self.shared.gens[slot].load(Ordering::SeqCst) {
                            return Recv::CorruptFrame { actor: slot };
                        }
                        // stale generation: noise from a replaced link
                    }
                    Event::Lost { slot, gen, mid_frame } => {
                        if gen == self.shared.gens[slot].load(Ordering::SeqCst) {
                            return Recv::ConnectionLost { actor: slot, mid_frame };
                        }
                    }
                }
            }
            let now = Instant::now();
            if now >= deadline {
                drop(q);
                let dead = lock_ok(&self.links).iter().all(|l| l.is_none());
                return if dead { Recv::Disconnected } else { Recv::Timeout };
            }
            let (guard, _) = self
                .shared
                .cv
                .wait_timeout(q, deadline - now)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            q = guard;
        }
    }
}

// ---------------------------------------------------------------------------
// Actor-process side: the body of `repro actor`.

/// Everything the `repro actor` subcommand parses off its command line.
#[derive(Debug, Clone)]
pub struct ActorProcCfg {
    pub socket: PathBuf,
    pub slot: usize,
    pub seed: u64,
    /// run fingerprint to present in the Hello
    pub fingerprint: u64,
    pub artifacts_dir: String,
    pub f32_fast: bool,
    pub deadline: Duration,
}

fn connect_retry(path: &Path, budget: Duration) -> Result<UnixStream> {
    let t0 = Instant::now();
    loop {
        match UnixStream::connect(path) {
            Ok(s) => return Ok(s),
            Err(e) if t0.elapsed() < budget => {
                let _ = e;
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("connecting to {}", path.display()))
            }
        }
    }
}

/// Run one actor process to completion: connect, handshake, then serve
/// Generate orders until Shutdown, learner hangup, or a fault says
/// otherwise. Wire-level fault orders are executed here by damaging the
/// already-encoded reply through `WireFaults` — the learner's counters
/// then measure its own detection of that exact damage.
pub fn run_actor(cfg: &ActorProcCfg) -> Result<()> {
    let eng = Engine::open(&cfg.artifacts_dir)?.with_f32_fast(cfg.f32_fast);
    let mut ctx = ActorCtx::new(&eng, cfg.seed)?;
    let mut stream = connect_retry(&cfg.socket, Duration::from_secs(10))?;
    stream.set_read_timeout(Some(READ_POLL))?;
    stream.set_write_timeout(Some(cfg.deadline))?;
    stream.write_all(&encode_hello(cfg.fingerprint, cfg.slot as u32))?;

    // await the verdict (generous budget: the learner may be busy
    // accepting a whole fleet)
    let hs_t0 = Instant::now();
    loop {
        match read_frame(&mut stream, cfg.deadline) {
            Ok((kind, payload)) => {
                match decode_payload(kind, &payload).map_err(anyhow::Error::from)? {
                    WireMsg::HelloAck => break,
                    WireMsg::HelloReject { reason } => {
                        bail!("handshake rejected by learner: {reason}")
                    }
                    other => bail!("expected HelloAck, got {other:?}"),
                }
            }
            Err(WireError::Idle) if hs_t0.elapsed() < Duration::from_secs(30) => continue,
            Err(e) => bail!("handshake failed: {e}"),
        }
    }

    let mut snapshots: BTreeMap<u64, PolicySnapshot> = BTreeMap::new();
    let mut last_heard = Instant::now();
    loop {
        let (kind, payload) = match read_frame(&mut stream, cfg.deadline) {
            Ok(f) => f,
            Err(WireError::Idle) => {
                if last_heard.elapsed() > IDLE_EXIT {
                    bail!("learner silent for {IDLE_EXIT:?}; exiting");
                }
                continue;
            }
            Err(WireError::Closed) => return Ok(()), // learner gone, clean
            Err(e) => bail!("wire error from learner: {e}"),
        };
        last_heard = Instant::now();
        let msg = decode_payload(kind, &payload).map_err(anyhow::Error::from)?;
        match msg {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::Snapshot(s) => {
                let v = s.version;
                snapshots.insert(v, s);
                if v > SNAPSHOT_CACHE {
                    snapshots = snapshots.split_off(&(v - SNAPSHOT_CACHE));
                }
            }
            WireMsg::Generate { step, snapshot_version, x, y, fault } => {
                if let Some(FaultKind::Crash) = fault {
                    let _ =
                        stream.write_all(&encode_died(cfg.slot, step, "injected crash"));
                    return Ok(());
                }
                if let Some(FaultKind::Stall { ms }) = fault {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                let Some(snap) = snapshots.get(&snapshot_version) else {
                    let _ = stream.write_all(&encode_died(
                        cfg.slot,
                        step,
                        &format!("snapshot v{snapshot_version} not cached"),
                    ));
                    return Ok(());
                };
                match ctx.rollout(cfg.slot, snap, step, &x, &y) {
                    Ok(mut rb) => {
                        if let Some(FaultKind::Poison { kind, count }) = fault {
                            apply_poison(&mut rb, kind, count);
                        }
                        let frame = encode_rollout(&rb);
                        match fault.and_then(|f| WireFaults::damage(&frame, f)) {
                            Some((bytes, sever)) => {
                                let _ = stream.write_all(&bytes);
                                let _ = stream.flush();
                                if sever {
                                    let _ = stream.shutdown(NetShutdown::Both);
                                    return Ok(());
                                }
                            }
                            None => stream.write_all(&frame)?,
                        }
                    }
                    Err(e) => {
                        let _ =
                            stream.write_all(&encode_died(cfg.slot, step, &format!("{e:#}")));
                        return Ok(());
                    }
                }
            }
            other => bail!("unexpected frame from learner: {other:?}"),
        }
    }
}

// The full transport (spawn, handshake, faults, reconnect) is exercised
// end-to-end against real subprocesses in tests/distrib_e2e.rs and the
// codec hardening in tests/wire_codec.rs; unit tests here would need a
// second process and would duplicate those.
