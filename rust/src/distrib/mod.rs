//! Fault-tolerant actor–learner runtime (DESIGN.md §12).
//!
//! N actor workers generate rollouts against policy snapshots that lag
//! the learner by a configurable number of steps; the learner ingests
//! them through a hardened admission path that *quarantines* bad data
//! (non-finite signals, shape/fingerprint lies, out-of-range actions)
//! instead of panicking, prices staleness through the Kondo gate, and
//! supervises the fleet (heartbeat timeouts, bounded-backoff respawn,
//! graceful degradation to the surviving actors). Every failure mode is
//! reproducible via the seeded `FaultPlan`, and the recorded-stream
//! replay mode extends the eta=0 bit-identity contract to the
//! distributed path.
//!
//! Module map:
//! - [`transport`] — message types and the socket-shaped `Transport`
//!   trait; `ChannelTransport` is the in-process implementation.
//! - [`wire`] — the hardened frame codec (length-prefixed, checksummed,
//!   versioned handshake) cross-process links speak, plus the byte-level
//!   fault shim.
//! - [`socket`] — `SocketTransport`: actor subprocesses over Unix
//!   sockets, with handshake validation, per-link reader threads, and
//!   learner-driven process respawn; also the actor-process entry point.
//! - [`actor`] — rollout workers; all per-sample randomness is keyed by
//!   (seed, step, sample), never by actor identity.
//! - [`faults`] — the seeded, consume-once fault schedule (process- and
//!   wire-level).
//! - [`supervisor`] — pure assignment/respawn state machine.
//! - [`learner`] — admission, staleness pricing, the execution modes,
//!   the transport-generic fleet driver, checkpointing.
//! - [`replay`] — recorded actor streams (bit-exact JSON codec).

pub mod actor;
pub mod faults;
pub mod learner;
pub mod replay;
pub mod socket;
pub mod supervisor;
pub mod transport;
pub mod wire;

pub use faults::{ExpectedCounts, FaultKind, FaultPlan, PoisonKind};
pub use learner::{train_distrib, DistribCfg, DistribMode, DistribRunResult};
pub use socket::{run_actor, ActorProcCfg, SocketCfg, SocketTransport};
pub use supervisor::{RespawnVerdict, Supervisor};
pub use transport::{
    ChannelTransport, FromActor, PolicySnapshot, Recv, RolloutBatch, ToActor, Transport,
    TransportKind, WorkItem,
};
