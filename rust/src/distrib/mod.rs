//! Fault-tolerant actor–learner runtime (DESIGN.md §12).
//!
//! N actor workers generate rollouts against policy snapshots that lag
//! the learner by a configurable number of steps; the learner ingests
//! them through a hardened admission path that *quarantines* bad data
//! (non-finite signals, shape/fingerprint lies, out-of-range actions)
//! instead of panicking, prices staleness through the Kondo gate, and
//! supervises the fleet (heartbeat timeouts, bounded-backoff respawn,
//! graceful degradation to the surviving actors). Every failure mode is
//! reproducible via the seeded `FaultPlan`, and the recorded-stream
//! replay mode extends the eta=0 bit-identity contract to the
//! distributed path.
//!
//! Module map:
//! - [`transport`] — message types and the socket-shaped `Transport`
//!   trait; `ChannelTransport` is the in-process implementation.
//! - [`actor`] — rollout workers; all per-sample randomness is keyed by
//!   (seed, step, sample), never by actor identity.
//! - [`faults`] — the seeded, consume-once fault schedule.
//! - [`supervisor`] — pure assignment/respawn state machine.
//! - [`learner`] — admission, staleness pricing, the three execution
//!   modes, checkpointing.
//! - [`replay`] — recorded actor streams (bit-exact JSON codec).

pub mod actor;
pub mod faults;
pub mod learner;
pub mod replay;
pub mod supervisor;
pub mod transport;

pub use faults::{ExpectedCounts, FaultKind, FaultPlan, PoisonKind};
pub use learner::{train_distrib, DistribCfg, DistribMode, DistribRunResult};
pub use supervisor::{RespawnVerdict, Supervisor};
pub use transport::{
    ChannelTransport, FromActor, PolicySnapshot, RolloutBatch, ToActor, Transport, WorkItem,
};
