//! Durable training snapshots with a bit-identity resume contract
//! (DESIGN.md §10).
//!
//! A checkpoint serializes *every* piece of state the deterministic
//! trajectory depends on — `ParamStore` tensors, Adam moments, the
//! tier-1 `DraftScreen` weights and warm-up counter, the streaming gate
//! price tracker, the trainer's master PCG32 stream, the merged compute
//! ledger, the eval curve, and a trainer-specific `extra` blob — through
//! `utils::json`, whose float encoding is bit-exact (including NaN, ±inf
//! and -0.0; see the json round-trip tests). Everything *not* in the
//! trajectory contract (worker count, gate profiles, scratch buffers,
//! the arena) is deliberately excluded: it is reconstructed fresh on
//! resume, which is exactly what lets a checkpoint taken under
//! `workers=1` resume under `workers=4` bit-identically.
//!
//! File format: one header line
//!
//! ```text
//! KONDO-CKPT v2 len=<body bytes> fnv=<16-hex FNV-1a-64 of body>
//! ```
//!
//! followed by the canonical JSON dump (`BTreeMap` keys ⇒ deterministic
//! byte layout, so identical state ⇒ identical file). `len` catches
//! truncation, the checksum catches corruption, the version gate catches
//! format drift, and the stored config fingerprint catches resuming into
//! the wrong run — each with a clean error, never a panic or a silent
//! wrong resume. Writes are atomic: serialize to `<path>.tmp` in the
//! same directory, fsync, then `rename` over the target, so a crash
//! mid-write leaves the previous checkpoint intact.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::accounting::{Ledger, ShardedLedger};
use crate::model::ParamStore;
use crate::optim::Adam;
use crate::trainers::{EvalPoint, GatedLoop};
use crate::utils::json::Json;
use crate::utils::rng::Pcg32;

pub const MAGIC: &str = "KONDO-CKPT";
/// v2: the ledger codec grew the fault/admission counters of the distrib
/// actor–learner runtime (quarantine, staleness, shedding, supervisor).
/// v3: the ledger codec grew the wire-level counters of the cross-process
/// transport (corrupt frames, reconnects, handshake rejects).
/// The codec is strict both ways, so older files are rejected by the
/// version gate instead of resuming with silently-zeroed counters.
pub const VERSION: u32 = 3;

/// Checkpointing knobs threaded from `ExpConfig` into the trainer cfgs.
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// target file; saves go through atomic write-rename
    pub path: String,
    /// save after every `every`-th optimizer step (0 = never)
    pub every: usize,
}

/// Tier-1 draft screen state (weights + warm-up counter).
#[derive(Debug, Clone, PartialEq)]
pub struct ScreenState {
    pub w: Vec<f32>,
    pub b: f32,
    pub seen: u64,
}

/// Streaming gate price tracker state.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamState {
    pub lam: f64,
    pub mad: f64,
    pub count: u64,
}

/// The full serialized training state.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// config identity of the run that wrote this checkpoint; validated
    /// key-by-key on resume (see [`validate_fingerprint`])
    pub fingerprint: Json,
    /// optimizer steps completed (resume continues at this step index)
    pub step: u64,
    pub params: Vec<Vec<f32>>,
    pub opt_t: u64,
    pub opt_m: Vec<Vec<f32>>,
    pub opt_v: Vec<Vec<f32>>,
    /// master RNG stream: `(state, inc, gauss_spare)`
    pub rng: (u64, u64, Option<f64>),
    pub screen: Option<ScreenState>,
    pub stream: Option<StreamState>,
    /// merged ledger totals at save time
    pub ledger: Ledger,
    pub curve: Vec<EvalPoint>,
    /// trainer-specific state (train-error window, reward sums, ...)
    pub extra: Json,
}

// ---- json building/parsing helpers (pub: trainers and tests use them) ----

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// u64 as a decimal string. `Json::Num` is an f64, which silently loses
/// integers above 2^53 — RNG states and sample counters live up there.
pub fn ju64(x: u64) -> Json {
    Json::Str(x.to_string())
}

/// Parse a [`ju64`]-encoded value.
pub fn pu64(j: &Json, what: &str) -> Result<u64> {
    let Json::Str(s) = j else {
        bail!("checkpoint field '{what}': expected a u64 string, got {}", j.dump().trim());
    };
    s.parse::<u64>().with_context(|| format!("checkpoint field '{what}': bad u64 '{s}'"))
}

/// Look up a required object field.
pub fn field<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
    j.as_obj()
        .and_then(|o| o.get(k))
        .with_context(|| format!("checkpoint missing field '{k}'"))
}

pub fn pf64(j: &Json, what: &str) -> Result<f64> {
    j.as_f64().with_context(|| format!("checkpoint field '{what}': expected a number"))
}

pub fn jf64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub fn pf64_arr(j: &Json, what: &str) -> Result<Vec<f64>> {
    let Json::Arr(a) = j else {
        bail!("checkpoint field '{what}': expected an array");
    };
    a.iter().map(|v| pf64(v, what)).collect()
}

/// f32 slice as an f64 array (f32 -> f64 is exact, so the round trip is
/// lossless given the json layer's bit-exact f64 encoding).
pub fn jf32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

pub fn pf32_arr(j: &Json, what: &str) -> Result<Vec<f32>> {
    Ok(pf64_arr(j, what)?.into_iter().map(|x| x as f32).collect())
}

fn jf32_tensors(ts: &[Vec<f32>]) -> Json {
    Json::Arr(ts.iter().map(|t| jf32_arr(t)).collect())
}

fn pf32_tensors(j: &Json, what: &str) -> Result<Vec<Vec<f32>>> {
    let Json::Arr(a) = j else {
        bail!("checkpoint field '{what}': expected an array of tensors");
    };
    a.iter().map(|t| pf32_arr(t, what)).collect()
}

/// FNV-1a 64-bit (the repo needs no cryptographic strength here — the
/// checksum guards against torn writes and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---- ledger / curve codecs ----

fn ledger_to_json(l: &Ledger) -> Json {
    let hist: BTreeMap<String, Json> =
        l.bucket_hist.iter().map(|(&cap, &n)| (cap.to_string(), ju64(n))).collect();
    obj(vec![
        ("forward_samples", ju64(l.forward_samples)),
        ("forward_executed", ju64(l.forward_executed)),
        ("forward_calls", ju64(l.forward_calls)),
        ("screen_samples", ju64(l.screen_samples)),
        ("forward_skipped", ju64(l.forward_skipped)),
        ("backward_kept", ju64(l.backward_kept)),
        ("backward_executed", ju64(l.backward_executed)),
        ("backward_calls", ju64(l.backward_calls)),
        ("bucket_hist", Json::Obj(hist)),
        ("quarantined_samples", ju64(l.quarantined_samples)),
        ("quarantined_batches", ju64(l.quarantined_batches)),
        ("stale_samples", ju64(l.stale_samples)),
        ("stale_kept", ju64(l.stale_kept)),
        ("shed_samples", ju64(l.shed_samples)),
        ("actor_crashes", ju64(l.actor_crashes)),
        ("actor_restarts", ju64(l.actor_restarts)),
        ("actor_timeouts", ju64(l.actor_timeouts)),
        ("wire_corrupt_frames", ju64(l.wire_corrupt_frames)),
        ("wire_reconnects", ju64(l.wire_reconnects)),
        ("handshake_rejects", ju64(l.handshake_rejects)),
    ])
}

fn ledger_from_json(j: &Json) -> Result<Ledger> {
    let mut l = Ledger::new();
    l.forward_samples = pu64(field(j, "forward_samples")?, "ledger.forward_samples")?;
    l.forward_executed = pu64(field(j, "forward_executed")?, "ledger.forward_executed")?;
    l.forward_calls = pu64(field(j, "forward_calls")?, "ledger.forward_calls")?;
    l.screen_samples = pu64(field(j, "screen_samples")?, "ledger.screen_samples")?;
    l.forward_skipped = pu64(field(j, "forward_skipped")?, "ledger.forward_skipped")?;
    l.backward_kept = pu64(field(j, "backward_kept")?, "ledger.backward_kept")?;
    l.backward_executed = pu64(field(j, "backward_executed")?, "ledger.backward_executed")?;
    l.backward_calls = pu64(field(j, "backward_calls")?, "ledger.backward_calls")?;
    l.quarantined_samples =
        pu64(field(j, "quarantined_samples")?, "ledger.quarantined_samples")?;
    l.quarantined_batches =
        pu64(field(j, "quarantined_batches")?, "ledger.quarantined_batches")?;
    l.stale_samples = pu64(field(j, "stale_samples")?, "ledger.stale_samples")?;
    l.stale_kept = pu64(field(j, "stale_kept")?, "ledger.stale_kept")?;
    l.shed_samples = pu64(field(j, "shed_samples")?, "ledger.shed_samples")?;
    l.actor_crashes = pu64(field(j, "actor_crashes")?, "ledger.actor_crashes")?;
    l.actor_restarts = pu64(field(j, "actor_restarts")?, "ledger.actor_restarts")?;
    l.actor_timeouts = pu64(field(j, "actor_timeouts")?, "ledger.actor_timeouts")?;
    l.wire_corrupt_frames =
        pu64(field(j, "wire_corrupt_frames")?, "ledger.wire_corrupt_frames")?;
    l.wire_reconnects = pu64(field(j, "wire_reconnects")?, "ledger.wire_reconnects")?;
    l.handshake_rejects = pu64(field(j, "handshake_rejects")?, "ledger.handshake_rejects")?;
    let Json::Obj(hist) = field(j, "bucket_hist")? else {
        bail!("checkpoint field 'ledger.bucket_hist': expected an object");
    };
    for (cap, n) in hist {
        let cap: usize = cap
            .parse()
            .with_context(|| format!("ledger.bucket_hist: bad capacity key '{cap}'"))?;
        l.bucket_hist.insert(cap, pu64(n, "ledger.bucket_hist")?);
    }
    Ok(l)
}

fn curve_to_json(curve: &[EvalPoint]) -> Json {
    Json::Arr(
        curve
            .iter()
            .map(|p| {
                obj(vec![
                    ("step", ju64(p.step as u64)),
                    ("forward_samples", ju64(p.forward_samples)),
                    ("screen_samples", ju64(p.screen_samples)),
                    ("forward_skipped", ju64(p.forward_skipped)),
                    ("backward_kept", ju64(p.backward_kept)),
                    ("backward_executed", ju64(p.backward_executed)),
                    ("metric", Json::Num(p.metric)),
                    ("metric2", Json::Num(p.metric2)),
                ])
            })
            .collect(),
    )
}

fn curve_from_json(j: &Json) -> Result<Vec<EvalPoint>> {
    let Json::Arr(a) = j else {
        bail!("checkpoint field 'curve': expected an array");
    };
    a.iter()
        .map(|p| {
            Ok(EvalPoint {
                step: pu64(field(p, "step")?, "curve.step")? as usize,
                forward_samples: pu64(field(p, "forward_samples")?, "curve.forward_samples")?,
                screen_samples: pu64(field(p, "screen_samples")?, "curve.screen_samples")?,
                forward_skipped: pu64(field(p, "forward_skipped")?, "curve.forward_skipped")?,
                backward_kept: pu64(field(p, "backward_kept")?, "curve.backward_kept")?,
                backward_executed: pu64(
                    field(p, "backward_executed")?,
                    "curve.backward_executed",
                )?,
                metric: pf64(field(p, "metric")?, "curve.metric")?,
                metric2: pf64(field(p, "metric2")?, "curve.metric2")?,
            })
        })
        .collect()
}

// ---- encode / decode ----

fn to_json(ck: &TrainCheckpoint) -> Json {
    let (state, inc, spare) = ck.rng;
    obj(vec![
        ("fingerprint", ck.fingerprint.clone()),
        ("step", ju64(ck.step)),
        ("params", jf32_tensors(&ck.params)),
        ("opt_t", ju64(ck.opt_t)),
        ("opt_m", jf32_tensors(&ck.opt_m)),
        ("opt_v", jf32_tensors(&ck.opt_v)),
        (
            "rng",
            obj(vec![
                ("state", ju64(state)),
                ("inc", ju64(inc)),
                ("gauss_spare", spare.map_or(Json::Null, Json::Num)),
            ]),
        ),
        (
            "screen",
            match &ck.screen {
                None => Json::Null,
                Some(s) => obj(vec![
                    ("w", jf32_arr(&s.w)),
                    ("b", Json::Num(s.b as f64)),
                    ("seen", ju64(s.seen)),
                ]),
            },
        ),
        (
            "stream",
            match &ck.stream {
                None => Json::Null,
                Some(s) => obj(vec![
                    ("lam", Json::Num(s.lam)),
                    ("mad", Json::Num(s.mad)),
                    ("count", ju64(s.count)),
                ]),
            },
        ),
        ("ledger", ledger_to_json(&ck.ledger)),
        ("curve", curve_to_json(&ck.curve)),
        ("extra", ck.extra.clone()),
    ])
}

fn from_json(j: &Json) -> Result<TrainCheckpoint> {
    let rng = field(j, "rng")?;
    let spare = match field(rng, "gauss_spare")? {
        Json::Null => None,
        v => Some(pf64(v, "rng.gauss_spare")?),
    };
    let screen = match field(j, "screen")? {
        Json::Null => None,
        s => Some(ScreenState {
            w: pf32_arr(field(s, "w")?, "screen.w")?,
            b: pf64(field(s, "b")?, "screen.b")? as f32,
            seen: pu64(field(s, "seen")?, "screen.seen")?,
        }),
    };
    let stream = match field(j, "stream")? {
        Json::Null => None,
        s => Some(StreamState {
            lam: pf64(field(s, "lam")?, "stream.lam")?,
            mad: pf64(field(s, "mad")?, "stream.mad")?,
            count: pu64(field(s, "count")?, "stream.count")?,
        }),
    };
    Ok(TrainCheckpoint {
        fingerprint: field(j, "fingerprint")?.clone(),
        step: pu64(field(j, "step")?, "step")?,
        params: pf32_tensors(field(j, "params")?, "params")?,
        opt_t: pu64(field(j, "opt_t")?, "opt_t")?,
        opt_m: pf32_tensors(field(j, "opt_m")?, "opt_m")?,
        opt_v: pf32_tensors(field(j, "opt_v")?, "opt_v")?,
        rng: (
            pu64(field(rng, "state")?, "rng.state")?,
            pu64(field(rng, "inc")?, "rng.inc")?,
            spare,
        ),
        screen,
        stream,
        ledger: ledger_from_json(field(j, "ledger")?)?,
        curve: curve_from_json(field(j, "curve")?)?,
        extra: field(j, "extra")?.clone(),
    })
}

/// Serialize with the versioned, checksummed header.
pub fn encode(ck: &TrainCheckpoint) -> String {
    let body = to_json(ck).dump();
    format!("{MAGIC} v{VERSION} len={} fnv={:016x}\n{body}", body.len(), fnv1a64(body.as_bytes()))
}

/// Parse and validate a serialized checkpoint (header, length, checksum,
/// then the body). Every failure mode is an error, never a panic.
pub fn decode(text: &str) -> Result<TrainCheckpoint> {
    let Some((header, body)) = text.split_once('\n') else {
        bail!("truncated checkpoint: no header line");
    };
    let toks: Vec<&str> = header.split_whitespace().collect();
    if toks.first().copied() != Some(MAGIC) {
        bail!("not a checkpoint file: header starts with {:?}", toks.first().unwrap_or(&""));
    }
    if toks.len() != 4 {
        bail!("malformed checkpoint header: {header:?}");
    }
    let ver: u32 = toks[1]
        .strip_prefix('v')
        .and_then(|v| v.parse().ok())
        .with_context(|| format!("malformed checkpoint version token {:?}", toks[1]))?;
    if ver != VERSION {
        bail!("unsupported checkpoint version v{ver} (this build reads v{VERSION})");
    }
    let len: usize = toks[2]
        .strip_prefix("len=")
        .and_then(|v| v.parse().ok())
        .with_context(|| format!("malformed checkpoint length token {:?}", toks[2]))?;
    let fnv: u64 = toks[3]
        .strip_prefix("fnv=")
        .and_then(|v| u64::from_str_radix(v, 16).ok())
        .with_context(|| format!("malformed checkpoint checksum token {:?}", toks[3]))?;
    if body.len() != len {
        bail!("truncated checkpoint: body is {} bytes, header promises {len}", body.len());
    }
    if fnv1a64(body.as_bytes()) != fnv {
        bail!("corrupt checkpoint: FNV-1a checksum mismatch");
    }
    let json = Json::parse(body).map_err(|e| anyhow::anyhow!("corrupt checkpoint body: {e}"))?;
    from_json(&json)
}

// ---- filesystem ----

/// The staging file a save writes before renaming over `path`.
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut p = path.as_os_str().to_owned();
    p.push(".tmp");
    PathBuf::from(p)
}

/// Atomic write: stage in the same directory, fsync, rename. A failure at
/// any point leaves the previous file at `path` untouched.
pub fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating checkpoint dir {}", parent.display()))?;
        }
    }
    let tmp = tmp_path(path);
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(contents.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
}

impl TrainCheckpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        write_atomic(path, &encode(self))
    }

    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        decode(&text).with_context(|| format!("loading checkpoint {}", path.display()))
    }
}

/// Resume-time config validation: every key present in either fingerprint
/// must match bit-for-bit (values compared by their canonical json dump,
/// so floats compare exactly). The fingerprint deliberately excludes
/// knobs outside the trajectory contract — step budget, worker count,
/// checkpoint settings — so run extension and cross-worker resume pass.
/// Every differing key is reported, not just the first: some knobs are
/// recorded both standalone and inside a composite Debug string (e.g. the
/// gate priority inside 'method'), and naming each key keeps the specific
/// mismatch visible.
pub fn validate_fingerprint(stored: &Json, current: &Json) -> Result<()> {
    let (Some(s), Some(c)) = (stored.as_obj(), current.as_obj()) else {
        bail!("config fingerprint must be an object");
    };
    let mut diffs = Vec::new();
    for k in s.keys().chain(c.keys().filter(|k| !s.contains_key(*k))) {
        let sv = s.get(k).map(Json::dump);
        let cv = c.get(k).map(Json::dump);
        if sv != cv {
            diffs.push(format!(
                "'{k}': checkpoint has {}, this run has {}",
                sv.map_or("<absent>".into(), |v| v.trim().to_string()),
                cv.map_or("<absent>".into(), |v| v.trim().to_string()),
            ));
        }
    }
    if !diffs.is_empty() {
        bail!("checkpoint config mismatch at {}", diffs.join("; at "));
    }
    Ok(())
}

// ---- capture / restore against the live training state ----

/// Snapshot the full training state between optimizer steps. `step` is
/// the number of completed steps; everything else is read through the
/// state owners' accessors.
#[allow(clippy::too_many_arguments)]
pub fn capture(
    fingerprint: Json,
    step: u64,
    params: &ParamStore,
    opt: &Adam,
    rng: &Pcg32,
    gl: &GatedLoop<'_>,
    acct: &ShardedLedger,
    curve: &[EvalPoint],
    extra: Json,
) -> TrainCheckpoint {
    let (m, v) = opt.moments();
    let screen = gl.screen_stage().map(|st| {
        let (w, b) = st.draft().weights();
        ScreenState { w: w.to_vec(), b, seen: st.draft().seen() }
    });
    let stream = gl.gate_stage().stream().map(|tr| {
        let (lam, mad, count) = tr.snapshot();
        StreamState { lam, mad, count: count as u64 }
    });
    TrainCheckpoint {
        fingerprint,
        step,
        params: (0..params.n_tensors()).map(|i| params.tensor(i).to_vec()).collect(),
        opt_t: opt.t(),
        opt_m: m.to_vec(),
        opt_v: v.to_vec(),
        rng: rng.snapshot(),
        screen,
        stream,
        ledger: acct.total(),
        curve: curve.to_vec(),
        extra,
    }
}

/// Restore a loaded checkpoint into freshly-constructed training state.
/// The ledger totals land in shard 0 of the *current* pool's sharded
/// ledger — totals are what the contract covers, and this is what makes
/// cross-worker resume work. Structural mismatches (tensor shapes, draft
/// dim, screen/stream presence) are clean errors.
pub fn restore(
    ck: &TrainCheckpoint,
    params: &mut ParamStore,
    opt: &mut Adam,
    rng: &mut Pcg32,
    gl: &mut GatedLoop<'_>,
    acct: &mut ShardedLedger,
    curve: &mut Vec<EvalPoint>,
) -> Result<()> {
    params.restore_tensors(&ck.params)?;
    opt.restore(ck.opt_t, ck.opt_m.clone(), ck.opt_v.clone())?;
    *rng = Pcg32::from_snapshot(ck.rng.0, ck.rng.1, ck.rng.2);
    match (gl.screen_stage_mut(), &ck.screen) {
        (Some(stage), Some(s)) => stage.draft_mut().restore(&s.w, s.b, s.seen)?,
        (None, None) => {}
        (Some(_), None) => bail!("this run screens but the checkpoint has no draft state"),
        (None, Some(_)) => bail!("checkpoint has draft state but this run does not screen"),
    }
    match (gl.gate_stage_mut().stream_mut(), &ck.stream) {
        (Some(tracker), Some(s)) => tracker.restore(s.lam, s.mad, s.count as usize),
        (None, None) => {}
        (Some(_), None) => {
            bail!("this run streams the gate price but the checkpoint has no tracker state")
        }
        (None, Some(_)) => {
            bail!("checkpoint has a gate price tracker but this run does not stream")
        }
    }
    *acct = ShardedLedger::new(acct.n_shards());
    acct.shard_mut(0).merge(&ck.ledger);
    *curve = ck.curve.clone();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("kondo_ckpt_test_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_ckpt() -> TrainCheckpoint {
        let mut ledger = Ledger::new();
        ledger.record_forward(64);
        ledger.record_backward(8, 5);
        ledger.record_screen(64);
        ledger.record_forward_skipped(32);
        ledger.record_quarantined(3);
        ledger.record_quarantined_batch(16);
        ledger.record_stale(16, 2);
        ledger.record_shed(8);
        ledger.record_actor_crash();
        ledger.record_actor_restart();
        ledger.record_actor_timeout();
        ledger.record_wire_corrupt_frame();
        ledger.record_wire_reconnect();
        ledger.record_handshake_rejects(2);
        TrainCheckpoint {
            fingerprint: obj(vec![
                ("trainer", Json::Str("unit".into())),
                ("seed", ju64(7)),
                ("lr", Json::Num(1e-3)),
            ]),
            step: 12,
            // deliberately awkward values: ±0.0, inf, subnormals, NaN-free
            params: vec![vec![1.5, -0.0, f32::INFINITY, 1.0e-40], vec![0.25]],
            opt_t: 12,
            opt_m: vec![vec![0.1, -0.2, 0.3, 0.4], vec![-1.0e-30]],
            opt_v: vec![vec![0.01, 0.02, 0.03, 0.04], vec![5.0e20]],
            rng: (u64::MAX - 3, 0xda3e39cb94b95bdb, Some(-1.25e-7)),
            screen: Some(ScreenState { w: vec![0.5, -0.5, 0.125], b: -0.75, seen: 640 }),
            stream: Some(StreamState { lam: 0.031415, mad: 1.0e-9, count: u64::from(u32::MAX) }),
            ledger,
            curve: vec![EvalPoint {
                step: 7,
                forward_samples: 512,
                screen_samples: 512,
                forward_skipped: 200,
                backward_kept: 30,
                backward_executed: 32,
                metric: 0.11,
                metric2: f64::NAN,
            }],
            extra: obj(vec![("reward_sum", Json::Num(-3.5))]),
        }
    }

    fn assert_ckpt_eq(a: &TrainCheckpoint, b: &TrainCheckpoint) {
        assert_eq!(a.fingerprint.dump(), b.fingerprint.dump());
        assert_eq!(a.step, b.step);
        for (x, y) in a.params.iter().flatten().zip(b.params.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.opt_t, b.opt_t);
        assert_eq!(a.opt_m, b.opt_m);
        assert_eq!(a.opt_v, b.opt_v);
        assert_eq!(a.rng.0, b.rng.0);
        assert_eq!(a.rng.1, b.rng.1);
        assert_eq!(a.rng.2.map(f64::to_bits), b.rng.2.map(f64::to_bits));
        assert_eq!(a.screen, b.screen);
        assert_eq!(a.stream, b.stream);
        assert_eq!(ledger_to_json(&a.ledger).dump(), ledger_to_json(&b.ledger).dump());
        assert_eq!(a.curve.len(), b.curve.len());
        for (p, q) in a.curve.iter().zip(&b.curve) {
            assert_eq!(p.step, q.step);
            assert_eq!(p.forward_samples, q.forward_samples);
            assert_eq!(p.metric.to_bits(), q.metric.to_bits());
            assert!(p.metric2.is_nan() == q.metric2.is_nan());
        }
        assert_eq!(a.extra.dump(), b.extra.dump());
    }

    #[test]
    fn encode_decode_roundtrip_bit_exact() {
        let ck = sample_ckpt();
        let text = encode(&ck);
        let back = decode(&text).unwrap();
        assert_ckpt_eq(&ck, &back);
        // canonical layout: re-encoding the decoded state is byte-identical
        assert_eq!(text, encode(&back));
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = test_dir("roundtrip");
        let path = dir.join("ck.ckpt");
        let ck = sample_ckpt();
        ck.save(&path).unwrap();
        let back = TrainCheckpoint::load(&path).unwrap();
        assert_ckpt_eq(&ck, &back);
        // the staging file does not linger after a successful save
        assert!(!tmp_path(&path).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_creates_parent_dirs() {
        let dir = test_dir("mkdirs");
        let path = dir.join("a/b/c/ck.ckpt");
        sample_ckpt().save(&path).unwrap();
        assert!(TrainCheckpoint::load(&path).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_file_is_clean_error() {
        let full = encode(&sample_ckpt());
        // cut at several depths: inside the body, inside the header, empty
        for cut in [full.len() - 1, full.len() / 2, 40, 10, 0] {
            let err = decode(&full[..cut]).unwrap_err().to_string();
            assert!(
                err.contains("truncated") || err.contains("malformed") || err.contains("not a"),
                "cut at {cut}: unexpected error {err:?}"
            );
        }
    }

    #[test]
    fn flipped_byte_is_clean_error() {
        let full = encode(&sample_ckpt());
        let header_end = full.find('\n').unwrap();
        // flip one byte in the body (past the header)
        let mut bytes = full.clone().into_bytes();
        let i = header_end + 1 + (bytes.len() - header_end) / 2;
        bytes[i] = bytes[i].wrapping_add(1);
        let err = decode(std::str::from_utf8(&bytes).unwrap()).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error {err:?}");
    }

    #[test]
    fn wrong_version_and_magic_are_clean_errors() {
        let full = encode(&sample_ckpt());
        let bumped = full.replacen(&format!("v{VERSION} "), &format!("v{} ", VERSION + 1), 1);
        let err = decode(&bumped).unwrap_err().to_string();
        assert!(err.contains(&format!("version v{}", VERSION + 1)), "unexpected error {err:?}");
        // the previous format version is rejected too: the v2 ledger codec
        // would otherwise resume a v1 file with silently-zeroed counters
        let old = full.replacen(&format!("v{VERSION} "), "v1 ", 1);
        let err = decode(&old).unwrap_err().to_string();
        assert!(err.contains("version v1"), "unexpected error {err:?}");
        let err = decode(&full.replacen(MAGIC, "OTHER-FMT", 1)).unwrap_err().to_string();
        assert!(err.contains("not a checkpoint"), "unexpected error {err:?}");
        assert!(decode("garbage with no newline").is_err());
        assert!(decode("").is_err());
    }

    #[test]
    fn interrupted_write_leaves_previous_checkpoint_intact() {
        let dir = test_dir("atomic");
        let path = dir.join("ck.ckpt");
        let v1 = sample_ckpt();
        v1.save(&path).unwrap();
        // simulate a crash mid-write: a partial staging file appears, the
        // rename never happens
        fs::write(tmp_path(&path), &encode(&v1)[..50]).unwrap();
        let back = TrainCheckpoint::load(&path).unwrap();
        assert_ckpt_eq(&v1, &back);
        // the next save replaces the stale staging file and the target
        let mut v2 = sample_ckpt();
        v2.step = 99;
        v2.save(&path).unwrap();
        assert_eq!(TrainCheckpoint::load(&path).unwrap().step, 99);
        assert!(!tmp_path(&path).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_mismatch_names_the_key() {
        let a = obj(vec![("seed", ju64(7)), ("rho", Json::Num(0.25))]);
        let b = obj(vec![("seed", ju64(7)), ("rho", Json::Num(0.5))]);
        let err = validate_fingerprint(&a, &b).unwrap_err().to_string();
        assert!(err.contains("'rho'"), "unexpected error {err:?}");
        // a key absent on one side is also a mismatch
        let c = obj(vec![("seed", ju64(7))]);
        assert!(validate_fingerprint(&a, &c).is_err());
        assert!(validate_fingerprint(&c, &a).is_err());
        // identity passes, including exact float comparison
        assert!(validate_fingerprint(&a, &a.clone()).is_ok());
    }

    #[test]
    fn u64_codec_covers_the_full_range() {
        for x in [0u64, 1, (1 << 53) - 1, 1 << 53, (1 << 53) + 1, u64::MAX] {
            assert_eq!(pu64(&ju64(x), "t").unwrap(), x);
        }
        assert!(pu64(&Json::Num(5.0), "t").is_err(), "raw numbers are rejected");
        assert!(pu64(&Json::Str("-1".into()), "t").is_err());
        assert!(pu64(&Json::Str("huge999999999999999999999".into()), "t").is_err());
    }

    #[test]
    fn corrupt_body_shapes_are_errors_not_panics() {
        // structurally valid header+json, semantically wrong bodies
        let wrap = |body: &str| format!("{MAGIC} v{VERSION} len={} fnv={:016x}\n{body}", body.len(), fnv1a64(body.as_bytes()));
        for body in [
            "null", "5", "[]", "{}", r#"{"step": "3"}"#,
        ] {
            assert!(decode(&wrap(body)).is_err(), "body {body:?} must not decode");
        }
    }
}
