//! Optimizers over the host-side parameter store (Adam is the paper's
//! optimizer; SGD kept for ablations). Gradients arrive as the backward
//! artifact's output tensors, accumulated across capacity buckets.

use crate::model::ParamStore;
use anyhow::{bail, Result};

pub trait Optimizer {
    /// Apply one update step given per-tensor gradients.
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<f32>]);
    fn lr(&self) -> f64;
    fn set_lr(&mut self, lr: f64);
}

/// Adam (Kingma & Ba) with bias correction; defaults match the paper's
/// experiments (betas 0.9/0.999, eps 1e-8).
pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f64, params: &ParamStore) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: params.zeros_like(),
            v: params.zeros_like(),
        }
    }

    /// Bias-correction step counter (checkpointing).
    pub fn t(&self) -> u64 {
        self.t
    }

    /// First/second-moment accumulators (checkpointing).
    pub fn moments(&self) -> (&[Vec<f32>], &[Vec<f32>]) {
        (&self.m, &self.v)
    }

    /// Restore optimizer state from a checkpoint. Shapes must match the
    /// `ParamStore` this optimizer was built for; mismatches are errors,
    /// never panics (corrupt checkpoints must fail cleanly).
    pub fn restore(&mut self, t: u64, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) -> Result<()> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            bail!(
                "optimizer moment arity mismatch: checkpoint has {}/{} tensors, model has {}",
                m.len(),
                v.len(),
                self.m.len()
            );
        }
        for i in 0..m.len() {
            if m[i].len() != self.m[i].len() || v[i].len() != self.v[i].len() {
                bail!(
                    "optimizer moment {} length mismatch: checkpoint {}/{}, model {}",
                    i,
                    m[i].len(),
                    v[i].len(),
                    self.m[i].len()
                );
            }
        }
        self.t = t;
        self.m = m;
        self.v = v;
        Ok(())
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), params.n_tensors());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..grads.len() {
            let g = &grads[i];
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            let p = params.tensor_mut(i);
            assert_eq!(g.len(), p.len());
            for j in 0..g.len() {
                let gj = g[j] as f64;
                let mj = self.beta1 * m[j] as f64 + (1.0 - self.beta1) * gj;
                let vj = self.beta2 * v[j] as f64 + (1.0 - self.beta2) * gj * gj;
                m[j] = mj as f32;
                v[j] = vj as f32;
                let mhat = mj / b1t;
                let vhat = vj / b2t;
                p[j] -= (self.lr * mhat / (vhat.sqrt() + self.eps)) as f32;
            }
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

/// Plain SGD (ablation baseline).
pub struct Sgd {
    lr: f64,
}

impl Sgd {
    pub fn new(lr: f64) -> Sgd {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<f32>]) {
        assert_eq!(grads.len(), params.n_tensors());
        for i in 0..grads.len() {
            let g = &grads[i];
            let p = params.tensor_mut(i);
            for j in 0..g.len() {
                p[j] -= (self.lr * g[j] as f64) as f32;
            }
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{InitKind, InitRule};

    fn quad_params() -> ParamStore {
        let rules = vec![InitRule {
            name: "x".into(),
            shape: vec![2],
            kind: InitKind::Ones,
        }];
        ParamStore::init(&rules, 0)
    }

    fn quad_grad(p: &ParamStore) -> Vec<Vec<f32>> {
        // f(x) = 0.5 * ||x - [3, -2]||^2 ; grad = x - target
        vec![vec![p.tensor(0)[0] - 3.0, p.tensor(0)[1] + 2.0]]
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut p = quad_params();
        let mut opt = Adam::new(0.1, &p);
        for _ in 0..500 {
            let g = quad_grad(&p);
            opt.step(&mut p, &g);
        }
        assert!((p.tensor(0)[0] - 3.0).abs() < 1e-2);
        assert!((p.tensor(0)[1] + 2.0).abs() < 1e-2);
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let mut p = quad_params();
        let mut opt = Sgd::new(0.3);
        for _ in 0..100 {
            let g = quad_grad(&p);
            opt.step(&mut p, &g);
        }
        assert!((p.tensor(0)[0] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first update| == lr regardless of grad scale
        let mut p = quad_params();
        let mut opt = Adam::new(0.05, &p);
        let before = p.tensor(0)[0];
        opt.step(&mut p, &[vec![1234.0, -0.001]]);
        let d0 = (p.tensor(0)[0] - before).abs();
        assert!((d0 - 0.05).abs() < 1e-3, "step {d0}");
    }

    #[test]
    fn zero_grad_is_noop_for_sgd() {
        let mut p = quad_params();
        let mut opt = Sgd::new(0.3);
        let before = p.tensor(0).to_vec();
        opt.step(&mut p, &[vec![0.0, 0.0]]);
        assert_eq!(before, p.tensor(0));
    }
}
