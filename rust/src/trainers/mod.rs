//! Training loops (the L3 scheduler): forward artifact -> delight -> Kondo
//! gate -> bucketed backward -> optimizer, with the compute ledger and
//! noise-injection hooks every experiment driver needs.

pub mod mnist;
pub mod reversal;

pub use mnist::{train_mnist, MnistTrainerCfg, MnistRunResult};
pub use reversal::{train_reversal, ReversalTrainerCfg, ReversalRunResult};

/// One point of a learning curve, indexed by both step and compute.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: usize,
    pub forward_samples: u64,
    pub backward_kept: u64,
    pub backward_executed: u64,
    /// task metric: classification error (MNIST) or mean reward (reversal)
    pub metric: f64,
    /// secondary metric: test error (MNIST) / unused (reversal)
    pub metric2: f64,
}
