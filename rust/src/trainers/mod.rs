//! Training loops (the L3 scheduler): screen -> forward artifact ->
//! delight -> Kondo gate -> bucketed backward -> optimizer, with the
//! compute ledger and noise-injection hooks every experiment driver needs.
//!
//! `GatedLoop` is the shared parallel substrate both trainers (and future
//! envs) run on. It owns the **persistent** worker pool (threads spawned
//! once in `new`, alive for the whole training run, joined when the loop
//! drops) and composes the four explicit stages of the L4 speculative
//! screening pipeline (`coordinator::pipeline`, DESIGN.md §8):
//!
//! 1. [`ScreenStage`] -- tier 1 of the two-tier gate: a warm draft model
//!    pre-gates the batch at `rho_screen` with one dot product per sample;
//!    cold batches fall back to the full-forward path.
//! 2. [`ForwardStage`] -- plans how the survivor set executes: contiguous
//!    shards for the unscreened batch, survivors packed densely through
//!    the forward capacity ladder when screened.
//! 3. [`GateStage`] -- exact delight on the survivors, one batch-global
//!    Kondo price (including the streaming-lambda pricing ablation).
//! 4. [`BackwardStage`] -- bucketed backward chunks across the pool,
//!    gradients merged in chunk order, one optimizer step.
//!
//! The hot path is zero-copy *and* allocation-free in the steady state:
//! trainers marshal the parameter tensors once per step into a reusable
//! buffer (`ParamStore::marshal_into`, which also rebuilds each weight
//! matrix's GEMM pack exactly once per step — the pack cache of
//! DESIGN.md §9) and the sharded phases share that buffer across every
//! chunk/shard by reference (`Engine::execute_refs`) instead of cloning
//! the full parameter list per call; the gradient accumulator is
//! preallocated once per run, and every per-call tensor buffer (gathered
//! chunk inputs, kernel outputs, merged rows) cycles through the tensor
//! arena (`runtime::tensor`), recycled by its consumer instead of
//! reallocated.
//!
//! Batch-global work -- the screen's quantile threshold and the Kondo
//! gate's quantile price, both over merged score vectors -- stays on the
//! caller's thread, which is what keeps `workers = N` trajectories
//! bit-identical to `workers = 1` (the determinism contract, DESIGN.md
//! §"L3 parallelism" and §8).

pub mod mnist;
pub mod reversal;

pub use mnist::{train_mnist, MnistRunResult, MnistTrainerCfg};
pub use reversal::{train_reversal, ReversalRunResult, ReversalTrainerCfg};

use anyhow::Result;

use crate::algo::{BatchSignals, Method, WeightDecision};
use crate::coordinator::batcher::BucketSet;
use crate::coordinator::pipeline::{
    BackwardStage, ForwardPlan, ForwardStage, GateStage, ScreenCfg, ScreenStage, ScreenVerdict,
};
use crate::coordinator::pool::{non_empty_shards, split_shards, Shard, WorkerPool};
use crate::coordinator::{PackedChunk, ShardedLedger};
use crate::model::ParamStore;
use crate::optim::Optimizer;
use crate::runtime::{tensor, Engine, HostTensor};
use crate::utils::rng::Pcg32;

/// Fingerprint value of a method's gate priority: `Priority::name()` for
/// gated methods, `"none"` otherwise. Both trainer fingerprints record it
/// as an explicit key -- the priority is a trajectory-contract knob, so a
/// wrong-priority resume must reject with an error that names 'priority'
/// rather than an opaque method-Debug diff.
pub(crate) fn priority_key(method: &Method) -> String {
    method.priority().map(|p| p.name()).unwrap_or_else(|| "none".into())
}

/// One point of a learning curve, indexed by both step and compute.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: usize,
    pub forward_samples: u64,
    /// tier-1 screen dot products so far (0 on unscreened runs)
    pub screen_samples: u64,
    /// forwards the screen spared so far (0 on unscreened runs)
    pub forward_skipped: u64,
    pub backward_kept: u64,
    pub backward_executed: u64,
    /// task metric: classification error (MNIST) or mean reward (reversal)
    pub metric: f64,
    /// secondary metric: test error (MNIST) / unused (reversal)
    pub metric2: f64,
}

/// The shared screen->forward->gate->backward substrate.
pub struct GatedLoop<'e> {
    eng: &'e Engine,
    pool: WorkerPool,
    screen: Option<ScreenStage>,
    fwd: ForwardStage,
    gate: GateStage,
    bwd: BackwardStage,
}

impl<'e> GatedLoop<'e> {
    /// Errors are real config/resource failures surfaced before any step
    /// runs: a bad bucket set, or worker-thread spawn failure
    /// (`WorkerPool::new` is fallible -- disable-don't-panic).
    pub fn new(eng: &'e Engine, workers: usize, bwd_caps: Vec<usize>) -> Result<GatedLoop<'e>> {
        Ok(GatedLoop {
            eng,
            pool: WorkerPool::new(workers)?,
            screen: None,
            fwd: ForwardStage::new(None),
            gate: GateStage::passthrough(),
            bwd: BackwardStage::new(bwd_caps)?,
        })
    }

    /// Attach the forward capacity ladder (enables both the unscreened
    /// shard path and the screened packed path).
    pub fn with_fwd_caps(mut self, caps: Option<BucketSet>) -> GatedLoop<'e> {
        self.fwd = ForwardStage::new(caps);
        self
    }

    /// Attach a tier-1 speculative screen over `dim`-wide draft features,
    /// with `unit` samples per batch (the warm-up denominator). Inactive
    /// configurations (`rho_screen = 1`) attach nothing.
    pub fn with_screen(mut self, dim: usize, unit: usize, cfg: ScreenCfg) -> GatedLoop<'e> {
        if cfg.active() && dim > 0 {
            // the screen inherits the engine's forward tier: under
            // f32-fast the draft's scoring dots run in the same non-golden
            // f32 tier as the forwards they stand in for (DESIGN.md §13)
            self.screen =
                Some(ScreenStage::new(dim, unit, cfg).with_f32_fast(self.eng.f32_fast()));
        }
        self
    }

    /// Configure the gate stage (streaming-lambda pricing ablation).
    pub fn with_gate(mut self, method: &Method, streaming: bool, min_count: usize) -> GatedLoop<'e> {
        self.gate = GateStage::new(method, streaming, min_count);
        self
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn buckets(&self) -> &BucketSet {
        self.bwd.buckets()
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    pub fn screen_stage(&self) -> Option<&ScreenStage> {
        self.screen.as_ref()
    }

    /// Mutable screen-stage access for checkpoint restore.
    pub fn screen_stage_mut(&mut self) -> Option<&mut ScreenStage> {
        self.screen.as_mut()
    }

    pub fn gate_stage(&self) -> &GateStage {
        &self.gate
    }

    /// Mutable gate-stage access for checkpoint restore.
    pub fn gate_stage_mut(&mut self) -> &mut GateStage {
        &mut self.gate
    }

    /// Contiguous shards of an `n`-row batch for this pool. This is the
    /// dispatch layer: empty shards (`split_shards(0, w)` yields one) are
    /// skipped (`pool::non_empty_shards`) so they are never handed to
    /// workers as tasks.
    pub fn shards(&self, n: usize) -> Vec<Shard> {
        non_empty_shards(n, self.pool.workers())
    }

    /// Stage 1: tier-1 verdict for one batch of `n` draft-feature rows
    /// (`feats` is `[n, dim]`). Returns `Full` when no screen is attached,
    /// the draft is cold, or the score distribution is degenerate. See
    /// `ScreenStage::screen` for the `u_hint` semantics.
    pub fn screen(
        &self,
        feats: &[f32],
        n: usize,
        u_hint: Option<&[f64]>,
        acct: &mut ShardedLedger,
    ) -> ScreenVerdict {
        match &self.screen {
            None => ScreenVerdict::Full,
            Some(stage) => stage.screen(&self.pool, &self.shards(n), feats, n, u_hint, acct),
        }
    }

    /// Train the draft online on the exact surprisals the surviving
    /// forwards produced (no-op when no screen is attached).
    pub fn observe_screen(&mut self, feats: &[f32], rows: &[usize], ell: &[f64]) {
        if let Some(stage) = self.screen.as_mut() {
            stage.observe(feats, rows, ell);
        }
    }

    /// Stage 2: execute the forward over `survivors` (original batch
    /// indices, ascending) of a `batch_n`-row batch, returning the f32
    /// output rows **in survivor order**. The returned buffer is arena-
    /// backed; the trainer recycles it at the end of the step
    /// (`tensor::recycle_f32`) so steady-state steps allocate nothing.
    ///
    /// The plan comes from `ForwardStage::plan`: the unscreened batch
    /// keeps the contiguous-shard path (or one `full_name` call), while a
    /// screened survivor set is packed densely through the forward
    /// capacity ladder -- skipped forwards are recorded in
    /// `forward_skipped` and never executed. A screened batch *without* a
    /// capacity ladder falls back to the full-batch call and gathers the
    /// survivor rows from its output (nothing skipped, nothing recorded).
    ///
    /// `param_inputs` is the step's marshalled parameter list, shared by
    /// reference across every call; `build(idx, cap)` returns only the
    /// non-parameter inputs for the rows `idx` padded to `cap`.
    ///
    /// Forward work is recorded into `acct` per logical shard/chunk, with
    /// padded capacity slots counted in `forward_executed` (mirroring the
    /// backward executed-slot convention); `forward_samples`,
    /// `screen_samples`, and `forward_skipped` stay worker-invariant.
    ///
    /// Bit-equality between the packed, sharded, and full paths is
    /// guaranteed by the backend's row-independence contract
    /// (runtime/native.rs).
    #[allow(clippy::too_many_arguments)]
    pub fn forward<F, N>(
        &self,
        param_inputs: &[HostTensor],
        full_name: &str,
        shard_name: N,
        survivors: &[usize],
        batch_n: usize,
        out_width: usize,
        acct: &mut ShardedLedger,
        build: F,
    ) -> Result<Vec<f32>>
    where
        F: Fn(&[usize], usize) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        let eng = self.eng;
        let k = survivors.len();
        match self.fwd.plan(survivors, batch_n, self.pool.workers()) {
            ForwardPlan::FullBatch => {
                // one full-batch call: no padding, and exactly one
                // recorded call, attributed to shard 0 (that is where the
                // work really ran)
                let all: Vec<usize> = (0..batch_n).collect();
                let extras = build(&all, batch_n);
                let mut inputs: Vec<&HostTensor> =
                    Vec::with_capacity(param_inputs.len() + extras.len());
                inputs.extend(param_inputs.iter());
                inputs.extend(extras.iter());
                let mut out = eng.execute_refs(full_name, &inputs)?;
                acct.shard_mut(0).record_forward(batch_n);
                for t in extras {
                    tensor::recycle_tensor(t);
                }
                let rows = out.remove(0).into_f32()?;
                if k == batch_n {
                    return Ok(rows);
                }
                // screened fallback without a capacity ladder: the full
                // forward ran, so nothing was skipped -- gather survivors
                let mut picked = tensor::take_f32_empty(k * out_width);
                for &i in survivors {
                    picked.extend_from_slice(&rows[i * out_width..(i + 1) * out_width]);
                }
                tensor::recycle_f32(rows);
                Ok(picked)
            }
            ForwardPlan::Sharded(pairs) => {
                // tasks borrow the plan: no per-step copies on the hot path
                let tasks: Vec<&(Shard, usize)> = pairs.iter().collect();
                let parts: Vec<Result<Vec<f32>>> = self.pool.run(tasks, |_, &(shard, cap)| {
                    let idx: Vec<usize> = shard.range().collect();
                    let extras = build(&idx, cap);
                    let mut inputs: Vec<&HostTensor> =
                        Vec::with_capacity(param_inputs.len() + extras.len());
                    inputs.extend(param_inputs.iter());
                    inputs.extend(extras.iter());
                    let mut out = eng.execute_refs(&shard_name(cap), &inputs)?;
                    // gathered inputs go straight back to this worker's arena
                    for t in extras {
                        tensor::recycle_tensor(t);
                    }
                    let mut rows_out = out.remove(0).into_f32()?;
                    rows_out.truncate(shard.len() * out_width);
                    Ok(rows_out)
                });
                for (shard, cap) in &pairs {
                    acct.shard_mut(shard.index).record_forward_padded(shard.len(), *cap);
                }
                let mut merged = tensor::take_f32_empty(batch_n * out_width);
                for part in parts {
                    let part = part?;
                    merged.extend_from_slice(&part);
                    tensor::recycle_f32(part);
                }
                Ok(merged)
            }
            ForwardPlan::Packed(chunks) => {
                // tasks borrow the plan: survivor index vectors are not
                // copied per step (the backward path does the same)
                let tasks: Vec<&PackedChunk> = chunks.iter().collect();
                let parts: Vec<Result<Vec<f32>>> = self.pool.run(tasks, |_, chunk| {
                    let extras = build(&chunk.idx, chunk.cap);
                    let mut inputs: Vec<&HostTensor> =
                        Vec::with_capacity(param_inputs.len() + extras.len());
                    inputs.extend(param_inputs.iter());
                    inputs.extend(extras.iter());
                    let mut out = eng.execute_refs(&shard_name(chunk.cap), &inputs)?;
                    // gathered inputs go straight back to this worker's arena
                    for t in extras {
                        tensor::recycle_tensor(t);
                    }
                    let mut rows_out = out.remove(0).into_f32()?;
                    rows_out.truncate(chunk.idx.len() * out_width);
                    Ok(rows_out)
                });
                for (ci, chunk) in chunks.iter().enumerate() {
                    acct.shard_mut(acct.chunk_owner(ci))
                        .record_forward_padded(chunk.idx.len(), chunk.cap);
                }
                // the screen's win, made real: these rows never ran
                acct.shard_mut(0).record_forward_skipped(batch_n - k);
                let mut merged = tensor::take_f32_empty(k * out_width);
                for part in parts {
                    let part = part?;
                    merged.extend_from_slice(&part);
                    tensor::recycle_f32(part);
                }
                Ok(merged)
            }
        }
    }

    /// Stage 3: the Kondo decision over the survivors' exact signals.
    /// Indices in the returned decision are relative to the signal vectors
    /// (survivor slots); callers map them back to batch indices.
    pub fn decide(
        &mut self,
        method: &Method,
        signals: &BatchSignals,
        rng: &mut Pcg32,
    ) -> WeightDecision {
        self.gate.decide(method, signals, rng)
    }

    /// Stage 4: execute packed backward chunks across the pool and apply
    /// one optimizer step (see `BackwardStage::run`).
    #[allow(clippy::too_many_arguments)]
    pub fn backward<F, N>(
        &mut self,
        params: &mut ParamStore,
        param_inputs: &[HostTensor],
        opt: &mut dyn Optimizer,
        chunks: &[PackedChunk],
        artifact: N,
        extra_inputs: F,
        denom: f32,
    ) -> Result<()>
    where
        F: Fn(&PackedChunk) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        self.bwd.run(
            self.eng,
            &self.pool,
            params,
            param_inputs,
            opt,
            chunks,
            artifact,
            extra_inputs,
            denom,
        )
    }

    /// Record one batch's backward chunks into a shard-aware ledger
    /// (round-robin chunk ownership; see `ShardedLedger::chunk_owner`).
    pub fn record_backward_chunks(
        &self,
        acct: &mut ShardedLedger,
        chunks: &[PackedChunk],
        slots_per_sample: usize,
        kept_of: impl Fn(&PackedChunk) -> usize,
    ) {
        for (ci, chunk) in chunks.iter().enumerate() {
            let owner = acct.backward_owner(ci);
            acct.shard_mut(owner)
                .record_backward(chunk.cap * slots_per_sample, kept_of(chunk));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_dispatch_skips_empty_batches() {
        // regression: split_shards(0, w) returns one empty shard (the
        // split covers the batch); the dispatch layer must drop it rather
        // than hand workers a zero-length task
        let eng = Engine::native_testbed();
        let gl = GatedLoop::new(&eng, 4, vec![4]).unwrap();
        assert!(split_shards(0, 4).iter().any(|s| s.is_empty()));
        assert!(gl.shards(0).is_empty(), "empty batch must dispatch no shard tasks");
        let ran = gl.pool().run(gl.shards(0), |_, s: Shard| s.len());
        assert!(ran.is_empty());
        // non-empty batches are unaffected
        let sh = gl.shards(10);
        assert_eq!(sh.iter().map(Shard::len).sum::<usize>(), 10);
        assert!(sh.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn inactive_screen_cfg_attaches_no_stage() {
        let eng = Engine::native_testbed();
        let gl = GatedLoop::new(&eng, 2, vec![4])
            .unwrap()
            .with_screen(16, 8, ScreenCfg::default());
        assert!(gl.screen_stage().is_none(), "rho_screen = 1 must not attach a screen");
        let mut acct = ShardedLedger::new(2);
        let v = gl.screen(&[], 8, None, &mut acct);
        assert!(!v.is_screened());
        assert_eq!(v.survivors_or_all(8), (0..8).collect::<Vec<_>>());

        let gl = GatedLoop::new(&eng, 2, vec![4])
            .unwrap()
            .with_screen(16, 8, ScreenCfg::at_rate(0.5));
        assert!(gl.screen_stage().is_some());
    }
}
