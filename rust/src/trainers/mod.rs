//! Training loops (the L3 scheduler): forward artifact -> delight -> Kondo
//! gate -> bucketed backward -> optimizer, with the compute ledger and
//! noise-injection hooks every experiment driver needs.
//!
//! `GatedLoop` is the shared parallel substrate both trainers (and future
//! envs) run on: it owns the worker pool and the backward bucket set, and
//! provides the two sharded phases of a gated training step --
//! `sharded_forward` (split the batch across shard-capacity forward
//! artifacts) and `sharded_backward` (execute packed backward chunks
//! concurrently, then merge gradients in chunk order and step the
//! optimizer). Batch-global work -- resolving the Kondo gate's quantile
//! price over the merged chi scores -- stays on the caller's thread, which
//! is what keeps `workers = N` trajectories bit-identical to `workers = 1`
//! (the determinism contract, DESIGN.md §"L3 parallelism").

pub mod mnist;
pub mod reversal;

pub use mnist::{train_mnist, MnistRunResult, MnistTrainerCfg};
pub use reversal::{train_reversal, ReversalRunResult, ReversalTrainerCfg};

use anyhow::Result;

use crate::coordinator::batcher::BucketSet;
use crate::coordinator::pool::{split_shards, Shard, WorkerPool};
use crate::coordinator::{PackedChunk, ShardedLedger};
use crate::model::{accumulate, ParamStore};
use crate::optim::Optimizer;
use crate::runtime::{Engine, HostTensor};

/// One point of a learning curve, indexed by both step and compute.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: usize,
    pub forward_samples: u64,
    pub backward_kept: u64,
    pub backward_executed: u64,
    /// task metric: classification error (MNIST) or mean reward (reversal)
    pub metric: f64,
    /// secondary metric: test error (MNIST) / unused (reversal)
    pub metric2: f64,
}

/// The shared gate->bucket->backward->optimizer substrate.
pub struct GatedLoop<'e> {
    eng: &'e Engine,
    pool: WorkerPool,
    buckets: BucketSet,
}

impl<'e> GatedLoop<'e> {
    pub fn new(eng: &'e Engine, workers: usize, bwd_caps: Vec<usize>) -> Result<GatedLoop<'e>> {
        Ok(GatedLoop { eng, pool: WorkerPool::new(workers), buckets: BucketSet::new(bwd_caps)? })
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn buckets(&self) -> &BucketSet {
        &self.buckets
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Contiguous shards of an `n`-row batch for this pool.
    pub fn shards(&self, n: usize) -> Vec<Shard> {
        split_shards(n, self.pool.workers())
    }

    /// Sharded forward: split `rows` inputs across workers, each executing
    /// the artifact `shard_name(cap)` at the smallest compiled capacity
    /// `cap >= shard len` from `fwd_caps`, then stitch the f32 output rows
    /// back in shard order. Falls back to one `full_name` call when the
    /// pool has a single worker, no shard capacities exist, or a shard
    /// does not fit any capacity.
    ///
    /// Forward work is recorded into `acct` per logical shard, with padded
    /// capacity slots counted in `forward_executed` (mirroring the
    /// backward executed-slot convention); `forward_samples` stays
    /// worker-invariant.
    ///
    /// Bit-equality between the sharded and full paths is guaranteed by
    /// the backend's row-independence contract (runtime/native.rs).
    #[allow(clippy::too_many_arguments)]
    pub fn sharded_forward<F, N>(
        &self,
        full_name: &str,
        shard_name: N,
        fwd_caps: Option<&BucketSet>,
        rows: usize,
        out_width: usize,
        acct: &mut ShardedLedger,
        build: F,
    ) -> Result<Vec<f32>>
    where
        F: Fn(&Shard, usize) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        let shards = self.shards(rows);
        let caps = match fwd_caps {
            Some(caps)
                if self.pool.workers() > 1
                    && shards.iter().all(|s| caps.smallest_fitting(s.len()).is_some()) =>
            {
                caps
            }
            _ => {
                // one full-batch call: no padding, and exactly one
                // recorded call, attributed to shard 0 (that is where the
                // work really ran)
                let full = Shard::full(rows);
                let out = self.eng.execute(full_name, &build(&full, rows))?;
                acct.shard_mut(0).record_forward(rows);
                return Ok(out[0].as_f32()?.to_vec());
            }
        };
        let parts: Vec<Result<Vec<f32>>> = self.pool.run(shards.clone(), |_, shard| {
            let cap = caps.smallest_fitting(shard.len()).unwrap();
            let out = self.eng.execute(&shard_name(cap), &build(&shard, cap))?;
            Ok(out[0].as_f32()?[..shard.len() * out_width].to_vec())
        });
        for shard in &shards {
            let cap = caps.smallest_fitting(shard.len()).unwrap();
            acct.shard_mut(shard.index).record_forward_padded(shard.len(), cap);
        }
        let mut merged = Vec::with_capacity(rows * out_width);
        for part in parts {
            merged.extend_from_slice(&part?);
        }
        Ok(merged)
    }

    /// Execute packed backward chunks across the pool, accumulate the
    /// gradient tensors in *chunk order* (not completion order), normalize
    /// by `denom`, and apply one optimizer step. `extra_inputs` builds the
    /// non-parameter inputs of chunk `c` for artifact `artifact(c.cap)`;
    /// the parameter tensors are marshalled once into a template and
    /// cloned per chunk (each engine call needs its own input list).
    pub fn sharded_backward<F, N>(
        &self,
        params: &mut ParamStore,
        opt: &mut dyn Optimizer,
        chunks: &[PackedChunk],
        artifact: N,
        extra_inputs: F,
        denom: f32,
    ) -> Result<()>
    where
        F: Fn(&PackedChunk) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        if chunks.is_empty() {
            return Ok(());
        }
        let param_inputs = params.as_inputs();
        let results: Vec<Result<Vec<HostTensor>>> =
            self.pool.run(chunks.to_vec(), |_, chunk| {
                let mut inputs = param_inputs.clone();
                inputs.extend(extra_inputs(&chunk));
                let out = self.eng.execute(&artifact(chunk.cap), &inputs)?;
                // out[0] is the loss scalar; the rest are gradients
                Ok(out.into_iter().skip(1).collect())
            });
        let mut acc = params.zeros_like();
        for result in results {
            let grads = result?;
            accumulate(&mut acc, &grads)?;
        }
        for tensor in acc.iter_mut() {
            for v in tensor.iter_mut() {
                *v /= denom;
            }
        }
        opt.step(params, &acc);
        Ok(())
    }

    /// Record one batch's backward chunks into a shard-aware ledger
    /// (round-robin chunk ownership; see `ShardedLedger::backward_owner`).
    pub fn record_backward_chunks(
        &self,
        acct: &mut ShardedLedger,
        chunks: &[PackedChunk],
        slots_per_sample: usize,
        kept_of: impl Fn(&PackedChunk) -> usize,
    ) {
        for (ci, chunk) in chunks.iter().enumerate() {
            let owner = acct.backward_owner(ci);
            acct.shard_mut(owner)
                .record_backward(chunk.cap * slots_per_sample, kept_of(chunk));
        }
    }
}
