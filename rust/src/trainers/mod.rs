//! Training loops (the L3 scheduler): forward artifact -> delight -> Kondo
//! gate -> bucketed backward -> optimizer, with the compute ledger and
//! noise-injection hooks every experiment driver needs.
//!
//! `GatedLoop` is the shared parallel substrate both trainers (and future
//! envs) run on: it owns the **persistent** worker pool (threads spawned
//! once in `new`, alive for the whole training run, joined when the loop
//! drops) and the backward bucket set, and provides the two sharded phases
//! of a gated training step -- `sharded_forward` (split the batch across
//! shard-capacity forward artifacts) and `sharded_backward` (execute
//! packed backward chunks concurrently, then merge the per-chunk partial
//! gradients in chunk order and step the optimizer).
//!
//! The hot path is zero-copy: trainers marshal the parameter tensors once
//! per step into a reusable buffer (`ParamStore::marshal_into`) and both
//! sharded phases share that buffer across every chunk/shard by reference
//! (`Engine::execute_refs`) instead of cloning the full parameter list per
//! call; the gradient accumulator is preallocated once per run and reused
//! every step.
//!
//! Batch-global work -- resolving the Kondo gate's quantile price over the
//! merged chi scores -- stays on the caller's thread, which is what keeps
//! `workers = N` trajectories bit-identical to `workers = 1` (the
//! determinism contract, DESIGN.md §"L3 parallelism").

pub mod mnist;
pub mod reversal;

pub use mnist::{train_mnist, MnistRunResult, MnistTrainerCfg};
pub use reversal::{train_reversal, ReversalRunResult, ReversalTrainerCfg};

use anyhow::Result;

use crate::coordinator::batcher::BucketSet;
use crate::coordinator::pool::{split_shards, Shard, WorkerPool};
use crate::coordinator::{PackedChunk, ShardedLedger};
use crate::model::{accumulate, ParamStore};
use crate::optim::Optimizer;
use crate::runtime::{Engine, HostTensor};

/// One point of a learning curve, indexed by both step and compute.
#[derive(Debug, Clone, Copy)]
pub struct EvalPoint {
    pub step: usize,
    pub forward_samples: u64,
    pub backward_kept: u64,
    pub backward_executed: u64,
    /// task metric: classification error (MNIST) or mean reward (reversal)
    pub metric: f64,
    /// secondary metric: test error (MNIST) / unused (reversal)
    pub metric2: f64,
}

/// The shared gate->bucket->backward->optimizer substrate.
pub struct GatedLoop<'e> {
    eng: &'e Engine,
    pool: WorkerPool,
    buckets: BucketSet,
    /// gradient accumulator reused across steps (sized on first backward)
    grad_acc: Vec<Vec<f32>>,
}

impl<'e> GatedLoop<'e> {
    pub fn new(eng: &'e Engine, workers: usize, bwd_caps: Vec<usize>) -> Result<GatedLoop<'e>> {
        Ok(GatedLoop {
            eng,
            pool: WorkerPool::new(workers),
            buckets: BucketSet::new(bwd_caps)?,
            grad_acc: Vec::new(),
        })
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn buckets(&self) -> &BucketSet {
        &self.buckets
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Contiguous shards of an `n`-row batch for this pool. This is the
    /// dispatch layer: empty shards (`split_shards(0, w)` yields one) are
    /// skipped here so they are never handed to workers as tasks.
    pub fn shards(&self, n: usize) -> Vec<Shard> {
        split_shards(n, self.pool.workers()).into_iter().filter(|s| !s.is_empty()).collect()
    }

    /// Sharded forward: split `rows` inputs across workers, each executing
    /// the artifact `shard_name(cap)` at the smallest compiled capacity
    /// `cap >= shard len` from `fwd_caps`, then stitch the f32 output rows
    /// back in shard order. Falls back to one `full_name` call when the
    /// pool has a single worker, no shard capacities exist, or a shard
    /// does not fit any capacity.
    ///
    /// `param_inputs` is the step's marshalled parameter list, shared by
    /// reference across every shard call; `build` returns only the
    /// non-parameter inputs of a shard.
    ///
    /// Forward work is recorded into `acct` per logical shard, with padded
    /// capacity slots counted in `forward_executed` (mirroring the
    /// backward executed-slot convention); `forward_samples` stays
    /// worker-invariant.
    ///
    /// Bit-equality between the sharded and full paths is guaranteed by
    /// the backend's row-independence contract (runtime/native.rs).
    #[allow(clippy::too_many_arguments)]
    pub fn sharded_forward<F, N>(
        &self,
        param_inputs: &[HostTensor],
        full_name: &str,
        shard_name: N,
        fwd_caps: Option<&BucketSet>,
        rows: usize,
        out_width: usize,
        acct: &mut ShardedLedger,
        build: F,
    ) -> Result<Vec<f32>>
    where
        F: Fn(&Shard, usize) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        let eng = self.eng;
        let shards = self.shards(rows);
        let caps = match fwd_caps {
            Some(caps)
                if self.pool.workers() > 1
                    && shards.iter().all(|s| caps.smallest_fitting(s.len()).is_some()) =>
            {
                caps
            }
            _ => {
                // one full-batch call: no padding, and exactly one
                // recorded call, attributed to shard 0 (that is where the
                // work really ran)
                let full = Shard::full(rows);
                let extras = build(&full, rows);
                let mut inputs: Vec<&HostTensor> =
                    Vec::with_capacity(param_inputs.len() + extras.len());
                inputs.extend(param_inputs.iter());
                inputs.extend(extras.iter());
                let mut out = eng.execute_refs(full_name, &inputs)?;
                acct.shard_mut(0).record_forward(rows);
                return out.remove(0).into_f32();
            }
        };
        let parts: Vec<Result<Vec<f32>>> = self.pool.run(shards.clone(), |_, shard| {
            let cap = caps.smallest_fitting(shard.len()).unwrap();
            let extras = build(&shard, cap);
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(param_inputs.len() + extras.len());
            inputs.extend(param_inputs.iter());
            inputs.extend(extras.iter());
            let mut out = eng.execute_refs(&shard_name(cap), &inputs)?;
            let mut rows_out = out.remove(0).into_f32()?;
            rows_out.truncate(shard.len() * out_width);
            Ok(rows_out)
        });
        for shard in &shards {
            let cap = caps.smallest_fitting(shard.len()).unwrap();
            acct.shard_mut(shard.index).record_forward_padded(shard.len(), cap);
        }
        let mut merged = Vec::with_capacity(rows * out_width);
        for part in parts {
            merged.extend_from_slice(&part?);
        }
        Ok(merged)
    }

    /// Execute packed backward chunks across the pool and apply one
    /// optimizer step. Each worker produces its chunk's partial gradient
    /// buffers (the backward artifact's output tensors); the caller merges
    /// them into the run-persistent accumulator in **chunk order** (the
    /// pool returns results in task order, never completion order), so the
    /// f32 reduction order is identical to the serial `workers = 1` path.
    /// The merged gradient is normalized by `denom` before the step.
    ///
    /// `param_inputs` is the step's marshalled parameter list, shared by
    /// reference across every chunk call; `extra_inputs` builds only the
    /// non-parameter inputs of chunk `c` for artifact `artifact(c.cap)`.
    #[allow(clippy::too_many_arguments)]
    pub fn sharded_backward<F, N>(
        &mut self,
        params: &mut ParamStore,
        param_inputs: &[HostTensor],
        opt: &mut dyn Optimizer,
        chunks: &[PackedChunk],
        artifact: N,
        extra_inputs: F,
        denom: f32,
    ) -> Result<()>
    where
        F: Fn(&PackedChunk) -> Vec<HostTensor> + Sync,
        N: Fn(usize) -> String + Sync,
    {
        if chunks.is_empty() {
            return Ok(());
        }
        // the zero-copy contract: callers re-marshal after every optimizer
        // step. Cheap to get wrong silently, so verify under debug builds
        // (the dev-profile test runs keep this armed).
        debug_assert!(
            param_inputs.len() == params.n_tensors()
                && (0..params.n_tensors()).all(|i| {
                    param_inputs[i].as_f32().map(|d| d == params.tensor(i)).unwrap_or(false)
                }),
            "sharded_backward: param_inputs is stale relative to params \
             (re-marshal after every optimizer step)"
        );
        let eng = self.eng;
        let tasks: Vec<&PackedChunk> = chunks.iter().collect();
        let results: Vec<Result<Vec<HostTensor>>> = self.pool.run(tasks, |_, chunk| {
            let extras = extra_inputs(chunk);
            let mut inputs: Vec<&HostTensor> =
                Vec::with_capacity(param_inputs.len() + extras.len());
            inputs.extend(param_inputs.iter());
            inputs.extend(extras.iter());
            let out = eng.execute_refs(&artifact(chunk.cap), &inputs)?;
            // out[0] is the loss scalar; the rest are gradients
            Ok(out.into_iter().skip(1).collect())
        });
        // reuse the run-persistent accumulator when the layout matches
        // (steady state after the first backward of a run)
        let n = params.n_tensors();
        if self.grad_acc.len() == n
            && (0..n).all(|i| self.grad_acc[i].len() == params.tensor(i).len())
        {
            for tensor in self.grad_acc.iter_mut() {
                tensor.fill(0.0);
            }
        } else {
            self.grad_acc = params.zeros_like();
        }
        // ordered reduction: chunk order, not completion order
        for result in results {
            let grads = result?;
            accumulate(&mut self.grad_acc, &grads)?;
        }
        for tensor in self.grad_acc.iter_mut() {
            for v in tensor.iter_mut() {
                *v /= denom;
            }
        }
        opt.step(params, &self.grad_acc);
        Ok(())
    }

    /// Record one batch's backward chunks into a shard-aware ledger
    /// (round-robin chunk ownership; see `ShardedLedger::backward_owner`).
    pub fn record_backward_chunks(
        &self,
        acct: &mut ShardedLedger,
        chunks: &[PackedChunk],
        slots_per_sample: usize,
        kept_of: impl Fn(&PackedChunk) -> usize,
    ) {
        for (ci, chunk) in chunks.iter().enumerate() {
            let owner = acct.backward_owner(ci);
            acct.shard_mut(owner)
                .record_backward(chunk.cap * slots_per_sample, kept_of(chunk));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_dispatch_skips_empty_batches() {
        // regression: split_shards(0, w) returns one empty shard (the
        // split covers the batch); the dispatch layer must drop it rather
        // than hand workers a zero-length task
        let eng = Engine::native_testbed();
        let gl = GatedLoop::new(&eng, 4, vec![4]).unwrap();
        assert!(split_shards(0, 4).iter().any(|s| s.is_empty()));
        assert!(gl.shards(0).is_empty(), "empty batch must dispatch no shard tasks");
        let ran = gl.pool().run(gl.shards(0), |_, s: Shard| s.len());
        assert!(ran.is_empty());
        // non-empty batches are unaffected
        let sh = gl.shards(10);
        assert_eq!(sh.iter().map(Shard::len).sum::<usize>(), 10);
        assert!(sh.iter().all(|s| !s.is_empty()));
    }
}
