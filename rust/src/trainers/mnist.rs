//! MNIST-bandit trainer (paper §3, App A): the full L3/L4 scheduling
//! loop, staged through the screening pipeline and sharded across the
//! coordinator's worker pool.
//!
//! Per step: sample contexts -> **screen** (a warm draft pre-gates the
//! batch at `rho_screen` on predicted surprisal, one dot per sample) ->
//! **forward** the survivors (packed through the forward capacity ladder
//! when screened, contiguous shards otherwise; L1 fused head inside) ->
//! per-sample action/reward/delight scoring on per-sample RNG streams ->
//! merge chi in batch order and resolve ONE batch-global quantile price in
//! the Kondo **gate** -> pack kept samples into **backward** buckets ->
//! execute backward chunks across the pool -> merge gradients in chunk
//! order -> Adam -> train the draft on the survivors' exact surprisals.
//! The shard-aware ledger records the exact screen/forward/backward sample
//! counts that form the paper's compute axes plus the three-term cost
//! model of DESIGN.md §8.
//!
//! Determinism contract: with `eta = 0` (hard gate) the entire trajectory
//! -- screened or not -- is a pure function of `cfg.seed`, bit-identical
//! for every `workers` value (locked by rust/tests/gated_e2e.rs). The
//! screen keeps this: per-sample RNG streams are keyed by the ORIGINAL
//! batch index, so surviving a screen never shifts anybody's draws.

use std::path::Path;

use anyhow::{bail, Result};

use crate::algo::baseline::Baseline;
use crate::algo::{perturb_delight_abs, perturb_delight_rel, BatchSignals, Method};
use crate::checkpoint::{self, CheckpointCfg, TrainCheckpoint};
use crate::coordinator::batcher::{gather_f32, gather_i32, gather_rows_f32, BucketSet};
use crate::coordinator::pool::unit_rng;
use crate::coordinator::{
    screening_precision, Ledger, Pricing, ScreenCfg, ScreenVerdict, ShardedLedger,
};
use crate::envs::mnist::{MnistBandit, RewardNoise};
use crate::model::ParamStore;
use crate::optim::Adam;
use crate::runtime::{tensor, Engine, HostTensor, InitRule};
use crate::utils::json::Json;
use crate::utils::rng::Pcg32;

use super::{priority_key, EvalPoint, GatedLoop};

#[derive(Debug, Clone)]
pub struct MnistTrainerCfg {
    pub method: Method,
    pub baseline: Baseline,
    pub lr: f64,
    pub steps: usize,
    pub eval_every: usize,
    /// number of test images used for evaluation (multiple of eval batch)
    pub eval_size: usize,
    pub seed: u64,
    pub noise: RewardNoise,
    /// relative delight noise (Fig 4a); 0 = off
    pub delight_noise_rel: f64,
    /// absolute delight noise (Fig 17); 0 = off
    pub delight_noise_abs: f64,
    /// logit noise sigma_Z (Fig 4b); 0 = off
    pub logit_noise: f64,
    /// record pi(y*) of kept/skipped samples at these steps (Figs 15-16)
    pub gate_profile_steps: Vec<usize>,
    /// price lambda from a streaming EW quantile across batches instead of
    /// the per-batch quantile (ablation of Algorithm 1 line 5)
    pub streaming_lambda: bool,
    /// tier-1 speculative screen (paper 3.2/7, DESIGN.md §8): a warm
    /// online linear draft pre-gates the batch at `rho_screen` on
    /// predicted surprisal; only survivors pay the full forward
    pub screen: ScreenCfg,
    /// worker threads for sharded forward/scoring/backward (1 = serial)
    pub workers: usize,
    /// periodic checkpointing (None = never); see `crate::checkpoint`
    pub checkpoint: Option<CheckpointCfg>,
    /// resume from this checkpoint file before taking any steps
    pub resume_from: Option<String>,
}

impl Default for MnistTrainerCfg {
    fn default() -> Self {
        MnistTrainerCfg {
            method: Method::Pg,
            baseline: Baseline::Expected,
            lr: 1e-3,
            steps: 1000,
            eval_every: 100,
            eval_size: 1000,
            seed: 0,
            noise: RewardNoise::clean(),
            delight_noise_rel: 0.0,
            delight_noise_abs: 0.0,
            logit_noise: 0.0,
            gate_profile_steps: vec![],
            streaming_lambda: false,
            screen: ScreenCfg::default(),
            workers: 1,
            checkpoint: None,
            resume_from: None,
        }
    }
}

/// Config identity stored in (and validated against) checkpoints: every
/// knob inside the deterministic-trajectory contract. Deliberately
/// excluded: `steps` (run extension), `workers` (cross-worker resume is
/// bit-identical by the determinism contract), `gate_profile_steps`
/// (diagnostics), and the checkpoint knobs themselves.
fn fingerprint(cfg: &MnistTrainerCfg, f32_fast: bool, rules: &[InitRule]) -> Json {
    checkpoint::obj(vec![
        ("trainer", Json::Str("mnist".into())),
        ("seed", checkpoint::ju64(cfg.seed)),
        ("method", Json::Str(format!("{:?}", cfg.method))),
        // the forward tier is a trajectory-contract knob exactly like a
        // learning rate: an f32-fast run must never silently resume a
        // golden checkpoint (or vice versa) -- DESIGN.md §13
        ("f32_fast", Json::Bool(f32_fast)),
        // the gate priority is inside the method Debug string already, but
        // it is a trajectory-contract knob in its own right: an explicit
        // key makes a wrong-priority resume rejection name 'priority'
        // whatever the Debug format does
        ("priority", Json::Str(priority_key(&cfg.method))),
        ("baseline", Json::Str(format!("{:?}", cfg.baseline))),
        ("noise", Json::Str(format!("{:?}", cfg.noise))),
        ("screen", Json::Str(format!("{:?}", cfg.screen))),
        ("lr", Json::Num(cfg.lr)),
        ("delight_noise_rel", Json::Num(cfg.delight_noise_rel)),
        ("delight_noise_abs", Json::Num(cfg.delight_noise_abs)),
        ("logit_noise", Json::Num(cfg.logit_noise)),
        ("eval_every", checkpoint::ju64(cfg.eval_every as u64)),
        ("eval_size", checkpoint::ju64(cfg.eval_size as u64)),
        ("streaming_lambda", Json::Bool(cfg.streaming_lambda)),
        (
            "shapes",
            Json::Str(
                rules
                    .iter()
                    .map(|r| format!("{}:{:?}", r.name, r.shape))
                    .collect::<Vec<_>>()
                    .join(","),
            ),
        ),
    ])
}

/// pi(y*) of kept vs skipped samples around one training step (Fig 15).
/// Under an active screen the profile covers the survivors only (the
/// screened-out rows have no forward, hence no pi).
#[derive(Debug, Clone)]
pub struct GateProfile {
    pub step: usize,
    pub kept_p: Vec<f64>,
    pub skipped_p: Vec<f64>,
    /// (y, a, p) exemplars for the kept/skipped image panels (Fig 16)
    pub kept_samples: Vec<(usize, usize, f64)>,
    pub skipped_samples: Vec<(usize, usize, f64)>,
}

#[derive(Debug, Clone)]
pub struct MnistRunResult {
    pub curve: Vec<EvalPoint>,
    /// batch totals; always equals `shard_ledger.total()` (derived once at
    /// the end of the run -- the shard ledger is the single source)
    pub ledger: Ledger,
    /// per-shard attribution of the same work (diagnostics / load balance)
    pub shard_ledger: ShardedLedger,
    pub gate_profiles: Vec<GateProfile>,
    pub final_test_err: f64,
    pub final_train_err: f64,
    /// mean precision of the screen's predicted-delight top-rho set vs the
    /// exact delight of the survivors (1.0 when screening never engaged)
    pub draft_precision: f64,
}

/// Per-shard scoring output, merged in shard order.
struct ShardScore {
    actions: Vec<i32>,
    u: Vec<f64>,
    ell: Vec<f64>,
    p_star: Vec<f64>,
    greedy_wrong: usize,
}

/// Train one MNIST-bandit policy; deterministic in `cfg.seed` for every
/// `cfg.workers` value.
pub fn train_mnist(eng: &Engine, cfg: &MnistTrainerCfg) -> Result<MnistRunResult> {
    let man = eng.manifest();
    let b = man.constants.mnist_batch;
    let n_act = man.constants.mnist_actions;
    let img = man.constants.mnist_in;
    let eval_b = man.constants.mnist_eval_batch;

    let rules = man.model("mnist")?.to_vec();
    let mut params = ParamStore::init(&rules, cfg.seed.wrapping_mul(0x51ed) ^ 0xbeef);
    let mut opt = Adam::new(cfg.lr, &params);
    // forward shard capacities are part of the manifest contract; an
    // empty list (older artifact sets) disables forward sharding AND the
    // screened packed path (a screened batch then forwards whole)
    let fwd_buckets = if man.constants.mnist_fwd_caps.is_empty() {
        None
    } else {
        Some(BucketSet::new(man.constants.mnist_fwd_caps.clone())?)
    };
    let mut gl = GatedLoop::new(eng, cfg.workers, man.constants.mnist_bwd_caps.clone())?
        .with_fwd_caps(fwd_buckets)
        .with_screen(img, b, cfg.screen)
        .with_gate(&cfg.method, cfg.streaming_lambda, b);
    // reusable parameter marshalling buffer: refreshed once per step and
    // shared by reference across forward shards and backward chunks
    let mut param_inputs: Vec<HostTensor> = Vec::new();

    // the corpus is fixed across seeds (like the MNIST download); only the
    // sampling / action / gate randomness varies per seed
    let env = MnistBandit::new(1234, b, cfg.noise);
    let mut rng = Pcg32::new(cfg.seed, 0x6d6e_6973_74);

    let test = env.test_set(cfg.eval_size.max(eval_b));
    let mut acct = ShardedLedger::new(gl.workers());
    let mut curve = Vec::new();
    let mut gate_profiles = Vec::new();
    let mut train_err_window = TrainWindow::new(10);
    let mut precisions: Vec<f64> = Vec::new();
    // step-persistent scratch: the noise matrix and the survivor-slot ->
    // batch-index scatter buffers are refilled per step, never reallocated
    let mut noise = vec![0.0f32; b * n_act];
    let mut w_batch = vec![0.0f32; b];
    let mut a_batch = vec![0i32; b];

    // ---- checkpoint resume: restore every trajectory-bearing piece of
    // state, then continue the loop from the saved step cursor as if the
    // run had never stopped (bit-identity locked by checkpoint_resume.rs)
    let fp = fingerprint(cfg, eng.f32_fast(), &rules);
    let mut start_step = 0usize;
    if let Some(path) = &cfg.resume_from {
        let ck = TrainCheckpoint::load(Path::new(path))?;
        checkpoint::validate_fingerprint(&ck.fingerprint, &fp)?;
        checkpoint::restore(
            &ck, &mut params, &mut opt, &mut rng, &mut gl, &mut acct, &mut curve,
        )?;
        train_err_window.restore(checkpoint::pf64_arr(
            checkpoint::field(&ck.extra, "train_window")?,
            "extra.train_window",
        )?);
        precisions = checkpoint::pf64_arr(
            checkpoint::field(&ck.extra, "precisions")?,
            "extra.precisions",
        )?;
        start_step = ck.step as usize;
        if start_step > cfg.steps {
            bail!(
                "checkpoint is at step {start_step}, beyond this run's {} steps",
                cfg.steps
            );
        }
    }

    for step in start_step..cfg.steps {
        let ctx = env.sample_contexts(&mut rng);
        if cfg.logit_noise > 0.0 {
            for nz in noise.iter_mut() {
                *nz = (cfg.logit_noise * rng.normal()) as f32;
            }
        }

        // ---- stage 1: SCREEN. A warm draft pre-gates the batch on
        // predicted surprisal (one dot per sample); cold batches pass
        // whole. No advantage hint here: U needs the forward.
        let verdict = gl.screen(&ctx.x, b, None, &mut acct);
        let survivors = verdict.survivors_or_all(b);
        let k = survivors.len();

        // ---- stage 2: FORWARD, survivors only (the only place the
        // policy is evaluated on the training path); the parameter
        // tensors are marshalled once here and shared across calls
        params.marshal_into(&mut param_inputs);
        let logp: Vec<f32> = gl.forward(
            &param_inputs,
            "mnist_fwd",
            |cap| format!("mnist_fwd_c{cap}"),
            &survivors,
            b,
            n_act,
            &mut acct,
            |idx, cap| {
                let xs = gather_rows_f32(&ctx.x, img, idx, cap);
                let ns = gather_rows_f32(&noise, n_act, idx, cap);
                vec![HostTensor::f32(&[cap, img], xs), HostTensor::f32(&[cap, n_act], ns)]
            },
        )?;

        // ---- act, observe rewards, build signals: sharded over survivor
        // slots, with per-sample RNG streams keyed by the ORIGINAL batch
        // index so draws are independent of sharding AND of screening
        let seed = cfg.seed;
        let survivors_ref = &survivors;
        let scored: Vec<ShardScore> = gl.pool().run(gl.shards(k), |_, shard| {
            let mut sc = ShardScore {
                actions: Vec::with_capacity(shard.len()),
                u: Vec::with_capacity(shard.len()),
                ell: Vec::with_capacity(shard.len()),
                p_star: Vec::with_capacity(shard.len()),
                greedy_wrong: 0,
            };
            for s in shard.range() {
                let i = survivors_ref[s];
                let mut srng = unit_rng(seed, step as u64, i as u64);
                let row = &logp[s * n_act..(s + 1) * n_act];
                let a = srng.categorical_from_logits(row);
                let pi: Vec<f32> = row.iter().map(|&l| l.exp()).collect();
                let y = ctx.y[i];
                sc.p_star.push(pi[y] as f64);
                let r = env.reward(a, y, &mut srng);
                let bval = cfg.baseline.value(&pi, y);
                sc.u.push(r - bval);
                sc.ell.push(-(row[a] as f64));
                sc.actions.push(a as i32);
                if argmax(row) != y {
                    sc.greedy_wrong += 1;
                }
            }
            sc
        });
        let mut actions = Vec::with_capacity(k);
        let mut u = Vec::with_capacity(k);
        let mut ell = Vec::with_capacity(k);
        let mut p_star = Vec::with_capacity(k);
        let mut greedy_wrong = 0usize;
        for sc in scored {
            actions.extend(sc.actions);
            u.extend(sc.u);
            ell.extend(sc.ell);
            p_star.extend(sc.p_star);
            greedy_wrong += sc.greedy_wrong;
        }
        // under an active screen this is the error over the survivor set
        // (the screened-out rows have no forward to grade)
        train_err_window.push(greedy_wrong as f64 / k as f64);

        // ---- stage 3: GATE on the survivors' exact delight (with
        // optional screening noise); chi is merged in batch order so the
        // quantile price is batch-global regardless of sharding
        let chi: Vec<f64> = u.iter().zip(&ell).map(|(&a, &l)| a * l).collect();
        let chi_noisy = if cfg.delight_noise_rel > 0.0 {
            Some(perturb_delight_rel(&chi, cfg.delight_noise_rel, &mut rng))
        } else if cfg.delight_noise_abs > 0.0 {
            Some(perturb_delight_abs(&chi, cfg.delight_noise_abs, &mut rng))
        } else {
            None
        };
        // screen quality diagnostic: the draft's predicted delight for the
        // survivors vs their exact delight, precision at the gate's rate
        if let (ScreenVerdict::Screened { scores, .. }, Method::DgK { gate, .. }) =
            (&verdict, &cfg.method)
        {
            if let Pricing::Rate(rho) = gate.pricing {
                let chi_hat: Vec<f64> =
                    survivors.iter().enumerate().map(|(s, &i)| u[s] * scores[i]).collect();
                precisions.push(screening_precision(&chi, &chi_hat, rho));
            }
        }
        let signals =
            BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: chi_noisy.as_deref() };
        let decision = gl.decide(&cfg.method, &signals, &mut rng);

        if cfg.gate_profile_steps.contains(&(step + 1)) {
            let keep_set: std::collections::HashSet<usize> =
                decision.keep.iter().copied().collect();
            let mut gp = GateProfile {
                step: step + 1,
                kept_p: vec![],
                skipped_p: vec![],
                kept_samples: vec![],
                skipped_samples: vec![],
            };
            for s in 0..k {
                let i = survivors[s];
                let rec = (ctx.y[i], actions[s] as usize, p_star[s]);
                if keep_set.contains(&s) {
                    gp.kept_p.push(p_star[s]);
                    gp.kept_samples.push(rec);
                } else {
                    gp.skipped_p.push(p_star[s]);
                    gp.skipped_samples.push(rec);
                }
            }
            gate_profiles.push(gp);
        }

        // ---- stage 4: BACKWARD over the kept set, chunks across workers.
        // The decision indexes survivor slots; packing and row gathering
        // use the original batch indices.
        if !decision.keep.is_empty() {
            let keep_orig: Vec<usize> = decision.keep.iter().map(|&s| survivors[s]).collect();
            let chunks = gl.buckets().pack(&keep_orig);
            gl.record_backward_chunks(&mut acct, &chunks, 1, |c| c.idx.len());
            // scatter the survivor-slot weights/actions back to batch
            // indices so chunk gathering works exactly as it always has
            // (step-persistent buffers; chunks only ever gather kept
            // indices, all freshly written below, but clear anyway)
            w_batch.fill(0.0);
            a_batch.fill(0);
            for (s, &i) in survivors.iter().enumerate() {
                w_batch[i] = decision.weights[s];
                a_batch[i] = actions[s];
            }
            // params are unchanged since the forward marshal above, so the
            // same buffer serves every backward chunk
            gl.backward(
                &mut params,
                &param_inputs,
                &mut opt,
                &chunks,
                |cap| format!("mnist_bwd_c{cap}"),
                |chunk| {
                    let cap = chunk.cap;
                    vec![
                        HostTensor::f32(&[cap, img], gather_rows_f32(&ctx.x, img, &chunk.idx, cap)),
                        HostTensor::i32(&[cap], gather_i32(&a_batch, &chunk.idx, cap)),
                        HostTensor::f32(&[cap], gather_f32(&w_batch, &chunk.idx, cap)),
                    ]
                },
                // average over the full batch (matches sum/B normalization)
                b as f32,
            )?;
        }

        // ---- the draft trains online on whatever exact surprisals the
        // surviving forwards produced (cold batches feed the whole batch)
        gl.observe_screen(&ctx.x, &survivors, &ell);

        // the step is done with the forward rows: back to the arena
        tensor::recycle_f32(logp);

        // ---- evaluation cadence
        let last = step + 1 == cfg.steps;
        if (step + 1) % cfg.eval_every == 0 || last {
            let test_err = eval_test_error(eng, &params, &test.x, &test.y, eval_b, img, n_act)?;
            let totals = acct.total();
            curve.push(EvalPoint {
                step: step + 1,
                forward_samples: totals.forward_samples,
                screen_samples: totals.screen_samples,
                forward_skipped: totals.forward_skipped,
                backward_kept: totals.backward_kept,
                backward_executed: totals.backward_executed,
                metric: train_err_window.mean(),
                metric2: test_err,
            });
        }

        // ---- checkpoint save: between optimizer steps, after the eval
        // cadence, so a resumed run replays neither a step nor an eval
        if let Some(ck_cfg) = &cfg.checkpoint {
            if ck_cfg.every > 0 && (step + 1) % ck_cfg.every == 0 {
                let extra = checkpoint::obj(vec![
                    ("train_window", checkpoint::jf64_arr(train_err_window.buf())),
                    ("precisions", checkpoint::jf64_arr(&precisions)),
                ]);
                checkpoint::capture(
                    fp.clone(),
                    (step + 1) as u64,
                    &params,
                    &opt,
                    &rng,
                    &gl,
                    &acct,
                    &curve,
                    extra,
                )
                .save(Path::new(&ck_cfg.path))?;
            }
        }
    }

    let final_test = curve.last().map(|p| p.metric2).unwrap_or(1.0);
    let final_train = curve.last().map(|p| p.metric).unwrap_or(1.0);
    Ok(MnistRunResult {
        curve,
        ledger: acct.total(),
        shard_ledger: acct,
        gate_profiles,
        final_test_err: final_test,
        final_train_err: final_train,
        draft_precision: if precisions.is_empty() {
            1.0
        } else {
            crate::utils::stats::mean(&precisions)
        },
    })
}

/// Greedy test error via the eval artifact, in chunks of the eval batch.
pub fn eval_test_error(
    eng: &Engine,
    params: &ParamStore,
    xs: &[f32],
    ys: &[usize],
    eval_b: usize,
    img: usize,
    n_act: usize,
) -> Result<f64> {
    let n = ys.len();
    let mut wrong = 0usize;
    let mut done = 0usize;
    // marshal the parameters once for the whole evaluation sweep (packs
    // included — as_inputs attaches them)
    let param_inputs = params.as_inputs();
    while done < n {
        let take = eval_b.min(n - done);
        // pad the final chunk up to eval_b with repeats; the buffer
        // cycles through the arena across eval chunks and eval sweeps
        let mut chunk = tensor::take_f32_zeroed(eval_b * img);
        for i in 0..eval_b {
            let src = (done + i.min(take - 1)).min(n - 1);
            chunk[i * img..(i + 1) * img].copy_from_slice(&xs[src * img..(src + 1) * img]);
        }
        let chunk_t = HostTensor::f32(&[eval_b, img], chunk);
        let mut inputs: Vec<&HostTensor> = param_inputs.iter().collect();
        inputs.push(&chunk_t);
        let out = eng.execute_refs("mnist_fwd_eval", &inputs)?;
        let logp = out[0].as_f32()?;
        for i in 0..take {
            let row = &logp[i * n_act..(i + 1) * n_act];
            if argmax(row) != ys[done + i] {
                wrong += 1;
            }
        }
        tensor::recycle_tensor(chunk_t);
        for t in out {
            tensor::recycle_tensor(t);
        }
        done += take;
    }
    Ok(wrong as f64 / n as f64)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = f32::NEG_INFINITY;
    let mut arg = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > best {
            best = x;
            arg = i;
        }
    }
    arg
}

/// Sliding window over recent per-batch train errors.
struct TrainWindow {
    buf: Vec<f64>,
    cap: usize,
}

impl TrainWindow {
    fn new(cap: usize) -> TrainWindow {
        TrainWindow { buf: vec![], cap }
    }

    fn push(&mut self, v: f64) {
        if self.buf.len() == self.cap {
            self.buf.remove(0);
        }
        self.buf.push(v);
    }

    fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 1.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    fn buf(&self) -> &[f64] {
        &self.buf
    }

    /// Checkpoint restore: adopt the saved window, keeping at most the
    /// last `cap` entries (push semantics).
    fn restore(&mut self, vals: Vec<f64>) {
        self.buf = vals;
        if self.buf.len() > self.cap {
            let excess = self.buf.len() - self.cap;
            self.buf.drain(..excess);
        }
    }
}
