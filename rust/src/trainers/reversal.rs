//! Token-reversal trainer (paper §5, App D): transformer rollout fully
//! inside the compiled artifact, per-token two-tier Kondo gating, episode-
//! level bucketed backward over the coordinator's worker pool.
//!
//! Gating is at TOKEN granularity (the paper gates tokens); the backward
//! executor works at EPISODE granularity (a sequence either enters the
//! backward batch or not), so an episode is executed iff it has at least
//! one kept token, and its weight tensor zeroes all skipped tokens.
//!
//! Screening (DESIGN.md §8): the tier-1 draft pre-gates TOKENS before the
//! exact-delight gate, drafting on **embedded token rows** -- each token is
//! represented by the current `emit`-table embedding of its sampled action
//! -- weighted by the exact grouped-baseline advantage (known before the
//! gate, unlike MNIST). The rollout itself is one fixed-shape batch-global
//! artifact call and always runs whole, so reversal screening narrows the
//! gate's candidate set and the backward episode set (`screen_samples`
//! counts the dots; `forward_skipped` stays 0 -- no forward is avoidable
//! here). Models without an `emit` tensor simply never screen.
//!
//! Sharding: the rollout stays one batch-global artifact call (the
//! autoregressive sampling loop lives inside the artifact and draws
//! per-episode RNG streams internally), while per-token delight scoring
//! and the bucketed backward chunks run across the pool. The gate price
//! is resolved once over the merged token scores. At eta = 0 the
//! trajectory is bit-identical for every `workers` value (gated_e2e.rs).

use std::path::Path;

use anyhow::{bail, Result};

use crate::algo::baseline::grouped_baseline;
use crate::algo::{BatchSignals, Method};
use crate::checkpoint::{self, CheckpointCfg, TrainCheckpoint};
use crate::coordinator::batcher::{gather_rows_f32, gather_rows_i32};
use crate::coordinator::{Ledger, ScreenCfg, ShardedLedger};
use crate::envs::reversal::ReversalEnv;
use crate::model::ParamStore;
use crate::optim::Adam;
use crate::runtime::{tensor, Engine, HostTensor, InitRule};
use crate::utils::json::Json;
use crate::utils::rng::Pcg32;

use super::{priority_key, EvalPoint, GatedLoop};

#[derive(Debug, Clone)]
pub struct ReversalTrainerCfg {
    pub method: Method,
    pub lr: f64,
    pub steps: usize,
    /// sequence length H <= h_max
    pub h: usize,
    /// vocabulary size M <= vocab
    pub m: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// PPO inner epochs (ratio updates against the rollout policy)
    pub inner_epochs: usize,
    /// tier-1 speculative token screen on embedded token rows (DESIGN.md
    /// §8); requires the model to expose an `emit` embedding table
    pub screen: ScreenCfg,
    /// worker threads for sharded scoring/backward (1 = serial)
    pub workers: usize,
    /// periodic checkpointing (None = never); see `crate::checkpoint`
    pub checkpoint: Option<CheckpointCfg>,
    /// resume from this checkpoint file before taking any steps
    pub resume_from: Option<String>,
}

impl Default for ReversalTrainerCfg {
    fn default() -> Self {
        ReversalTrainerCfg {
            method: Method::Pg,
            lr: 3e-4,
            steps: 300,
            h: 5,
            m: 2,
            seed: 0,
            eval_every: 10,
            inner_epochs: 1,
            screen: ScreenCfg::default(),
            workers: 1,
            checkpoint: None,
            resume_from: None,
        }
    }
}

/// Config identity stored in (and validated against) checkpoints. Same
/// exclusions as the MNIST fingerprint: `steps`, `workers`, and the
/// checkpoint knobs are outside the trajectory contract.
fn fingerprint(cfg: &ReversalTrainerCfg, f32_fast: bool, rules: &[InitRule]) -> Json {
    checkpoint::obj(vec![
        ("trainer", Json::Str("reversal".into())),
        ("seed", checkpoint::ju64(cfg.seed)),
        ("method", Json::Str(format!("{:?}", cfg.method))),
        // forward-tier knob: pinned like a learning rate (DESIGN.md §13)
        ("f32_fast", Json::Bool(f32_fast)),
        // explicit fingerprint membership for the gate priority (see the
        // MNIST fingerprint: wrong-priority resumes reject readably)
        ("priority", Json::Str(priority_key(&cfg.method))),
        ("screen", Json::Str(format!("{:?}", cfg.screen))),
        ("lr", Json::Num(cfg.lr)),
        ("h", checkpoint::ju64(cfg.h as u64)),
        ("m", checkpoint::ju64(cfg.m as u64)),
        ("inner_epochs", checkpoint::ju64(cfg.inner_epochs as u64)),
        ("eval_every", checkpoint::ju64(cfg.eval_every as u64)),
        (
            "shapes",
            Json::Str(
                rules
                    .iter()
                    .map(|r| format!("{}:{:?}", r.name, r.shape))
                    .collect::<Vec<_>>()
                    .join(","),
            ),
        ),
    ])
}

#[derive(Debug, Clone)]
pub struct ReversalRunResult {
    pub curve: Vec<EvalPoint>,
    /// batch totals; always equals `shard_ledger.total()` (derived once at
    /// the end of the run -- the shard ledger is the single source)
    pub ledger: Ledger,
    /// per-shard attribution of the same work (diagnostics / load balance)
    pub shard_ledger: ShardedLedger,
    pub final_reward: f64,
    /// mean reward over the whole run (the paper's "solved" statistic)
    pub mean_reward: f64,
}

pub fn train_reversal(eng: &Engine, cfg: &ReversalTrainerCfg) -> Result<ReversalRunResult> {
    let man = eng.manifest();
    // pick the smallest compiled shape set that fits H (masks carve out
    // the active problem inside the artifact)
    let h_max = *man
        .constants
        .rev_sets
        .iter()
        .find(|&&hm| hm >= cfg.h)
        .unwrap_or(&man.constants.h_max);
    let prefix = format!("rev{h_max}");
    let batch = man.constants.rev_batch;
    let pad = man.constants.pad as i32;
    assert!(cfg.h <= h_max && cfg.m <= man.constants.vocab);

    let env = ReversalEnv::new(cfg.h, cfg.m, 10, 10, h_max, pad);
    assert_eq!(env.batch_size(), batch);

    let rules = man.model(&format!("reversal{h_max}"))?.to_vec();
    let mut params = ParamStore::init(&rules, cfg.seed.wrapping_mul(0x2545) ^ 0xcafe);
    let mut opt = Adam::new(cfg.lr, &params);
    let n_tok = batch * cfg.h;
    // the token screen drafts on embedded token rows: it needs the emit
    // table's row width, and quietly stays off for models without one
    let emit_width = rules
        .iter()
        .find(|r| r.name == "emit")
        .and_then(|r| r.shape.last().copied())
        .unwrap_or(0);
    let mut gl = GatedLoop::new(eng, cfg.workers, man.constants.rev_bwd_caps.clone())?
        .with_screen(emit_width.max(1), n_tok, if emit_width > 0 { cfg.screen } else { ScreenCfg::default() })
        .with_gate(&cfg.method, false, n_tok);
    // artifact names are fixed for the whole run; build them once
    let rollout_name = format!("{prefix}_rollout");
    let fwd_name = format!("{prefix}_fwd");
    // reusable parameter marshalling buffer: refreshed at each step (and
    // after each inner-epoch optimizer step), shared across artifact calls
    let mut param_inputs: Vec<HostTensor> = Vec::new();

    let mut rng = Pcg32::new(cfg.seed, 0x7265_76);
    let mut acct = ShardedLedger::new(gl.workers());
    let mut curve = Vec::new();
    let mut reward_sum = 0.0;
    let mut reward_window = Vec::new();

    let h_t = HostTensor::scalar_i32(cfg.h as i32);
    let m_t = HostTensor::scalar_i32(cfg.m as i32);
    // step-persistent scratch: the token-keep -> episode-weight scatter
    // buffers are refilled per epoch, never reallocated
    let mut ep_weights = vec![0.0f32; batch * h_max];
    let mut ep_has = vec![false; batch];

    // ---- checkpoint resume (bit-identity locked by checkpoint_resume.rs)
    let fp = fingerprint(cfg, eng.f32_fast(), &rules);
    let mut start_step = 0usize;
    if let Some(path) = &cfg.resume_from {
        let ck = TrainCheckpoint::load(Path::new(path))?;
        checkpoint::validate_fingerprint(&ck.fingerprint, &fp)?;
        checkpoint::restore(
            &ck, &mut params, &mut opt, &mut rng, &mut gl, &mut acct, &mut curve,
        )?;
        reward_sum = checkpoint::pf64(
            checkpoint::field(&ck.extra, "reward_sum")?,
            "extra.reward_sum",
        )?;
        reward_window = checkpoint::pf64_arr(
            checkpoint::field(&ck.extra, "reward_window")?,
            "extra.reward_window",
        )?;
        start_step = ck.step as usize;
        if start_step > cfg.steps {
            bail!(
                "checkpoint is at step {start_step}, beyond this run's {} steps",
                cfg.steps
            );
        }
    }

    for step in start_step..cfg.steps {
        let prompts = env.sample_prompts(&mut rng);
        let prompt_t = {
            let mut buf = tensor::take_i32_zeroed(batch * h_max);
            buf.copy_from_slice(&prompts.tokens);
            HostTensor::i32(&[batch, h_max], buf)
        };

        // ---- rollout (autoregressive sampling inside the artifact)
        params.marshal_into(&mut param_inputs);
        let seed_t = HostTensor::scalar_i32(rng.next_u32() as i32 & 0x7fffffff);
        let mut inputs: Vec<&HostTensor> = param_inputs.iter().collect();
        inputs.push(&prompt_t);
        inputs.push(&h_t);
        inputs.push(&m_t);
        inputs.push(&seed_t);
        let out = eng.execute_refs(&rollout_name, &inputs)?;
        let mut out = out.into_iter();
        let actions = out.next().unwrap().into_i32()?;
        let logp = out.next().unwrap().into_f32()?;
        // the rollout is one batch-global call: one recorded call, on
        // shard 0 (forward_calls must not depend on the worker count)
        acct.shard_mut(0).record_forward(batch * cfg.h);

        // ---- rewards, grouped baseline, per-token signals (sharded over
        // episodes; pure math, so sharding cannot change the values)
        let rewards = env.rewards(&prompts, &actions);
        let base = grouped_baseline(&rewards, 10);
        reward_sum += crate::utils::stats::mean(&rewards);
        reward_window.push(crate::utils::stats::mean(&rewards));

        let h = cfg.h;
        let signals_per_shard: Vec<(Vec<f64>, Vec<f64>)> =
            gl.pool().run(gl.shards(batch), |_, shard| {
                let mut u = Vec::with_capacity(shard.len() * h);
                let mut ell = Vec::with_capacity(shard.len() * h);
                for ep in shard.range() {
                    let adv = rewards[ep] - base[ep];
                    for j in 0..h {
                        u.push(adv);
                        ell.push(-(logp[ep * h_max + j] as f64));
                    }
                }
                (u, ell)
            });
        let mut u = Vec::with_capacity(n_tok);
        let mut ell = Vec::with_capacity(n_tok);
        for (su, sell) in signals_per_shard {
            u.extend(su);
            ell.extend(sell);
        }

        // ---- stage 1: SCREEN over tokens. Features are the CURRENT emit
        // embeddings of the sampled action tokens; the exact advantage
        // (known pre-gate, unlike MNIST) weights predicted surprisal into
        // predicted delight. The rollout already ran whole -- reversal
        // screening narrows the gate candidate set, it skips no forwards.
        let feats = if gl.screen_stage().is_some() {
            token_feats(&params, &actions, batch, cfg.h, h_max, emit_width)
        } else {
            Vec::new()
        };
        let verdict = gl.screen(&feats, n_tok, Some(&u), &mut acct);
        let survivors = verdict.survivors_or_all(n_tok);

        // the draft trains online on the exact surprisals the rollout
        // produced for the surviving tokens
        if gl.screen_stage().is_some() {
            let sell0: Vec<f64> = survivors.iter().map(|&t| ell[t]).collect();
            gl.observe_screen(&feats, &survivors, &sell0);
        }
        // the screen is done with the embedded token rows
        tensor::recycle_f32(feats);

        let logp_roll: Vec<f64> = ell.iter().map(|&e| -e).collect();
        for epoch in 0..cfg.inner_epochs.max(1) {
            // ratios: first epoch is on-policy; later epochs re-score the
            // sampled actions under the updated policy via rev_fwd.
            let (ell_cur, lp_old): (Vec<f64>, Option<&[f64]>) = if epoch == 0 {
                (ell.clone(), None)
            } else {
                // the previous epoch's backward stepped the optimizer, so
                // refresh the shared parameter buffer before re-scoring
                params.marshal_into(&mut param_inputs);
                let actions_t = {
                    let mut buf = tensor::take_i32_zeroed(batch * h_max);
                    buf.copy_from_slice(&actions);
                    HostTensor::i32(&[batch, h_max], buf)
                };
                let mut finputs: Vec<&HostTensor> = param_inputs.iter().collect();
                finputs.push(&prompt_t);
                finputs.push(&actions_t);
                finputs.push(&h_t);
                finputs.push(&m_t);
                let fout = eng.execute_refs(&fwd_name, &finputs)?;
                let lp_new = fout[0].as_f32()?;
                acct.shard_mut(0).record_forward(batch * cfg.h);
                let mut e = vec![0.0f64; n_tok];
                for ep in 0..batch {
                    for j in 0..cfg.h {
                        e[ep * cfg.h + j] = -(lp_new[ep * h_max + j] as f64);
                    }
                }
                tensor::recycle_tensor(actions_t);
                for t in fout {
                    tensor::recycle_tensor(t);
                }
                (e, Some(logp_roll.as_slice()))
            };

            // ---- stage 3: one batch-global gate decision over the merged
            // SURVIVOR token scores (tier 2 of the two-tier gate)
            let su: Vec<f64> = survivors.iter().map(|&t| u[t]).collect();
            let sell: Vec<f64> = survivors.iter().map(|&t| ell_cur[t]).collect();
            let slp_old: Option<Vec<f64>> =
                lp_old.map(|l| survivors.iter().map(|&t| l[t]).collect());
            let signals = BatchSignals {
                u: &su,
                ell: &sell,
                logp_old: slp_old.as_deref(),
                chi_override: None,
            };
            let decision = gl.decide(&cfg.method, &signals, &mut rng);
            if decision.keep.is_empty() {
                continue;
            }

            // ---- token keep-set (survivor slots) -> episode list + weights
            // (step-persistent buffers, cleared per epoch)
            ep_weights.fill(0.0);
            ep_has.fill(false);
            for &s in &decision.keep {
                let t = survivors[s];
                let ep = t / cfg.h;
                let j = t % cfg.h;
                ep_weights[ep * h_max + j] = decision.weights[s];
                ep_has[ep] = true;
            }
            let episodes: Vec<usize> = (0..batch).filter(|&e| ep_has[e]).collect();
            let kept_tokens = decision.keep.len();

            let chunks = gl.buckets().pack(&episodes);
            // token-denominated ledger: kept tokens vs executed slots
            let n_episodes = episodes.len();
            gl.record_backward_chunks(&mut acct, &chunks, cfg.h, |c| {
                let share = c.idx.len() as f64 / n_episodes as f64;
                (kept_tokens as f64 * share) as usize
            });
            // params unchanged since this epoch's marshal: share the buffer
            gl.backward(
                &mut params,
                &param_inputs,
                &mut opt,
                &chunks,
                |cap| format!("{prefix}_bwd_c{cap}"),
                |chunk| {
                    let cap = chunk.cap;
                    // the h/m scalars are arena-sourced (not clones of
                    // h_t/m_t): the backward stage recycles every extra,
                    // and a recycled clone would grow the freelists by
                    // one fresh allocation per chunk forever
                    let scalar = |v: i32| {
                        let mut buf = tensor::take_i32_zeroed(1);
                        buf[0] = v;
                        HostTensor::i32(&[1], buf)
                    };
                    vec![
                        HostTensor::i32(
                            &[cap, h_max],
                            gather_rows_i32(&prompts.tokens, h_max, &chunk.idx, cap),
                        ),
                        HostTensor::i32(
                            &[cap, h_max],
                            gather_rows_i32(&actions, h_max, &chunk.idx, cap),
                        ),
                        HostTensor::f32(
                            &[cap, h_max],
                            gather_rows_f32(&ep_weights, h_max, &chunk.idx, cap),
                        ),
                        scalar(cfg.h as i32),
                        scalar(cfg.m as i32),
                    ]
                },
                batch as f32,
            )?;
        }

        let last = step + 1 == cfg.steps;
        if (step + 1) % cfg.eval_every == 0 || last {
            let recent = reward_window.iter().rev().take(10).sum::<f64>()
                / reward_window.iter().rev().take(10).count().max(1) as f64;
            let totals = acct.total();
            curve.push(EvalPoint {
                step: step + 1,
                forward_samples: totals.forward_samples,
                screen_samples: totals.screen_samples,
                forward_skipped: totals.forward_skipped,
                backward_kept: totals.backward_kept,
                backward_executed: totals.backward_executed,
                metric: recent,
                metric2: 0.0,
            });
        }

        // ---- checkpoint save: between optimizer steps, after the eval
        // cadence. Only the tail of the reward window is stored -- the
        // eval metric reads at most the last 10 entries, so the tail is
        // the whole trajectory-bearing state of the window.
        if let Some(ck_cfg) = &cfg.checkpoint {
            if ck_cfg.every > 0 && (step + 1) % ck_cfg.every == 0 {
                let tail_at = reward_window.len().saturating_sub(10);
                let extra = checkpoint::obj(vec![
                    ("reward_sum", Json::Num(reward_sum)),
                    ("reward_window", checkpoint::jf64_arr(&reward_window[tail_at..])),
                ]);
                checkpoint::capture(
                    fp.clone(),
                    (step + 1) as u64,
                    &params,
                    &opt,
                    &rng,
                    &gl,
                    &acct,
                    &curve,
                    extra,
                )
                .save(Path::new(&ck_cfg.path))?;
            }
        }

        // step teardown: rollout outputs and the prompt copy return to
        // the arena for the next step
        tensor::recycle_tensor(prompt_t);
        tensor::recycle_i32(actions);
        tensor::recycle_f32(logp);
    }

    let final_reward = curve.last().map(|p| p.metric).unwrap_or(0.0);
    Ok(ReversalRunResult {
        curve,
        ledger: acct.total(),
        shard_ledger: acct,
        final_reward,
        mean_reward: reward_sum / cfg.steps.max(1) as f64,
    })
}

/// Draft features for the token screen: token (ep, j) is represented by
/// the current `emit`-table embedding row of its sampled action. Pure
/// function of the parameters and the sampled actions, so the feature
/// matrix -- like every screen input -- is worker-invariant.
fn token_feats(
    params: &ParamStore,
    actions: &[i32],
    batch: usize,
    h: usize,
    h_max: usize,
    width: usize,
) -> Vec<f32> {
    let emit = params.by_name("emit").expect("token_feats requires an emit table");
    let rows = emit.len() / width;
    let mut feats = tensor::take_f32_zeroed(batch * h * width);
    for ep in 0..batch {
        for j in 0..h {
            let tok = (actions[ep * h_max + j].max(0) as usize).min(rows - 1);
            let t = ep * h + j;
            feats[t * width..(t + 1) * width].copy_from_slice(&emit[tok * width..(tok + 1) * width]);
        }
    }
    feats
}
