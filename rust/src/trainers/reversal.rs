//! Token-reversal trainer (paper §5, App D): transformer rollout fully
//! inside the compiled artifact, per-token Kondo gating, episode-level
//! bucketed backward.
//!
//! Gating is at TOKEN granularity (the paper gates tokens); the backward
//! executor works at EPISODE granularity (a sequence either enters the
//! backward batch or not), so an episode is executed iff it has at least
//! one kept token, and its weight tensor zeroes all skipped tokens.

use anyhow::Result;

use crate::algo::baseline::grouped_baseline;
use crate::algo::{BatchSignals, Method};
use crate::coordinator::batcher::{gather_rows_f32, gather_rows_i32};
use crate::coordinator::{BucketSet, Ledger};
use crate::envs::reversal::ReversalEnv;
use crate::model::{accumulate, ParamStore};
use crate::optim::{Adam, Optimizer};
use crate::runtime::{Engine, HostTensor};
use crate::utils::rng::Pcg32;

use super::EvalPoint;

#[derive(Debug, Clone)]
pub struct ReversalTrainerCfg {
    pub method: Method,
    pub lr: f64,
    pub steps: usize,
    /// sequence length H <= h_max
    pub h: usize,
    /// vocabulary size M <= vocab
    pub m: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// PPO inner epochs (ratio updates against the rollout policy)
    pub inner_epochs: usize,
}

impl Default for ReversalTrainerCfg {
    fn default() -> Self {
        ReversalTrainerCfg {
            method: Method::Pg,
            lr: 3e-4,
            steps: 300,
            h: 5,
            m: 2,
            seed: 0,
            eval_every: 10,
            inner_epochs: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ReversalRunResult {
    pub curve: Vec<EvalPoint>,
    pub ledger: Ledger,
    pub final_reward: f64,
    /// mean reward over the whole run (the paper's "solved" statistic)
    pub mean_reward: f64,
}

pub fn train_reversal(eng: &Engine, cfg: &ReversalTrainerCfg) -> Result<ReversalRunResult> {
    let man = eng.manifest();
    // pick the smallest compiled shape set that fits H (two sets are
    // compiled; masks carve out the active problem inside the artifact)
    let h_max = *man
        .constants
        .rev_sets
        .iter()
        .find(|&&hm| hm >= cfg.h)
        .unwrap_or(&man.constants.h_max);
    let prefix = format!("rev{h_max}");
    let batch = man.constants.rev_batch;
    let pad = man.constants.pad as i32;
    assert!(cfg.h <= h_max && cfg.m <= man.constants.vocab);

    let env = ReversalEnv::new(cfg.h, cfg.m, 10, 10, h_max, pad);
    assert_eq!(env.batch_size(), batch);

    let rules = man.model(&format!("reversal{h_max}"))?.to_vec();
    let mut params = ParamStore::init(&rules, cfg.seed.wrapping_mul(0x2545) ^ 0xcafe);
    let mut opt = Adam::new(cfg.lr, &params);
    let buckets = BucketSet::new(man.constants.rev_bwd_caps.clone())?;

    let mut rng = Pcg32::new(cfg.seed, 0x7265_76);
    let mut ledger = Ledger::new();
    let mut curve = Vec::new();
    let mut reward_sum = 0.0;
    let mut reward_window = Vec::new();

    let h_t = HostTensor::scalar_i32(cfg.h as i32);
    let m_t = HostTensor::scalar_i32(cfg.m as i32);

    for step in 0..cfg.steps {
        let prompts = env.sample_prompts(&mut rng);
        let prompt_t = HostTensor::i32(&[batch, h_max], prompts.tokens.clone());

        // ---- rollout (autoregressive sampling inside the artifact)
        let mut inputs = params.as_inputs();
        inputs.push(prompt_t.clone());
        inputs.push(h_t.clone());
        inputs.push(m_t.clone());
        inputs.push(HostTensor::scalar_i32(rng.next_u32() as i32 & 0x7fffffff));
        let out = eng.execute(&format!("{prefix}_rollout"), &inputs)?;
        let actions = out[0].as_i32()?.to_vec();
        let logp = out[1].as_f32()?.to_vec();
        ledger.record_forward(batch * cfg.h);

        // ---- rewards, grouped baseline, per-token signals
        let rewards = env.rewards(&prompts, &actions);
        let base = grouped_baseline(&rewards, 10);
        reward_sum += crate::utils::stats::mean(&rewards);
        reward_window.push(crate::utils::stats::mean(&rewards));

        let n_tok = batch * cfg.h;
        let mut u = vec![0.0f64; n_tok];
        let mut ell = vec![0.0f64; n_tok];
        for ep in 0..batch {
            let adv = rewards[ep] - base[ep];
            for j in 0..cfg.h {
                let t = ep * cfg.h + j;
                u[t] = adv;
                ell[t] = -(logp[ep * h_max + j] as f64);
            }
        }

        let logp_roll: Vec<f64> = ell.iter().map(|&e| -e).collect();
        for epoch in 0..cfg.inner_epochs.max(1) {
            // ratios: first epoch is on-policy; later epochs re-score the
            // sampled actions under the updated policy via rev_fwd.
            let (ell_cur, lp_old): (Vec<f64>, Option<&[f64]>) = if epoch == 0 {
                (ell.clone(), None)
            } else {
                let mut finputs = params.as_inputs();
                finputs.push(prompt_t.clone());
                finputs.push(HostTensor::i32(&[batch, h_max], actions.clone()));
                finputs.push(h_t.clone());
                finputs.push(m_t.clone());
                let fout = eng.execute(&format!("{prefix}_fwd"), &finputs)?;
                let lp_new = fout[0].as_f32()?;
                ledger.record_forward(batch * cfg.h);
                let mut e = vec![0.0f64; n_tok];
                for ep in 0..batch {
                    for j in 0..cfg.h {
                        e[ep * cfg.h + j] = -(lp_new[ep * h_max + j] as f64);
                    }
                }
                (e, Some(logp_roll.as_slice()))
            };

            let signals =
                BatchSignals { u: &u, ell: &ell_cur, logp_old: lp_old, chi_override: None };
            let decision = cfg.method.decide(&signals, &mut rng);
            if decision.keep.is_empty() {
                continue;
            }

            // ---- token keep-set -> episode list + weight tensor
            let mut ep_weights = vec![0.0f32; batch * h_max];
            let mut ep_has = vec![false; batch];
            for &t in &decision.keep {
                let ep = t / cfg.h;
                let j = t % cfg.h;
                ep_weights[ep * h_max + j] = decision.weights[t];
                ep_has[ep] = true;
            }
            let episodes: Vec<usize> = (0..batch).filter(|&e| ep_has[e]).collect();
            let kept_tokens = decision.keep.len();

            let mut acc = params.zeros_like();
            for chunk in buckets.pack(&episodes) {
                let cap = chunk.cap;
                let p_rows = gather_rows_i32(&prompts.tokens, h_max, &chunk.idx, cap);
                let a_rows = gather_rows_i32(&actions, h_max, &chunk.idx, cap);
                let w_rows = gather_rows_f32(&ep_weights, h_max, &chunk.idx, cap);
                let mut binputs = params.as_inputs();
                binputs.push(HostTensor::i32(&[cap, h_max], p_rows));
                binputs.push(HostTensor::i32(&[cap, h_max], a_rows));
                binputs.push(HostTensor::f32(&[cap, h_max], w_rows));
                binputs.push(h_t.clone());
                binputs.push(m_t.clone());
                let bout = eng.execute(&format!("{prefix}_bwd_c{cap}"), &binputs)?;
                accumulate(&mut acc, &bout[1..])?;
                // token-denominated ledger: kept tokens vs executed slots
                let share = chunk.idx.len() as f64 / episodes.len() as f64;
                ledger.record_backward(cap * cfg.h, (kept_tokens as f64 * share) as usize);
            }
            for t in acc.iter_mut() {
                for v in t.iter_mut() {
                    *v /= batch as f32;
                }
            }
            opt.step(&mut params, &acc);
        }

        let last = step + 1 == cfg.steps;
        if (step + 1) % cfg.eval_every == 0 || last {
            let recent = reward_window.iter().rev().take(10).sum::<f64>()
                / reward_window.iter().rev().take(10).count().max(1) as f64;
            curve.push(EvalPoint {
                step: step + 1,
                forward_samples: ledger.forward_samples,
                backward_kept: ledger.backward_kept,
                backward_executed: ledger.backward_executed,
                metric: recent,
                metric2: 0.0,
            });
        }
    }

    let final_reward = curve.last().map(|p| p.metric).unwrap_or(0.0);
    Ok(ReversalRunResult {
        curve,
        ledger,
        final_reward,
        mean_reward: reward_sum / cfg.steps.max(1) as f64,
    })
}
