//! Experiment configuration: TOML presets (`configs/*.toml`) + CLI
//! overrides. Two preset families ship with the repo: `scaled` (fits this
//! testbed's budget; the EXPERIMENTS.md runs) and `paper` (the paper's
//! full seed/step counts).

use std::path::Path;

use anyhow::{Context, Result};

use crate::checkpoint::CheckpointCfg;
use crate::coordinator::{Priority, ScreenCfg};
use crate::utils::toml::TomlDoc;

#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// seeds per configuration
    pub seeds: usize,
    /// MNIST gradient steps
    pub mnist_steps: usize,
    /// token-reversal gradient steps
    pub rev_steps: usize,
    /// evaluation cadence (steps)
    pub eval_every: usize,
    /// test images per evaluation
    pub eval_size: usize,
    /// Adam learning rates
    pub lr_mnist: f64,
    pub lr_rev: f64,
    /// output directory for CSVs
    pub out_dir: String,
    /// artifact directory (`"native"` selects the built-in pure-Rust testbed)
    pub artifacts_dir: String,
    /// worker threads for the sharded training coordinator (1 = serial)
    pub workers: usize,
    /// tier-1 speculative screen survival rate in (0, 1]; 1 = screening off
    pub rho_screen: f64,
    /// learning rate of the online linear draft behind the screen
    pub draft_lr: f64,
    /// batches of exact surprisal the draft absorbs before screening
    pub screen_warmup: usize,
    /// save a training checkpoint every N optimizer steps (0 = never)
    pub checkpoint_every: usize,
    /// checkpoint file path; empty = `<out_dir>/kondo.ckpt` when enabled
    pub checkpoint_path: String,
    /// resume training from this checkpoint file (empty = fresh run)
    pub resume_from: String,
    /// gate priority for DG-K methods (the Fig-5 comparison set):
    /// `delight|advantage|surprisal|abs_advantage|uniform|additive:<alpha>`.
    /// Stored as the raw knob string; `gate_priority()` parses/validates.
    pub priority: String,
    /// actor slots for the distributed runtime
    pub actors: usize,
    /// snapshot staleness: step t is computed on policy version t - lag
    pub snapshot_lag: usize,
    /// per-lag-step gate-rate decay in (0, 1]; 1 = staleness priced like fresh
    pub stale_penalty: f64,
    /// seeded fault schedule (distrib::faults grammar); empty = no faults
    pub fault_spec: String,
    /// silent-actor timeout (ms) before the learner re-dispatches
    pub heartbeat_ms: u64,
    /// per-slot respawn budget before an actor slot is left dead
    pub max_respawns: u32,
    /// distributed fleet carrier: `channel` (in-process threads) or
    /// `socket` (actor subprocesses over Unix sockets)
    pub transport: String,
    /// directory for the learner's socket file; empty = system temp dir
    pub socket_dir: String,
    /// per-frame wire read/write deadline (ms, socket transport)
    pub wire_deadline_ms: u64,
    /// base reconnect backoff (ms, socket transport; doubles per
    /// consecutive loss, capped, jittered)
    pub reconnect_backoff_ms: u64,
    /// route forward-tier GEMMs through the **non-golden** f32-fast
    /// kernels (screen/forward only, never the gated backward; DESIGN.md
    /// §13). A method-axis knob: it enters checkpoint fingerprints.
    pub f32_fast: bool,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            seeds: 3,
            mnist_steps: 1000,
            rev_steps: 200,
            eval_every: 50,
            eval_size: 1000,
            lr_mnist: 1e-3,
            lr_rev: 3e-4,
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
            workers: 1,
            rho_screen: 1.0,
            draft_lr: 1e-3,
            screen_warmup: 20,
            checkpoint_every: 0,
            checkpoint_path: String::new(),
            resume_from: String::new(),
            priority: "delight".into(),
            actors: 2,
            snapshot_lag: 0,
            stale_penalty: 1.0,
            fault_spec: String::new(),
            heartbeat_ms: 1000,
            max_respawns: 2,
            transport: "channel".into(),
            socket_dir: String::new(),
            wire_deadline_ms: 2000,
            reconnect_backoff_ms: 25,
            f32_fast: false,
        }
    }
}

impl ExpConfig {
    /// Apply a parsed TOML document on top of the current values.
    pub fn apply_doc(&mut self, doc: &TomlDoc) {
        if let Some(v) = doc.i64("exp.seeds") {
            self.seeds = v as usize;
        }
        if let Some(v) = doc.i64("exp.mnist_steps") {
            self.mnist_steps = v as usize;
        }
        if let Some(v) = doc.i64("exp.rev_steps") {
            self.rev_steps = v as usize;
        }
        if let Some(v) = doc.i64("exp.eval_every") {
            self.eval_every = v as usize;
        }
        if let Some(v) = doc.i64("exp.eval_size") {
            self.eval_size = v as usize;
        }
        if let Some(v) = doc.f64("exp.lr_mnist") {
            self.lr_mnist = v;
        }
        if let Some(v) = doc.f64("exp.lr_rev") {
            self.lr_rev = v;
        }
        if let Some(v) = doc.str("exp.out_dir") {
            self.out_dir = v.to_string();
        }
        if let Some(v) = doc.str("exp.artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.i64("exp.workers") {
            self.workers = (v.max(1)) as usize;
        }
        if let Some(v) = doc.f64("exp.rho_screen") {
            // out-of-range rates disable screening rather than panic a run
            self.rho_screen = if v > 0.0 && v <= 1.0 { v } else { 1.0 };
        }
        if let Some(v) = doc.f64("exp.draft_lr") {
            self.draft_lr = v;
        }
        if let Some(v) = doc.i64("exp.screen_warmup") {
            self.screen_warmup = v.max(0) as usize;
        }
        if let Some(v) = doc.i64("exp.checkpoint_every") {
            self.checkpoint_every = v.max(0) as usize;
        }
        if let Some(v) = doc.str("exp.checkpoint_path") {
            self.checkpoint_path = v.to_string();
        }
        if let Some(v) = doc.str("exp.resume_from") {
            self.resume_from = v.to_string();
        }
        if let Some(v) = doc.str("exp.priority") {
            self.priority = v.to_string();
        }
        if let Some(v) = doc.i64("exp.actors") {
            self.actors = (v.max(1)) as usize;
        }
        if let Some(v) = doc.i64("exp.snapshot_lag") {
            self.snapshot_lag = v.max(0) as usize;
        }
        if let Some(v) = doc.f64("exp.stale_penalty") {
            // out-of-range decays turn staleness pricing off, like rho_screen
            self.stale_penalty = if v > 0.0 && v <= 1.0 { v } else { 1.0 };
        }
        if let Some(v) = doc.str("exp.fault_spec") {
            self.fault_spec = v.to_string();
        }
        if let Some(v) = doc.i64("exp.heartbeat_ms") {
            self.heartbeat_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.i64("exp.max_respawns") {
            self.max_respawns = v.max(0) as u32;
        }
        if let Some(v) = doc.str("exp.transport") {
            self.transport = v.to_string();
        }
        if let Some(v) = doc.str("exp.socket_dir") {
            self.socket_dir = v.to_string();
        }
        if let Some(v) = doc.i64("exp.wire_deadline_ms") {
            self.wire_deadline_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.i64("exp.reconnect_backoff_ms") {
            self.reconnect_backoff_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.bool("exp.f32_fast") {
            self.f32_fast = v;
        }
    }

    /// The gate priority these knobs select, parsed and validated. A
    /// typo'd name or malformed additive alpha errors here -- loudly, at
    /// config time -- instead of silently running delight.
    pub fn gate_priority(&self) -> Result<Priority> {
        Priority::parse(&self.priority)
    }

    /// The screen configuration these knobs describe (threaded into both
    /// trainer configs by the CLI and the experiment drivers).
    pub fn screen_cfg(&self) -> ScreenCfg {
        ScreenCfg {
            rho_screen: self.rho_screen,
            draft_lr: self.draft_lr,
            warmup_batches: self.screen_warmup as u64,
        }
    }

    /// The checkpointing configuration these knobs describe, or `None`
    /// when checkpointing is off. An empty path defaults into `out_dir`.
    pub fn checkpoint_cfg(&self) -> Option<CheckpointCfg> {
        if self.checkpoint_every == 0 {
            return None;
        }
        let path = if self.checkpoint_path.is_empty() {
            format!("{}/kondo.ckpt", self.out_dir)
        } else {
            self.checkpoint_path.clone()
        };
        Some(CheckpointCfg { path, every: self.checkpoint_every })
    }

    /// The distributed-runtime configuration these knobs describe, for a
    /// given method and seed. The CLI `train distrib` arm and the `dist`
    /// experiment driver both build from here so the knob plumbing has
    /// exactly one owner. Errors on an unknown `transport` name — at
    /// config time, before a run starts.
    pub fn distrib_cfg(
        &self,
        method: crate::algo::Method,
        seed: u64,
    ) -> Result<crate::distrib::DistribCfg> {
        Ok(crate::distrib::DistribCfg {
            method,
            lr: self.lr_mnist,
            steps: self.mnist_steps,
            eval_every: self.eval_every,
            eval_size: self.eval_size,
            seed,
            actors: self.actors,
            workers: self.workers,
            lag: self.snapshot_lag,
            stale_penalty: self.stale_penalty,
            fault_spec: self.fault_spec.clone(),
            heartbeat_ms: self.heartbeat_ms,
            max_respawns: self.max_respawns,
            record_to: None,
            checkpoint: self.checkpoint_cfg(),
            resume_from: self.resume_from_opt(),
            transport: crate::distrib::TransportKind::parse(&self.transport)?,
            artifacts_dir: self.artifacts_dir.clone(),
            socket_dir: if self.socket_dir.is_empty() {
                None
            } else {
                Some(self.socket_dir.clone())
            },
            wire_deadline_ms: self.wire_deadline_ms,
            reconnect_backoff_ms: self.reconnect_backoff_ms,
            actor_bin: None,
        })
    }

    /// The resume source, or `None` for a fresh run.
    pub fn resume_from_opt(&self) -> Option<String> {
        if self.resume_from.is_empty() { None } else { Some(self.resume_from.clone()) }
    }

    /// Load a preset file on top of defaults.
    pub fn load(path: &Path) -> Result<ExpConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut cfg = ExpConfig::default();
        cfg.apply_doc(&doc);
        Ok(cfg)
    }

    /// Apply `key=value` CLI overrides (same keys as the TOML, without the
    /// `exp.` prefix). Values of the string-valued keys are auto-quoted so
    /// `artifacts_dir=native` works from a shell without TOML quoting
    /// gymnastics (`artifacts_dir='"native"'`); numeric keys keep strict
    /// parsing so typos (`workers=eight`) still error instead of silently
    /// falling back to defaults.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        const STR_KEYS: &[&str] = &[
            "out_dir",
            "artifacts_dir",
            "checkpoint_path",
            "resume_from",
            "priority",
            "fault_spec",
            "transport",
            "socket_dir",
        ];
        let quoted;
        let value_toml = if STR_KEYS.contains(&key) && !value.starts_with('"') {
            quoted = format!("\"{value}\"");
            quoted.as_str()
        } else {
            value
        };
        let doc = TomlDoc::parse(&format!("[exp]\n{key} = {value_toml}"))
            .map_err(|e| anyhow::anyhow!("bad override {key}={value}: {e}"))?;
        self.apply_doc(&doc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_doc_then_override() {
        let mut cfg = ExpConfig::default();
        let doc = TomlDoc::parse("[exp]\nseeds = 10\nlr_mnist = 0.003").unwrap();
        cfg.apply_doc(&doc);
        assert_eq!(cfg.seeds, 10);
        assert_eq!(cfg.lr_mnist, 0.003);
        cfg.apply_override("seeds", "2").unwrap();
        assert_eq!(cfg.seeds, 2);
        // untouched field keeps default
        assert_eq!(cfg.eval_every, 50);
        assert_eq!(cfg.workers, 1);
    }

    #[test]
    fn workers_override_clamps_to_one() {
        let mut cfg = ExpConfig::default();
        cfg.apply_override("workers", "4").unwrap();
        assert_eq!(cfg.workers, 4);
        cfg.apply_override("workers", "0").unwrap();
        assert_eq!(cfg.workers, 1);
    }

    #[test]
    fn screen_knobs_thread_through() {
        let mut cfg = ExpConfig::default();
        assert!(!cfg.screen_cfg().active(), "screening is off by default");
        cfg.apply_override("rho_screen", "0.25").unwrap();
        cfg.apply_override("draft_lr", "0.01").unwrap();
        cfg.apply_override("screen_warmup", "5").unwrap();
        let sc = cfg.screen_cfg();
        assert!(sc.active());
        assert_eq!(sc.rho_screen, 0.25);
        assert_eq!(sc.draft_lr, 0.01);
        assert_eq!(sc.warmup_batches, 5);
        // out-of-range rates fall back to off instead of panicking a run
        cfg.apply_override("rho_screen", "1.5").unwrap();
        assert!(!cfg.screen_cfg().active());
        cfg.apply_override("rho_screen", "0.0").unwrap();
        assert!(!cfg.screen_cfg().active());
    }

    #[test]
    fn checkpoint_knobs_thread_through() {
        let mut cfg = ExpConfig::default();
        assert!(cfg.checkpoint_cfg().is_none(), "checkpointing is off by default");
        assert!(cfg.resume_from_opt().is_none());
        cfg.apply_override("checkpoint_every", "50").unwrap();
        let ck = cfg.checkpoint_cfg().unwrap();
        assert_eq!(ck.every, 50);
        assert_eq!(ck.path, "results/kondo.ckpt", "empty path defaults into out_dir");
        // explicit path wins (bare value auto-quoted like other str keys)
        cfg.apply_override("checkpoint_path", "/tmp/run7.ckpt").unwrap();
        assert_eq!(cfg.checkpoint_cfg().unwrap().path, "/tmp/run7.ckpt");
        cfg.apply_override("resume_from", "/tmp/run7.ckpt").unwrap();
        assert_eq!(cfg.resume_from_opt().as_deref(), Some("/tmp/run7.ckpt"));
        // negative cadence clamps to off, matching the other numeric knobs
        cfg.apply_override("checkpoint_every", "-3").unwrap();
        assert!(cfg.checkpoint_cfg().is_none());
    }

    #[test]
    fn priority_knob_threads_through() {
        let mut cfg = ExpConfig::default();
        assert_eq!(cfg.gate_priority().unwrap(), Priority::Delight);
        // bare CLI values auto-quote like the other string keys, so
        // `priority=additive:0.25` works without shell quoting gymnastics
        cfg.apply_override("priority", "additive:0.25").unwrap();
        assert_eq!(cfg.gate_priority().unwrap(), Priority::Additive { alpha: 0.25 });
        for name in ["delight", "advantage", "surprisal", "abs_advantage", "uniform"] {
            cfg.apply_override("priority", name).unwrap();
            assert!(cfg.gate_priority().is_ok(), "{name}");
        }
        // a typo'd name survives the override (it is just a string) but
        // errors at parse time, before any run starts
        cfg.apply_override("priority", "delite").unwrap();
        assert!(cfg.gate_priority().is_err());
        // and the TOML path reads the same knob
        let mut cfg = ExpConfig::default();
        cfg.apply_doc(&TomlDoc::parse("[exp]\npriority = \"surprisal\"").unwrap());
        assert_eq!(cfg.gate_priority().unwrap(), Priority::Surprisal);
    }

    #[test]
    fn distrib_knobs_thread_through() {
        let mut cfg = ExpConfig::default();
        // fault_spec is a string key: commas/colons/@ pass through a bare
        // CLI override without shell quoting gymnastics
        cfg.apply_override("fault_spec", "crash@5,poison@8:nan_u:4").unwrap();
        cfg.apply_override("actors", "4").unwrap();
        cfg.apply_override("snapshot_lag", "3").unwrap();
        cfg.apply_override("stale_penalty", "0.5").unwrap();
        cfg.apply_override("heartbeat_ms", "250").unwrap();
        cfg.apply_override("max_respawns", "0").unwrap();
        let d = cfg.distrib_cfg(crate::algo::Method::Pg, 7).unwrap();
        assert_eq!(d.fault_spec, "crash@5,poison@8:nan_u:4");
        assert_eq!(d.actors, 4);
        assert_eq!(d.lag, 3);
        assert_eq!(d.stale_penalty, 0.5);
        assert_eq!(d.heartbeat_ms, 250);
        assert_eq!(d.max_respawns, 0);
        assert_eq!(d.seed, 7);
        assert_eq!(d.steps, cfg.mnist_steps);
        assert_eq!(d.transport, crate::distrib::TransportKind::Channel);
        assert_eq!(d.artifacts_dir, cfg.artifacts_dir, "actors open the same artifacts");
        assert!(d.socket_dir.is_none(), "empty socket_dir means the temp dir");
        // clamps: a zero fleet and out-of-range decay fall back sanely
        cfg.apply_override("actors", "0").unwrap();
        assert_eq!(cfg.actors, 1);
        cfg.apply_override("stale_penalty", "1.5").unwrap();
        assert_eq!(cfg.stale_penalty, 1.0);
        cfg.apply_override("heartbeat_ms", "0").unwrap();
        assert_eq!(cfg.heartbeat_ms, 1);
        // and the TOML path reads the same knobs
        let mut cfg = ExpConfig::default();
        cfg.apply_doc(&TomlDoc::parse("[exp]\nactors = 3\nfault_spec = \"stall@2:900\"").unwrap());
        assert_eq!(cfg.actors, 3);
        assert_eq!(cfg.fault_spec, "stall@2:900");
    }

    #[test]
    fn transport_knobs_thread_through() {
        let mut cfg = ExpConfig::default();
        // transport and socket_dir are string keys: bare CLI values work
        cfg.apply_override("transport", "socket").unwrap();
        cfg.apply_override("socket_dir", "/tmp/kondo-socks").unwrap();
        cfg.apply_override("wire_deadline_ms", "500").unwrap();
        cfg.apply_override("reconnect_backoff_ms", "40").unwrap();
        let d = cfg.distrib_cfg(crate::algo::Method::Pg, 0).unwrap();
        assert_eq!(d.transport, crate::distrib::TransportKind::Socket);
        assert_eq!(d.socket_dir.as_deref(), Some("/tmp/kondo-socks"));
        assert_eq!(d.wire_deadline_ms, 500);
        assert_eq!(d.reconnect_backoff_ms, 40);
        // degenerate deadlines clamp instead of disabling the wire clock
        cfg.apply_override("wire_deadline_ms", "0").unwrap();
        assert_eq!(cfg.wire_deadline_ms, 1);
        cfg.apply_override("reconnect_backoff_ms", "-5").unwrap();
        assert_eq!(cfg.reconnect_backoff_ms, 1);
        // a typo'd transport errors at config time, not mid-run
        cfg.apply_override("transport", "tcp").unwrap();
        assert!(cfg.distrib_cfg(crate::algo::Method::Pg, 0).is_err());
    }

    #[test]
    fn f32_fast_knob_threads_through() {
        let mut cfg = ExpConfig::default();
        assert!(!cfg.f32_fast, "exact kernels by default");
        // bare CLI booleans parse as TOML booleans, no quoting needed
        cfg.apply_override("f32_fast", "true").unwrap();
        assert!(cfg.f32_fast);
        cfg.apply_override("f32_fast", "false").unwrap();
        assert!(!cfg.f32_fast);
        // and the TOML path reads the same knob
        let mut cfg = ExpConfig::default();
        cfg.apply_doc(&TomlDoc::parse("[exp]\nf32_fast = true").unwrap());
        assert!(cfg.f32_fast);
    }

    #[test]
    fn string_override() {
        let mut cfg = ExpConfig::default();
        cfg.apply_override("out_dir", "\"/tmp/r\"").unwrap();
        assert_eq!(cfg.out_dir, "/tmp/r");
    }

    #[test]
    fn bare_string_override_is_auto_quoted() {
        // the CLI (and CI smoke) pass artifacts_dir=native unquoted; the
        // TOML subset only knows quoted strings, so the override layer
        // must quote bare values for the string-valued keys itself
        let mut cfg = ExpConfig::default();
        cfg.apply_override("artifacts_dir", "native").unwrap();
        assert_eq!(cfg.artifacts_dir, "native");
        cfg.apply_override("out_dir", "/tmp/spec-smoke").unwrap();
        assert_eq!(cfg.out_dir, "/tmp/spec-smoke");
        // numbers still parse as numbers, not strings
        cfg.apply_override("workers", "3").unwrap();
        assert_eq!(cfg.workers, 3);
        // ...and numeric typos still ERROR instead of silently becoming
        // strings that apply_doc drops on the floor
        assert!(cfg.apply_override("workers", "eight").is_err());
        assert!(cfg.apply_override("mnist_steps", "5oo").is_err());
        assert_eq!(cfg.workers, 3, "failed override must not change state");
    }
}
