//! Experiment configuration: TOML presets (`configs/*.toml`) + CLI
//! overrides. Two preset families ship with the repo: `scaled` (fits this
//! testbed's budget; the EXPERIMENTS.md runs) and `paper` (the paper's
//! full seed/step counts).

use std::path::Path;

use anyhow::{Context, Result};

use crate::utils::toml::TomlDoc;

#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// seeds per configuration
    pub seeds: usize,
    /// MNIST gradient steps
    pub mnist_steps: usize,
    /// token-reversal gradient steps
    pub rev_steps: usize,
    /// evaluation cadence (steps)
    pub eval_every: usize,
    /// test images per evaluation
    pub eval_size: usize,
    /// Adam learning rates
    pub lr_mnist: f64,
    pub lr_rev: f64,
    /// output directory for CSVs
    pub out_dir: String,
    /// artifact directory (`"native"` selects the built-in pure-Rust testbed)
    pub artifacts_dir: String,
    /// worker threads for the sharded training coordinator (1 = serial)
    pub workers: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            seeds: 3,
            mnist_steps: 1000,
            rev_steps: 200,
            eval_every: 50,
            eval_size: 1000,
            lr_mnist: 1e-3,
            lr_rev: 3e-4,
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
            workers: 1,
        }
    }
}

impl ExpConfig {
    /// Apply a parsed TOML document on top of the current values.
    pub fn apply_doc(&mut self, doc: &TomlDoc) {
        if let Some(v) = doc.i64("exp.seeds") {
            self.seeds = v as usize;
        }
        if let Some(v) = doc.i64("exp.mnist_steps") {
            self.mnist_steps = v as usize;
        }
        if let Some(v) = doc.i64("exp.rev_steps") {
            self.rev_steps = v as usize;
        }
        if let Some(v) = doc.i64("exp.eval_every") {
            self.eval_every = v as usize;
        }
        if let Some(v) = doc.i64("exp.eval_size") {
            self.eval_size = v as usize;
        }
        if let Some(v) = doc.f64("exp.lr_mnist") {
            self.lr_mnist = v;
        }
        if let Some(v) = doc.f64("exp.lr_rev") {
            self.lr_rev = v;
        }
        if let Some(v) = doc.str("exp.out_dir") {
            self.out_dir = v.to_string();
        }
        if let Some(v) = doc.str("exp.artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.i64("exp.workers") {
            self.workers = (v.max(1)) as usize;
        }
    }

    /// Load a preset file on top of defaults.
    pub fn load(path: &Path) -> Result<ExpConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = TomlDoc::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut cfg = ExpConfig::default();
        cfg.apply_doc(&doc);
        Ok(cfg)
    }

    /// Apply `key=value` CLI overrides (same keys as the TOML, without the
    /// `exp.` prefix).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        let doc = TomlDoc::parse(&format!("[exp]\n{key} = {value}"))
            .map_err(|e| anyhow::anyhow!("bad override {key}={value}: {e}"))?;
        self.apply_doc(&doc);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_doc_then_override() {
        let mut cfg = ExpConfig::default();
        let doc = TomlDoc::parse("[exp]\nseeds = 10\nlr_mnist = 0.003").unwrap();
        cfg.apply_doc(&doc);
        assert_eq!(cfg.seeds, 10);
        assert_eq!(cfg.lr_mnist, 0.003);
        cfg.apply_override("seeds", "2").unwrap();
        assert_eq!(cfg.seeds, 2);
        // untouched field keeps default
        assert_eq!(cfg.eval_every, 50);
        assert_eq!(cfg.workers, 1);
    }

    #[test]
    fn workers_override_clamps_to_one() {
        let mut cfg = ExpConfig::default();
        cfg.apply_override("workers", "4").unwrap();
        assert_eq!(cfg.workers, 4);
        cfg.apply_override("workers", "0").unwrap();
        assert_eq!(cfg.workers, 1);
    }

    #[test]
    fn string_override() {
        let mut cfg = ExpConfig::default();
        cfg.apply_override("out_dir", "\"/tmp/r\"").unwrap();
        assert_eq!(cfg.out_dir, "/tmp/r");
    }
}
