//! Exact tabular analysis (paper §4, App C): numerical validation of
//! Lemma 1, Propositions 1-3 and Remark 1 on the symmetric softmax bandit.
//!
//! Everything here is closed-form or Monte-Carlo over the exact bandit —
//! no artifacts and no function approximation, matching the paper's
//! "setting with exact gradients".

use crate::coordinator::KondoGate;
use crate::envs::bandit::{GamblingBandit, SymmetricBandit};
use crate::utils::math::{cosine, perp_norm2};
use crate::utils::rng::Pcg32;
use crate::utils::stats;

/// Monte-Carlo batch-gradient geometry for PG vs the zero-price Kondo gate
/// (Proposition 1 / Remark 1).
#[derive(Debug, Clone, Copy)]
pub struct GeometryStats {
    pub p: f64,
    pub batch: usize,
    /// mean cosine(batch gradient, grad J)
    pub cos_pg: f64,
    pub cos_kg: f64,
    /// mean perpendicular variance per sample
    pub varperp_pg: f64,
    pub varperp_kg: f64,
    /// mean backward passes per batch
    pub bwd_pg: f64,
    pub bwd_kg: f64,
}

/// Simulate `trials` batches of size `batch` and compare PG vs zero-price
/// hard-gated (KG) batch gradients. The baseline is b = p (expected
/// confidence), matching Eq. (2).
pub fn gradient_geometry(
    k: usize,
    p: f64,
    batch: usize,
    trials: usize,
    rng: &mut Pcg32,
) -> GeometryStats {
    let bandit = SymmetricBandit::with_p(k, 0, p);
    let grad_j = bandit.grad_j();
    let b = p; // expected-confidence baseline
    let gate = KondoGate::price(0.0);

    let mut cos_pg = Vec::with_capacity(trials);
    let mut cos_kg = Vec::with_capacity(trials);
    let mut varperp_pg = Vec::new();
    let mut varperp_kg = Vec::new();
    let mut bwd_pg = 0usize;
    let mut bwd_kg = 0usize;

    for _ in 0..trials {
        let mut gsum_pg = vec![0.0f32; k];
        let mut gsum_kg = vec![0.0f32; k];
        let mut chi = Vec::with_capacity(batch);
        let mut samples = Vec::with_capacity(batch);
        for _ in 0..batch {
            let a = bandit.sample(rng);
            let u = bandit.reward(a) - b;
            let ell = bandit.surprisal(a);
            chi.push(u * ell);
            samples.push((a, u));
        }
        let keep = gate.decide(&chi, rng).keep;
        let kept: std::collections::HashSet<usize> = keep.iter().copied().collect();
        for (i, &(a, u)) in samples.iter().enumerate() {
            let g = bandit.phi(a);
            let gi: Vec<f32> = g.iter().map(|&x| u as f32 * x).collect();
            for j in 0..k {
                gsum_pg[j] += gi[j];
            }
            varperp_pg.push(perp_norm2(&gi, &grad_j));
            bwd_pg += 1;
            if kept.contains(&i) {
                for j in 0..k {
                    gsum_kg[j] += gi[j];
                }
                varperp_kg.push(perp_norm2(&gi, &grad_j));
                bwd_kg += 1;
            }
        }
        cos_pg.push(cosine(&gsum_pg, &grad_j));
        if !keep.is_empty() {
            cos_kg.push(cosine(&gsum_kg, &grad_j));
        }
    }

    GeometryStats {
        p,
        batch,
        cos_pg: stats::mean(&cos_pg),
        cos_kg: stats::mean(&cos_kg),
        varperp_pg: stats::mean(&varperp_pg),
        varperp_kg: if varperp_kg.is_empty() { 0.0 } else { stats::mean(&varperp_kg) },
        bwd_pg: bwd_pg as f64 / trials as f64,
        bwd_kg: bwd_kg as f64 / trials as f64,
    }
}

/// Proposition 2: the additive-mix separation threshold
/// alpha*(p, K) = L / (1 + L), L = log(p(K-1)/(1-p)); 0 when L <= 0.
pub fn alpha_star(p: f64, k: usize) -> f64 {
    let l = (p * (k - 1) as f64 / (1.0 - p)).ln();
    if l <= 0.0 {
        0.0
    } else {
        l / (1.0 + l)
    }
}

/// Proposition 2 check: does f_alpha = alpha*U + (1-alpha)*ell rank the
/// correct action above incorrect ones, at baseline b = p?
pub fn additive_separates(p: f64, k: usize, alpha: f64) -> bool {
    let bandit = SymmetricBandit::with_p(k, 0, p);
    let u_c = 1.0 - p;
    let u_w = -p;
    let ell_c = bandit.surprisal(0);
    let ell_w = bandit.surprisal(1);
    let f_c = alpha * u_c + (1.0 - alpha) * ell_c;
    let f_w = alpha * u_w + (1.0 - alpha) * ell_w;
    f_c > f_w
}

/// Delight's sign consistency (Prop 2 part 1) at baseline b = p.
pub fn delight_separates(p: f64, k: usize) -> bool {
    let bandit = SymmetricBandit::with_p(k, 0, p);
    let chi_c = (1.0 - p) * bandit.surprisal(0);
    let chi_w = -p * bandit.surprisal(1);
    chi_c > 0.0 && chi_w < 0.0
}

/// Proposition 3 numbers for a gambling bandit: exact false-positive
/// probability and the delight amplification factor.
#[derive(Debug, Clone, Copy)]
pub struct GamblingStats {
    pub sigma_over_delta: f64,
    pub p_false_positive: f64,
    pub amplification: f64,
}

pub fn gambling_stats(g: &GamblingBandit) -> GamblingStats {
    GamblingStats {
        sigma_over_delta: g.sigma / g.delta,
        p_false_positive: g.p_false_positive(),
        amplification: g.gamble_surprisal(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop1_direction_and_variance() {
        let mut rng = Pcg32::seeded(21);
        let g = gradient_geometry(10, 0.1, 100, 200, &mut rng);
        // KG batch cosine ~ 1 (every kept term is the same vector)
        assert!(g.cos_kg > 0.999, "cos_kg = {}", g.cos_kg);
        // KG kills perpendicular variance
        assert!(g.varperp_kg < 1e-9, "varperp_kg = {}", g.varperp_kg);
        assert!(g.varperp_pg > 1e-4, "varperp_pg = {}", g.varperp_pg);
        // KG backward cost ~ p * B
        assert!((g.bwd_kg - 0.1 * 100.0).abs() < 3.0, "bwd_kg = {}", g.bwd_kg);
        assert_eq!(g.bwd_pg, 100.0);
    }

    #[test]
    fn remark1_cosine_scaling() {
        // cos(PG batch grad, grad J) ~ p sqrt(B) for p^2 B << 1
        let mut rng = Pcg32::seeded(22);
        let p = 0.02;
        let g1 = gradient_geometry(10, p, 25, 400, &mut rng);
        let g2 = gradient_geometry(10, p, 400, 400, &mut rng);
        // 16x batch -> ~4x cosine
        let ratio = g2.cos_pg / g1.cos_pg.max(1e-9);
        assert!(ratio > 2.0 && ratio < 8.0, "ratio = {ratio}");
        // and PG cosine is small in this regime while KG is ~1
        assert!(g1.cos_pg < 0.75, "cos_pg = {}", g1.cos_pg);
        assert!(g1.cos_kg > 0.99);
    }

    #[test]
    fn prop2_alpha_star_table() {
        // App C.3 table values
        assert!((alpha_star(0.5, 10) - 0.69).abs() < 0.01);
        assert!((alpha_star(0.5, 100) - 0.82).abs() < 0.01);
        assert!((alpha_star(0.9, 100) - 0.87).abs() < 0.01);
        assert!((alpha_star(0.5, 50_000) - 0.92).abs() < 0.01);
    }

    #[test]
    fn prop2_separation_thresholds() {
        for &(p, k) in &[(0.5, 10), (0.9, 100), (0.3, 50)] {
            let astar = alpha_star(p, k);
            assert!(delight_separates(p, k));
            // slightly above the threshold separates, slightly below fails
            assert!(additive_separates(p, k, astar + 0.02), "p={p} k={k}");
            assert!(!additive_separates(p, k, astar - 0.02), "p={p} k={k}");
        }
    }

    #[test]
    fn prop2_no_tuning_needed_below_uniform() {
        // p <= 1/K: any alpha separates (L <= 0)
        let (p, k) = (0.03, 20);
        assert_eq!(alpha_star(p, k), 0.0);
        for alpha in [0.0, 0.25, 0.5, 0.75, 1.0] {
            assert!(additive_separates(p, k, alpha));
        }
    }

    #[test]
    fn prop3_regimes() {
        let reliable = gambling_stats(&GamblingBandit::new(1.0, 0.5, 0.05, 0.01));
        let patho = gambling_stats(&GamblingBandit::new(1.0, 0.5, 5.0, 0.01));
        assert!(reliable.p_false_positive < 1e-6);
        assert!(patho.p_false_positive > 0.4);
        // the paper's slot machine: sigma/delta = 10
        assert!((patho.sigma_over_delta - 10.0).abs() < 1e-9);
        // amplification = log(1/eps)
        assert!((patho.amplification - (100.0f64).ln()).abs() < 1e-9);
    }
}
