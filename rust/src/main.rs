//! `repro` — the launcher for the kondo reproduction.
//!
//! Subcommands:
//!   repro list                          — experiments and what they reproduce
//!   repro exp <id>|all [overrides]     — regenerate a paper figure/table
//!   repro train mnist|reversal [...]   — run one training job
//!   repro stats                         — artifact inventory
//!
//! Overrides are `key=value` pairs over configs/default.toml (seeds,
//! mnist_steps, rev_steps, eval_every, eval_size, lr_mnist, lr_rev,
//! out_dir, artifacts_dir, workers, rho_screen, draft_lr, screen_warmup,
//! checkpoint_every, checkpoint_path, resume_from, priority, actors,
//! snapshot_lag, stale_penalty, fault_spec, heartbeat_ms, max_respawns,
//! transport, socket_dir, wire_deadline_ms, reconnect_backoff_ms,
//! f32_fast), plus `preset=scaled|paper` to load configs/<preset>.toml
//! first. `f32_fast=true` routes the forward/screen tier through the
//! non-golden f32 kernels (DESIGN.md §13); the gated backward stays exact.
//! `priority=delight|advantage|surprisal|abs_advantage|uniform|
//! additive:<alpha>` selects the Fig-5 gate-priority ablation for DG-K
//! methods (both `repro train` and the exp drivers honour it).
//! `repro train distrib` runs the fault-tolerant actor–learner runtime
//! (DESIGN.md §12): `mode=threaded|inline`, `record_to=PATH` to persist
//! the actor stream, `replay_from=PATH` to re-ingest a recorded one,
//! `transport=socket` to run the fleet as subprocesses over Unix sockets
//! (DESIGN.md §14). `repro actor --slot N --socket PATH [k=v...]` is the
//! subprocess entry point those fleets spawn — not for interactive use.

use std::path::Path;

use anyhow::{bail, Context, Result};

use kondo::algo::{baseline::Baseline, Method};
use kondo::config::ExpConfig;
use kondo::coordinator::{KondoGate, Priority};
use kondo::distrib::{train_distrib, DistribMode};
use kondo::exp::{self, ExpCtx};
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_config(args: &[String]) -> Result<ExpConfig> {
    let mut cfg = ExpConfig::default();
    // default preset file if present
    let default_path = Path::new("configs/default.toml");
    if default_path.exists() {
        cfg = ExpConfig::load(default_path)?;
    }
    // preset=NAME loads configs/NAME.toml on top
    for a in args {
        if let Some(name) = a.strip_prefix("preset=") {
            let p = format!("configs/{name}.toml");
            let doc = kondo::utils::toml::TomlDoc::parse(&std::fs::read_to_string(&p)?)
                .map_err(|e| anyhow::anyhow!("{p}: {e}"))?;
            cfg.apply_doc(&doc);
        }
    }
    const CFG_KEYS: &[&str] = &[
        "seeds", "mnist_steps", "rev_steps", "eval_every", "eval_size", "lr_mnist",
        "lr_rev", "out_dir", "artifacts_dir", "workers", "rho_screen", "draft_lr",
        "screen_warmup", "checkpoint_every", "checkpoint_path", "resume_from", "priority",
        "actors", "snapshot_lag", "stale_penalty", "fault_spec", "heartbeat_ms",
        "max_respawns", "transport", "socket_dir", "wire_deadline_ms",
        "reconnect_backoff_ms", "f32_fast",
    ];
    for a in args {
        if let Some((k, v)) = a.split_once('=') {
            if CFG_KEYS.contains(&k) {
                cfg.apply_override(k, v)?;
            }
        }
    }
    Ok(cfg)
}

fn real_main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("list") => {
            println!("experiments (repro exp <id>):");
            for id in exp::ALL {
                println!("  {id:<12} {}", exp::describe(id));
            }
            println!("extensions (repro exp <id> | repro exp extras):");
            for id in exp::EXTRAS {
                println!("  {id:<12} {}", exp::describe(id));
            }
            Ok(())
        }
        Some("exp") => {
            let id = args.get(1).map(String::as_str).unwrap_or("all");
            let cfg = load_config(&args[2.min(args.len())..])?;
            let eng = Engine::open(&cfg.artifacts_dir)?.with_f32_fast(cfg.f32_fast);
            // make the backend unmistakable in experiment logs: figures
            // from the native testbed must not pass as artifact runs
            println!("platform: {}", eng.platform());
            let ctx = ExpCtx { eng: &eng, cfg: &cfg };
            let ids: Vec<&str> = match id {
                "all" => exp::ALL.to_vec(),
                "extras" => exp::EXTRAS.to_vec(),
                other => vec![other],
            };
            for i in ids {
                let summary = exp::run(i, &ctx)?;
                println!("{summary}");
            }
            print_artifact_stats(&eng);
            Ok(())
        }
        Some("train") => {
            let what = args.get(1).map(String::as_str).unwrap_or("mnist");
            let rest = &args[2.min(args.len())..];
            let cfg = load_config(rest)?;
            let eng = Engine::open(&cfg.artifacts_dir)?.with_f32_fast(cfg.f32_fast);
            // the priority knob re-ranks any DG-K method's gate (a no-op
            // for ungated methods); validated before the run starts
            let method = parse_method(rest)?.with_priority(cfg.gate_priority()?);
            match what {
                "mnist" => {
                    let tcfg = MnistTrainerCfg {
                        method,
                        baseline: Baseline::Expected,
                        lr: cfg.lr_mnist,
                        steps: cfg.mnist_steps,
                        eval_every: cfg.eval_every,
                        eval_size: cfg.eval_size,
                        seed: arg_u64(rest, "seed").unwrap_or(0),
                        workers: cfg.workers,
                        screen: cfg.screen_cfg(),
                        checkpoint: cfg.checkpoint_cfg(),
                        resume_from: cfg.resume_from_opt(),
                        ..Default::default()
                    };
                    let res = train_mnist(&eng, &tcfg)?;
                    println!(
                        "final train err {:.4} | test err {:.4} | fwd {} (skipped {} of {} screened) bwd_kept {} bwd_exec {} (gate rate {:.3}, padding {:.1}%)",
                        res.final_train_err,
                        res.final_test_err,
                        res.ledger.forward_samples,
                        res.ledger.forward_skipped,
                        res.ledger.screen_samples,
                        res.ledger.backward_kept,
                        res.ledger.backward_executed,
                        res.ledger.gate_rate(),
                        100.0 * res.ledger.padding_overhead(),
                    );
                }
                "reversal" => {
                    let tcfg = ReversalTrainerCfg {
                        method,
                        lr: cfg.lr_rev,
                        steps: cfg.rev_steps,
                        h: arg_u64(rest, "h").unwrap_or(5) as usize,
                        m: arg_u64(rest, "m").unwrap_or(2) as usize,
                        seed: arg_u64(rest, "seed").unwrap_or(0),
                        eval_every: (cfg.rev_steps / 20).max(1),
                        inner_epochs: arg_u64(rest, "epochs").unwrap_or(1) as usize,
                        screen: cfg.screen_cfg(),
                        workers: cfg.workers,
                        checkpoint: cfg.checkpoint_cfg(),
                        resume_from: cfg.resume_from_opt(),
                    };
                    let res = train_reversal(&eng, &tcfg)?;
                    println!(
                        "final reward {:.4} | mean reward {:.4} | fwd {} (screened {}) bwd_kept {} bwd_exec {}",
                        res.final_reward,
                        res.mean_reward,
                        res.ledger.forward_samples,
                        res.ledger.screen_samples,
                        res.ledger.backward_kept,
                        res.ledger.backward_executed,
                    );
                }
                "distrib" => {
                    let mut dcfg = cfg.distrib_cfg(method, arg_u64(rest, "seed").unwrap_or(0))?;
                    dcfg.record_to = arg_str(rest, "record_to");
                    dcfg.actor_bin = arg_str(rest, "actor_bin");
                    let mode = match (arg_str(rest, "replay_from"), arg_str(rest, "mode")) {
                        (Some(path), _) => DistribMode::Replay(path),
                        (None, Some(m)) if m == "inline" => DistribMode::Inline,
                        (None, Some(m)) if m == "threaded" => DistribMode::Threaded,
                        (None, None) => DistribMode::Threaded,
                        (None, Some(other)) => {
                            bail!("unknown distrib mode '{other}' (threaded|inline)")
                        }
                    };
                    let res = train_distrib(&eng, &dcfg, &mode)?;
                    // one greppable line per fault counter: CI's smoke
                    // test asserts recovery happened from this output
                    println!(
                        "final train err {:.4} | test err {:.4} | fwd {} bwd_kept {} bwd_exec {}",
                        res.final_train_err,
                        res.final_test_err,
                        res.ledger.forward_samples,
                        res.ledger.backward_kept,
                        res.ledger.backward_executed,
                    );
                    println!(
                        "distrib: actor_crashes={} actor_restarts={} timeouts={} shed={} quarantined={} quarantined_batches={} stale={} stale_kept={} wire_corrupt_frames={} wire_reconnects={} handshake_rejects={}",
                        res.ledger.actor_crashes,
                        res.ledger.actor_restarts,
                        res.ledger.actor_timeouts,
                        res.ledger.shed_samples,
                        res.ledger.quarantined_samples,
                        res.ledger.quarantined_batches,
                        res.ledger.stale_samples,
                        res.ledger.stale_kept,
                        res.ledger.wire_corrupt_frames,
                        res.ledger.wire_reconnects,
                        res.ledger.handshake_rejects,
                    );
                }
                other => bail!("unknown trainer '{other}' (mnist|reversal|distrib)"),
            }
            print_artifact_stats(&eng);
            Ok(())
        }
        // subprocess entry point for socket-transport fleets; spawned by
        // the learner, speaks the distrib::wire protocol on --socket
        Some("actor") => run_actor_proc(&args[1..]),
        Some("stats") => {
            let cfg = load_config(&args[1.min(args.len())..])?;
            let eng = Engine::open(&cfg.artifacts_dir)?;
            let man = eng.manifest();
            println!("platform: {}", eng.platform());
            println!("artifacts ({}):", man.artifacts.len());
            for (name, sig) in &man.artifacts {
                let in_el: usize = sig.inputs.iter().map(|t| t.numel()).sum();
                let out_el: usize = sig.outputs.iter().map(|t| t.numel()).sum();
                println!(
                    "  {name:<18} {} inputs ({in_el:>8} elems) -> {} outputs ({out_el:>8} elems)",
                    sig.inputs.len(),
                    sig.outputs.len()
                );
            }
            for (model, rules) in &man.models {
                let n: usize = rules.iter().map(|r| r.numel()).sum();
                println!("model {model}: {} tensors, {} params", rules.len(), n);
            }
            Ok(())
        }
        Some("help") | None => {
            println!(
                "usage: repro <list|exp|train|stats>\n  repro exp fig1 seeds=5 mnist_steps=2000\n  repro exp all preset=scaled\n  repro train reversal method=dgk_rho0.03 h=10 m=2\n  repro train mnist method=dg\n  repro train mnist method=dgk_rho0.25 priority=additive:0.2\n  repro train distrib method=dgk_rho0.25 actors=4 snapshot_lag=3 fault_spec=crash@5\n  repro train distrib transport=socket actors=2 fault_spec=disconnect@4,bitflip@6:17\n  repro train distrib mode=inline record_to=out/stream.json"
            );
            Ok(())
        }
        Some(other) => bail!("unknown command '{other}' (try `repro help`)"),
    }
}

/// Parse `repro actor --slot N --socket PATH [k=v...]` and run the actor
/// loop to completion. Accepts both `--flag value` (the fields every
/// spawn needs) and `k=v` (the tunables) so the learner's spawn line
/// stays greppable in `ps` output.
fn run_actor_proc(rest: &[String]) -> Result<()> {
    let mut socket: Option<String> = None;
    let mut slot: Option<usize> = None;
    let mut seed = 0u64;
    let mut fingerprint = 0u64;
    let mut artifacts_dir = String::from("native");
    let mut f32_fast = false;
    let mut deadline_ms = 2000u64;
    let mut i = 0;
    while i < rest.len() {
        let a = rest[i].as_str();
        match a {
            "--slot" => {
                slot = Some(rest.get(i + 1).context("actor: --slot needs a value")?.parse()?);
                i += 2;
            }
            "--socket" => {
                socket = Some(rest.get(i + 1).context("actor: --socket needs a value")?.clone());
                i += 2;
            }
            _ => {
                let Some((k, v)) = a.split_once('=') else {
                    bail!("actor: unexpected argument '{a}'");
                };
                match k {
                    "slot" => slot = Some(v.parse()?),
                    "socket" => socket = Some(v.to_string()),
                    "seed" => seed = v.parse()?,
                    // shipped as 16 hex digits; a mangled value simply
                    // fails the handshake instead of erroring here
                    "fingerprint" => {
                        fingerprint = u64::from_str_radix(v, 16)
                            .with_context(|| format!("actor: bad fingerprint '{v}'"))?
                    }
                    "artifacts_dir" => artifacts_dir = v.to_string(),
                    "f32_fast" => f32_fast = v == "1" || v == "true",
                    "deadline_ms" => deadline_ms = v.parse::<u64>()?.max(1),
                    other => bail!("actor: unknown key '{other}'"),
                }
                i += 1;
            }
        }
    }
    let acfg = kondo::distrib::ActorProcCfg {
        socket: socket.context("actor: --socket PATH required")?.into(),
        slot: slot.context("actor: --slot N required")?,
        seed,
        fingerprint,
        artifacts_dir,
        f32_fast,
        deadline: std::time::Duration::from_millis(deadline_ms),
    };
    kondo::distrib::run_actor(&acfg)
}

fn arg_u64(args: &[String], key: &str) -> Option<u64> {
    args.iter()
        .find_map(|a| a.strip_prefix(&format!("{key}=")))
        .and_then(|v| v.parse().ok())
}

fn arg_str(args: &[String], key: &str) -> Option<String> {
    args.iter()
        .find_map(|a| a.strip_prefix(&format!("{key}=")))
        .map(String::from)
}

fn parse_method(args: &[String]) -> Result<Method> {
    let name = args
        .iter()
        .find_map(|a| a.strip_prefix("method="))
        .unwrap_or("dg");
    Ok(match name {
        "pg" => Method::Pg,
        "dg" => Method::Dg,
        "ppo" => Method::Ppo { eps: 0.2 },
        "pmpo" => Method::Pmpo { alpha: 1.0 },
        "dgk_lam0" => {
            Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight }
        }
        other => {
            if let Some(rho) = other.strip_prefix("dgk_rho") {
                let rho: f64 = rho.parse()?;
                Method::DgK { gate: KondoGate::rate(rho), priority: Priority::Delight }
            } else {
                bail!("unknown method '{other}' (pg|dg|ppo|pmpo|dgk_lam0|dgk_rho<r>)")
            }
        }
    })
}

fn print_artifact_stats(eng: &Engine) {
    let stats = eng.stats();
    if stats.is_empty() {
        return;
    }
    println!("\nartifact timings:");
    for (name, st) in stats {
        if st.calls > 0 {
            println!(
                "  {name:<18} {:>6} calls, {:>8.2} ms/call (compile {:.2}s)",
                st.calls,
                1e3 * st.total_secs / st.calls as f64,
                st.compile_secs
            );
        }
    }
}
