//! Model parameter store: named tensors in artifact-argument order.
//!
//! The Rust side owns parameters (the Python layer only defines shapes and
//! init rules in the manifest); every training step marshals them as the
//! leading artifact inputs and applies optimizer updates to the host copy.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::{kernels::WeightPack, tensor, HostTensor, InitKind, InitRule};
use crate::utils::rng::Pcg32;

#[derive(Debug, Clone)]
pub struct ParamStore {
    rules: Vec<InitRule>,
    tensors: Vec<Vec<f32>>,
    /// bumped on every mutable tensor access — the pack-cache key: a
    /// `WeightPack` built at version v is valid exactly while the store
    /// stays at v (checked by `BackwardStage`'s stale-marshal guard in
    /// debug builds)
    version: u64,
}

impl ParamStore {
    /// Initialize parameters from manifest init rules, deterministically in
    /// `seed` (normal / zeros / ones — mirrors python init exactly in law).
    pub fn init(rules: &[InitRule], seed: u64) -> ParamStore {
        let mut rng = Pcg32::new(seed, 0x9d2c5680);
        let tensors = rules
            .iter()
            .map(|r| {
                let n = r.numel();
                match r.kind {
                    InitKind::Normal { scale } => {
                        (0..n).map(|_| (rng.normal() * scale) as f32).collect()
                    }
                    InitKind::Zeros => vec![0.0; n],
                    InitKind::Ones => vec![1.0; n],
                }
            })
            .collect();
        ParamStore { rules: rules.to_vec(), tensors, version: 0 }
    }

    /// The pack-cache key: increments on every mutable tensor access.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    pub fn n_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn rules(&self) -> &[InitRule] {
        &self.rules
    }

    pub fn tensor(&self, i: usize) -> &[f32] {
        &self.tensors[i]
    }

    pub fn tensor_mut(&mut self, i: usize) -> &mut [f32] {
        self.version += 1;
        &mut self.tensors[i]
    }

    pub fn by_name(&self, name: &str) -> Option<&[f32]> {
        self.rules
            .iter()
            .position(|r| r.name == name)
            .map(|i| self.tensors[i].as_slice())
    }

    /// Parameters as the leading artifact inputs (fresh allocation).
    /// Two-dimensional tensors get their GEMM [`WeightPack`] built here,
    /// so every consumer of a marshalled parameter list — including the
    /// eval path, which marshals once per sweep — hands the native
    /// kernels pre-packed weights.
    pub fn as_inputs(&self) -> Vec<HostTensor> {
        self.rules
            .iter()
            .zip(&self.tensors)
            .map(|(r, t)| match r.shape.as_slice() {
                &[k, n] => HostTensor::f32_packed(
                    &r.shape,
                    t.clone(),
                    Arc::new(WeightPack::new(t, k, n, self.version)),
                ),
                _ => HostTensor::f32(&r.shape, t.clone()),
            })
            .collect()
    }

    /// Refresh a reusable marshalling buffer with the current parameter
    /// values. When `out` already has the right layout (the steady state:
    /// one buffer per training run, refreshed after each optimizer step)
    /// this is a pure `copy_from_slice` with no allocation; otherwise the
    /// buffer is (re)built from scratch.
    ///
    /// Packing happens here, beside marshalling: each 2-D tensor's
    /// [`WeightPack`] is refilled in place (`Arc::get_mut` — nobody holds
    /// the pack between steps, so the steady state never allocates),
    /// keyed by the current [`ParamStore::version`]. One pack per weight
    /// matrix per step, shared by reference across every forward shard
    /// and backward chunk — never packed per call.
    ///
    /// The rule is deliberately uniform ("every 2-D tensor"), not
    /// consumer-aware: the reversal model's `attn` (8x8) and `emit`
    /// (9x8) tables get packs no kernel reads, but refilling those 136
    /// elements per step is noise next to the step itself, and the
    /// uniform rule keeps marshalling free of per-model knowledge.
    pub fn marshal_into(&self, out: &mut Vec<HostTensor>) {
        if out.len() != self.tensors.len() {
            *out = self.as_inputs();
            return;
        }
        for ((rule, src), dst) in self.rules.iter().zip(&self.tensors).zip(out.iter_mut()) {
            match dst {
                HostTensor::F32 { shape, data, pack }
                    if shape.as_slice() == rule.shape.as_slice() && data.len() == src.len() =>
                {
                    data.copy_from_slice(src);
                    if let &[k, n] = rule.shape.as_slice() {
                        match pack.as_mut().and_then(Arc::get_mut) {
                            Some(p) if p.k() == k && p.n() == n => p.refill(src, self.version),
                            _ => *pack = Some(Arc::new(WeightPack::new(src, k, n, self.version))),
                        }
                    }
                }
                _ => {
                    *dst = match rule.shape.as_slice() {
                        &[k, n] => HostTensor::f32_packed(
                            &rule.shape,
                            src.clone(),
                            Arc::new(WeightPack::new(src, k, n, self.version)),
                        ),
                        _ => HostTensor::f32(&rule.shape, src.clone()),
                    }
                }
            }
        }
    }

    /// Validate a gradient tensor list (bwd artifact outputs after the loss).
    pub fn check_grads(&self, grads: &[HostTensor]) -> Result<()> {
        if grads.len() != self.tensors.len() {
            bail!("got {} grad tensors, expected {}", grads.len(), self.tensors.len());
        }
        for (g, r) in grads.iter().zip(&self.rules) {
            if g.shape() != r.shape.as_slice() {
                bail!("grad for '{}': shape {:?} != {:?}", r.name, g.shape(), r.shape);
            }
        }
        Ok(())
    }

    /// Accumulate `other`-scaled gradients into an f32 accumulator with the
    /// same layout (used when a gated batch spans several buckets).
    pub fn zeros_like(&self) -> Vec<Vec<f32>> {
        self.tensors.iter().map(|t| vec![0.0; t.len()]).collect()
    }

    /// Overwrite every tensor from checkpointed values. All lengths are
    /// validated before any write, so a corrupt checkpoint cannot leave the
    /// store half-restored (and cannot panic). Bumps the version so stale
    /// `WeightPack`s are rebuilt on the next marshal.
    pub fn restore_tensors(&mut self, tensors: &[Vec<f32>]) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!(
                "checkpoint has {} tensors, model expects {}",
                tensors.len(),
                self.tensors.len()
            );
        }
        for i in 0..tensors.len() {
            if tensors[i].len() != self.tensors[i].len() {
                bail!(
                    "tensor '{}': checkpoint length {} != model length {}",
                    self.rules[i].name,
                    tensors[i].len(),
                    self.tensors[i].len()
                );
            }
        }
        for i in 0..tensors.len() {
            self.tensors[i].copy_from_slice(&tensors[i]);
        }
        self.version += 1;
        Ok(())
    }
}

/// Gradient accumulator matching a ParamStore layout.
pub fn accumulate(acc: &mut [Vec<f32>], grads: &[HostTensor]) -> Result<()> {
    if acc.len() != grads.len() {
        bail!("accumulator arity mismatch");
    }
    for (a, g) in acc.iter_mut().zip(grads) {
        let gs = g.as_f32()?;
        if a.len() != gs.len() {
            bail!("accumulator length mismatch");
        }
        for (x, &y) in a.iter_mut().zip(gs) {
            *x += y;
        }
    }
    Ok(())
}

/// Hot-path variant of [`accumulate`]: consumes the gradient tensors and
/// hands their buffers back to the tensor arena once summed — this is
/// where per-chunk gradient allocations return to the pool, closing the
/// take/recycle cycle of the backward stage.
pub fn accumulate_recycle(acc: &mut [Vec<f32>], grads: Vec<HostTensor>) -> Result<()> {
    if acc.len() != grads.len() {
        bail!("accumulator arity mismatch");
    }
    for (a, g) in acc.iter_mut().zip(grads) {
        {
            let gs = g.as_f32()?;
            if a.len() != gs.len() {
                bail!("accumulator length mismatch");
            }
            for (x, &y) in a.iter_mut().zip(gs) {
                *x += y;
            }
        }
        tensor::recycle_tensor(g);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules() -> Vec<InitRule> {
        vec![
            InitRule {
                name: "w".into(),
                shape: vec![4, 3],
                kind: InitKind::Normal { scale: 0.5 },
            },
            InitRule { name: "b".into(), shape: vec![3], kind: InitKind::Zeros },
            InitRule { name: "s".into(), shape: vec![3], kind: InitKind::Ones },
        ]
    }

    #[test]
    fn init_respects_rules() {
        let p = ParamStore::init(&rules(), 1);
        assert_eq!(p.n_tensors(), 3);
        assert_eq!(p.n_scalars(), 18);
        assert!(p.tensor(0).iter().any(|&x| x != 0.0));
        assert!(p.tensor(1).iter().all(|&x| x == 0.0));
        assert!(p.tensor(2).iter().all(|&x| x == 1.0));
        assert_eq!(p.by_name("b").unwrap().len(), 3);
        assert!(p.by_name("nope").is_none());
    }

    #[test]
    fn init_deterministic_and_seed_sensitive() {
        let a = ParamStore::init(&rules(), 7);
        let b = ParamStore::init(&rules(), 7);
        let c = ParamStore::init(&rules(), 8);
        assert_eq!(a.tensor(0), b.tensor(0));
        assert_ne!(a.tensor(0), c.tensor(0));
    }

    #[test]
    fn normal_scale_applied() {
        let big = vec![InitRule {
            name: "w".into(),
            shape: vec![10_000],
            kind: InitKind::Normal { scale: 0.02 },
        }];
        let p = ParamStore::init(&big, 3);
        let var: f64 =
            p.tensor(0).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / 10_000.0;
        assert!((var.sqrt() - 0.02).abs() < 0.002, "std {}", var.sqrt());
    }

    #[test]
    fn marshal_into_reuses_buffer_and_tracks_updates() {
        let mut p = ParamStore::init(&rules(), 7);
        let mut buf = Vec::new();
        p.marshal_into(&mut buf);
        assert_eq!(buf, p.as_inputs());
        // mutate a parameter; the refreshed buffer must match, reusing the
        // existing tensor allocations (same layout, no reallocation path)
        p.tensor_mut(0)[0] += 1.0;
        let before_ptr = buf[0].as_f32().unwrap().as_ptr();
        p.marshal_into(&mut buf);
        assert_eq!(buf, p.as_inputs());
        assert_eq!(buf[0].as_f32().unwrap().as_ptr(), before_ptr);
    }

    #[test]
    fn marshal_packs_2d_tensors_and_refills_in_place() {
        let mut p = ParamStore::init(&rules(), 7);
        let mut buf = Vec::new();
        p.marshal_into(&mut buf);
        // the [4,3] matrix is packed; the 1-D tensors are not
        let pack = buf[0].pack().expect("2-D tensor must carry a pack");
        assert_eq!(pack.unpack(), p.tensor(0));
        assert_eq!(pack.version(), p.version());
        assert!(buf[1].pack().is_none() && buf[2].pack().is_none());
        // a refresh after mutation refills the same pack allocation
        // (Arc refcount 1 between steps) and tracks the new version
        p.tensor_mut(0)[0] += 2.0;
        let v = p.version();
        p.marshal_into(&mut buf);
        let pack = buf[0].pack().unwrap();
        assert_eq!(pack.version(), v);
        assert_eq!(pack.unpack(), p.tensor(0));
        // as_inputs packs identically
        let fresh = p.as_inputs();
        assert_eq!(fresh[0].pack().unwrap().unpack(), p.tensor(0));
    }

    #[test]
    fn version_bumps_on_mutable_access_only() {
        let mut p = ParamStore::init(&rules(), 7);
        let v0 = p.version();
        let _ = p.tensor(0);
        let _ = p.by_name("w");
        assert_eq!(p.version(), v0, "read access must not bump the version");
        p.tensor_mut(1);
        assert_eq!(p.version(), v0 + 1);
    }

    #[test]
    fn accumulate_adds() {
        let p = ParamStore::init(&rules(), 1);
        let mut acc = p.zeros_like();
        let g: Vec<HostTensor> = p
            .rules()
            .iter()
            .map(|r| HostTensor::f32(&r.shape, vec![1.0; r.numel()]))
            .collect();
        accumulate(&mut acc, &g).unwrap();
        accumulate(&mut acc, &g).unwrap();
        assert!(acc[0].iter().all(|&x| x == 2.0));
    }

    #[test]
    fn accumulate_recycle_matches_accumulate() {
        let p = ParamStore::init(&rules(), 1);
        let g: Vec<HostTensor> = p
            .rules()
            .iter()
            .map(|r| HostTensor::f32(&r.shape, vec![2.0; r.numel()]))
            .collect();
        let mut a = p.zeros_like();
        let mut b = p.zeros_like();
        accumulate(&mut a, &g).unwrap();
        accumulate_recycle(&mut b, g).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn check_grads_rejects_bad_shapes() {
        let p = ParamStore::init(&rules(), 1);
        let bad = vec![
            HostTensor::f32(&[4, 3], vec![0.0; 12]),
            HostTensor::f32(&[4], vec![0.0; 4]), // wrong
            HostTensor::f32(&[3], vec![0.0; 3]),
        ];
        assert!(p.check_grads(&bad).is_err());
    }
}
