//! `kondo`: Rust + JAX + Pallas reproduction of *"Does This Gradient Spark
//! Joy?"* -- the Kondo gate over the Delightful Policy Gradient.
//!
//! Three-layer architecture (see DESIGN.md): Pallas kernels (L1) and JAX
//! models (L2) are AOT-compiled to HLO-text artifacts at build time; this
//! crate is the L3 coordinator that owns the training loop, the Kondo gate,
//! the bucketed backward executor, every environment/substrate, and the
//! experiment harness that regenerates each figure of the paper.

pub mod algo;
pub mod bandit_math;
pub mod config;
pub mod coordinator;
pub mod envs;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod trainers;
pub mod utils;
