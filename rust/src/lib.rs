//! `kondo`: Rust + JAX + Pallas reproduction of *"Does This Gradient Spark
//! Joy?"* -- the Kondo gate over the Delightful Policy Gradient.
//!
//! Three-layer architecture (see DESIGN.md): Pallas kernels (L1) and JAX
//! models (L2) are AOT-compiled to HLO-text artifacts at build time; this
//! crate is the L3 coordinator that owns the training loop, the Kondo gate,
//! the bucketed backward executor, every environment/substrate, and the
//! experiment harness that regenerates each figure of the paper.
//!
//! # Sharded training (DESIGN.md §"L3 parallelism")
//!
//! The coordinator shards every training step across a worker pool
//! ([`coordinator::pool`], the `workers` knob in [`config::ExpConfig`]):
//! forward execution and delight scoring run per contiguous shard, the
//! Kondo gate resolves one batch-global quantile price over the merged
//! chi scores, and the bucketed backward chunks execute concurrently with
//! gradients merged in chunk order. [`trainers::GatedLoop`] is the shared
//! substrate both trainers run on, structured as the explicit L4
//! screening pipeline ([`coordinator::pipeline`], DESIGN.md §8): a warm
//! draft model pre-gates each batch at `rho_screen` with one dot product
//! per sample, only the survivors pay the full forward (packed through
//! the forward capacity ladder), and the Kondo gate then prices the
//! backward over the survivors' exact delight -- a two-tier gate.
//!
//! # Determinism contract
//!
//! With the hard gate (eta = 0) a training trajectory is a pure function
//! of the seed, bit-identical for every `workers` value: per-sample
//! randomness comes from `unit_rng(seed, step, sample)` streams, backends
//! compute output rows independently (see [`runtime::native`]), and all
//! cross-shard merges happen in fixed batch/chunk order. Locked by
//! `rust/tests/gated_e2e.rs`.
//!
//! # Backends
//!
//! [`runtime::Engine`] fronts two interchangeable backends: the PJRT
//! engine over compiled HLO artifacts (`Engine::new`), and the pure-Rust
//! native testbed (`Engine::native_testbed()`) implementing the same
//! artifact contract -- the substrate tests and benches run on in this
//! offline build.

pub mod algo;
pub mod bandit_math;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod distrib;
pub mod envs;
pub mod exp;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod trainers;
pub mod utils;
