//! Offline stub of the xla-rs API surface used by `kondo::runtime`.
//!
//! The build environment has no network access and no PJRT shared
//! library, so the real `xla` bindings cannot be built here. This stub
//! keeps the PJRT integration code in `runtime/engine.rs` compiling
//! unchanged; the host-side `Literal` container is fully functional
//! (construction, reshape, readback), while everything that would need
//! the real runtime -- loading HLO text, creating a PJRT client,
//! compiling, executing -- returns a clear `Error` at runtime. Training
//! and tests use `Engine::native_testbed()` instead (see
//! `runtime/native.rs`); swapping the real bindings back in is a one-line
//! change in rust/Cargo.toml.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    fn unavailable(what: &str) -> Error {
        Error::new(format!(
            "{what} requires the real XLA/PJRT runtime, which is not available in this \
             offline build (the `xla` crate is a vendored stub); use \
             Engine::native_testbed() or link the real xla-rs bindings"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Internal typed buffer (public only because `NativeType` names it).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host-side literal: shape + typed buffer. Fully functional (the L3 side
/// marshals tensors through this type even in the stub build).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types a `Literal` can hold (f32 and i32 are the only dtypes the
/// artifact contract uses).
pub trait NativeType: sealed::Sealed + Copy {
    fn wrap(data: Vec<Self>) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<f32>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::I32(data)
    }
    fn unwrap(data: &Data) -> Option<Vec<i32>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements into dims {dims:?}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Read the buffer back as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error::new("to_vec: dtype mismatch"))
    }

    /// Destructure a tuple literal. Stub literals are never tuples (tuples
    /// only come back from real PJRT execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple on an execution result"))
    }
}

/// Parsed HLO module. Only constructible via the real runtime.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[derive(Clone)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_calls_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("native_testbed"));
    }
}
