//! Offline shim of the `anyhow` error-handling API.
//!
//! The build environment for this repo has no network or registry access,
//! so the real `anyhow` crate cannot be fetched. This shim implements the
//! exact subset the workspace uses -- `Error`, `Result`, `anyhow!`,
//! `bail!`, and the `Context` extension trait for `Result` and `Option` --
//! with the same observable formatting behaviour (`{e}` prints the
//! outermost message, `{e:#}` prints the whole context chain joined by
//! `": "`, `{e:?}` prints the chain as a "Caused by" list). Swapping the
//! real crate back in is a one-line change in rust/Cargo.toml.

use std::fmt;

/// A dynamic error: an outermost message plus the chain of wrapped causes
/// (outermost first, like `anyhow::Error`'s context chain).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (innermost stays last).
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, outermost first, joined by ": ".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Lets `?` convert any std error (io, parse, ...) into `Error`. `Error`
// itself deliberately does not implement `std::error::Error`, exactly like
// the real anyhow, so this blanket impl cannot overlap the reflexive
// `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension: attach a message to the error of a `Result`, or turn
/// an `Option::None` into an error.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` / `anyhow!("{} ...", args)` / `anyhow!(err)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(...)`: early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad input {}", 3);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "bad input 3");
        let e = anyhow!("plain {x}", x = 2);
        assert_eq!(format!("{e}"), "plain 2");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("x").is_err());
    }
}
