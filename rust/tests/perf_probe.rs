use kondo::algo::Method;
use kondo::runtime::Engine;
use kondo::trainers::{train_reversal, ReversalTrainerCfg};

#[test]
fn per_artifact_timing() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() { return }
    let eng = Engine::new(&dir).unwrap();
    let cfg = ReversalTrainerCfg { method: Method::Dg, steps: 5, h: 10, m: 2, seed: 0, eval_every: 5, ..Default::default() };
    train_reversal(&eng, &cfg).unwrap();
    for (name, st) in eng.stats() {
        println!("{name}: calls={} mean={:.1}ms compile={:.1}s", st.calls, 1e3*st.total_secs/st.calls.max(1) as f64, st.compile_secs);
    }
}
