//! Tier-1 schema lock on the committed `BENCH_e2e.json` perf-trajectory
//! file: a hand-edited, truncated, or stale (schema-1) file fails the
//! test suite instead of silently corrupting the PR-over-PR record.
//!
//! Schema 2:
//! ```json
//! {
//!   "schema": 2,
//!   "note": "...",
//!   "benches": {
//!     "<bench>": {
//!       "platform": "<string>",
//!       "entries": [
//!         {"section": s, "method": s, "workers": int >= 1,
//!          "mean_ns_per_step": num > 0, "unit": s,
//!          "throughput_per_s": num >= 0,
//!          "throughput_per_s_per_worker": num >= 0,
//!          // optional roofline columns (kernel bench only):
//!          "bytes_per_call": num > 0, "gbytes_per_s": num >= 0,
//!          "simd": 0 | 1}
//!       ]
//!     }
//!   }
//! }
//! ```
//! Sections may have empty `entries` only while `platform` is the
//! `"unmeasured"` skeleton (no toolchain has populated the file yet); a
//! measured platform with no entries is a stale or hand-gutted file.

use kondo::utils::json::Json;

fn load() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_e2e.json");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("BENCH_e2e.json must exist at the repo root: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("BENCH_e2e.json is not valid JSON: {e}"))
}

fn require_num(entry: &Json, key: &str, what: &str) -> f64 {
    entry
        .get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("{what}: missing or non-numeric '{key}'"))
}

fn require_str<'j>(entry: &'j Json, key: &str, what: &str) -> &'j str {
    let s = entry
        .get(key)
        .and_then(Json::as_str)
        .unwrap_or_else(|| panic!("{what}: missing or non-string '{key}'"));
    assert!(!s.is_empty(), "{what}: '{key}' is empty");
    s
}

#[test]
fn bench_json_matches_schema_2() {
    let doc = load();
    assert_eq!(
        doc.get("schema").and_then(Json::as_f64),
        Some(2.0),
        "BENCH_e2e.json must be schema 2 (a schema-1 or unversioned file is stale)"
    );
    require_str(&doc, "note", "top level");
    let benches = doc
        .get("benches")
        .and_then(Json::as_obj)
        .expect("top level must hold a 'benches' object");
    for required in ["e2e_step", "kernels"] {
        assert!(
            benches.contains_key(required),
            "'benches' must keep a '{required}' section (benches merge-write; \
             losing a section means the file was hand-edited or clobbered)"
        );
    }

    let known_units = ["samples", "tokens", "gflops"];
    for (name, section) in benches {
        let what = format!("bench section '{name}'");
        let platform = require_str(section, "platform", &what);
        let entries = section
            .get("entries")
            .and_then(Json::as_arr)
            .unwrap_or_else(|| panic!("{what}: missing 'entries' array"));
        if platform != "unmeasured" {
            assert!(
                !entries.is_empty(),
                "{what}: measured platform '{platform}' with zero entries — stale file"
            );
        }
        for (i, entry) in entries.iter().enumerate() {
            let what = format!("bench '{name}' entry {i}");
            require_str(entry, "section", &what);
            require_str(entry, "method", &what);
            let unit = require_str(entry, "unit", &what);
            assert!(
                known_units.contains(&unit),
                "{what}: unknown unit '{unit}' (expected one of {known_units:?})"
            );
            let workers = require_num(entry, "workers", &what);
            assert!(
                workers >= 1.0 && workers.fract() == 0.0,
                "{what}: workers must be a positive integer, got {workers}"
            );
            let ns = require_num(entry, "mean_ns_per_step", &what);
            assert!(ns > 0.0 && ns.is_finite(), "{what}: mean_ns_per_step {ns} not positive");
            let tput = require_num(entry, "throughput_per_s", &what);
            assert!(tput >= 0.0 && tput.is_finite(), "{what}: bad throughput {tput}");
            let per_worker = require_num(entry, "throughput_per_s_per_worker", &what);
            assert!(
                per_worker >= 0.0 && per_worker <= tput * 1.0001 + 1e-9,
                "{what}: per-worker throughput {per_worker} exceeds total {tput}"
            );
            // optional extras are allowlisted: an unknown key means the
            // sink and this lock disagree (or the file was hand-edited)
            let known = [
                "section",
                "method",
                "unit",
                "workers",
                "mean_ns_per_step",
                "throughput_per_s",
                "throughput_per_s_per_worker",
                "bytes_per_call",
                "gbytes_per_s",
                "simd",
            ];
            for key in entry.as_obj().unwrap().keys() {
                assert!(known.contains(&key.as_str()), "{what}: unknown key '{key}'");
            }
            if let Some(b) = entry.get("bytes_per_call").and_then(Json::as_f64) {
                assert!(b > 0.0 && b.is_finite(), "{what}: bad bytes_per_call {b}");
            }
            if let Some(g) = entry.get("gbytes_per_s").and_then(Json::as_f64) {
                assert!(g >= 0.0 && g.is_finite(), "{what}: bad gbytes_per_s {g}");
            }
            if let Some(s) = entry.get("simd").and_then(Json::as_f64) {
                assert!(s == 0.0 || s == 1.0, "{what}: simd must be 0 or 1, got {s}");
            }
        }
    }
}

#[test]
fn bench_json_skeleton_is_what_a_report_would_write() {
    // the committed skeleton and the bench sink must agree on shape: a
    // fresh report writing over the skeleton yields schema-2 again and
    // keeps the other section (the merge contract the benches rely on)
    let doc = load();
    let benches = doc.get("benches").and_then(Json::as_obj).unwrap();
    // every section a report writes is exactly {platform, entries}
    for (name, section) in benches {
        let obj = section
            .as_obj()
            .unwrap_or_else(|| panic!("section '{name}' must be an object"));
        assert_eq!(
            obj.keys().map(String::as_str).collect::<Vec<_>>(),
            vec!["entries", "platform"],
            "section '{name}' must hold exactly entries + platform"
        );
    }
}
