//! Scalar <-> SIMD equivalence lock (DESIGN.md §13): every dispatched
//! kernel must be **bitwise** identical to its scalar twin, across ragged
//! shapes — `n % PANEL != 0`, `k % LANES != 0`, empty batches — and
//! every `KernelTune` blocking. The suite is deliberately NOT
//! feature-gated: both twins exist in every build, so without `--features
//! simd` (or off-AVX2) it degrades to scalar-vs-scalar self-consistency
//! and the same binary assertions still run. With the feature on an AVX2
//! host, this is the proof that the vector lowering preserved the
//! `(l0+l1)+(l2+l3)` lane tree exactly — the property the bit-identity
//! suites (gated_e2e, checkpoint_resume, distrib_e2e) stand on.

use kondo::runtime::kernels::{
    gather_mix_masked, gather_mix_masked_scalar, gemm_bias_logsoftmax,
    gemm_bias_logsoftmax_scalar, gemm_bias_logsoftmax_with, gemm_bias_tanh,
    gemm_bias_tanh_f32fast, gemm_bias_tanh_scalar, gemm_bias_tanh_with, log_softmax_rows,
    log_softmax_rows_scalar, simd_enabled, softmax_jacobian_rows, softmax_jacobian_rows_scalar,
    softmax_rows, softmax_rows_scalar, KernelTune, WeightPack, PANEL,
};
use kondo::utils::math::{dot, dot_scalar, perp_norm2, perp_norm2_scalar, LANES};
use kondo::utils::rng::Pcg32;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs ({x:?} vs {y:?}, simd_enabled={})",
            simd_enabled()
        );
    }
}

/// The ragged-shape matrix: every boundary the tail handling must cross.
/// `k` exercises the LANES remainder (the panel-dot spill path), `n` the
/// `PANEL.min(n - j0)` partial-panel edge, `rows` includes empty.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut v = Vec::new();
    for &rows in &[0usize, 1, 3, 7, 32] {
        for &k in &[1usize, 2, 3, 4, 5, 7, 8, 33, 784] {
            for &n in &[1usize, 2, 3, 4, 5, 9, 10, 11, 32] {
                // keep the sweep fast: the big-k column only at small n
                if k == 784 && n > 5 {
                    continue;
                }
                v.push((rows, k, n));
            }
        }
    }
    v
}

#[test]
fn gemm_bias_tanh_dispatch_is_bitwise_scalar() {
    for (rows, k, n) in shapes() {
        let x = randv(rows * k, 11 + (rows * 1000 + k * 10 + n) as u64);
        let w = randv(k * n, 13);
        let bias = randv(n, 17);
        let pack = WeightPack::new(&w, k, n, 0);
        let mut a = vec![f32::NAN; rows * n];
        let mut b = vec![f32::NAN; rows * n];
        gemm_bias_tanh(&x, rows, &pack, &bias, &mut a);
        gemm_bias_tanh_scalar(&x, rows, &pack, &bias, &mut b);
        assert_bits_eq(&a, &b, &format!("gemm_bias_tanh {rows}x{k}x{n}"));
    }
}

#[test]
fn gemm_bias_logsoftmax_dispatch_is_bitwise_scalar() {
    for (rows, k, n) in shapes() {
        let x = randv(rows * k, 19 + (rows * 1000 + k * 10 + n) as u64);
        let w = randv(k * n, 23);
        let bias = randv(n, 29);
        let noise = randv(rows * n, 31);
        let pack = WeightPack::new(&w, k, n, 0);
        for with_noise in [false, true] {
            let nz = with_noise.then_some(noise.as_slice());
            let mut a = vec![f32::NAN; rows * n];
            let mut b = vec![f32::NAN; rows * n];
            gemm_bias_logsoftmax(&x, rows, &pack, &bias, nz, &mut a);
            gemm_bias_logsoftmax_scalar(&x, rows, &pack, &bias, nz, &mut b);
            assert_bits_eq(
                &a,
                &b,
                &format!("gemm_bias_logsoftmax {rows}x{k}x{n} noise={with_noise}"),
            );
        }
    }
}

#[test]
fn partial_panel_tail_is_exact_not_padded() {
    // regression for the `PANEL.min(n - j0)` edge: with n = PANEL + 1 the
    // last panel holds ONE live column; the epilogue must write exactly
    // that column and never smear the zero-padded pack slots into out.
    let (rows, k, n) = (3usize, 7usize, PANEL + 1);
    let x = randv(rows * k, 41);
    let w = randv(k * n, 43);
    let bias = randv(n, 47);
    let pack = WeightPack::new(&w, k, n, 0);
    // canary beyond each logical row: if the tail wrote PANEL slots
    // instead of n - j0, the canary in the next row's first slot moves
    let mut out = vec![f32::NAN; rows * n];
    gemm_bias_tanh(&x, rows, &pack, &bias, &mut out);
    assert!(out.iter().all(|v| v.is_finite()), "tail column never written");
    // reference: unpack and compute the last column by the lane-tree rule
    let wref = pack.unpack();
    for r in 0..rows {
        let mut acc = [0.0f64; LANES];
        for kk in 0..k {
            acc[kk % LANES] += x[r * k + kk] as f64 * wref[kk * n + (n - 1)] as f64;
        }
        let pre = bias[n - 1] as f64 + ((acc[0] + acc[1]) + (acc[2] + acc[3]));
        let expect = pre.tanh() as f32;
        assert_eq!(
            out[r * n + (n - 1)].to_bits(),
            expect.to_bits(),
            "row {r} tail column"
        );
    }
}

#[test]
fn every_tune_is_bitwise_identical() {
    // blocking may change traversal order only — never bits. Sweep tunes
    // over a ragged shape on both GEMMs, against the default dispatch.
    let (rows, k, n) = (7usize, 33usize, 11usize);
    let x = randv(rows * k, 53);
    let w = randv(k * n, 59);
    let bias = randv(n, 61);
    let pack = WeightPack::new(&w, k, n, 0);
    let mut want_t = vec![0.0f32; rows * n];
    let mut want_l = vec![0.0f32; rows * n];
    gemm_bias_tanh(&x, rows, &pack, &bias, &mut want_t);
    gemm_bias_logsoftmax(&x, rows, &pack, &bias, None, &mut want_l);
    for t in [
        KernelTune { row_block: 1, panel_block: 1 },
        KernelTune { row_block: 2, panel_block: 1 },
        KernelTune { row_block: 3, panel_block: 2 },
        KernelTune { row_block: 5, panel_block: 3 },
        KernelTune { row_block: 100, panel_block: 100 },
        KernelTune::DEFAULT,
    ] {
        let mut got = vec![0.0f32; rows * n];
        gemm_bias_tanh_with(t, &x, rows, &pack, &bias, &mut got);
        assert_bits_eq(&got, &want_t, &format!("tanh tune {t:?}"));
        gemm_bias_logsoftmax_with(t, &x, rows, &pack, &bias, None, &mut got);
        assert_bits_eq(&got, &want_l, &format!("logsoftmax tune {t:?}"));
    }
}

#[test]
fn softmax_family_dispatch_is_bitwise_scalar() {
    for &(rows, n) in &[(0usize, 5usize), (1, 1), (3, 7), (8, 8), (32, 10), (5, 33)] {
        let x = randv(rows * n, 67 + (rows * 100 + n) as u64);
        let mut a = vec![f32::NAN; rows * n];
        let mut b = vec![f32::NAN; rows * n];
        softmax_rows(&x, rows, n, &mut a);
        softmax_rows_scalar(&x, rows, n, &mut b);
        assert_bits_eq(&a, &b, &format!("softmax_rows {rows}x{n}"));
        log_softmax_rows(&x, rows, n, &mut a);
        log_softmax_rows_scalar(&x, rows, n, &mut b);
        assert_bits_eq(&a, &b, &format!("log_softmax_rows {rows}x{n}"));

        let alpha = {
            let mut s = vec![0.0f32; rows * n];
            softmax_rows_scalar(&x, rows, n, &mut s);
            s
        };
        let da = randv(rows * n, 71);
        softmax_jacobian_rows(&alpha, &da, rows, n, &mut a);
        softmax_jacobian_rows_scalar(&alpha, &da, rows, n, &mut b);
        assert_bits_eq(&a, &b, &format!("softmax_jacobian_rows {rows}x{n}"));
    }
}

#[test]
fn gather_mix_dispatch_is_bitwise_scalar() {
    // ragged coefficient counts exercise the kk % LANES chunk tail
    for &(h, width, m) in &[
        (1usize, 8usize, 8usize),
        (2, 8, 8),
        (3, 8, 5),
        (4, 8, 8),
        (5, 9, 9),
        (7, 3, 2),
        (8, 8, 8),
        (13, 16, 11),
    ] {
        let coef = randv(h, 73 + h as u64);
        let table = randv((h + 2) * width, 79);
        let idx: Vec<usize> = (0..h).map(|i| (i * 5) % (h + 2)).collect();
        let mut acc_a = vec![0.0f64; m * LANES];
        let mut acc_b = vec![0.0f64; m * LANES];
        let mut a = vec![f32::NAN; width];
        let mut b = vec![f32::NAN; width];
        gather_mix_masked(&coef, &table, width, &idx, m, -1.0e30, &mut acc_a, &mut a);
        gather_mix_masked_scalar(&coef, &table, width, &idx, m, -1.0e30, &mut acc_b, &mut b);
        assert_bits_eq(&a, &b, &format!("gather_mix h={h} width={width} m={m}"));
        // the mask slots came out as fill on both paths
        for v in m..width {
            assert_eq!(a[v], -1.0e30, "mask slot {v}");
        }
    }
}

#[test]
fn math_dots_dispatch_is_bitwise_scalar() {
    for &n in &[0usize, 1, 2, 3, 4, 5, 7, 8, 31, 33, 784] {
        let a = randv(n, 83 + n as u64);
        let b = randv(n, 89 + n as u64);
        assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(), "dot n={n}");
        assert_eq!(
            perp_norm2(&a, &b).to_bits(),
            perp_norm2_scalar(&a, &b).to_bits(),
            "perp_norm2 n={n}"
        );
    }
}

#[test]
fn f32fast_is_close_but_never_claimed_golden() {
    // the non-golden tier: deterministic per shape, within forward-tier
    // tolerance of the exact kernel, and NOT asserted bit-equal — its
    // contract is a separate method axis (DESIGN.md §13)
    let (rows, k, n) = (4usize, 784usize, 32usize);
    let x = randv(rows * k, 97);
    let w = randv(k * n, 101);
    let bias = randv(n, 103);
    let pack = WeightPack::new(&w, k, n, 0);
    let mut exact = vec![0.0f32; rows * n];
    let mut fast = vec![0.0f32; rows * n];
    let mut fast2 = vec![0.0f32; rows * n];
    gemm_bias_tanh(&x, rows, &pack, &bias, &mut exact);
    gemm_bias_tanh_f32fast(&x, rows, &pack, &bias, &mut fast);
    gemm_bias_tanh_f32fast(&x, rows, &pack, &bias, &mut fast2);
    for i in 0..rows * n {
        assert!((exact[i] - fast[i]).abs() < 1e-3, "element {i} drifted too far");
        assert_eq!(fast[i].to_bits(), fast2[i].to_bits(), "f32fast must be deterministic");
    }
}
