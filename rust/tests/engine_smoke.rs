//! End-to-end smoke: load real artifacts, execute mnist_fwd, check logp.
use kondo::runtime::{Engine, HostTensor};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn mnist_fwd_produces_normalized_logprobs() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let eng = Engine::new(&dir).unwrap();
    let man = eng.manifest();
    let rules = man.model("mnist").unwrap().to_vec();
    let b = man.constants.mnist_batch;
    let d = man.constants.mnist_in;
    let a = man.constants.mnist_actions;

    let mut inputs: Vec<HostTensor> = Vec::new();
    let mut rng = kondo::utils::rng::Pcg32::seeded(0);
    for r in &rules {
        let n: usize = r.shape.iter().product();
        let data: Vec<f32> = match r.kind {
            kondo::runtime::InitKind::Normal { scale } => {
                (0..n).map(|_| (rng.normal() * scale) as f32).collect()
            }
            kondo::runtime::InitKind::Zeros => vec![0.0; n],
            kondo::runtime::InitKind::Ones => vec![1.0; n],
        };
        inputs.push(HostTensor::f32(&r.shape, data));
    }
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    inputs.push(HostTensor::f32(&[b, d], x));
    inputs.push(HostTensor::zeros_f32(&[b, a]));

    let out = eng.execute("mnist_fwd", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let logp = out[0].as_f32().unwrap();
    assert_eq!(logp.len(), b * a);
    for row in logp.chunks(a) {
        let s: f32 = row.iter().map(|&l| l.exp()).sum();
        assert!((s - 1.0).abs() < 1e-4, "row sums to {s}");
        assert!(row.iter().all(|&l| l <= 1e-5));
    }
}
