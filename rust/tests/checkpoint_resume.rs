//! Deterministic checkpoint/resume, locked by bit identity (DESIGN.md
//! §10), on the native testbed backend.
//!
//! The contract under test: training 2K steps uninterrupted vs training
//! K steps -> checkpoint -> dropping every piece of in-memory state ->
//! resuming K more steps must be indistinguishable. Concretely: the
//! `EvalPoint` trajectories are bit-identical (exact f64 bit equality,
//! no tolerances), the compute-ledger totals match, and -- at the same
//! worker count -- the checkpoint files the two runs write at the final
//! step are BYTE-identical, which pins the parameters, Adam moments,
//! RNG stream, draft-screen state and trainer extras all at once
//! through the canonical serialization. Both trainers, screened and
//! unscreened, and across worker counts (the worker count is outside
//! the checkpoint's config fingerprint, so the determinism contract of
//! gated_e2e.rs extends through the save/load boundary).

use std::fs;
use std::path::{Path, PathBuf};

use kondo::algo::{baseline::Baseline, Method};
use kondo::checkpoint::{CheckpointCfg, TrainCheckpoint};
use kondo::coordinator::{KondoGate, Ledger, Priority, ScreenCfg};
use kondo::runtime::Engine;
use kondo::trainers::{
    train_mnist, train_reversal, EvalPoint, MnistTrainerCfg, ReversalTrainerCfg,
};

fn test_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("kondo_resume_test_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

fn ckpt(path: &Path, every: usize) -> Option<CheckpointCfg> {
    Some(CheckpointCfg { path: path.to_string_lossy().into_owned(), every })
}

fn resume(path: &Path) -> Option<String> {
    Some(path.to_string_lossy().into_owned())
}

/// Exact (bitwise) equality of two learning curves, field by field.
fn assert_curves_bit_identical(a: &[EvalPoint], b: &[EvalPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert_eq!(pa.step, pb.step, "{what}[{i}].step");
        assert_eq!(pa.forward_samples, pb.forward_samples, "{what}[{i}].forward_samples");
        assert_eq!(pa.screen_samples, pb.screen_samples, "{what}[{i}].screen_samples");
        assert_eq!(pa.forward_skipped, pb.forward_skipped, "{what}[{i}].forward_skipped");
        assert_eq!(pa.backward_kept, pb.backward_kept, "{what}[{i}].backward_kept");
        assert_eq!(pa.backward_executed, pb.backward_executed, "{what}[{i}].backward_executed");
        assert_eq!(
            pa.metric.to_bits(),
            pb.metric.to_bits(),
            "{what}[{i}].metric: {} vs {}",
            pa.metric,
            pb.metric
        );
        assert_eq!(
            pa.metric2.to_bits(),
            pb.metric2.to_bits(),
            "{what}[{i}].metric2: {} vs {}",
            pa.metric2,
            pb.metric2
        );
    }
}

/// Every ledger total, including the worker-dependent execution-shape
/// fields -- valid when both runs used the same worker count.
fn assert_ledger_totals_equal(a: &Ledger, b: &Ledger, what: &str) {
    assert_eq!(a.forward_samples, b.forward_samples, "{what}: forward_samples");
    assert_eq!(a.forward_executed, b.forward_executed, "{what}: forward_executed");
    assert_eq!(a.forward_calls, b.forward_calls, "{what}: forward_calls");
    assert_eq!(a.screen_samples, b.screen_samples, "{what}: screen_samples");
    assert_eq!(a.forward_skipped, b.forward_skipped, "{what}: forward_skipped");
    assert_eq!(a.backward_kept, b.backward_kept, "{what}: backward_kept");
    assert_eq!(a.backward_executed, b.backward_executed, "{what}: backward_executed");
    assert_eq!(a.backward_calls, b.backward_calls, "{what}: backward_calls");
    assert_eq!(a.bucket_hist, b.bucket_hist, "{what}: bucket_hist");
}

/// The worker-invariant ledger subset (the determinism contract): shard
/// padding makes `forward_executed`/`forward_calls` depend on the worker
/// count, everything else must not.
fn assert_invariant_totals_equal(a: &Ledger, b: &Ledger, what: &str) {
    assert_eq!(a.forward_samples, b.forward_samples, "{what}: forward_samples");
    assert_eq!(a.screen_samples, b.screen_samples, "{what}: screen_samples");
    assert_eq!(a.forward_skipped, b.forward_skipped, "{what}: forward_skipped");
    assert_eq!(a.backward_kept, b.backward_kept, "{what}: backward_kept");
    assert_eq!(a.backward_executed, b.backward_executed, "{what}: backward_executed");
    assert_eq!(a.bucket_hist, b.bucket_hist, "{what}: bucket_hist");
}

fn assert_files_identical(a: &Path, b: &Path, what: &str) {
    let ba = fs::read(a).unwrap();
    let bb = fs::read(b).unwrap();
    assert!(ba.len() > 100, "{what}: checkpoint {} suspiciously small", a.display());
    assert_eq!(ba, bb, "{what}: final checkpoints are not byte-identical");
}

/// Bit-exact equality of everything in a checkpoint EXCEPT the ledger
/// (used for cross-worker comparisons, where the execution-shape ledger
/// fields legitimately differ).
fn assert_state_bit_identical(a: &TrainCheckpoint, b: &TrainCheckpoint, what: &str) {
    assert_eq!(a.step, b.step, "{what}: step");
    assert_eq!(a.params.len(), b.params.len(), "{what}: tensor count");
    for (i, (ta, tb)) in a.params.iter().zip(&b.params).enumerate() {
        assert_eq!(ta.len(), tb.len(), "{what}: params[{i}] length");
        for (j, (x, y)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: params[{i}][{j}]: {x} vs {y}");
        }
    }
    assert_eq!(a.opt_t, b.opt_t, "{what}: opt_t");
    for (ma, mb) in a.opt_m.iter().flatten().zip(b.opt_m.iter().flatten()) {
        assert_eq!(ma.to_bits(), mb.to_bits(), "{what}: opt_m");
    }
    for (va, vb) in a.opt_v.iter().flatten().zip(b.opt_v.iter().flatten()) {
        assert_eq!(va.to_bits(), vb.to_bits(), "{what}: opt_v");
    }
    assert_eq!(a.rng.0, b.rng.0, "{what}: rng state");
    assert_eq!(a.rng.1, b.rng.1, "{what}: rng inc");
    assert_eq!(
        a.rng.2.map(f64::to_bits),
        b.rng.2.map(f64::to_bits),
        "{what}: rng gauss spare"
    );
    assert_eq!(a.screen, b.screen, "{what}: draft screen state");
    assert_eq!(a.stream, b.stream, "{what}: gate price tracker state");
    assert_eq!(a.extra.dump(), b.extra.dump(), "{what}: extras");
}

// ---- MNIST ----

fn mnist_base(workers: usize) -> MnistTrainerCfg {
    MnistTrainerCfg {
        // hard gate (eta = 0) at rho = 0.25: the determinism-contract case
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Delight },
        baseline: Baseline::Expected,
        lr: 1e-3,
        steps: 24,
        eval_every: 6,
        eval_size: 64,
        seed: 17,
        workers,
        ..Default::default()
    }
}

fn mnist_screen_base(workers: usize) -> MnistTrainerCfg {
    MnistTrainerCfg {
        steps: 30,
        eval_every: 10,
        seed: 13,
        // two-tier gate: rho_screen = 0.5 pre-gate over a 5-batch-warm draft
        screen: ScreenCfg { rho_screen: 0.5, draft_lr: 1e-3, warmup_batches: 5 },
        ..mnist_base(workers)
    }
}

#[test]
fn mnist_unscreened_resume_is_bit_identical() {
    let eng = Engine::native_testbed();
    let dir = test_dir("mnist_plain");
    let (full_ck, mid_ck, end_ck) =
        (dir.join("full.ckpt"), dir.join("mid.ckpt"), dir.join("end.ckpt"));

    // uninterrupted 24-step run, checkpointing once at the very end
    let mut full_cfg = mnist_base(1);
    full_cfg.checkpoint = ckpt(&full_ck, 24);
    let full = train_mnist(&eng, &full_cfg).unwrap();

    // part 1: stop at step 12, leaving a checkpoint behind
    let mut part1 = mnist_base(1);
    part1.steps = 12;
    part1.checkpoint = ckpt(&mid_ck, 12);
    train_mnist(&eng, &part1).unwrap();

    // part 2: a FRESH trainer invocation -- every piece of state is
    // reconstructed from the checkpoint file alone
    let mut part2 = mnist_base(1);
    part2.resume_from = resume(&mid_ck);
    part2.checkpoint = ckpt(&end_ck, 24);
    let resumed = train_mnist(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "mnist resume");
    assert_ledger_totals_equal(&full.ledger, &resumed.ledger, "mnist resume");
    assert_eq!(full.final_train_err.to_bits(), resumed.final_train_err.to_bits());
    assert_eq!(full.final_test_err.to_bits(), resumed.final_test_err.to_bits());
    // byte-identical final checkpoints: params, moments, RNG, window and
    // all, pinned at once through the canonical serialization
    assert_files_identical(&full_ck, &end_ck, "mnist resume");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn mnist_screened_resume_is_bit_identical() {
    // the checkpoint lands at step 10: the draft is just past its 5-batch
    // warm-up, so the restore must carry a PARTIALLY-trained draft and its
    // warm-up counter, not merely converged weights
    let eng = Engine::native_testbed();
    let dir = test_dir("mnist_screen");
    let (full_ck, mid_ck, end_ck) =
        (dir.join("full.ckpt"), dir.join("mid.ckpt"), dir.join("end.ckpt"));

    let mut full_cfg = mnist_screen_base(1);
    full_cfg.checkpoint = ckpt(&full_ck, 30);
    let full = train_mnist(&eng, &full_cfg).unwrap();

    let mut part1 = mnist_screen_base(1);
    part1.steps = 10;
    part1.checkpoint = ckpt(&mid_ck, 10);
    train_mnist(&eng, &part1).unwrap();

    let mut part2 = mnist_screen_base(1);
    part2.resume_from = resume(&mid_ck);
    part2.checkpoint = ckpt(&end_ck, 30);
    let resumed = train_mnist(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "mnist screened resume");
    assert_ledger_totals_equal(&full.ledger, &resumed.ledger, "mnist screened resume");
    assert_files_identical(&full_ck, &end_ck, "mnist screened resume");
    // the run really screened on both sides of the save/load boundary
    assert!(full.ledger.screen_samples > 0 && full.ledger.forward_skipped > 0);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn mnist_resume_restores_warm_draft() {
    // the airtight no-cold-start proof is in the ledger arithmetic: cold
    // batches record NO screen dots and warm batches record exactly one
    // per sample, so with a 5-batch warm-up a 20-step run screens exactly
    // (20-5)*b samples. If resume re-entered the cold-start fallback, the
    // 10 post-resume steps would screen only (10-5)*b more; a warm resume
    // screens all 10*b.
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch as u64;
    let dir = test_dir("mnist_warm");
    let mid_ck = dir.join("mid.ckpt");

    let mut part1 = mnist_screen_base(1);
    part1.steps = 20;
    part1.checkpoint = ckpt(&mid_ck, 20);
    train_mnist(&eng, &part1).unwrap();

    let ck = TrainCheckpoint::load(&mid_ck).unwrap();
    assert_eq!(ck.step, 20);
    assert_eq!(ck.ledger.screen_samples, (20 - 5) * b, "warm batches screen exactly b dots");
    let screen = ck.screen.as_ref().expect("screened run must checkpoint its draft");
    assert!(
        screen.seen >= 5 * b,
        "saved draft is past warm-up (seen {} < {})",
        screen.seen,
        5 * b
    );

    let mut part2 = mnist_screen_base(1);
    part2.resume_from = resume(&mid_ck);
    let resumed = train_mnist(&eng, &part2).unwrap();

    // every one of the 10 post-resume batches screened: the draft came
    // back warm, with no cold-start fallback
    assert_eq!(resumed.ledger.screen_samples, (30 - 5) * b);
    assert!(
        resumed.ledger.forward_skipped > ck.ledger.forward_skipped,
        "the resumed screen never skipped a forward"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn mnist_cross_worker_resume_is_bit_identical() {
    // a checkpoint saved under workers=2 resumes under workers=3: worker
    // count is outside the fingerprint, and the trajectory is worker-
    // invariant, so the resumed run matches an uninterrupted serial run
    let eng = Engine::native_testbed();
    let dir = test_dir("mnist_xworker");
    let (full_ck, mid_ck, end_ck) =
        (dir.join("full.ckpt"), dir.join("mid.ckpt"), dir.join("end.ckpt"));

    let mut full_cfg = mnist_screen_base(1);
    full_cfg.checkpoint = ckpt(&full_ck, 30);
    let full = train_mnist(&eng, &full_cfg).unwrap();

    let mut part1 = mnist_screen_base(2);
    part1.steps = 10;
    part1.checkpoint = ckpt(&mid_ck, 10);
    train_mnist(&eng, &part1).unwrap();

    let mut part2 = mnist_screen_base(3);
    part2.resume_from = resume(&mid_ck);
    part2.checkpoint = ckpt(&end_ck, 30);
    let resumed = train_mnist(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "mnist 2->3 workers");
    assert_invariant_totals_equal(&full.ledger, &resumed.ledger, "mnist 2->3 workers");
    // the final states are bit-identical even though the execution-shape
    // ledger fields (shard padding) differ across worker counts
    let a = TrainCheckpoint::load(&full_ck).unwrap();
    let b = TrainCheckpoint::load(&end_ck).unwrap();
    assert_state_bit_identical(&a, &b, "mnist 2->3 workers");
    let _ = fs::remove_dir_all(&dir);
}

// ---- token reversal ----

fn rev_base(workers: usize) -> ReversalTrainerCfg {
    ReversalTrainerCfg {
        // lambda = 0 adaptive hard gate (Prop 1): eta = 0 determinism case
        method: Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight },
        lr: 3e-4,
        steps: 12,
        h: 4,
        m: 2,
        seed: 9,
        eval_every: 4,
        inner_epochs: 1,
        workers,
        ..Default::default()
    }
}

fn rev_screen_base(workers: usize) -> ReversalTrainerCfg {
    ReversalTrainerCfg {
        screen: ScreenCfg { rho_screen: 0.5, draft_lr: 1e-3, warmup_batches: 2 },
        ..rev_base(workers)
    }
}

#[test]
fn reversal_unscreened_resume_is_bit_identical() {
    let eng = Engine::native_testbed();
    let dir = test_dir("rev_plain");
    let (full_ck, mid_ck, end_ck) =
        (dir.join("full.ckpt"), dir.join("mid.ckpt"), dir.join("end.ckpt"));

    let mut full_cfg = rev_base(1);
    full_cfg.checkpoint = ckpt(&full_ck, 12);
    let full = train_reversal(&eng, &full_cfg).unwrap();

    let mut part1 = rev_base(1);
    part1.steps = 8;
    part1.checkpoint = ckpt(&mid_ck, 8);
    train_reversal(&eng, &part1).unwrap();

    let mut part2 = rev_base(1);
    part2.resume_from = resume(&mid_ck);
    part2.checkpoint = ckpt(&end_ck, 12);
    let resumed = train_reversal(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "reversal resume");
    assert_ledger_totals_equal(&full.ledger, &resumed.ledger, "reversal resume");
    assert_eq!(full.final_reward.to_bits(), resumed.final_reward.to_bits());
    // mean_reward folds the restored reward_sum into the same left-to-
    // right addition order, so even this cross-run statistic is exact
    assert_eq!(full.mean_reward.to_bits(), resumed.mean_reward.to_bits());
    assert_files_identical(&full_ck, &end_ck, "reversal resume");
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn reversal_screened_resume_is_bit_identical() {
    let eng = Engine::native_testbed();
    let dir = test_dir("rev_screen");
    let (full_ck, mid_ck, end_ck) =
        (dir.join("full.ckpt"), dir.join("mid.ckpt"), dir.join("end.ckpt"));

    let mut full_cfg = rev_screen_base(1);
    full_cfg.checkpoint = ckpt(&full_ck, 12);
    let full = train_reversal(&eng, &full_cfg).unwrap();

    let mut part1 = rev_screen_base(1);
    part1.steps = 4;
    part1.checkpoint = ckpt(&mid_ck, 4);
    train_reversal(&eng, &part1).unwrap();

    let mut part2 = rev_screen_base(1);
    part2.resume_from = resume(&mid_ck);
    part2.checkpoint = ckpt(&end_ck, 12);
    let resumed = train_reversal(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "reversal screened resume");
    assert_ledger_totals_equal(&full.ledger, &resumed.ledger, "reversal screened resume");
    assert_files_identical(&full_ck, &end_ck, "reversal screened resume");
    // the token screen engaged on both sides of the boundary: 2 warm-up
    // batches, then every batch screens all its tokens
    let n_tok = (eng.manifest().constants.rev_batch * 4) as u64;
    assert_eq!(full.ledger.screen_samples, (12 - 2) * n_tok);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn reversal_cross_worker_resume_is_bit_identical() {
    let eng = Engine::native_testbed();
    let dir = test_dir("rev_xworker");
    let mid_ck = dir.join("mid.ckpt");

    let full = train_reversal(&eng, &rev_base(1)).unwrap();

    let mut part1 = rev_base(4);
    part1.steps = 8;
    part1.checkpoint = ckpt(&mid_ck, 8);
    train_reversal(&eng, &part1).unwrap();

    let mut part2 = rev_base(2);
    part2.resume_from = resume(&mid_ck);
    let resumed = train_reversal(&eng, &part2).unwrap();

    assert_curves_bit_identical(&full.curve, &resumed.curve, "reversal 4->2 workers");
    assert_invariant_totals_equal(&full.ledger, &resumed.ledger, "reversal 4->2 workers");
    let _ = fs::remove_dir_all(&dir);
}

// ---- guard rails: wrong-run resumes are clean errors, never panics ----

#[test]
fn mismatched_resume_is_rejected() {
    let eng = Engine::native_testbed();
    let dir = test_dir("mismatch");
    let mid_ck = dir.join("mid.ckpt");

    let mut part1 = mnist_base(1);
    part1.steps = 6;
    part1.checkpoint = ckpt(&mid_ck, 6);
    train_mnist(&eng, &part1).unwrap();

    // different seed: a different run entirely
    let mut wrong = mnist_base(1);
    wrong.seed = 18;
    wrong.resume_from = resume(&mid_ck);
    let err = train_mnist(&eng, &wrong).unwrap_err().to_string();
    assert!(err.contains("seed"), "unexpected error: {err:?}");

    // different gate rate: the method is in the fingerprint
    let mut wrong = mnist_base(1);
    wrong.method = Method::DgK { gate: KondoGate::rate(0.5), priority: Priority::Delight };
    wrong.resume_from = resume(&mid_ck);
    let err = train_mnist(&eng, &wrong).unwrap_err().to_string();
    assert!(err.contains("method"), "unexpected error: {err:?}");

    // same gate, different priority: the priority knob is a fingerprint
    // key of its own, so the rejection names it explicitly
    let mut wrong = mnist_base(1);
    wrong.method = Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Surprisal };
    wrong.resume_from = resume(&mid_ck);
    let err = train_mnist(&eng, &wrong).unwrap_err().to_string();
    assert!(err.contains("'priority'"), "unexpected error: {err:?}");
    assert!(err.contains("surprisal"), "unexpected error: {err:?}");

    // a screened run cannot adopt an unscreened checkpoint
    let mut wrong = mnist_screen_base(1);
    wrong.seed = 17;
    wrong.resume_from = resume(&mid_ck);
    assert!(train_mnist(&eng, &wrong).is_err());

    // the other trainer's checkpoint is rejected up front
    let mut wrong_trainer = rev_base(1);
    wrong_trainer.resume_from = resume(&mid_ck);
    let err = train_reversal(&eng, &wrong_trainer).unwrap_err().to_string();
    assert!(err.contains("trainer") || err.contains("mismatch"), "unexpected error: {err:?}");

    // a run shorter than the checkpoint's step cursor cannot continue
    let mut too_short = mnist_base(1);
    too_short.steps = 3;
    too_short.resume_from = resume(&mid_ck);
    let err = train_mnist(&eng, &too_short).unwrap_err().to_string();
    assert!(err.contains("beyond"), "unexpected error: {err:?}");

    // a missing file is a clean error too
    let mut gone = mnist_base(1);
    gone.resume_from = resume(&dir.join("nope.ckpt"));
    assert!(train_mnist(&eng, &gone).is_err());

    // and a corrupted file never panics the trainer
    let garbled = dir.join("garbled.ckpt");
    let mut bytes = fs::read(&mid_ck).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] = bytes[mid].wrapping_add(1);
    fs::write(&garbled, &bytes).unwrap();
    let mut corrupt = mnist_base(1);
    corrupt.resume_from = resume(&garbled);
    // {:#} prints the whole context chain ("loading checkpoint ...: ...")
    let err = format!("{:#}", train_mnist(&eng, &corrupt).unwrap_err());
    assert!(err.contains("checksum"), "unexpected error: {err:?}");

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn resume_extends_past_the_original_budget() {
    // `steps` is outside the fingerprint by design: a finished 12-step
    // run extends to 18 steps from its final checkpoint, and the extended
    // trajectory's prefix is the original run's, bit for bit
    let eng = Engine::native_testbed();
    let dir = test_dir("extend");
    let end_ck = dir.join("end.ckpt");

    let mut orig = mnist_base(1);
    orig.steps = 12;
    orig.checkpoint = ckpt(&end_ck, 12);
    let short = train_mnist(&eng, &orig).unwrap();

    let mut ext = mnist_base(1);
    ext.steps = 18;
    ext.resume_from = resume(&end_ck);
    let long = train_mnist(&eng, &ext).unwrap();

    assert!(long.curve.len() > short.curve.len());
    assert_curves_bit_identical(
        &short.curve,
        &long.curve[..short.curve.len()],
        "extended-run prefix",
    );
    assert_eq!(long.curve.last().unwrap().step, 18);
    let _ = fs::remove_dir_all(&dir);
}
