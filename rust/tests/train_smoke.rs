//! End-to-end training smoke tests over real artifacts.
use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::new(&dir).unwrap())
}

#[test]
fn mnist_dgk_learns_and_saves_backward() {
    let Some(eng) = engine() else { return };
    let t0 = std::time::Instant::now();
    let cfg = MnistTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.03), priority: Priority::Delight },
        baseline: Baseline::Expected,
        steps: 300,
        eval_every: 100,
        eval_size: 500,
        seed: 1,
        ..Default::default()
    };
    let res = train_mnist(&eng, &cfg).unwrap();
    println!("300 DG-K steps in {:.1}s; test err {:.3}; bwd kept {} / fwd {}",
        t0.elapsed().as_secs_f64(), res.final_test_err,
        res.ledger.backward_kept, res.ledger.forward_samples);
    assert!(res.final_test_err < 0.5, "did not learn: {}", res.final_test_err);
    // gate keeps ~3%: kept backward samples far below forward samples
    assert!(res.ledger.backward_kept * 10 < res.ledger.forward_samples);
}

#[test]
fn reversal_dg_learns() {
    let Some(eng) = engine() else { return };
    let t0 = std::time::Instant::now();
    let cfg = ReversalTrainerCfg {
        method: Method::Dg,
        steps: 60,
        h: 3,
        m: 2,
        seed: 1,
        eval_every: 20,
        ..Default::default()
    };
    let res = train_reversal(&eng, &cfg).unwrap();
    println!("60 reversal steps in {:.1}s; final reward {:.3}",
        t0.elapsed().as_secs_f64(), res.final_reward);
    assert!(res.final_reward > 0.55, "no learning: {}", res.final_reward);
}
