//! Byte-level hardening contracts for the wire codec (DESIGN.md §14).
//!
//! The frame layer's promise is narrow and absolute: damaged bytes
//! produce a *classified error*, never a panic, never a silently wrong
//! decode. These tests attack an encoded rollout frame exhaustively —
//! every truncation point, every single-bit flip — and drive the real
//! `SocketTransport` handshake with impostor connections.

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::time::Duration;

use kondo::distrib::wire::{
    decode_payload, encode_hello, encode_rollout, read_frame, WireError, WireMsg, HDR,
    LEN_XOR, MAX_FRAME,
};
use kondo::distrib::{RolloutBatch, SocketCfg, SocketTransport};
use kondo::utils::rng::Pcg32;

const DEADLINE: Duration = Duration::from_millis(500);

/// A random rollout with hostile floats mixed in: NaN, both infinities,
/// subnormals, and negative zero all have to survive the wire bitwise.
fn rand_batch(r: &mut Pcg32) -> RolloutBatch {
    let n = 1 + (r.next_u64() % 40) as usize;
    let hostile = [
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        -0.0,
        f64::MIN_POSITIVE / 2.0, // subnormal
    ];
    let mut actions = Vec::with_capacity(n);
    let mut u = Vec::with_capacity(n);
    let mut ell = Vec::with_capacity(n);
    for i in 0..n {
        actions.push((r.next_u64() % 10) as i32);
        u.push(if r.next_u64() % 4 == 0 {
            hostile[i % hostile.len()]
        } else {
            f64::from_bits(r.next_u64())
        });
        ell.push(f64::from_bits(r.next_u64()));
    }
    RolloutBatch {
        actor: (r.next_u64() % 8) as usize,
        step: r.next_u64(),
        snapshot_version: r.next_u64(),
        fingerprint: r.next_u64(),
        n,
        actions,
        u,
        ell,
    }
}

fn decode_one(frame: &[u8]) -> Result<WireMsg, WireError> {
    let mut cur = frame;
    let (kind, payload) = read_frame(&mut cur, DEADLINE)?;
    decode_payload(kind, &payload)
}

#[test]
fn random_rollouts_round_trip_bitwise() {
    let mut r = Pcg32::new(99, 7);
    for case in 0..200 {
        let rb = rand_batch(&mut r);
        let frame = encode_rollout(&rb);
        let got = match decode_one(&frame) {
            Ok(WireMsg::Rollout(got)) => got,
            other => panic!("case {case}: {other:?}"),
        };
        assert_eq!(got.actor, rb.actor, "case {case}");
        assert_eq!(got.step, rb.step, "case {case}");
        assert_eq!(got.snapshot_version, rb.snapshot_version, "case {case}");
        assert_eq!(got.fingerprint, rb.fingerprint, "case {case}");
        assert_eq!(got.n, rb.n, "case {case}");
        assert_eq!(got.actions, rb.actions, "case {case}");
        // float equality is BIT equality: NaN payloads included
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&got.u), bits(&rb.u), "case {case}: u");
        assert_eq!(bits(&got.ell), bits(&rb.ell), "case {case}: ell");
    }
}

#[test]
fn every_truncation_point_errors_cleanly() {
    let mut r = Pcg32::new(3, 1);
    let frame = encode_rollout(&rand_batch(&mut r));
    for cut in 0..frame.len() {
        match decode_one(&frame[..cut]) {
            Ok(msg) => panic!("truncated at {cut}/{} decoded: {msg:?}", frame.len()),
            // nothing at all is a clean boundary close; any strict
            // prefix is a torn frame — never a panic, never Ok
            Err(WireError::Closed) => assert_eq!(cut, 0),
            Err(WireError::Torn) => assert!(cut > 0),
            Err(e) => panic!("truncated at {cut}: unexpected class {e:?}"),
        }
    }
}

#[test]
fn every_single_bitflip_is_caught_and_classified() {
    let mut r = Pcg32::new(5, 2);
    let frame = encode_rollout(&rand_batch(&mut r));
    for i in 0..frame.len() {
        let mut damaged = frame.clone();
        damaged[i] ^= 1 << (i % 8);
        match decode_one(&damaged) {
            // flips inside the dual length fields break the header's
            // self-check (fatal: the stream is desynchronized) ...
            Err(WireError::Header(_)) => assert!(i < HDR, "Header class at byte {i}"),
            // ... flips anywhere else are caught by the checksum
            // (recoverable: the NEXT frame is still readable)
            Err(WireError::Corrupt(_)) => assert!(i >= HDR, "Corrupt class at byte {i}"),
            other => panic!("flip at byte {i} slipped through: {other:?}"),
        }
    }
}

#[test]
fn an_oversized_length_claim_is_refused_before_allocation() {
    // a malicious header claiming a huge-but-self-consistent length must
    // be refused by the size guard, not handed to Vec::with_capacity
    for claim in [MAX_FRAME as u32 + 1, u32::MAX] {
        let mut frame = Vec::new();
        frame.extend_from_slice(&claim.to_le_bytes());
        frame.extend_from_slice(&(claim ^ LEN_XOR).to_le_bytes());
        frame.extend_from_slice(&[0u8; 32]);
        match decode_one(&frame) {
            Err(WireError::Header(m)) => {
                assert!(m.contains("length"), "guard should name the length: {m}")
            }
            other => panic!("length bomb {claim}: {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------
// the real handshake, attacked over a real socket
// ---------------------------------------------------------------------

/// Connect to the learner's socket and present `hello`; return the
/// learner's verdict frame.
fn impostor(path: &std::path::Path, hello: Vec<u8>) -> WireMsg {
    let mut s = UnixStream::connect(path).expect("connecting impostor");
    s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    s.write_all(&hello).unwrap();
    let t0 = std::time::Instant::now();
    loop {
        match read_frame(&mut s, DEADLINE) {
            Ok((kind, payload)) => return decode_payload(kind, &payload).unwrap(),
            Err(WireError::Idle) if t0.elapsed() < Duration::from_secs(2) => continue,
            Err(e) => panic!("no verdict frame: {e:?}"),
        }
    }
}

#[test]
fn wrong_fingerprint_and_wrong_slot_handshakes_are_rejected_and_counted() {
    let tp = SocketTransport::bind(SocketCfg {
        dir: std::env::temp_dir(),
        n_actors: 1,
        fingerprint: 0xF00D_F00D,
        deadline: DEADLINE,
        accept_timeout: Duration::from_millis(1500),
        // start() spawns one "actor" that exits immediately and never
        // connects — only the impostors below ever reach the listener
        bin: PathBuf::from("/bin/true"),
        args: vec![],
    })
    .unwrap();
    let path = tp.socket_path().to_path_buf();

    let attacker = std::thread::spawn(move || {
        // wrong run fingerprint: right protocol, wrong universe
        let v1 = impostor(&path, encode_hello(0xDEAD_BEEF, 0));
        // right fingerprint, nonexistent slot
        let v2 = impostor(&path, encode_hello(0xF00D_F00D, 7));
        (v1, v2)
    });

    // no valid actor ever arrives, so start() must give up on its own
    // deadline rather than hang
    let err = tp.start().unwrap_err().to_string();
    assert!(err.contains("handshake"), "{err}");

    let (v1, v2) = attacker.join().unwrap();
    match v1 {
        WireMsg::HelloReject { reason } => {
            assert!(reason.contains("fingerprint"), "{reason}")
        }
        other => panic!("fingerprint impostor got {other:?}"),
    }
    match v2 {
        WireMsg::HelloReject { reason } => assert!(reason.contains("slot"), "{reason}"),
        other => panic!("slot impostor got {other:?}"),
    }
    assert_eq!(tp.handshake_rejects(), 2, "every reject is counted exactly once");
}
