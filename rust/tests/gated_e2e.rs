//! Determinism-locked end-to-end tests for the sharded training
//! coordinator, on the native testbed backend (always available -- no
//! compiled artifacts needed).
//!
//! The contract under test (DESIGN.md §"L3 parallelism"): with the hard
//! Kondo gate (eta = 0) a training run is a pure function of the seed --
//! re-running it, and running it sharded across any number of workers,
//! must emit a bit-identical `EvalPoint` trajectory and identical compute
//! ledger totals. The trajectories are compared field by field with exact
//! bit equality on the f64 metrics (no tolerances: "roughly equal" curves
//! would mean the shard merge reordered floating-point work).

use std::path::PathBuf;

use kondo::algo::{baseline::Baseline, BatchSignals, Method};
use kondo::checkpoint::CheckpointCfg;
use kondo::coordinator::{KondoGate, Priority, ScreenCfg};
use kondo::runtime::Engine;
use kondo::utils::rng::Pcg32;
use kondo::trainers::{
    train_mnist, train_reversal, EvalPoint, MnistTrainerCfg, ReversalTrainerCfg,
};

/// Exact (bitwise) equality of two learning curves. The screen counters
/// are inside the determinism contract (batch-global decisions), so they
/// are compared exactly too.
fn assert_curves_bit_identical(a: &[EvalPoint], b: &[EvalPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert_eq!(pa.step, pb.step, "{what}[{i}].step");
        assert_eq!(pa.forward_samples, pb.forward_samples, "{what}[{i}].forward_samples");
        assert_eq!(pa.screen_samples, pb.screen_samples, "{what}[{i}].screen_samples");
        assert_eq!(pa.forward_skipped, pb.forward_skipped, "{what}[{i}].forward_skipped");
        assert_eq!(pa.backward_kept, pb.backward_kept, "{what}[{i}].backward_kept");
        assert_eq!(pa.backward_executed, pb.backward_executed, "{what}[{i}].backward_executed");
        assert_eq!(
            pa.metric.to_bits(),
            pb.metric.to_bits(),
            "{what}[{i}].metric: {} vs {}",
            pa.metric,
            pb.metric
        );
        assert_eq!(
            pa.metric2.to_bits(),
            pb.metric2.to_bits(),
            "{what}[{i}].metric2: {} vs {}",
            pa.metric2,
            pb.metric2
        );
    }
}

fn mnist_cfg(workers: usize) -> MnistTrainerCfg {
    MnistTrainerCfg {
        // hard gate (eta = 0) at rho = 0.25: the determinism-contract case
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Delight },
        baseline: Baseline::Expected,
        lr: 1e-3,
        steps: 24,
        eval_every: 8,
        eval_size: 64,
        seed: 11,
        workers,
        ..Default::default()
    }
}

#[test]
fn mnist_sharded_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch as u64;

    let serial_a = train_mnist(&eng, &mnist_cfg(1)).unwrap();
    let serial_b = train_mnist(&eng, &mnist_cfg(1)).unwrap();
    assert_curves_bit_identical(&serial_a.curve, &serial_b.curve, "mnist serial reproducibility");

    for workers in [2, 4, 7] {
        let sharded = train_mnist(&eng, &mnist_cfg(workers)).unwrap();
        assert_curves_bit_identical(
            &serial_a.curve,
            &sharded.curve,
            &format!("mnist workers={workers}"),
        );
        // ledger totals agree exactly (calls may differ: shards vs batch)
        assert_eq!(serial_a.ledger.forward_samples, sharded.ledger.forward_samples);
        assert_eq!(serial_a.ledger.backward_kept, sharded.ledger.backward_kept);
        assert_eq!(serial_a.ledger.backward_executed, sharded.ledger.backward_executed);
        assert_eq!(serial_a.ledger.bucket_hist, sharded.ledger.bucket_hist);
        // shard attribution covers the same totals
        let t = sharded.shard_ledger.total();
        assert_eq!(t.forward_samples, sharded.ledger.forward_samples);
        assert_eq!(t.backward_kept, sharded.ledger.backward_kept);
        assert_eq!(sharded.shard_ledger.n_shards(), workers);
        // executed forward slots include shard padding: outside the
        // determinism contract, but never below the logical sample count
        assert!(sharded.ledger.forward_executed >= sharded.ledger.forward_samples);
    }

    // unsharded forward has no padding
    assert_eq!(serial_a.ledger.forward_executed, serial_a.ledger.forward_samples);

    // the trajectory is also structurally exact for this fixed cfg
    assert_eq!(serial_a.curve.len(), 3);
    assert_eq!(
        serial_a.curve.iter().map(|p| p.step).collect::<Vec<_>>(),
        vec![8, 16, 24]
    );
    for point in &serial_a.curve {
        assert_eq!(point.forward_samples, b * point.step as u64);
    }
    // the gate really gates: rho = 0.25 keeps well under half the batch
    let last = serial_a.curve.last().unwrap();
    assert!(last.backward_kept * 2 < last.forward_samples);
    assert!(last.backward_executed >= last.backward_kept);
}

#[test]
fn ungated_multi_chunk_backward_is_bit_identical() {
    // DG keeps every sample: the batch splits across SEVERAL backward
    // chunks (native caps top out below the batch), so this pins the
    // chunk-order gradient merge, not just the gated single-chunk path.
    let eng = Engine::native_testbed();
    let mk = |workers| MnistTrainerCfg {
        method: Method::Dg,
        steps: 10,
        eval_every: 5,
        eval_size: 64,
        seed: 21,
        workers,
        ..Default::default()
    };
    let serial = train_mnist(&eng, &mk(1)).unwrap();
    let sharded = train_mnist(&eng, &mk(4)).unwrap();
    assert_curves_bit_identical(&serial.curve, &sharded.curve, "mnist DG workers=4");
    // every step really executed more than one chunk
    let max_cap = *eng.manifest().constants.mnist_bwd_caps.iter().max().unwrap() as u64;
    let b = eng.manifest().constants.mnist_batch as u64;
    assert!(b > max_cap, "native caps should force chunk splits");
    assert_eq!(serial.ledger.backward_calls, 10 * ((b + max_cap - 1) / max_cap));

    let rk = |workers| ReversalTrainerCfg { method: Method::Dg, workers, ..rev_cfg(workers) };
    let rs = train_reversal(&eng, &rk(1)).unwrap();
    let rp = train_reversal(&eng, &rk(4)).unwrap();
    assert_curves_bit_identical(&rs.curve, &rp.curve, "reversal DG workers=4");
    assert!(rs.ledger.backward_calls >= 2 * 12, "expected >= 2 chunks per step");
}

#[test]
fn mnist_oversubscribed_workers_match_serial() {
    // more workers than samples per shard-capacity: shards degenerate to
    // tiny slices; the trajectory must not move
    let eng = Engine::native_testbed();
    let serial = train_mnist(&eng, &mnist_cfg(1)).unwrap();
    let over = train_mnist(&eng, &mnist_cfg(64)).unwrap();
    assert_curves_bit_identical(&serial.curve, &over.curve, "mnist workers=64");
}

#[test]
fn mnist_seeds_actually_differ() {
    // guard against the degenerate "deterministic because constant" case
    let eng = Engine::native_testbed();
    let a = train_mnist(&eng, &mnist_cfg(4)).unwrap();
    let mut cfg = mnist_cfg(4);
    cfg.seed = 12;
    let b = train_mnist(&eng, &cfg).unwrap();
    let same = a.curve.iter().zip(&b.curve).all(|(x, y)| {
        x.metric.to_bits() == y.metric.to_bits() && x.backward_kept == y.backward_kept
    });
    assert!(!same, "different seeds produced identical trajectories");
}

fn rev_cfg(workers: usize) -> ReversalTrainerCfg {
    ReversalTrainerCfg {
        // lambda = 0 adaptive hard gate (Prop 1): eta = 0 determinism case
        method: Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight },
        lr: 3e-4,
        steps: 12,
        h: 4,
        m: 2,
        seed: 7,
        eval_every: 4,
        inner_epochs: 1,
        workers,
        ..Default::default()
    }
}

#[test]
fn reversal_sharded_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    let batch = eng.manifest().constants.rev_batch as u64;

    let serial_a = train_reversal(&eng, &rev_cfg(1)).unwrap();
    let serial_b = train_reversal(&eng, &rev_cfg(1)).unwrap();
    assert_curves_bit_identical(&serial_a.curve, &serial_b.curve, "reversal serial");

    for workers in [2, 4] {
        let sharded = train_reversal(&eng, &rev_cfg(workers)).unwrap();
        assert_curves_bit_identical(
            &serial_a.curve,
            &sharded.curve,
            &format!("reversal workers={workers}"),
        );
        assert_eq!(serial_a.ledger.forward_samples, sharded.ledger.forward_samples);
        assert_eq!(serial_a.ledger.backward_kept, sharded.ledger.backward_kept);
        assert_eq!(serial_a.ledger.backward_executed, sharded.ledger.backward_executed);
        assert_eq!(serial_a.ledger.bucket_hist, sharded.ledger.bucket_hist);
    }

    // structural exactness: 12 steps, eval every 4 -> 3 points; each
    // rollout is batch * h token-forwards
    assert_eq!(serial_a.curve.len(), 3);
    assert_eq!(
        serial_a.curve.iter().map(|p| p.step).collect::<Vec<_>>(),
        vec![4, 8, 12]
    );
    for point in &serial_a.curve {
        assert_eq!(point.forward_samples, batch * 4 * point.step as u64);
    }
    // the zero-price gate keeps only positive-delight tokens
    let last = serial_a.curve.last().unwrap();
    assert!(last.backward_kept < last.forward_samples);
}

// ---- L4 screening pipeline: the determinism contract extends to the
// tier-1 screen (DESIGN.md §8) ----

fn mnist_screen_cfg(workers: usize) -> MnistTrainerCfg {
    MnistTrainerCfg {
        // hard two-tier gate: rho_screen = 0.5 pre-gate, rho = 0.25 gate
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Delight },
        baseline: Baseline::Expected,
        lr: 1e-3,
        steps: 30,
        eval_every: 10,
        eval_size: 64,
        seed: 13,
        screen: ScreenCfg { rho_screen: 0.5, draft_lr: 1e-3, warmup_batches: 5 },
        workers,
        ..Default::default()
    }
}

#[test]
fn mnist_screened_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch as u64;

    let serial_a = train_mnist(&eng, &mnist_screen_cfg(1)).unwrap();
    let serial_b = train_mnist(&eng, &mnist_screen_cfg(1)).unwrap();
    assert_curves_bit_identical(&serial_a.curve, &serial_b.curve, "mnist screened serial");

    for workers in [2, 4, 7] {
        let sharded = train_mnist(&eng, &mnist_screen_cfg(workers)).unwrap();
        assert_curves_bit_identical(
            &serial_a.curve,
            &sharded.curve,
            &format!("mnist screened workers={workers}"),
        );
        // exact ledger totals, screen counters included: every screen
        // decision is batch-global, hence worker-invariant
        assert_eq!(serial_a.ledger.forward_samples, sharded.ledger.forward_samples);
        assert_eq!(serial_a.ledger.screen_samples, sharded.ledger.screen_samples);
        assert_eq!(serial_a.ledger.forward_skipped, sharded.ledger.forward_skipped);
        assert_eq!(serial_a.ledger.backward_kept, sharded.ledger.backward_kept);
        assert_eq!(serial_a.ledger.backward_executed, sharded.ledger.backward_executed);
        assert_eq!(serial_a.ledger.bucket_hist, sharded.ledger.bucket_hist);
        // shard attribution still covers the same totals
        let t = sharded.shard_ledger.total();
        assert_eq!(t.screen_samples, sharded.ledger.screen_samples);
        assert_eq!(t.forward_skipped, sharded.ledger.forward_skipped);
    }

    // the screen really engaged after warm-up and really skipped forwards
    let l = &serial_a.ledger;
    assert!(l.screen_samples > 0, "warm draft never screened");
    assert!(l.forward_skipped > 0, "screen skipped no forwards");
    // warm-up: 5 batches pass whole before the draft screens
    assert!(l.screen_samples <= (30 - 5) * b, "cold batches must not screen");
    // every sample is either forwarded or skipped -- nothing double-counted
    assert_eq!(l.forward_samples + l.forward_skipped, 30 * b);
    // and the forward axis really drops below the unscreened run's
    assert!(l.forward_samples < 30 * b);
    // screened survivor chunks are padded to the capacity ladder, so the
    // executed forward slots also stay below the unscreened full batches
    assert!(l.forward_executed < 30 * b);
}

#[test]
fn mnist_screened_vs_unscreened_trajectories_differ() {
    // guard against a vacuously-passing screen: with the same seed, the
    // screened run must actually change the trajectory and skip forwards
    let eng = Engine::native_testbed();
    let screened = train_mnist(&eng, &mnist_screen_cfg(4)).unwrap();
    let mut cfg = mnist_screen_cfg(4);
    cfg.screen = ScreenCfg::default();
    let unscreened = train_mnist(&eng, &cfg).unwrap();
    assert_eq!(unscreened.ledger.forward_skipped, 0);
    assert_eq!(unscreened.ledger.screen_samples, 0);
    assert!(screened.ledger.forward_samples < unscreened.ledger.forward_samples);
    // the two-tier gate prices over survivors, so the kept backward set
    // genuinely differs from the single-tier run
    let same = screened
        .curve
        .iter()
        .zip(&unscreened.curve)
        .all(|(x, y)| {
            x.metric2.to_bits() == y.metric2.to_bits() && x.backward_kept == y.backward_kept
        });
    assert!(!same, "screening changed nothing");
}

fn rev_screen_cfg(workers: usize) -> ReversalTrainerCfg {
    ReversalTrainerCfg {
        screen: ScreenCfg { rho_screen: 0.5, draft_lr: 1e-3, warmup_batches: 2 },
        ..rev_cfg(workers)
    }
}

#[test]
fn reversal_screened_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    let serial = train_reversal(&eng, &rev_screen_cfg(1)).unwrap();
    for workers in [2, 4] {
        let sharded = train_reversal(&eng, &rev_screen_cfg(workers)).unwrap();
        assert_curves_bit_identical(
            &serial.curve,
            &sharded.curve,
            &format!("reversal screened workers={workers}"),
        );
        assert_eq!(serial.ledger.screen_samples, sharded.ledger.screen_samples);
        assert_eq!(serial.ledger.backward_kept, sharded.ledger.backward_kept);
        assert_eq!(serial.ledger.bucket_hist, sharded.ledger.bucket_hist);
    }
    // the token screen engaged (embedded-token-row draft over the emit
    // table), but the fixed-shape rollout always runs whole
    let n_tok = (eng.manifest().constants.rev_batch * 4) as u64;
    assert!(serial.ledger.screen_samples > 0, "token screen never engaged");
    assert_eq!(
        serial.ledger.screen_samples % n_tok,
        0,
        "screened batches screen every token exactly once"
    );
    assert_eq!(serial.ledger.forward_skipped, 0, "reversal has no skippable forward");
    // the two-tier gate still gates: kept tokens well below the rollout
    assert!(serial.ledger.backward_kept > 0);
    assert!(serial.ledger.backward_kept < serial.ledger.forward_samples);
    // and the screened trajectory is a genuinely different run than the
    // unscreened one (the tier-1 pre-gate has teeth)
    let unscreened = train_reversal(&eng, &rev_cfg(1)).unwrap();
    let same = serial
        .curve
        .iter()
        .zip(&unscreened.curve)
        .all(|(x, y)| x.metric.to_bits() == y.metric.to_bits() && x.backward_kept == y.backward_kept);
    assert!(!same, "token screening changed nothing");
}

// ---- checkpoint/resume rides the same contract: a checkpoint written
// under one worker count resumes under another, bit-identically (the
// deep end-to-end coverage lives in rust/tests/checkpoint_resume.rs) ----

#[test]
fn checkpointed_resume_is_worker_invariant() {
    let eng = Engine::native_testbed();
    let dir = std::env::temp_dir()
        .join(format!("kondo_gated_e2e_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_at = |p: &PathBuf, every: usize| {
        Some(CheckpointCfg { path: p.to_string_lossy().into_owned(), every })
    };

    // mnist: save under w workers at step 16 of 24, resume under w'
    let serial = train_mnist(&eng, &mnist_cfg(1)).unwrap();
    for (w_save, w_resume) in [(1usize, 4usize), (4, 1)] {
        let mid = dir.join(format!("mnist_{w_save}to{w_resume}.ckpt"));
        let mut part1 = mnist_cfg(w_save);
        part1.steps = 16;
        part1.checkpoint = ckpt_at(&mid, 16);
        train_mnist(&eng, &part1).unwrap();
        let mut part2 = mnist_cfg(w_resume);
        part2.resume_from = Some(mid.to_string_lossy().into_owned());
        let resumed = train_mnist(&eng, &part2).unwrap();
        let what = format!("mnist ckpt w={w_save} -> resume w={w_resume}");
        assert_curves_bit_identical(&serial.curve, &resumed.curve, &what);
        assert_eq!(serial.ledger.forward_samples, resumed.ledger.forward_samples, "{what}");
        assert_eq!(serial.ledger.backward_kept, resumed.ledger.backward_kept, "{what}");
        assert_eq!(serial.ledger.backward_executed, resumed.ledger.backward_executed, "{what}");
        assert_eq!(serial.ledger.bucket_hist, resumed.ledger.bucket_hist, "{what}");
    }

    // reversal: save under w workers at step 8 of 12, resume under w'
    let rserial = train_reversal(&eng, &rev_cfg(1)).unwrap();
    for (w_save, w_resume) in [(1usize, 4usize), (4, 1)] {
        let mid = dir.join(format!("rev_{w_save}to{w_resume}.ckpt"));
        let mut part1 = rev_cfg(w_save);
        part1.steps = 8;
        part1.checkpoint = ckpt_at(&mid, 8);
        train_reversal(&eng, &part1).unwrap();
        let mut part2 = rev_cfg(w_resume);
        part2.resume_from = Some(mid.to_string_lossy().into_owned());
        let resumed = train_reversal(&eng, &part2).unwrap();
        let what = format!("reversal ckpt w={w_save} -> resume w={w_resume}");
        assert_curves_bit_identical(&rserial.curve, &resumed.curve, &what);
        assert_eq!(rserial.ledger.forward_samples, resumed.ledger.forward_samples, "{what}");
        assert_eq!(rserial.ledger.backward_kept, resumed.ledger.backward_kept, "{what}");
        assert_eq!(rserial.ledger.bucket_hist, resumed.ledger.bucket_hist, "{what}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- PR 7: every Priority rides the same determinism contract. The
// gate's ranking signal is a knob (Fig 5), so the eta = 0 bit-identity
// guarantee has to hold per priority -- Uniform in particular draws its
// scores from a batch-global keyed stream on the caller's thread. ----

fn priority_set() -> Vec<Priority> {
    vec![
        Priority::Delight,
        Priority::Advantage,
        Priority::Surprisal,
        Priority::AbsAdvantage,
        Priority::Uniform,
        Priority::Additive { alpha: 0.2 },
    ]
}

#[test]
fn every_priority_mnist_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    for pr in priority_set() {
        let mk = |workers: usize| MnistTrainerCfg {
            method: Method::DgK { gate: KondoGate::rate(0.25), priority: pr },
            steps: 16,
            ..mnist_cfg(workers)
        };
        let serial = train_mnist(&eng, &mk(1)).unwrap();
        for workers in [2, 4] {
            let sharded = train_mnist(&eng, &mk(workers)).unwrap();
            let what = format!("mnist priority={} workers={workers}", pr.name());
            assert_curves_bit_identical(&serial.curve, &sharded.curve, &what);
            assert_eq!(serial.ledger.forward_samples, sharded.ledger.forward_samples, "{what}");
            assert_eq!(serial.ledger.backward_kept, sharded.ledger.backward_kept, "{what}");
            assert_eq!(
                serial.ledger.backward_executed, sharded.ledger.backward_executed,
                "{what}"
            );
            assert_eq!(serial.ledger.bucket_hist, sharded.ledger.bucket_hist, "{what}");
        }
        // the rate gate holds the budget no matter which signal ranks
        let last = serial.curve.last().unwrap();
        assert!(last.backward_kept > 0, "priority {} kept nothing", pr.name());
        assert!(
            last.backward_kept * 2 < last.forward_samples,
            "priority {} overspent the rho=0.25 budget",
            pr.name()
        );
    }
}

#[test]
fn every_priority_reversal_trajectory_is_bit_identical() {
    let eng = Engine::native_testbed();
    for pr in priority_set() {
        let mk = |workers: usize| ReversalTrainerCfg {
            method: Method::DgK { gate: KondoGate::rate(0.25), priority: pr },
            steps: 8,
            ..rev_cfg(workers)
        };
        let serial = train_reversal(&eng, &mk(1)).unwrap();
        for workers in [2, 4] {
            let sharded = train_reversal(&eng, &mk(workers)).unwrap();
            let what = format!("reversal priority={} workers={workers}", pr.name());
            assert_curves_bit_identical(&serial.curve, &sharded.curve, &what);
            assert_eq!(serial.ledger.forward_samples, sharded.ledger.forward_samples, "{what}");
            assert_eq!(serial.ledger.backward_kept, sharded.ledger.backward_kept, "{what}");
            assert_eq!(serial.ledger.bucket_hist, sharded.ledger.bucket_hist, "{what}");
        }
    }
}

#[test]
fn every_priority_screened_run_is_deterministic_and_panic_free() {
    // the two-tier pipeline (screen -> forward -> gate) must accept every
    // priority: the tier-2 gate re-ranks the screen's survivors by the
    // configured signal, and the whole thing stays worker-invariant
    let eng = Engine::native_testbed();
    for pr in priority_set() {
        let mk = |workers: usize| MnistTrainerCfg {
            method: Method::DgK { gate: KondoGate::rate(0.25), priority: pr },
            steps: 20,
            eval_every: 10,
            ..mnist_screen_cfg(workers)
        };
        let serial = train_mnist(&eng, &mk(1)).unwrap();
        let sharded = train_mnist(&eng, &mk(2)).unwrap();
        let what = format!("mnist screened priority={}", pr.name());
        assert_curves_bit_identical(&serial.curve, &sharded.curve, &what);
        assert_eq!(serial.ledger.screen_samples, sharded.ledger.screen_samples, "{what}");
        assert_eq!(serial.ledger.forward_skipped, sharded.ledger.forward_skipped, "{what}");
        assert_eq!(serial.ledger.backward_kept, sharded.ledger.backward_kept, "{what}");
        assert!(serial.ledger.screen_samples > 0, "{what}: screen never engaged");

        let rk = |workers: usize| ReversalTrainerCfg {
            method: Method::DgK { gate: KondoGate::rate(0.25), priority: pr },
            steps: 8,
            ..rev_screen_cfg(workers)
        };
        let rs = train_reversal(&eng, &rk(1)).unwrap();
        let rp = train_reversal(&eng, &rk(2)).unwrap();
        let rwhat = format!("reversal screened priority={}", pr.name());
        assert_curves_bit_identical(&rs.curve, &rp.curve, &rwhat);
        assert_eq!(rs.ledger.screen_samples, rp.ledger.screen_samples, "{rwhat}");
        assert_eq!(rs.ledger.backward_kept, rp.ledger.backward_kept, "{rwhat}");
    }
}

#[test]
fn additive_small_alpha_keeps_rare_failures_delight_skips() {
    // Fig 5 / Prop 2 mis-ranking, at decision level on the real gate path:
    // a batch of 90 common modest successes (u > 0, tiny ell) and 10 rare
    // high-surprisal failures (u < 0, huge ell). At the same rho = 0.1
    // backward budget, delight (chi = u*ell) ranks every failure at the
    // bottom, while additive with small alpha is dominated by the ell term
    // and spends the budget on exactly those failures.
    let mut u = Vec::new();
    let mut ell = Vec::new();
    for i in 0..90 {
        u.push(0.3 + 0.005 * i as f64);
        ell.push(0.05 + 0.001 * i as f64);
    }
    for i in 0..10 {
        u.push(-0.1 - 0.02 * i as f64);
        ell.push(6.0 + 0.4 * i as f64);
    }
    let s = BatchSignals { u: &u, ell: &ell, logp_old: None, chi_override: None };
    let gate = KondoGate::rate(0.1);

    let mut rng = Pcg32::seeded(0);
    let del = Method::DgK { gate, priority: Priority::Delight }.decide(&s, &mut rng);
    let mut rng = Pcg32::seeded(0);
    let add =
        Method::DgK { gate, priority: Priority::Additive { alpha: 0.1 } }.decide(&s, &mut rng);

    // matched budget: same rate gate, ~10 of 100 kept by both
    assert!((8..=12).contains(&del.keep.len()), "delight kept {}", del.keep.len());
    assert!((8..=12).contains(&add.keep.len()), "additive kept {}", add.keep.len());

    // delight never touches a failure; additive spends its budget on them
    assert!(
        del.keep.iter().all(|&i| u[i] > 0.0),
        "delight kept a negative-advantage sample"
    );
    let add_failures = add.keep.iter().filter(|&&i| u[i] < 0.0).count();
    assert!(
        add_failures * 2 > add.keep.len(),
        "additive alpha=0.1 kept only {add_failures} failures of {}",
        add.keep.len()
    );

    // and at trainer scale the budgets still match while the runs diverge
    let eng = Engine::native_testbed();
    let mk = |pr| MnistTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: pr },
        ..mnist_cfg(1)
    };
    let tdel = train_mnist(&eng, &mk(Priority::Delight)).unwrap();
    let tadd = train_mnist(&eng, &mk(Priority::Additive { alpha: 0.1 })).unwrap();
    let (a, b) = (tdel.ledger.backward_kept as i64, tadd.ledger.backward_kept as i64);
    assert!((a - b).abs() <= 24, "budgets not matched at rho=0.25: {a} vs {b}");
    let same = tdel
        .curve
        .iter()
        .zip(&tadd.curve)
        .all(|(x, y)| x.metric.to_bits() == y.metric.to_bits());
    assert!(!same, "swapping the priority changed nothing");
}

#[test]
fn sharded_run_still_learns() {
    // determinism would be vacuous if the sharded loop broke learning:
    // a short DG-K run must beat the 10% random-guess error by a margin
    let eng = Engine::native_testbed();
    let cfg = MnistTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Delight },
        baseline: Baseline::Expected,
        lr: 3e-3,
        steps: 150,
        eval_every: 50,
        eval_size: 128,
        seed: 3,
        workers: 4,
        ..Default::default()
    };
    let res = train_mnist(&eng, &cfg).unwrap();
    let first = res.curve.first().unwrap().metric2;
    let last = res.final_test_err;
    assert!(
        last < first - 0.03 || last < 0.6,
        "no learning signal: test err {first:.3} -> {last:.3}"
    );
}
