//! End-to-end contracts for the fault-injected actor–learner runtime
//! (DESIGN.md §12), on the native testbed backend.
//!
//! Two contracts, both exact — no tolerances anywhere:
//!
//! 1. **Bit identity.** At eta = 0 the learner trajectory is a pure
//!    function of the seed: the inline reference, the threaded runtime
//!    at any actor/worker count, and a replay of the recorded stream all
//!    produce bit-identical curves, and zero-fault recorded streams are
//!    BYTE-identical across fleet shapes. Checkpoint/resume extends the
//!    same contract through the save/load boundary with a lagged
//!    snapshot ring in flight.
//!
//! 2. **Exact fault ledgers.** Every fault in a seeded `FaultPlan` is
//!    consumed exactly once, so the recovery counters (crashes,
//!    restarts, timeouts, shed, quarantined) must EQUAL the plan's
//!    `expected_counts` — not "at least", equal — across worker and
//!    actor counts.

use std::fs;
use std::path::PathBuf;

use kondo::checkpoint::CheckpointCfg;
use kondo::coordinator::{KondoGate, Priority};
use kondo::distrib::{train_distrib, DistribCfg, DistribMode, FaultPlan, TransportKind};
use kondo::runtime::Engine;
use kondo::trainers::EvalPoint;

fn test_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("kondo_distrib_test_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Short-run config: eta = 0 (hard gate) so the trajectory is
/// deterministic, eval every 2 steps so curves carry enough points to
/// disagree on.
fn base_cfg(seed: u64) -> DistribCfg {
    DistribCfg {
        method: kondo::algo::Method::DgK {
            gate: KondoGate::rate(0.25),
            priority: Priority::Delight,
        },
        steps: 10,
        eval_every: 2,
        eval_size: 64,
        seed,
        ..Default::default()
    }
}

/// Socket-fleet variant of [`base_cfg`]: same trajectory knobs, but the
/// actors are OS processes reached over a Unix socket. The heartbeat is
/// generous (process spawn and engine boot must not read as silence) and
/// the respawn budget covers every sever the wire-fault tests schedule
/// on one slot (torn + disconnect + crash all land on slot 0).
fn socket_cfg(seed: u64) -> DistribCfg {
    let mut cfg = base_cfg(seed);
    cfg.transport = TransportKind::Socket;
    cfg.actor_bin = Some(env!("CARGO_BIN_EXE_repro").to_string());
    cfg.heartbeat_ms = 4_000;
    cfg.max_respawns = 4;
    cfg
}

fn assert_curves_bit_identical(a: &[EvalPoint], b: &[EvalPoint], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: curve lengths differ");
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert_eq!(pa.step, pb.step, "{what}[{i}].step");
        assert_eq!(pa.forward_samples, pb.forward_samples, "{what}[{i}].forward_samples");
        assert_eq!(pa.backward_kept, pb.backward_kept, "{what}[{i}].backward_kept");
        assert_eq!(pa.backward_executed, pb.backward_executed, "{what}[{i}].backward_executed");
        assert_eq!(pa.metric.to_bits(), pb.metric.to_bits(), "{what}[{i}].metric");
        assert_eq!(pa.metric2.to_bits(), pb.metric2.to_bits(), "{what}[{i}].metric2");
    }
}

// ---------------------------------------------------------------------
// contract 1: bit identity across modes, fleet shapes, and replay
// ---------------------------------------------------------------------

#[test]
fn threaded_and_replay_match_the_inline_reference_bit_for_bit() {
    let eng = Engine::native_testbed();
    let dir = test_dir("modes");

    // inline reference, recording its stream
    let mut cfg = base_cfg(3);
    let inline_stream = dir.join("inline.json");
    cfg.record_to = Some(inline_stream.to_string_lossy().into_owned());
    let inline = train_distrib(&eng, &cfg, &DistribMode::Inline).unwrap();

    // threaded across fleet shapes: same curve; and the recorded stream
    // is byte-identical to an inline run of the SAME actor count (the
    // `actor` provenance stamp is `t % actors`, everything else is a
    // pure function of the seed)
    for (actors, workers) in [(1usize, 1usize), (2, 2), (4, 1)] {
        let mut cfg = base_cfg(3);
        cfg.actors = actors;
        cfg.workers = workers;
        let stream = dir.join(format!("threaded_{actors}x{workers}.json"));
        cfg.record_to = Some(stream.to_string_lossy().into_owned());
        let res = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap();
        let what = format!("threaded {actors} actors x {workers} workers");
        assert_curves_bit_identical(&inline.curve, &res.curve, &what);
        assert_eq!(
            res.final_test_err.to_bits(),
            inline.final_test_err.to_bits(),
            "{what}: final test err"
        );
        let mut ref_cfg = base_cfg(3);
        ref_cfg.actors = actors;
        let ref_stream = dir.join(format!("inline_{actors}.json"));
        ref_cfg.record_to = Some(ref_stream.to_string_lossy().into_owned());
        train_distrib(&eng, &ref_cfg, &DistribMode::Inline).unwrap();
        assert_eq!(
            fs::read(&ref_stream).unwrap(),
            fs::read(&stream).unwrap(),
            "{what}: recorded stream must be byte-identical to the inline one"
        );
        // no faults injected: the recovery ledger is all zeros
        let l = &res.ledger;
        assert_eq!(
            (l.actor_crashes, l.actor_restarts, l.actor_timeouts, l.shed_samples),
            (0, 0, 0, 0),
            "{what}: zero-fault run must report a clean recovery ledger"
        );
        assert_eq!((l.quarantined_samples, l.quarantined_batches), (0, 0), "{what}");
    }

    // replaying the recorded stream reproduces the run exactly
    let cfg = base_cfg(3);
    let mode = DistribMode::Replay(inline_stream.to_string_lossy().into_owned());
    let replay = train_distrib(&eng, &cfg, &mode).unwrap();
    assert_curves_bit_identical(&inline.curve, &replay.curve, "replay");

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_lag_changes_the_trajectory_but_not_its_determinism() {
    let eng = Engine::native_testbed();

    let mut lagged = base_cfg(5);
    lagged.lag = 3;
    lagged.stale_penalty = 0.5;
    let a = train_distrib(&eng, &lagged, &DistribMode::Threaded).unwrap();
    let b = train_distrib(&eng, &lagged, &DistribMode::Threaded).unwrap();
    assert_curves_bit_identical(&a.curve, &b.curve, "lag=3 rerun");

    // the inline reference honours the same lag ring
    let c = train_distrib(&eng, &lagged, &DistribMode::Inline).unwrap();
    assert_curves_bit_identical(&a.curve, &c.curve, "lag=3 inline vs threaded");

    // all but the first `lag` steps run on stale snapshots and are priced
    let b_sz = eng.manifest().constants.mnist_batch;
    assert_eq!(a.ledger.stale_samples, ((lagged.steps - 1) * b_sz) as u64);

    // and a lag-0 run really is a different trajectory (the knob bites)
    let fresh = train_distrib(&eng, &base_cfg(5), &DistribMode::Threaded).unwrap();
    assert_ne!(
        fresh.curve.last().unwrap().metric2.to_bits(),
        a.curve.last().unwrap().metric2.to_bits(),
        "lag must alter the trajectory (else the ring is dead code)"
    );
}

// ---------------------------------------------------------------------
// contract 2: ledger totals exactly match the seeded FaultPlan
// ---------------------------------------------------------------------

#[test]
fn fault_ledger_exactly_matches_the_plan_across_fleet_shapes() {
    let eng = Engine::native_testbed();
    let spec = "crash@3,poison@6:nan_u:3,poison@8:fingerprint,lag=2";
    let b = eng.manifest().constants.mnist_batch;
    let expect = FaultPlan::parse(spec).unwrap().expected_counts(b);
    assert_eq!(expect.crashes, 1);
    assert_eq!(expect.restarts, 1);
    assert_eq!(expect.quarantined_samples, 3 + b as u64);
    assert_eq!(expect.quarantined_batches, 1);

    for (actors, workers) in [(2usize, 1usize), (3, 2)] {
        let mut cfg = base_cfg(7);
        cfg.actors = actors;
        cfg.workers = workers;
        cfg.fault_spec = spec.into();
        let res = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap();
        let l = &res.ledger;
        let what = format!("{actors} actors x {workers} workers");
        assert_eq!(l.actor_crashes, expect.crashes, "{what}: crashes");
        assert_eq!(l.actor_restarts, expect.restarts, "{what}: restarts");
        assert_eq!(l.quarantined_samples, expect.quarantined_samples, "{what}: quarantined");
        assert_eq!(l.quarantined_batches, expect.quarantined_batches, "{what}: q-batches");
        assert_eq!(l.actor_timeouts, 0, "{what}: a crash announces itself, no timeout");
        assert_eq!(l.shed_samples, 0, "{what}: nothing shed without a stall");
        // every step still ingested something: quarantined batches skip
        // record_forward, admitted ones log the full batch
        assert_eq!(
            l.forward_samples,
            ((cfg.steps - 1) * b) as u64,
            "{what}: one batch quarantined wholesale"
        );
    }
}

#[test]
fn a_stalled_actor_times_out_and_its_late_delivery_is_shed() {
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch;

    let mut cfg = base_cfg(11);
    cfg.actors = 2;
    cfg.heartbeat_ms = 250;
    cfg.fault_spec = "stall@2:1500".into();
    let res = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap();
    let l = &res.ledger;
    assert_eq!(l.actor_timeouts, 1, "one stall, one timeout");
    assert_eq!(l.shed_samples, b as u64, "the superseded delivery is shed");
    assert_eq!(l.actor_crashes, 0, "a slow actor is not a dead actor");
    assert_eq!(l.actor_restarts, 0);

    // the re-dispatched rollout is bit-identical to the stalled one, so
    // the trajectory still matches a fault-free run exactly
    let clean = train_distrib(&eng, &base_cfg(11), &DistribMode::Threaded).unwrap();
    assert_curves_bit_identical(&clean.curve, &res.curve, "stall vs clean");
}

#[test]
fn respawn_budget_zero_degrades_to_the_survivor_and_still_finishes() {
    let eng = Engine::native_testbed();
    let mut cfg = base_cfg(13);
    cfg.actors = 2;
    cfg.max_respawns = 0;
    cfg.fault_spec = "crash@4".into();
    let res = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap();
    assert_eq!(res.ledger.actor_crashes, 1);
    assert_eq!(res.ledger.actor_restarts, 0, "budget 0: no respawn granted");
    assert_eq!(res.curve.last().unwrap().step, cfg.steps, "run completed on the survivor");

    // the trajectory is indifferent to which slot computed what
    let clean = train_distrib(&eng, &base_cfg(13), &DistribMode::Threaded).unwrap();
    assert_curves_bit_identical(&clean.curve, &res.curve, "degraded vs clean");

    // a sole actor with no budget left cannot survive its own crash
    let mut cfg = base_cfg(13);
    cfg.actors = 1;
    cfg.max_respawns = 0;
    cfg.fault_spec = "crash@4".into();
    let err = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap_err().to_string();
    assert!(err.contains("dead"), "total fleet loss is a clean error: {err}");
}

#[test]
fn a_faulted_run_replays_into_the_same_trajectory_and_quarantine_ledger() {
    let eng = Engine::native_testbed();
    let dir = test_dir("faulted_replay");

    // poison + lag, inline (replay carries data faults; crash/stall are
    // runtime events and documented as outside the stream)
    let mut cfg = base_cfg(17);
    cfg.fault_spec = "poison@3:nan_ell:4,poison@5:bad_action:2,lag=1".into();
    cfg.stale_penalty = 0.5;
    let stream = dir.join("poisoned.json");
    cfg.record_to = Some(stream.to_string_lossy().into_owned());
    let live = train_distrib(&eng, &cfg, &DistribMode::Inline).unwrap();
    assert_eq!(live.ledger.quarantined_samples, 6);

    let mut replay_cfg = cfg.clone();
    replay_cfg.record_to = None;
    let mode = DistribMode::Replay(stream.to_string_lossy().into_owned());
    let replay = train_distrib(&eng, &replay_cfg, &mode).unwrap();
    assert_curves_bit_identical(&live.curve, &replay.curve, "poisoned replay");
    assert_eq!(replay.ledger.quarantined_samples, live.ledger.quarantined_samples);
    assert_eq!(replay.ledger.stale_samples, live.ledger.stale_samples);

    // a config drift (different penalty => different fingerprint) refuses
    // to ingest the recording
    let mut drifted = replay_cfg.clone();
    drifted.stale_penalty = 0.9;
    let err = train_distrib(&eng, &drifted, &mode).unwrap_err().to_string();
    assert!(err.contains("fingerprint"), "{err}");

    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// checkpoint/resume through the distributed path
// ---------------------------------------------------------------------

#[test]
fn resume_with_a_lagged_ring_is_bit_identical_to_the_uninterrupted_run() {
    let eng = Engine::native_testbed();
    let dir = test_dir("resume");
    let ck_path = dir.join("dist.ckpt");

    let mut full = base_cfg(19);
    full.lag = 2;
    full.stale_penalty = 0.5;
    full.steps = 8;
    full.checkpoint =
        Some(CheckpointCfg { path: ck_path.to_string_lossy().into_owned(), every: 4 });
    let uninterrupted = train_distrib(&eng, &full, &DistribMode::Threaded).unwrap();

    // run to the mid checkpoint only, then resume from it
    let mut half = full.clone();
    half.steps = 4;
    train_distrib(&eng, &half, &DistribMode::Threaded).unwrap();
    let mut resumed_cfg = full.clone();
    resumed_cfg.resume_from = Some(ck_path.to_string_lossy().into_owned());
    let resumed = train_distrib(&eng, &resumed_cfg, &DistribMode::Threaded).unwrap();
    assert_curves_bit_identical(&uninterrupted.curve, &resumed.curve, "resume");
    assert_eq!(
        uninterrupted.ledger.backward_kept, resumed.ledger.backward_kept,
        "ledger totals survive the boundary"
    );

    // the ring is part of the contract: resuming under a different lag
    // must be refused, naming the knob
    let mut wrong = resumed_cfg.clone();
    wrong.lag = 1;
    let err = train_distrib(&eng, &wrong, &DistribMode::Threaded).unwrap_err().to_string();
    assert!(err.contains("lag"), "wrong-lag resume must name the knob: {err}");

    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// cross-process transport: socket == channel == inline, exactly
// ---------------------------------------------------------------------

#[test]
fn a_socket_fleet_matches_channel_and_inline_bit_for_bit() {
    let eng = Engine::native_testbed();
    let inline = train_distrib(&eng, &base_cfg(23), &DistribMode::Inline).unwrap();

    for (actors, workers) in [(1usize, 1usize), (2, 2)] {
        let mut ch = base_cfg(23);
        ch.actors = actors;
        ch.workers = workers;
        let channel = train_distrib(&eng, &ch, &DistribMode::Threaded).unwrap();

        let mut sk = socket_cfg(23);
        sk.actors = actors;
        sk.workers = workers;
        let socket = train_distrib(&eng, &sk, &DistribMode::Threaded).unwrap();

        let what = format!("socket fleet {actors} actors x {workers} workers");
        assert_curves_bit_identical(&inline.curve, &socket.curve, &what);
        assert_curves_bit_identical(&channel.curve, &socket.curve, &what);
        assert_eq!(
            socket.final_test_err.to_bits(),
            inline.final_test_err.to_bits(),
            "{what}: final test err"
        );

        // clean run: wire and recovery ledgers are all zeros, and the
        // ingest totals match the channel fleet exactly
        let l = &socket.ledger;
        assert_eq!(
            (l.wire_corrupt_frames, l.wire_reconnects, l.handshake_rejects),
            (0, 0, 0),
            "{what}: clean wire"
        );
        assert_eq!(
            (l.actor_crashes, l.actor_restarts, l.actor_timeouts, l.shed_samples),
            (0, 0, 0, 0),
            "{what}: clean recovery ledger"
        );
        assert_eq!(l.forward_samples, channel.ledger.forward_samples, "{what}");
        assert_eq!(l.backward_kept, channel.ledger.backward_kept, "{what}");
    }
}

#[test]
fn a_socket_fleet_quarantines_poison_exactly_like_the_channel_one() {
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch;
    let spec = "poison@3:nan_u:3,poison@6:fingerprint";

    let mut ch = base_cfg(27);
    ch.actors = 2;
    ch.fault_spec = spec.into();
    let channel = train_distrib(&eng, &ch, &DistribMode::Threaded).unwrap();

    let mut sk = socket_cfg(27);
    sk.actors = 2;
    sk.fault_spec = spec.into();
    let socket = train_distrib(&eng, &sk, &DistribMode::Threaded).unwrap();

    // the poison crossed the wire intact (NaNs round-trip bitwise) and
    // hit the same admission path: same curves, same quarantine ledger
    assert_curves_bit_identical(&channel.curve, &socket.curve, "poisoned socket vs channel");
    assert_eq!(socket.ledger.quarantined_samples, 3 + b as u64);
    assert_eq!(socket.ledger.quarantined_samples, channel.ledger.quarantined_samples);
    assert_eq!(socket.ledger.quarantined_batches, channel.ledger.quarantined_batches);
    assert_eq!(
        socket.ledger.wire_corrupt_frames, 0,
        "poison is bad data in valid frames, not wire damage"
    );
}

#[test]
fn a_torn_disconnected_bitflipped_and_crashed_socket_run_recovers_exactly() {
    let eng = Engine::native_testbed();
    let b = eng.manifest().constants.mnist_batch;
    let spec = "torn@2,disconnect@4,bitflip@6:17,crash@8";
    let expect = FaultPlan::parse(spec).unwrap().expected_counts(b);
    assert_eq!(expect.wire_corrupt_frames, 2, "torn + bitflip each cost a frame");
    assert_eq!(expect.wire_reconnects, 2, "torn + disconnect each sever the link");
    assert_eq!(expect.crashes, 1);
    assert_eq!(expect.restarts, 1);

    let mut cfg = socket_cfg(29);
    cfg.actors = 2;
    cfg.fault_spec = spec.into();
    let res = train_distrib(&eng, &cfg, &DistribMode::Threaded).unwrap();

    // recovery is asserted by EQUALITY against the plan, not survival
    let l = &res.ledger;
    assert_eq!(l.wire_corrupt_frames, expect.wire_corrupt_frames, "corrupt frames");
    assert_eq!(l.wire_reconnects, expect.wire_reconnects, "reconnects");
    assert_eq!(l.actor_crashes, expect.crashes, "crashes");
    assert_eq!(l.actor_restarts, expect.restarts, "restarts");
    assert_eq!(l.handshake_rejects, 0, "respawned actors present the right fingerprint");
    assert_eq!(
        (l.quarantined_samples, l.quarantined_batches),
        (0, 0),
        "wire damage is dropped before admission, never quarantined as data"
    );

    // wire damage happens AFTER the rollout is computed, so the repaired
    // trajectory is bit-identical to an undamaged fleet and to inline
    let mut clean = socket_cfg(29);
    clean.actors = 2;
    let reference = train_distrib(&eng, &clean, &DistribMode::Threaded).unwrap();
    assert_curves_bit_identical(&reference.curve, &res.curve, "faulted socket vs clean");
    let inline = train_distrib(&eng, &base_cfg(29), &DistribMode::Inline).unwrap();
    assert_curves_bit_identical(&inline.curve, &res.curve, "faulted socket vs inline");
}

#[test]
fn wire_faults_demand_the_socket_transport() {
    let eng = Engine::native_testbed();
    let mut cfg = base_cfg(31);
    cfg.fault_spec = "torn@2,bitflip@5:3".into();
    for mode in [DistribMode::Inline, DistribMode::Threaded] {
        let err = train_distrib(&eng, &cfg, &mode).unwrap_err().to_string();
        assert!(err.contains("transport=socket"), "must name the fix: {err}");
    }
}
