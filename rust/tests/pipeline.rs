//! Integration + property tests over the full L3 pipeline with real
//! artifacts. Property-style tests draw seeded random cases (proptest is
//! not in the offline vendor set; the loop-with-seeds pattern below is the
//! same idea with reproducible failures).

use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::batcher::{gather_f32, gather_i32, gather_rows_f32};
use kondo::coordinator::{BucketSet, KondoGate, Priority};
use kondo::model::{accumulate, ParamStore};
use kondo::runtime::{Engine, HostTensor};
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};
use kondo::utils::rng::Pcg32;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::new(&dir).unwrap())
}

/// PROPERTY (the bucketed-backward invariant, end to end): for random kept
/// subsets, the gradient computed by packing kept samples into the
/// smallest bucket equals the full-batch gradient with zeroed weights.
#[test]
fn bucketed_backward_equals_full_batch_zero_weight() {
    let Some(eng) = engine() else { return };
    let man = eng.manifest();
    let b = man.constants.mnist_batch;
    let img = man.constants.mnist_in;
    let rules = man.model("mnist").unwrap().to_vec();
    let params = ParamStore::init(&rules, 3);
    let buckets = BucketSet::new(man.constants.mnist_bwd_caps.clone()).unwrap();

    for case_seed in 0..5u64 {
        let mut rng = Pcg32::seeded(100 + case_seed);
        let x: Vec<f32> = (0..b * img).map(|_| rng.normal() as f32).collect();
        let actions: Vec<i32> = (0..b).map(|_| rng.below(10) as i32).collect();
        let n_keep = 1 + rng.below(20) as usize;
        let mut idx: Vec<usize> = (0..b).collect();
        rng.shuffle(&mut idx);
        let kept: Vec<usize> = idx[..n_keep].to_vec();
        let weights: Vec<f32> = (0..b).map(|_| rng.normal() as f32).collect();

        // full batch, zeroing skipped weights
        let mut w_full = vec![0.0f32; b];
        for &i in &kept {
            w_full[i] = weights[i];
        }
        let mut inp = params.as_inputs();
        inp.push(HostTensor::f32(&[b, img], x.clone()));
        inp.push(HostTensor::i32(&[b], actions.clone()));
        inp.push(HostTensor::f32(&[b], w_full));
        let full = eng.execute(&format!("mnist_bwd_c{b}"), &inp).unwrap();

        // bucketed path
        let mut acc = params.zeros_like();
        for chunk in buckets.pack(&kept) {
            let cap = chunk.cap;
            let xs = gather_rows_f32(&x, img, &chunk.idx, cap);
            let acts = gather_i32(&actions, &chunk.idx, cap);
            let per: Vec<f32> = chunk.idx.iter().map(|&i| weights[i]).collect();
            let w = gather_f32(&per, &(0..chunk.idx.len()).collect::<Vec<_>>(), cap);
            let mut binp = params.as_inputs();
            binp.push(HostTensor::f32(&[cap, img], xs));
            binp.push(HostTensor::i32(&[cap], acts));
            binp.push(HostTensor::f32(&[cap], w));
            let out = eng.execute(&format!("mnist_bwd_c{cap}"), &binp).unwrap();
            accumulate(&mut acc, &out[1..]).unwrap();
        }

        for (ti, g_full) in full[1..].iter().enumerate() {
            let gf = g_full.as_f32().unwrap();
            let gb = &acc[ti];
            let max_abs = gf.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
            for (a, bb) in gf.iter().zip(gb) {
                assert!(
                    (a - bb).abs() <= 1e-4 * max_abs + 1e-6,
                    "case {case_seed}, tensor {ti}: {a} vs {bb}"
                );
            }
        }
    }
}

/// DG-K with rho = 1 keeps everything with weight U: it must be EXACTLY
/// PG (same seeds -> bitwise-equal training trajectory).
#[test]
fn dgk_rate_one_is_pg() {
    let Some(eng) = engine() else { return };
    let mk = |method| MnistTrainerCfg {
        method,
        baseline: Baseline::Expected,
        lr: 1e-3,
        steps: 30,
        eval_every: 30,
        eval_size: 500,
        seed: 5,
        ..Default::default()
    };
    let pg = train_mnist(&eng, &mk(Method::Pg)).unwrap();
    let kg = train_mnist(
        &eng,
        &mk(Method::DgK { gate: KondoGate::rate(1.0), priority: Priority::Delight }),
    )
    .unwrap();
    assert_eq!(pg.final_test_err, kg.final_test_err);
    assert_eq!(pg.curve.last().unwrap().metric, kg.curve.last().unwrap().metric);
    // but the ledgers agree too: rho=1 pays for every backward pass
    assert_eq!(pg.ledger.backward_kept, kg.ledger.backward_kept);
}

/// Training is deterministic in the seed and differs across seeds.
#[test]
fn mnist_training_deterministic_in_seed() {
    let Some(eng) = engine() else { return };
    let mk = |seed| MnistTrainerCfg {
        method: Method::Dg,
        steps: 20,
        eval_every: 20,
        eval_size: 500,
        seed,
        ..Default::default()
    };
    let a = train_mnist(&eng, &mk(7)).unwrap();
    let b = train_mnist(&eng, &mk(7)).unwrap();
    let c = train_mnist(&eng, &mk(8)).unwrap();
    assert_eq!(a.final_test_err, b.final_test_err);
    assert_eq!(a.ledger.backward_kept, b.ledger.backward_kept);
    assert!(
        (a.curve[0].metric - c.curve[0].metric).abs() > 0.0
            || a.final_test_err != c.final_test_err
    );
}

/// The ledger adds up: forward samples = steps * B; the adaptive gate's
/// empirical rate is close to rho; executed slots >= kept samples.
#[test]
fn ledger_consistency_under_gating() {
    let Some(eng) = engine() else { return };
    let cfg = MnistTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.05), priority: Priority::Delight },
        steps: 100,
        eval_every: 100,
        eval_size: 500,
        seed: 2,
        ..Default::default()
    };
    let res = train_mnist(&eng, &cfg).unwrap();
    assert_eq!(res.ledger.forward_samples, 100 * 100);
    assert!(res.ledger.backward_executed >= res.ledger.backward_kept);
    let rate = res.ledger.gate_rate();
    assert!((rate - 0.05).abs() < 0.02, "gate rate {rate}");
    // executed slots land on compiled bucket capacities only
    for cap in res.ledger.bucket_hist.keys() {
        assert!(eng.manifest().constants.mnist_bwd_caps.contains(cap));
    }
}

/// Reversal: the lambda=0 adaptive gate must keep roughly the positive-
/// advantage token fraction and save backward compute vs full DG.
#[test]
fn reversal_adaptive_gate_saves_backward() {
    let Some(eng) = engine() else { return };
    let mk = |method| ReversalTrainerCfg {
        method,
        steps: 15,
        h: 5,
        m: 2,
        seed: 3,
        eval_every: 15,
        ..Default::default()
    };
    let dg = train_reversal(&eng, &mk(Method::Dg)).unwrap();
    let kg = train_reversal(
        &eng,
        &mk(Method::DgK { gate: KondoGate::price(0.0), priority: Priority::Delight }),
    )
    .unwrap();
    assert!(kg.ledger.backward_kept < dg.ledger.backward_kept);
    assert!(kg.ledger.backward_executed <= dg.ledger.backward_executed);
    assert_eq!(dg.ledger.forward_samples, kg.ledger.forward_samples);
}

/// PPO with inner epochs runs the rev_fwd re-scoring path.
#[test]
fn ppo_inner_epochs_exercise_ratio_path() {
    let Some(eng) = engine() else { return };
    let cfg = ReversalTrainerCfg {
        method: Method::Ppo { eps: 0.2 },
        steps: 4,
        h: 4,
        m: 2,
        seed: 1,
        eval_every: 4,
        inner_epochs: 2,
        ..Default::default()
    };
    let res = train_reversal(&eng, &cfg).unwrap();
    // 4 rollouts + 4 re-scoring forwards, tokens each
    assert_eq!(res.ledger.forward_samples, (4 + 4) * 100 * 4);
    assert!(res.ledger.backward_calls >= 8);
}

/// PROPERTY: gather with identity indices is the identity (random shapes).
#[test]
fn gather_identity_property() {
    for seed in 0..10u64 {
        let mut rng = Pcg32::seeded(seed);
        let rows = 1 + rng.below(20) as usize;
        let width = 1 + rng.below(50) as usize;
        let src: Vec<f32> = (0..rows * width).map(|_| rng.normal() as f32).collect();
        let idx: Vec<usize> = (0..rows).collect();
        let out = gather_rows_f32(&src, width, &idx, rows);
        assert_eq!(out, src);
    }
}
