//! Integration locks on the two resource contracts of the kernel layer
//! (DESIGN.md §9), measured over real training runs:
//!
//! - **Pack cache:** each weight matrix is packed exactly once per
//!   optimizer step (beside the marshal), never per call — so the pack
//!   count is a function of steps alone, identical for every worker
//!   count.
//! - **Tensor arena:** per-step buffers cycle through the arena, so a
//!   warm process runs whole training runs with zero (serial) or
//!   near-zero (sharded) fresh allocations.
//!
//! Both contracts are asserted against process-global counters
//! (`kernels::packs_built`, `tensor::arena_stats`), so the tests live in
//! their own test binary and serialize on a local mutex — nothing else
//! in this process touches the counters between measurements.

use std::sync::Mutex;

use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::runtime::kernels::packs_built;
use kondo::runtime::{arena_stats, Engine};
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

static GATE: Mutex<()> = Mutex::new(());

fn mnist_cfg(steps: usize, workers: usize) -> MnistTrainerCfg {
    MnistTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.25), priority: Priority::Delight },
        baseline: Baseline::Expected,
        lr: 1e-3,
        steps,
        eval_every: 10_000, // only the mandatory last-step eval runs
        eval_size: 128,
        seed: 3,
        workers,
        ..Default::default()
    }
}

#[test]
fn weights_pack_once_per_step_for_any_worker_count() {
    let _lock = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::native_testbed();
    let steps = 5;
    // expected per run: 2 packs per step (w1, w2 refilled beside the
    // marshal) + 2 for the single end-of-run eval marshal (as_inputs)
    let expected = (steps as u64) * 2 + 2;
    for workers in [1usize, 2, 4] {
        let before = packs_built();
        train_mnist(&eng, &mnist_cfg(steps, workers)).unwrap();
        let built = packs_built() - before;
        assert_eq!(
            built, expected,
            "workers={workers}: {built} packs built over {steps} steps, expected {expected} \
             (per-call packing would scale with chunk count, not steps)"
        );
    }

    // reversal: attn + emit, one marshal per step, no eval marshal
    let rev = ReversalTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.2), priority: Priority::Delight },
        steps: 4,
        h: 5,
        m: 2,
        seed: 1,
        eval_every: 10_000,
        inner_epochs: 1,
        workers: 2,
        ..Default::default()
    };
    let before = packs_built();
    train_reversal(&eng, &rev).unwrap();
    assert_eq!(packs_built() - before, 4 * 2, "reversal packs attn+emit once per step");
}

#[test]
fn arena_recycles_serial_steady_state_to_zero_fresh_allocations() {
    let _lock = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::native_testbed();
    // run A warms the arena from empty; run B re-runs the identical
    // trajectory (same seed => same buffer sequence) on the warm arena
    let cfg = mnist_cfg(6, 1);
    train_mnist(&eng, &cfg).unwrap();
    let warm = arena_stats();
    train_mnist(&eng, &cfg).unwrap();
    let after = arena_stats();
    assert_eq!(
        after.total() - warm.total(),
        0,
        "warm serial run must serve every take from the freelists \
         (fresh f32 {} -> {}, i32 {} -> {})",
        warm.fresh_f32,
        after.fresh_f32,
        warm.fresh_i32,
        after.fresh_i32
    );
}

#[test]
fn arena_recycles_across_sharded_runs() {
    let _lock = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::native_testbed();
    // sharded: worker threads allocate, the caller recycles, exited
    // workers flush their freelists to the shared pool — so repeated
    // runs converge to (near-)zero fresh allocations. Exact zero is not
    // guaranteed (scheduling decides which worker serves which chunk),
    // and a cold/warm ratio would be order-dependent (another test in
    // this binary may already have warmed the process-global shared
    // pool), so the lock is an absolute bound on a run that is warm no
    // matter which test ran first: two warm-up runs, then the measured
    // run must stay an order of magnitude below what the ~20 takes/step
    // x 6 steps would allocate without recycling (> 100).
    let cfg = mnist_cfg(6, 2);
    train_mnist(&eng, &cfg).unwrap();
    train_mnist(&eng, &cfg).unwrap();
    let warm_before = arena_stats();
    train_mnist(&eng, &cfg).unwrap();
    let warm = arena_stats().total() - warm_before.total();
    assert!(
        warm <= 12,
        "sharded warm run still allocating: {warm} fresh buffers in a 6-step run \
         (an unrecycled hot path would allocate > 100)"
    );
}

#[test]
fn reversal_arena_reaches_steady_state() {
    let _lock = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let eng = Engine::native_testbed();
    let cfg = ReversalTrainerCfg {
        method: Method::DgK { gate: KondoGate::rate(0.2), priority: Priority::Delight },
        steps: 4,
        h: 5,
        m: 2,
        seed: 2,
        eval_every: 10_000,
        inner_epochs: 2, // exercises the re-scoring forward path too
        workers: 1,
        ..Default::default()
    };
    train_reversal(&eng, &cfg).unwrap();
    let warm = arena_stats();
    train_reversal(&eng, &cfg).unwrap();
    let after = arena_stats();
    assert_eq!(
        after.total() - warm.total(),
        0,
        "warm serial reversal run must allocate nothing fresh"
    );
}
