//! Minimal bench harness (criterion is not in the offline vendor set):
//! warmup + timed iterations, reporting mean / p50 / p99 per op.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, warmup: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p50,
        p99_ns: p99,
    };
    println!(
        "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns)
    );
    r
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}
