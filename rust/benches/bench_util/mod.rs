//! Minimal bench harness (criterion is not in the offline vendor set):
//! warmup + timed iterations, reporting mean / p50 / p99 per op, plus a
//! machine-readable JSON sink so the repo's perf trajectory is recorded
//! PR-over-PR (`BENCH_e2e.json`) instead of living only in scrollback.
#![allow(dead_code)] // each bench target compiles its own subset

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, warmup: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p50,
        p99_ns: p99,
    };
    println!(
        "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns)
    );
    r
}

/// One machine-readable bench entry: a (section, method, workers) cell of
/// the e2e matrix with its per-step latency and throughput.
pub struct BenchEntry {
    pub section: String,
    pub method: String,
    pub workers: usize,
    pub mean_ns_per_step: f64,
    pub throughput_per_sec: f64,
    /// what `throughput_per_sec` counts ("samples" for MNIST rows,
    /// "tokens" for reversal) -- keeps cross-section comparisons honest
    pub unit: String,
}

/// Collects bench entries and writes them as a JSON report. The format is
/// intentionally flat (one object per (section, method, workers) cell) so
/// PR-over-PR diffs and plots need no schema gymnastics.
pub struct JsonReport {
    bench: String,
    platform: String,
    entries: Vec<BenchEntry>,
}

impl JsonReport {
    pub fn new(bench: &str, platform: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), platform: platform.to_string(), entries: Vec::new() }
    }

    pub fn record(
        &mut self,
        section: &str,
        method: &str,
        workers: usize,
        mean_ns_per_step: f64,
        throughput_per_sec: f64,
        unit: &str,
    ) {
        self.entries.push(BenchEntry {
            section: section.to_string(),
            method: method.to_string(),
            workers,
            mean_ns_per_step,
            throughput_per_sec,
            unit: unit.to_string(),
        });
    }

    /// Serialize to pretty-printed JSON. Strings here are simple
    /// identifiers (method/section names), so escaping is limited to the
    /// characters they could plausibly contain.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", esc(&self.bench)));
        s.push_str("  \"schema\": 1,\n");
        s.push_str(&format!("  \"platform\": \"{}\",\n", esc(&self.platform)));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            let per_worker = e.throughput_per_sec / e.workers.max(1) as f64;
            s.push_str(&format!(
                "    {{\"section\": \"{}\", \"method\": \"{}\", \"workers\": {}, \
                 \"mean_ns_per_step\": {:.1}, \"unit\": \"{}\", \
                 \"samples_per_s\": {:.1}, \"samples_per_s_per_worker\": {:.1}}}{}\n",
                esc(&e.section),
                esc(&e.method),
                e.workers,
                e.mean_ns_per_step,
                esc(&e.unit),
                e.throughput_per_sec,
                per_worker,
                if i + 1 == self.entries.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the report to `path`, replacing any previous trajectory
    /// point. Errors are reported, not fatal: a read-only checkout must
    /// not fail the bench run itself.
    pub fn write(&self, path: &str) {
        match std::fs::write(path, self.to_json()) {
            Ok(()) => println!("\nwrote {path} ({} entries)", self.entries.len()),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}
