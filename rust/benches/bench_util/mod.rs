//! Minimal bench harness (criterion is not in the offline vendor set):
//! warmup + timed iterations, reporting mean / p50 / p99 per op, plus a
//! machine-readable JSON sink so the repo's perf trajectory is recorded
//! PR-over-PR (`BENCH_e2e.json`) instead of living only in scrollback.
#![allow(dead_code)] // each bench target compiles its own subset

use std::collections::BTreeMap;
use std::time::Instant;

use kondo::utils::json::Json;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

pub fn bench<F: FnMut()>(name: &str, iters: usize, warmup: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: p50,
        p99_ns: p99,
    };
    println!(
        "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
        r.name,
        r.iters,
        fmt_ns(r.mean_ns),
        fmt_ns(r.p50_ns),
        fmt_ns(r.p99_ns)
    );
    r
}

/// One machine-readable bench entry: a (section, method, workers) cell
/// of a bench matrix with its per-call latency and throughput. The
/// `unit` names what `throughput_per_sec` counts ("samples" for MNIST
/// rows, "tokens" for reversal, "gflops" for kernel microbenchmarks) --
/// keeps cross-section comparisons honest.
pub struct BenchEntry {
    pub section: String,
    pub method: String,
    pub workers: usize,
    pub mean_ns_per_step: f64,
    pub throughput_per_sec: f64,
    pub unit: String,
    /// optional extra numeric columns (e.g. the kernel bench's
    /// roofline-style `bytes_per_call` / `gbytes_per_s`); keys must stay
    /// within the allowlist of `rust/tests/bench_schema.rs`
    pub extras: Vec<(String, f64)>,
}

/// Collects bench entries and merge-writes them into the shared
/// `BENCH_e2e.json` trajectory file (schema 2): the file holds one
/// section per bench binary under `"benches"`, and each bench run
/// replaces only its own section, so `e2e_step` and `kernels` results
/// coexist in one committed trajectory point. The entry format is flat
/// (one object per (section, method, workers) cell) so PR-over-PR diffs
/// and plots need no schema gymnastics; `rust/tests/bench_schema.rs`
/// validates the committed file against this schema in tier-1.
pub struct JsonReport {
    bench: String,
    platform: String,
    entries: Vec<BenchEntry>,
}

impl JsonReport {
    pub fn new(bench: &str, platform: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), platform: platform.to_string(), entries: Vec::new() }
    }

    pub fn record(
        &mut self,
        section: &str,
        method: &str,
        workers: usize,
        mean_ns_per_step: f64,
        throughput_per_sec: f64,
        unit: &str,
    ) {
        self.record_with(section, method, workers, mean_ns_per_step, throughput_per_sec, unit, &[]);
    }

    /// `record` plus extra numeric columns for this cell.
    #[allow(clippy::too_many_arguments)]
    pub fn record_with(
        &mut self,
        section: &str,
        method: &str,
        workers: usize,
        mean_ns_per_step: f64,
        throughput_per_sec: f64,
        unit: &str,
        extras: &[(&str, f64)],
    ) {
        self.entries.push(BenchEntry {
            section: section.to_string(),
            method: method.to_string(),
            workers,
            mean_ns_per_step,
            throughput_per_sec,
            unit: unit.to_string(),
            extras: extras.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// This report's section as a Json value:
    /// `{"platform": ..., "entries": [...]}`.
    fn section_json(&self) -> Json {
        let mut entries = Vec::with_capacity(self.entries.len());
        for e in &self.entries {
            let mut o = BTreeMap::new();
            o.insert("section".to_string(), Json::Str(e.section.clone()));
            o.insert("method".to_string(), Json::Str(e.method.clone()));
            o.insert("workers".to_string(), Json::Num(e.workers as f64));
            o.insert(
                "mean_ns_per_step".to_string(),
                Json::Num((e.mean_ns_per_step * 10.0).round() / 10.0),
            );
            o.insert("unit".to_string(), Json::Str(e.unit.clone()));
            o.insert(
                "throughput_per_s".to_string(),
                Json::Num((e.throughput_per_sec * 10.0).round() / 10.0),
            );
            let per_worker = e.throughput_per_sec / e.workers.max(1) as f64;
            o.insert(
                "throughput_per_s_per_worker".to_string(),
                Json::Num((per_worker * 10.0).round() / 10.0),
            );
            for (k, v) in &e.extras {
                o.insert(k.clone(), Json::Num((v * 10.0).round() / 10.0));
            }
            entries.push(Json::Obj(o));
        }
        let mut sec = BTreeMap::new();
        sec.insert("platform".to_string(), Json::Str(self.platform.clone()));
        sec.insert("entries".to_string(), Json::Arr(entries));
        Json::Obj(sec)
    }

    fn merged_doc(&self, existing: Option<Json>) -> Json {
        // start from the existing benches map when the file is already
        // schema 2; anything else (schema 1, corrupt, missing) is
        // replaced wholesale
        let mut benches = match existing.as_ref().and_then(|j| j.get("benches")) {
            Some(Json::Obj(m))
                if existing.as_ref().and_then(|j| j.get("schema")).and_then(Json::as_f64)
                    == Some(2.0) =>
            {
                m.clone()
            }
            _ => BTreeMap::new(),
        };
        benches.insert(self.bench.clone(), self.section_json());
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::Num(2.0));
        doc.insert(
            "note".to_string(),
            Json::Str(
                "Perf trajectory, one section per bench binary; each run of a bench \
                 replaces its own section only. Populate with `cargo bench --bench \
                 e2e_step` and `cargo bench --bench kernels` from the repo root."
                    .to_string(),
            ),
        );
        doc.insert("benches".to_string(), Json::Obj(benches));
        Json::Obj(doc)
    }

    /// Merge-write the report into `path`: sections owned by other
    /// benches survive, this bench's section is replaced. Errors are
    /// reported, not fatal: a read-only checkout must not fail the bench
    /// run itself.
    pub fn write(&self, path: &str) {
        let existing = std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok());
        let doc = self.merged_doc(existing);
        match std::fs::write(path, doc.dump()) {
            Ok(()) => println!(
                "\nwrote {path} ({} entries in section '{}')",
                self.entries.len(),
                self.bench
            ),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}
