//! End-to-end training-step latency per method AND per worker count — the
//! bench behind the paper's headline claim (at a fixed forward cost,
//! gating collapses the per-step backward wall-clock; Figs 1b/3/8b in
//! time rather than counts) plus the scaling axis of the sharded
//! coordinator: per-step latency, sample throughput, and per-worker
//! throughput as `workers` grows — and a **screened axis** (`dgk_rho3_s25`:
//! the L4 two-tier gate at rho_screen = 0.25, same 3% backward budget)
//! where skipped *forwards* must show up as wall-clock savings too. Runs
//! on compiled artifacts when `artifacts/` exists, otherwise on the
//! native testbed backend.
//!
//! The worker axis is derived from `std::thread::available_parallelism()`
//! (powers of two up to the core count, core count included); set
//! `KONDO_BENCH_WORKERS=1,2,8` to override it. Besides the human-readable
//! table, the run merge-writes its section of `BENCH_e2e.json` (schema 2,
//! one section per bench binary — the `kernels` microbench owns the
//! other; override the path with `KONDO_BENCH_JSON`) so the repo's perf
//! trajectory is recorded PR-over-PR.

mod bench_util;

use bench_util::{bench, fmt_ns, JsonReport};
use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority, ScreenCfg};
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

/// Worker counts to sweep: `KONDO_BENCH_WORKERS` (comma-separated) if set,
/// else 1, 2, 4, ... up to and including `available_parallelism()`.
fn worker_axis() -> Vec<usize> {
    if let Ok(spec) = std::env::var("KONDO_BENCH_WORKERS") {
        let axis: Vec<usize> =
            spec.split(',').filter_map(|t| t.trim().parse().ok()).filter(|&w| w > 0).collect();
        if !axis.is_empty() {
            return axis;
        }
        eprintln!("KONDO_BENCH_WORKERS='{spec}' has no usable counts; using the derived axis");
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut axis = vec![1];
    let mut w = 2;
    while w < cores {
        axis.push(w);
        w *= 2;
    }
    if cores > 1 {
        axis.push(cores);
    }
    axis
}

fn main() {
    let eng = match Engine::new("artifacts") {
        Ok(eng) => eng,
        Err(_) => {
            eprintln!("artifacts not built; benchmarking on the native testbed backend");
            Engine::native_testbed()
        }
    };
    println!("platform: {}", eng.platform());
    let axis = worker_axis();
    println!("worker axis: {axis:?}");
    let batch = eng.manifest().constants.mnist_batch;
    let mut report = JsonReport::new("e2e_step", &eng.platform());

    let methods: Vec<(&str, Method)> = vec![
        ("pg", Method::Pg),
        ("dg", Method::Dg),
        ("dgk_rho3", Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Delight,
        }),
    ];

    println!("--- MNIST: 50-step runs (amortized per-step latency) ---");
    let mnist_steps = 50;
    // the screened axis: same 3% backward budget, but tier-1 pre-gates at
    // rho_screen = 0.25 so only a quarter of the batch pays the forward
    // (gate rate rescaled to 0.12 over the survivors)
    let mut mnist_variants: Vec<(String, Method, ScreenCfg)> = methods
        .iter()
        .map(|(n, m)| (n.to_string(), *m, ScreenCfg::default()))
        .collect();
    mnist_variants.push((
        "dgk_rho3_s25".into(),
        Method::DgK { gate: KondoGate::rate(0.12), priority: Priority::Delight },
        ScreenCfg { rho_screen: 0.25, draft_lr: 1e-3, warmup_batches: 10 },
    ));
    let mut pg_serial_ns = 0.0;
    let mut dgk_serial_ns = 0.0;
    let mut screened_serial_ns = 0.0;
    for (name, m, screen) in &mnist_variants {
        for &workers in &axis {
            let r = bench(&format!("mnist step [{name} w{workers}]"), 3, 1, || {
                let cfg = MnistTrainerCfg {
                    method: *m,
                    baseline: Baseline::Expected,
                    lr: 3e-4,
                    steps: mnist_steps,
                    eval_every: 1000, // no eval inside the timed region
                    eval_size: 128,
                    seed: 0,
                    screen: *screen,
                    workers,
                    ..Default::default()
                };
                std::hint::black_box(train_mnist(&eng, &cfg).unwrap());
            });
            let step_ns = r.mean_ns / mnist_steps as f64;
            let samples_per_sec = batch as f64 * 1e9 / step_ns;
            report.record("mnist", name, workers, step_ns, samples_per_sec, "samples");
            println!(
                "  [{name} w{workers}] per-step {:>10}  {:>10.0} samples/s  \
                 {:>10.0} samples/s/worker",
                fmt_ns(step_ns),
                samples_per_sec,
                samples_per_sec / workers as f64
            );
            if workers == 1 && name.as_str() == "pg" {
                pg_serial_ns = step_ns;
            }
            if workers == 1 && name.as_str() == "dgk_rho3" {
                dgk_serial_ns = step_ns;
            }
            if workers == 1 && name.as_str() == "dgk_rho3_s25" {
                screened_serial_ns = step_ns;
            }
        }
    }
    if pg_serial_ns > 0.0 && dgk_serial_ns > 0.0 {
        println!("  step-time speedup DG-K vs PG (serial): {:.2}x", pg_serial_ns / dgk_serial_ns);
    }
    if dgk_serial_ns > 0.0 && screened_serial_ns > 0.0 {
        println!(
            "  step-time speedup screened DG-K vs DG-K (serial): {:.2}x (skipped forwards)",
            dgk_serial_ns / screened_serial_ns
        );
    }

    println!("\n--- token reversal H=5 M=2: 20-step runs ---");
    let rev_steps = 20;
    let rev_batch = eng.manifest().constants.rev_batch;
    let h = 5.min(eng.manifest().constants.h_max);
    for (name, m) in &methods {
        for &workers in &axis {
            let r = bench(&format!("reversal step [{name} w{workers}]"), 2, 1, || {
                let cfg = ReversalTrainerCfg {
                    method: *m,
                    lr: 3e-4,
                    steps: rev_steps,
                    h,
                    m: 2,
                    seed: 0,
                    eval_every: 1000,
                    inner_epochs: 1,
                    workers,
                    ..Default::default()
                };
                std::hint::black_box(train_reversal(&eng, &cfg).unwrap());
            });
            let step_ns = r.mean_ns / rev_steps as f64;
            let tokens_per_sec = (rev_batch * h) as f64 * 1e9 / step_ns;
            report.record("reversal", name, workers, step_ns, tokens_per_sec, "tokens");
            println!(
                "  [{name} w{workers}] per-step {:>10}  {:>10.0} tokens/s  \
                 {:>10.0} tokens/s/worker",
                fmt_ns(step_ns),
                tokens_per_sec,
                tokens_per_sec / workers as f64
            );
        }
    }

    // default to the workspace root (cargo runs bench binaries with CWD =
    // package dir, i.e. rust/), where the trajectory file is committed
    let json_path = std::env::var("KONDO_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_e2e.json").to_string());
    report.write(&json_path);

    println!("\nexpected shape: DG-K per-step latency well below PG/DG (skipped backward");
    println!("passes are real wall-clock savings), and samples/s growing with workers");
    println!("while the learning trajectory stays bit-identical (see gated_e2e.rs).");
}
