//! End-to-end training-step latency per method — the bench behind the
//! paper's headline claim: at a fixed forward cost, gating collapses the
//! per-step backward wall-clock (Figs 1b/3/8b in time rather than counts).

mod bench_util;

use bench_util::{bench, fmt_ns};
use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

fn main() {
    let Ok(eng) = Engine::new("artifacts") else {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    };

    let methods: Vec<(&str, Method)> = vec![
        ("pg", Method::Pg),
        ("dg", Method::Dg),
        ("dgk_rho3", Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Delight,
        }),
    ];

    println!("--- MNIST: 50-step runs (amortized per-step latency) ---");
    let mut mnist_ns = Vec::new();
    for (name, m) in &methods {
        let r = bench(&format!("mnist step [{name}]"), 3, 1, || {
            let cfg = MnistTrainerCfg {
                method: *m,
                baseline: Baseline::Expected,
                lr: 3e-4,
                steps: 50,
                eval_every: 1000, // no eval inside the timed region
                eval_size: 500,
                seed: 0,
                ..Default::default()
            };
            std::hint::black_box(train_mnist(&eng, &cfg).unwrap());
        });
        mnist_ns.push((name.to_string(), r.mean_ns / 50.0));
    }
    for (name, ns) in &mnist_ns {
        println!("  per-step [{name}]: {}", fmt_ns(*ns));
    }
    let pg = mnist_ns[0].1;
    let kg = mnist_ns[2].1;
    println!("  step-time speedup DG-K vs PG: {:.2}x", pg / kg);

    println!("\n--- token reversal H=10 M=2: 10-step runs ---");
    let mut rev_ns = Vec::new();
    for (name, m) in &methods {
        let r = bench(&format!("reversal step [{name}]"), 2, 1, || {
            let cfg = ReversalTrainerCfg {
                method: *m,
                lr: 3e-4,
                steps: 10,
                h: 10,
                m: 2,
                seed: 0,
                eval_every: 1000,
                inner_epochs: 1,
            };
            std::hint::black_box(train_reversal(&eng, &cfg).unwrap());
        });
        rev_ns.push((name.to_string(), r.mean_ns / 10.0));
    }
    for (name, ns) in &rev_ns {
        println!("  per-step [{name}]: {}", fmt_ns(*ns));
    }
    let pg = rev_ns[0].1;
    let kg = rev_ns[2].1;
    println!("  step-time speedup DG-K vs PG: {:.2}x", pg / kg);
    println!("\nexpected shape: DG-K per-step latency well below PG/DG — the skipped");
    println!("backward passes are real wall-clock savings, not just counter savings.");
}
