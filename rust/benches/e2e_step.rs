//! End-to-end training-step latency per method AND per worker count — the
//! bench behind the paper's headline claim (at a fixed forward cost,
//! gating collapses the per-step backward wall-clock; Figs 1b/3/8b in
//! time rather than counts) plus the scaling axis of the sharded
//! coordinator: per-step latency, sample throughput, and per-worker
//! throughput as `workers` grows. Runs on compiled artifacts when
//! `artifacts/` exists, otherwise on the native testbed backend.

mod bench_util;

use bench_util::{bench, fmt_ns};
use kondo::algo::{baseline::Baseline, Method};
use kondo::coordinator::{KondoGate, Priority};
use kondo::runtime::Engine;
use kondo::trainers::{train_mnist, train_reversal, MnistTrainerCfg, ReversalTrainerCfg};

const WORKER_AXIS: [usize; 3] = [1, 2, 4];

fn main() {
    let eng = match Engine::new("artifacts") {
        Ok(eng) => eng,
        Err(_) => {
            eprintln!("artifacts not built; benchmarking on the native testbed backend");
            Engine::native_testbed()
        }
    };
    println!("platform: {}", eng.platform());
    let batch = eng.manifest().constants.mnist_batch;

    let methods: Vec<(&str, Method)> = vec![
        ("pg", Method::Pg),
        ("dg", Method::Dg),
        ("dgk_rho3", Method::DgK {
            gate: KondoGate::rate(0.03),
            priority: Priority::Delight,
        }),
    ];

    println!("--- MNIST: 50-step runs (amortized per-step latency) ---");
    let mnist_steps = 50;
    let mut pg_serial_ns = 0.0;
    let mut dgk_serial_ns = 0.0;
    for (name, m) in &methods {
        for workers in WORKER_AXIS {
            let r = bench(&format!("mnist step [{name} w{workers}]"), 3, 1, || {
                let cfg = MnistTrainerCfg {
                    method: *m,
                    baseline: Baseline::Expected,
                    lr: 3e-4,
                    steps: mnist_steps,
                    eval_every: 1000, // no eval inside the timed region
                    eval_size: 128,
                    seed: 0,
                    workers,
                    ..Default::default()
                };
                std::hint::black_box(train_mnist(&eng, &cfg).unwrap());
            });
            let step_ns = r.mean_ns / mnist_steps as f64;
            let samples_per_sec = batch as f64 * 1e9 / step_ns;
            println!(
                "  [{name} w{workers}] per-step {:>10}  {:>10.0} samples/s  \
                 {:>10.0} samples/s/worker",
                fmt_ns(step_ns),
                samples_per_sec,
                samples_per_sec / workers as f64
            );
            if workers == 1 && *name == "pg" {
                pg_serial_ns = step_ns;
            }
            if workers == 1 && *name == "dgk_rho3" {
                dgk_serial_ns = step_ns;
            }
        }
    }
    if dgk_serial_ns > 0.0 {
        println!("  step-time speedup DG-K vs PG (serial): {:.2}x", pg_serial_ns / dgk_serial_ns);
    }

    println!("\n--- token reversal H=5 M=2: 20-step runs ---");
    let rev_steps = 20;
    let rev_batch = eng.manifest().constants.rev_batch;
    let h = 5.min(eng.manifest().constants.h_max);
    for (name, m) in &methods {
        for workers in WORKER_AXIS {
            let r = bench(&format!("reversal step [{name} w{workers}]"), 2, 1, || {
                let cfg = ReversalTrainerCfg {
                    method: *m,
                    lr: 3e-4,
                    steps: rev_steps,
                    h,
                    m: 2,
                    seed: 0,
                    eval_every: 1000,
                    inner_epochs: 1,
                    workers,
                };
                std::hint::black_box(train_reversal(&eng, &cfg).unwrap());
            });
            let step_ns = r.mean_ns / rev_steps as f64;
            let tokens_per_sec = (rev_batch * h) as f64 * 1e9 / step_ns;
            println!(
                "  [{name} w{workers}] per-step {:>10}  {:>10.0} tokens/s  \
                 {:>10.0} tokens/s/worker",
                fmt_ns(step_ns),
                tokens_per_sec,
                tokens_per_sec / workers as f64
            );
        }
    }
    println!("\nexpected shape: DG-K per-step latency well below PG/DG (skipped backward");
    println!("passes are real wall-clock savings), and samples/s growing with workers");
    println!("while the learning trajectory stays bit-identical (see gated_e2e.rs).");
}
