//! Artifact execution latency per bucket — the measured substance behind
//! the paper's cost model (Fig 3) and the bucketed-backward design: the
//! rows show how backward wall-clock scales with compiled capacity.

mod bench_util;

use bench_util::bench;
use kondo::model::ParamStore;
use kondo::runtime::{Engine, HostTensor};
use kondo::utils::rng::Pcg32;

fn main() {
    let Ok(eng) = Engine::new("artifacts") else {
        eprintln!("artifacts not built; run `make artifacts` first");
        return;
    };
    let man = eng.manifest().clone();
    let mut rng = Pcg32::seeded(3);

    // ---- MNIST forward + every backward bucket
    let rules = man.model("mnist").unwrap().to_vec();
    let params = ParamStore::init(&rules, 0);
    let b = man.constants.mnist_batch;
    let img = man.constants.mnist_in;
    let nact = man.constants.mnist_actions;
    let x: Vec<f32> = (0..b * img).map(|_| rng.normal() as f32).collect();

    let mut fwd_in = params.as_inputs();
    fwd_in.push(HostTensor::f32(&[b, img], x.clone()));
    fwd_in.push(HostTensor::zeros_f32(&[b, nact]));
    bench("mnist_fwd B=100 (L1 fused head)", 300, 20, || {
        std::hint::black_box(eng.execute("mnist_fwd", &fwd_in).unwrap());
    });

    for &cap in &man.constants.mnist_bwd_caps {
        let mut bin = params.as_inputs();
        bin.push(HostTensor::f32(&[cap, img], x[..cap * img].to_vec()));
        bin.push(HostTensor::i32(&[cap], vec![1; cap]));
        bin.push(HostTensor::f32(&[cap], vec![0.5; cap]));
        let name = format!("mnist_bwd_c{cap}");
        bench(&format!("{name} (bucketed backward)"), 200, 10, || {
            std::hint::black_box(eng.execute(&name, &bin).unwrap());
        });
    }

    // ---- reversal (fast shape set): rollout + backward buckets
    let hm = man.constants.rev_sets[0];
    let rules = man.model(&format!("reversal{hm}")).unwrap().to_vec();
    let params = ParamStore::init(&rules, 0);
    let batch = man.constants.rev_batch;
    let prompt: Vec<i32> = (0..batch * hm)
        .map(|i| if i % hm < hm - 10 { man.constants.pad as i32 } else { (i % 2) as i32 })
        .collect();
    let h_t = HostTensor::scalar_i32(10);
    let m_t = HostTensor::scalar_i32(2);

    let mut rin = params.as_inputs();
    rin.push(HostTensor::i32(&[batch, hm], prompt.clone()));
    rin.push(h_t.clone());
    rin.push(m_t.clone());
    rin.push(HostTensor::scalar_i32(7));
    bench(
        &format!("rev{hm}_rollout B=100 (L1 flash prefill + scan decode)"),
        30,
        3,
        || {
            std::hint::black_box(eng.execute(&format!("rev{hm}_rollout"), &rin).unwrap());
        },
    );

    for &cap in &man.constants.rev_bwd_caps {
        let mut bin = params.as_inputs();
        bin.push(HostTensor::i32(&[cap, hm], prompt[..cap * hm].to_vec()));
        bin.push(HostTensor::i32(&[cap, hm], vec![0; cap * hm]));
        bin.push(HostTensor::f32(&[cap, hm], vec![0.1; cap * hm]));
        bin.push(h_t.clone());
        bin.push(m_t.clone());
        let name = format!("rev{hm}_bwd_c{cap}");
        bench(&format!("{name} (bucketed backward)"), 30, 3, || {
            std::hint::black_box(eng.execute(&name, &bin).unwrap());
        });
    }

    println!("\nexpected shape: backward wall-clock grows with bucket capacity — the gate's");
    println!("skipped samples are real skipped compute (DESIGN.md 'gating = shape choice').");
}
